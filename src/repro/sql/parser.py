"""Recursive-descent parser for the TPC-H-covering SQL subset.

Grammar (roughly)::

    query     := declare* [WITH ctes] select
    declare   := DECLARE name type DEFAULT expr IN ( expr , expr ) ;
    select    := SELECT [hints] items FROM from_list [WHERE expr]
                 [GROUP BY exprs] [HAVING expr] [ORDER BY orders] [LIMIT n]
    from_item := table_ref { [LEFT [OUTER]] JOIN table_ref ON expr }
    expr      := OR / AND / NOT / comparison / IN / BETWEEN / LIKE / EXISTS
                 / + - * / / unary minus / CASE / functions / subqueries

Optimizer hints ride in ``/*+ ... */`` tokens: after SELECT they attach to
the select (``groups(N)``); after a predicate they attach to that conjunct
(``shrink(N)``).  All errors are :class:`SqlError` with line/col.
"""
from __future__ import annotations

from . import ast as A
from .lexer import SqlError, Token, tokenize

__all__ = ["parse", "parse_expr", "parse_select"]

_CMP_OPS = {"=", "<>", "<", "<=", ">", ">="}
_AGG_FUNCS = {"sum", "count", "min", "max", "avg"}


class _Parser:
    def __init__(self, text: str):
        self.toks = tokenize(text)
        self.i = 0

    # ------------------------------------------------------------ plumbing
    @property
    def cur(self) -> Token:
        return self.toks[self.i]

    def err(self, msg: str, tok: Token | None = None) -> SqlError:
        tok = tok or self.cur
        return SqlError(msg, tok.line, tok.col)

    def advance(self) -> Token:
        tok = self.cur
        if tok.kind != "EOF":
            self.i += 1
        return tok

    def at_kw(self, *words: str) -> bool:
        return self.cur.kind == "KEYWORD" and self.cur.value in words

    def at_op(self, *ops: str) -> bool:
        return self.cur.kind == "OP" and self.cur.value in ops

    def eat_kw(self, word: str) -> bool:
        if self.at_kw(word):
            self.advance()
            return True
        return False

    def expect_kw(self, word: str) -> None:
        if not self.eat_kw(word):
            raise self.err(f"expected {word.upper()}, "
                           f"got {self.cur.value or self.cur.kind!r}")

    def eat_op(self, op: str) -> bool:
        if self.at_op(op):
            self.advance()
            return True
        return False

    def expect_op(self, op: str) -> None:
        if not self.eat_op(op):
            raise self.err(f"expected {op!r}, "
                           f"got {self.cur.value or self.cur.kind!r}")

    def name(self, what: str = "name") -> str:
        if self.cur.kind != "NAME":
            raise self.err(f"expected {what}, "
                           f"got {self.cur.value or self.cur.kind!r}")
        return self.advance().value

    # --------------------------------------------------------------- query
    def parse_query(self) -> A.Query:
        declares = []
        while self.at_kw("declare"):
            declares.append(self.declare())
        ctes: list[tuple[str, A.Select]] = []
        if self.eat_kw("with"):
            while True:
                name = self.name("CTE name")
                self.expect_kw("as")
                self.expect_op("(")
                ctes.append((name, self.select()))
                self.expect_op(")")
                if not self.eat_op(","):
                    break
        body = self.select()
        self.eat_op(";")
        if self.cur.kind != "EOF":
            raise self.err(f"unexpected trailing input "
                           f"{self.cur.value or self.cur.kind!r}")
        return A.Query(body, tuple(ctes), tuple(declares))

    def declare(self) -> A.Declare:
        self.expect_kw("declare")
        name = self.name("parameter name")
        if self.at_kw("int", "float", "date"):
            dtype = self.advance().value
        else:
            raise self.err("expected parameter type (INT, FLOAT or DATE)")
        self.expect_kw("default")
        default = self.additive()
        self.expect_kw("in")
        self.expect_op("(")
        lo = self.additive()
        self.expect_op(",")
        hi = self.additive()
        self.expect_op(")")
        self.expect_op(";")
        return A.Declare(name, dtype, lo, hi, default)

    def hint_list(self) -> list[tuple[str, int]]:
        hints = []
        while self.cur.kind == "HINT":
            text = self.advance().value
            try:
                fn, rest = text.split("(", 1)
                n = int(rest.rstrip().rstrip(")"))
            except ValueError:
                raise self.err(f"malformed hint {text!r}",
                               self.toks[self.i - 1]) from None
            if fn.strip() not in ("groups", "shrink"):
                raise self.err(f"unknown hint {fn.strip()!r}",
                               self.toks[self.i - 1])
            hints.append((fn.strip(), n))
        return hints

    def select(self) -> A.Select:
        self.expect_kw("select")
        hints = self.hint_list()
        if self.eat_kw("distinct"):
            raise self.err("unsupported syntax: SELECT DISTINCT (use GROUP "
                           "BY, or COUNT(DISTINCT ...) for counts)",
                           self.toks[self.i - 1])
        items = [self.select_item()]
        while self.eat_op(","):
            items.append(self.select_item())
        self.expect_kw("from")
        frm = [self.from_item()]
        while self.eat_op(","):
            frm.append(self.from_item())
        where = self.expr() if self.eat_kw("where") else None
        group: list[A.Expr] = []
        having = None
        if self.eat_kw("group"):
            self.expect_kw("by")
            group.append(self.expr())
            while self.eat_op(","):
                group.append(self.expr())
        if self.eat_kw("having"):
            having = self.expr()
        order: list[tuple[A.Expr, bool]] = []
        if self.eat_kw("order"):
            self.expect_kw("by")
            while True:
                e = self.expr()
                asc = True
                if self.eat_kw("desc"):
                    asc = False
                else:
                    self.eat_kw("asc")
                order.append((e, asc))
                if not self.eat_op(","):
                    break
        limit = None
        if self.eat_kw("limit"):
            tok = self.cur
            if tok.kind != "NUMBER":
                raise self.err("expected integer after LIMIT")
            self.advance()
            limit = int(tok.value)
        return A.Select(tuple(items), tuple(frm), where, tuple(group),
                        having, tuple(order), limit, tuple(hints))

    def select_item(self) -> A.SelectItem:
        e = self.expr()
        alias = None
        if self.eat_kw("as"):
            alias = self.name("alias")
        elif self.cur.kind == "NAME":
            alias = self.advance().value
        return A.SelectItem(e, alias)

    def table_ref(self) -> "A.Table | A.Derived":
        if self.eat_op("("):
            sel = self.select()
            self.expect_op(")")
            self.eat_kw("as")
            return A.Derived(sel, self.name("derived-table alias"))
        tok = self.cur
        name = self.name("table name")
        alias = None
        if self.eat_kw("as"):
            alias = self.name("alias")
        elif self.cur.kind == "NAME":
            alias = self.advance().value
        return A.Table(name, alias, pos=(tok.line, tok.col))

    def from_item(self) -> A.FromItem:
        ref = self.table_ref()
        joins = []
        while True:
            if self.at_kw("join", "inner"):
                self.eat_kw("inner")
                self.expect_kw("join")
                kind = "inner"
            elif self.at_kw("left"):
                self.advance()
                self.eat_kw("outer")
                self.expect_kw("join")
                kind = "left"
            else:
                break
            right = self.table_ref()
            self.expect_kw("on")
            joins.append(A.JoinStep(kind, right, self.expr()))
        return A.FromItem(ref, tuple(joins))

    # --------------------------------------------------------- expressions
    def expr(self) -> A.Expr:
        return self.or_expr()

    def _hinted(self, e: A.Expr) -> A.Expr:
        if self.cur.kind == "HINT":
            return A.Hinted(e, tuple(self.hint_list()))
        return e

    def or_expr(self) -> A.Expr:
        e = self.and_expr()
        while self.at_kw("or"):
            self.advance()
            e = self._hinted(A.Binary("or", e, self.and_expr()))
        return e

    def and_expr(self) -> A.Expr:
        e = self.not_expr()
        while self.at_kw("and"):
            self.advance()
            e = A.Binary("and", e, self.not_expr())
            e = self._hinted(e)
        return e

    def not_expr(self) -> A.Expr:
        if self.at_kw("not"):
            tok = self.advance()
            if self.at_kw("exists"):
                ex = self.not_expr()
                assert isinstance(ex, A.ExistsE)
                return self._hinted(A.ExistsE(ex.query, negated=True))
            del tok
            return self._hinted(A.Unary("not", self.not_expr()))
        if self.at_kw("exists"):
            self.advance()
            self.expect_op("(")
            sel = self.select()
            self.expect_op(")")
            return self._hinted(A.ExistsE(sel))
        return self.predicate()

    def predicate(self) -> A.Expr:
        e = self.additive()
        while True:
            if self.cur.kind == "OP" and self.cur.value in _CMP_OPS:
                op = self.advance().value
                e = A.Binary(op, e, self.additive())
                continue
            negated = False
            if self.at_kw("not"):
                # NOT here must precede IN / BETWEEN / LIKE
                save = self.i
                self.advance()
                if self.at_kw("in", "between", "like"):
                    negated = True
                else:
                    self.i = save
                    break
            if self.eat_kw("between"):
                lo = self.additive()
                self.expect_kw("and")
                e = A.Between(e, lo, self.additive(), negated)
                continue
            if self.eat_kw("in"):
                self.expect_op("(")
                if self.at_kw("select"):
                    sel = self.select()
                    self.expect_op(")")
                    e = A.InQuery(e, sel, negated)
                else:
                    items = [self.additive()]
                    while self.eat_op(","):
                        items.append(self.additive())
                    self.expect_op(")")
                    e = A.InList(e, tuple(items), negated)
                continue
            if self.eat_kw("like"):
                tok = self.cur
                if tok.kind != "STRING":
                    raise self.err("LIKE pattern must be a string literal")
                self.advance()
                e = A.LikeE(e, tok.value, negated)
                continue
            if self.at_kw("is"):
                raise self.err("unsupported syntax: IS [NOT] NULL (the "
                               "engine's LEFT JOIN defaults make columns "
                               "non-null)")
            break
        return self._hinted(e)

    def additive(self) -> A.Expr:
        e = self.multiplicative()
        while self.at_op("+", "-"):
            op = self.advance().value
            e = A.Binary(op, e, self.multiplicative())
        return e

    def multiplicative(self) -> A.Expr:
        e = self.unary()
        while self.at_op("*", "/"):
            op = self.advance().value
            e = A.Binary(op, e, self.unary())
        return e

    def unary(self) -> A.Expr:
        if self.at_op("-"):
            self.advance()
            return A.Unary("-", self.unary())
        return self.primary()

    def primary(self) -> A.Expr:
        tok = self.cur
        if tok.kind == "NUMBER":
            self.advance()
            is_float = any(c in tok.value for c in ".eE")
            return A.Number(float(tok.value) if is_float else int(tok.value))
        if tok.kind == "STRING":
            self.advance()
            return A.String(tok.value)
        if tok.kind == "PARAM":
            self.advance()
            return A.ParamE(tok.value)
        if self.at_op("*"):
            self.advance()
            return A.Star()
        if self.at_kw("date"):
            self.advance()
            if self.cur.kind != "STRING":
                raise self.err("expected 'YYYY-MM-DD' after DATE")
            return A.DateL(self.advance().value)
        if self.at_kw("interval"):
            self.advance()
            if self.cur.kind != "STRING":
                raise self.err("expected quoted count after INTERVAL")
            n = int(self.advance().value)
            if not self.at_kw("day", "month", "year"):
                raise self.err("expected DAY, MONTH or YEAR")
            return A.IntervalL(n, self.advance().value)
        if self.at_kw("case"):
            return self.case()
        if self.at_kw("extract"):
            self.advance()
            self.expect_op("(")
            self.expect_kw("year")
            self.expect_kw("from")
            e = self.expr()
            self.expect_op(")")
            return A.Func("year", (e,))
        if self.at_kw("cast"):
            raise self.err("unsupported syntax: CAST (the binder types "
                           "expressions automatically)")
        if self.at_kw(*_AGG_FUNCS) or self.at_kw("year"):
            fn = self.advance().value
            self.expect_op("(")
            distinct = bool(self.eat_kw("distinct"))
            if fn == "count" and self.at_op("*"):
                self.advance()
                args: tuple[A.Expr, ...] = (A.Star(),)
            else:
                args = (self.expr(),)
            self.expect_op(")")
            return A.Func(fn, args, distinct)
        if tok.kind == "NAME":
            self.advance()
            if self.eat_op("("):
                args = []
                if not self.at_op(")"):
                    args.append(self.expr())
                    while self.eat_op(","):
                        args.append(self.expr())
                self.expect_op(")")
                return A.Func(tok.value.lower(), tuple(args))
            if self.eat_op("."):
                return A.Ident(self.name("column name"), tok.value,
                               pos=(tok.line, tok.col))
            return A.Ident(tok.value, pos=(tok.line, tok.col))
        if self.eat_op("("):
            if self.at_kw("select"):
                sel = self.select()
                self.expect_op(")")
                return A.Scalar(sel)
            e = self.expr()
            self.expect_op(")")
            return e
        raise self.err(f"unexpected {tok.value or tok.kind!r} in expression",
                       tok)

    def case(self) -> A.Expr:
        self.expect_kw("case")
        whens = []
        while self.eat_kw("when"):
            cond = self.expr()
            self.expect_kw("then")
            whens.append((cond, self.expr()))
        if not whens:
            raise self.err("CASE requires at least one WHEN")
        default = self.expr() if self.eat_kw("else") else None
        self.expect_kw("end")
        return A.CaseE(tuple(whens), default)


def parse(text: str) -> A.Query:
    """Parse a full statement (declares + optional WITH + select)."""
    return _Parser(text).parse_query()


def parse_select(text: str) -> A.Select:
    p = _Parser(text)
    sel = p.select()
    p.eat_op(";")
    if p.cur.kind != "EOF":
        raise p.err(f"unexpected trailing input "
                    f"{p.cur.value or p.cur.kind!r}")
    return sel


def parse_expr(text: str) -> A.Expr:
    """Parse a standalone expression (hypothesis round-trip entry point)."""
    p = _Parser(text)
    e = p.expr()
    if p.cur.kind != "EOF":
        raise p.err(f"unexpected trailing input "
                    f"{p.cur.value or p.cur.kind!r}")
    return e

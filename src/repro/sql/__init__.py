"""SQL frontend: tokenizer, parser, binder/lowering, and IR optimizer.

Compiles a TPC-H-covering SQL subset into :mod:`repro.core.plan` DAGs that
the existing planner/backends run unchanged.  ``compile_sql`` turns ad-hoc
SQL text into a :class:`repro.core.planner.CompiledQuery`; ``sql_queries``
loads the committed TPC-H suite (``src/repro/queries/sql/``), which
``REPRO_FRONTEND=sql`` swaps in for the hand-built plans engine-wide.  See
docs/ARCHITECTURE.md section 9 for the pass pipeline.
"""
from .frontend import compile_sql, plan_sql, sql_plans, sql_queries
from .lexer import SqlError
from .lower import lower
from .optimizer import optimize
from .parser import parse

__all__ = ["SqlError", "parse", "lower", "optimize", "plan_sql",
           "compile_sql", "sql_plans", "sql_queries"]

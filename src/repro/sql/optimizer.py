"""IR-to-IR optimizer: naive lowered plans -> budget-matching physical plans.

The passes transform the exchange-free output of :mod:`repro.sql.lower` into
plans that pass ``planner.validate`` with zero notes and hit the hand-built
exchange / sort / wire budgets:

  1. **push** — predicate pushdown + semi/anti sinking.  Filters and
     membership constraints travel down through projections, renames, join
     probes, inner-join build sides and group-by keys until they sit on the
     scans (never into shared CTE subtrees).
  2. **merge** — adjacent Filter nodes collapse into one conjunction.
  3. **shared shuffle** — a group-by and a join that consume the same
     shared subtree on the same key get one Shuffle below the share point
     (TPC-H Q17's idiom), making the group-by local and the join
     co-partitioned at the cost of a single exchange.
  4. **pack** — multi-column group keys whose runtime method would be the
     sorted path (provable widths too wide for the direct path, domain too
     big for hash compaction) fold into one strided int64 key with
     ``max``-recovery aggregates, mirroring the hand plans' Q7/Q16 packing.
     The decision procedure replicates ``planner``'s hint inference exactly:
     packing is applied only where the planner would otherwise sort.
  5. **prune** — projection pruning: join takes narrow to consumed columns,
     unused aggregates and computed columns drop, scans grow a Select of
     exactly the required columns.
  6. **place** — exchange placement by the paper's §4.3/§4.4 rules:
     co-partitioned joins stay local; small builds broadcast (narrowed to
     the consumed columns); bounded probes broadcast against huge
     partitioned builds (Q18); single-key mismatches shuffle the probe;
     group-bys become local / gather+final / partial-shuffle by partition
     containment, membership-only consumption, and finality.
  7. **cse** — duplicate subtrees (same ``subplan_signatures`` hash) merge
     into one shared node.

Statistics are *static*: the catalog's scale-invariant domains plus
selectivity guesses over SF=1 cardinalities.  Estimates steer only
broadcast-vs-shuffle choices (always semantically sound either way); bound
claims (packing strides, narrow-wire widths) use invariant domains only, and
the engine re-checks every claimed bound at runtime via ``ctx.overflow``.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core import plan as P
from repro.core import planner as PL

from . import catalog as C
from .ir import (clone_with, conjoin, conjuncts, expr_cols, output_columns,
                 rewrite, rewrite_expr, scalar_deps, walk)

__all__ = ["optimize"]

_BCAST = C.BCAST_MAX_ROWS
_GATHER_MAX = 1 << 17           # largest group count worth a final gather
REPL = PL.REPL


# ---------------------------------------------------------------------------
# static column statistics (db-free mirror of planner.ColStats inference)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _St:
    """(lo, hi, card) with ``inv`` marking bounds that hold at every scale
    factor (the only bounds packing may rely on)."""
    lo: int | None = None
    hi: int | None = None
    card: int | None = None
    inv: bool = False

    def clamped(self) -> "_St":
        if self.lo is None or self.hi is None:
            return self
        width = max(0, int(self.hi) - int(self.lo) + 1)
        card = width if self.card is None else min(self.card, width)
        return _St(self.lo, self.hi, card, self.inv)


_UNK = _St()


def _scan_stats(table: str) -> dict[str, _St]:
    out = {}
    for cname, col in C.table_of(table).columns.items():
        if col.kind == "float":
            out[cname] = _UNK
        else:
            out[cname] = _St(col.lo, col.hi, None, col.invariant).clamped()
    return out


def _static_const(e):
    """Host-constant value when statically known (CodeLit codes are not)."""
    if isinstance(e, P.Lit):
        return e.value
    if isinstance(e, P.Param):
        return e.default
    if isinstance(e, P.DbScale):
        return 1.0
    if isinstance(e, P.Cast):
        return _static_const(e.a)
    if isinstance(e, P.BinOp) and e.op in ("+", "-", "*", "/"):
        a, b = _static_const(e.a), _static_const(e.b)
        if a is None or b is None:
            return None
        if e.op == "/" and b == 0:
            return None
        return {"+": a + b, "-": a - b, "*": a * b, "/": a / b}[e.op]
    return None


def _const_range(e):
    """(lo, hi) over every admissible binding; Params use their domain."""
    if isinstance(e, P.Param):
        return None if e.lo is None else (e.lo, e.hi)
    if isinstance(e, (P.Lit, P.DbScale)):
        c = _static_const(e)
        return None if c is None else (c, c)
    if isinstance(e, P.Cast):
        return _const_range(e.a)
    if isinstance(e, P.BinOp) and e.op in ("+", "-", "*"):
        a, b = _const_range(e.a), _const_range(e.b)
        if a is None or b is None:
            return None
        if e.op == "+":
            return (a[0] + b[0], a[1] + b[1])
        if e.op == "-":
            return (a[0] - b[1], a[1] - b[0])
        ps = [a[0] * b[0], a[0] * b[1], a[1] * b[0], a[1] * b[1]]
        return (min(ps), max(ps))
    return None


def _expr_st(e, sch: dict) -> _St:
    if isinstance(e, P.Col):
        return sch.get(e.name, _UNK)
    if isinstance(e, P.Lit):
        if isinstance(e.value, bool) or not isinstance(e.value, int):
            return _UNK
        return _St(e.value, e.value, 1, True)
    if isinstance(e, P.CodeLit):
        col = C.column_table(e.col)
        size = C.table_of(col).columns[e.col].hi if col else None
        return _St(0, size, 1, True) if size is not None else _UNK
    if isinstance(e, P.Param):
        if e.dtype == "int64" and e.lo is not None:
            return _St(int(math.ceil(e.lo)), int(math.floor(e.hi)),
                       1, True).clamped()
        return _UNK
    if isinstance(e, P.Cast):
        return _expr_st(e.a, sch)
    if isinstance(e, P.BinOp) and e.op in ("+", "-", "*"):
        a, b = _expr_st(e.a, sch), _expr_st(e.b, sch)
        if None in (a.lo, a.hi, b.lo, b.hi):
            return _UNK
        if e.op == "+":
            lo, hi = a.lo + b.lo, a.hi + b.hi
        elif e.op == "-":
            lo, hi = a.lo - b.hi, a.hi - b.lo
        else:
            ps = [a.lo * b.lo, a.lo * b.hi, a.hi * b.lo, a.hi * b.hi]
            lo, hi = min(ps), max(ps)
        card = None if (a.card is None or b.card is None) else a.card * b.card
        return _St(lo, hi, card, a.inv and b.inv).clamped()
    if isinstance(e, P.Year):
        a = _expr_st(e.a, sch)
        if a.lo is None or a.hi is None:
            return _UNK
        return _St(PL._year_of_day(a.lo), PL._year_of_day(a.hi), a.card,
                   a.inv).clamped()
    if isinstance(e, P.Where):
        a, b = _expr_st(e.a, sch), _expr_st(e.b, sch)
        if None in (a.lo, a.hi, b.lo, b.hi):
            return _UNK
        card = None if (a.card is None or b.card is None) else a.card + b.card
        return _St(min(a.lo, b.lo), max(a.hi, b.hi), card,
                   a.inv and b.inv).clamped()
    if isinstance(e, P.AlphaRank):
        col = C.column_table(e.col)
        size = C.table_of(col).columns[e.col].hi if col else None
        return _St(0, size, None, True).clamped() if size is not None \
            else _UNK
    return _UNK


_FLIP = {"<": ">", "<=": ">=", ">": "<", ">=": "<=", "==": "=="}


def _refine(pred, sch: dict) -> dict:
    """Static mirror of ``planner._refine_filter`` (CodeLit values unknown:
    they refine cardinality via InSet but never bounds)."""
    out = dict(sch)

    def _mn(a, b):
        return b if a is None else (a if b is None else min(a, b))

    def _mx(a, b):
        return b if a is None else (a if b is None else max(a, b))

    def apply(name, op, rng):
        s = out.get(name)
        if s is None or rng is None:
            return
        clo, chi = rng
        if not all(isinstance(v, (int, float)) and not isinstance(v, bool)
                   for v in (clo, chi)):
            return
        lo, hi, card = s.lo, s.hi, s.card
        if op == "<=":
            hi = _mn(hi, math.floor(chi))
        elif op == "<":
            hi = _mn(hi, math.ceil(chi) - 1)
        elif op == ">=":
            lo = _mx(lo, math.ceil(clo))
        elif op == ">":
            lo = _mx(lo, math.floor(clo) + 1)
        elif op == "==":
            lo = _mx(lo, math.ceil(clo))
            hi = _mn(hi, math.floor(chi))
            if lo is not None and hi is not None:
                card = _mn(card, max(1, hi - lo + 1))
        # a literal refinement is invariant on the refined side; keep the
        # conservative flag: invariant only if BOTH bounds now are
        inv = s.inv or (op == "==" and lo is not None and hi is not None)
        out[name] = _St(lo, hi, card, inv if op == "==" else s.inv).clamped()

    def visit(e):
        if isinstance(e, P.BinOp) and e.op == "&":
            visit(e.a)
            visit(e.b)
        elif isinstance(e, P.BinOp) and e.op in _FLIP:
            if isinstance(e.a, P.Col):
                apply(e.a.name, e.op, _const_range(e.b))
            elif isinstance(e.b, P.Col):
                apply(e.b.name, _FLIP[e.op], _const_range(e.a))
        elif isinstance(e, P.InSet) and isinstance(e.a, P.Col):
            s = out.get(e.a.name)
            vals = [_static_const(v) for v in e.values]
            if s is not None:
                k = len(e.values)
                if all(isinstance(v, int) and not isinstance(v, bool)
                       for v in vals):
                    lo = _mx(s.lo, min(vals))
                    hi = _mn(s.hi, max(vals))
                    out[e.a.name] = _St(lo, hi, _mn(s.card, k),
                                        s.inv).clamped()
                else:
                    out[e.a.name] = _St(s.lo, s.hi, _mn(s.card, k), s.inv)

    visit(pred)
    return out


class _Ctx:
    """Per-tree memoized schema / row-estimate / cap / consumer context."""

    def __init__(self, root):
        self.nodes = walk(root)
        self.consumers: dict[int, list] = {}
        for n in self.nodes:
            for i, ch in enumerate(n.children):
                self.consumers.setdefault(id(ch), []).append((n, i))
            for d in scalar_deps(n):
                self.consumers.setdefault(id(d), []).append((n, -1))
        self._sch: dict[int, dict] = {}
        self._est: dict[int, float] = {}

    # -- schema ------------------------------------------------------------
    def schema(self, n) -> dict:
        got = self._sch.get(id(n))
        if got is not None:
            return got
        if isinstance(n, P.Scan):
            s = _scan_stats(n.table)
        elif isinstance(n, P.Filter):
            s = _refine(n.pred, self.schema(n.children[0]))
        elif isinstance(n, P.Select):
            ch = self.schema(n.children[0])
            s = {c: ch[c] for c in n.names if c in ch}
        elif isinstance(n, P.WithCol):
            s = dict(self.schema(n.children[0]))
            for name, e in n.exprs.items():
                s[name] = _expr_st(e, s)
        elif isinstance(n, P.Rename):
            s = {n.mapping.get(c, c): v
                 for c, v in self.schema(n.children[0]).items()}
        elif isinstance(n, (P.Join, P.Left)):
            s = dict(self.schema(n.children[0]))
            bs = self.schema(n.children[1])
            for c in n.take:
                s[c] = bs.get(c, _UNK)
        elif isinstance(n, (P.Semi, P.Anti)):
            s = dict(self.schema(n.children[0]))
        elif isinstance(n, P.GroupBy):
            ch = self.schema(n.children[0])
            s = {k: ch.get(k, _UNK) for k in n.keys}
            for name, op, v in n.aggs:
                if op in ("min", "max"):
                    s[name] = ch.get(v, _UNK) if isinstance(v, str) else (
                        _expr_st(v, ch) if isinstance(v, P.Expr) else _UNK)
                elif op == "count":
                    s[name] = _St(0, None, None)
                else:
                    s[name] = _UNK
        elif isinstance(n, (P.Shuffle, P.Broadcast, P.Shrink, P.Finalize)):
            s = self.schema(n.children[0])
        else:
            s = {}
        self._sch[id(n)] = s
        return s

    # -- row estimates (SF=1; steer broadcast choices only) ----------------
    def keyspace(self, build, build_on) -> float:
        cols = (build_on,) if isinstance(build_on, str) else tuple(build_on)
        sch = self.schema(build)
        out = 1.0
        for c in cols:
            card = sch.get(c, _UNK).card
            out *= card if card else 1e9
        return out

    def selectivity(self, pred, sch: dict) -> float:
        sel = 1.0
        for c in conjuncts(pred):
            sel *= self._sel1(c, sch)
        return sel

    def _sel1(self, e, sch) -> float:
        if isinstance(e, P.NotE):
            return max(0.0, 1.0 - self._sel1(e.a, sch))
        if isinstance(e, P.BinOp) and e.op == "|":
            return min(1.0, self._sel1(e.a, sch) + self._sel1(e.b, sch))
        if isinstance(e, P.BinOp) and e.op == "&":
            return self._sel1(e.a, sch) * self._sel1(e.b, sch)
        if isinstance(e, (P.Like, P.StartsWith, P.EndsWith)):
            return 0.1
        if isinstance(e, P.InSet) and isinstance(e.a, P.Col):
            s = sch.get(e.a.name, _UNK)
            dom = s.card if s.card else 50
            return min(1.0, len(e.values) / dom)
        if isinstance(e, P.BinOp) and e.op in _FLIP:
            col, other, op = None, None, e.op
            if isinstance(e.a, P.Col):
                col, other = e.a, e.b
            elif isinstance(e.b, P.Col):
                col, other, op = e.b, e.a, _FLIP[e.op]
            if col is None:
                return 0.3
            s = sch.get(col.name, _UNK)
            if op == "==":
                if isinstance(other, P.CodeLit):
                    tab = C.column_table(other.col)
                    size = C.table_of(tab).columns[other.col].hi + 1
                    return 1.0 / size
                return 1.0 / s.card if s.card else 0.1
            c = _static_const(other)
            if c is None or s.lo is None or s.hi is None or s.hi <= s.lo:
                return 0.3
            span = s.hi - s.lo
            if op in ("<", "<="):
                return min(1.0, max(0.0, (c - s.lo) / span))
            return min(1.0, max(0.0, (s.hi - c) / span))
        return 0.3

    def est(self, n) -> float:
        got = self._est.get(id(n))
        if got is not None:
            return got
        if isinstance(n, P.Scan):
            r = float(C.table_of(n.table).rows)
        elif isinstance(n, P.Filter):
            r = self.est(n.children[0]) * self.selectivity(
                n.pred, self.schema(n.children[0]))
        elif isinstance(n, (P.Select, P.Rename, P.WithCol, P.Shuffle,
                            P.Broadcast, P.Finalize)):
            r = self.est(n.children[0])
        elif isinstance(n, P.Shrink):
            r = min(self.est(n.children[0]), float(n.cap))
        elif isinstance(n, (P.Join, P.Semi)):
            ks = self.keyspace(n.children[1], n.build_on)
            r = self.est(n.children[0]) * min(
                1.0, self.est(n.children[1]) / ks)
        elif isinstance(n, (P.Anti, P.Left)):
            r = self.est(n.children[0])
        elif isinstance(n, P.GroupBy):
            r = self.est(n.children[0])
            sch = self.schema(n.children[0])
            dom = 1.0
            for k in n.keys:
                card = sch.get(k, _UNK).card
                dom *= card if card else 1e9
            r = min(r, dom)
            if n.groups_hint is not None:
                r = min(r, float(n.groups_hint))
        else:
            r = self.est(n.children[0]) if n.children else 0.0
        self._est[id(n)] = r
        return r

    def cap(self, n):
        """Provable row cap (Shrink claims only — never estimates)."""
        if isinstance(n, P.Shrink):
            return n.cap
        if isinstance(n, (P.Filter, P.Select, P.WithCol, P.Rename, P.Semi,
                          P.Anti, P.Shuffle, P.Broadcast)):
            return self.cap(n.children[0])
        if isinstance(n, (P.Join, P.Left)):
            bon = n.on_pairs()[0][1]
            build = n.children[1]
            uniq = len(n.on_pairs()) == 1 and self._unique_on(build, bon)
            if uniq or isinstance(n, P.Left):
                return self.cap(n.children[0])
            return None
        return None

    def _unique_on(self, n, col) -> bool:
        if isinstance(n, P.Scan):
            return col in C.table_of(n.table).unique
        if isinstance(n, (P.Filter, P.Select, P.Shrink, P.Semi, P.Anti,
                          P.Broadcast, P.Shuffle, P.WithCol)):
            return self._unique_on(n.children[0], col)
        if isinstance(n, P.Rename):
            inv = {v: k for k, v in n.mapping.items()}
            return self._unique_on(n.children[0], inv.get(col, col))
        if isinstance(n, P.GroupBy):
            return len(n.keys) == 1 and n.keys[0] == col
        return False

    def membership_only(self, n) -> bool:
        for parent, role in self.consumers.get(id(n), []):
            if isinstance(parent, (P.Select, P.Rename, P.Broadcast)):
                if not self.membership_only(parent):
                    return False
            elif isinstance(parent, (P.Semi, P.Anti)) and role == 1:
                continue
            else:
                return False
        return bool(self.consumers.get(id(n)))

    def final_chain(self, n) -> bool:
        """True when every consumer path reaches Finalize through per-row
        operators only (the group-by's output is the query result)."""
        cons = self.consumers.get(id(n), [])
        if not cons:
            return False
        for parent, _role in cons:
            if isinstance(parent, P.Finalize):
                continue
            if isinstance(parent, (P.Filter, P.WithCol, P.Select, P.Rename,
                                   P.Shrink)) and self.final_chain(parent):
                continue
            return False
        return True


# ---------------------------------------------------------------------------
# pass 1+2: predicate pushdown, semi/anti sinking, filter merging
# ---------------------------------------------------------------------------

def _item_cols(it) -> set:
    if it[0] == "f":
        return expr_cols(it[1])
    on = it[2]
    return set(on) if isinstance(on, tuple) else {on}


class _Push:
    def __init__(self, root):
        self.ctx = _Ctx(root)
        self.memo: dict[int, object] = {}

    def shared(self, n) -> bool:
        return len(self.ctx.consumers.get(id(n), ())) > 1

    def run(self, n):
        got = self.memo.get(id(n))
        if got is None:
            got = self.push(n, [])
            self.memo[id(n)] = got
        return got

    def child(self, n, pending):
        if not pending:
            return self.run(n)
        if self.shared(n):
            return self.deposit(self.run(n), pending)
        return self.push(n, pending)

    def deposit(self, node, items):
        for it in items:
            if it[0] == "f":
                node = P.Filter(node, it[1])
            else:
                _, cls, on, bon, build = it
                node = cls(node, build, on, bon)
        return node

    def fix_expr(self, e):
        stack, refs = [e], []
        while stack:
            x = stack.pop()
            if isinstance(x, P.ScalarRef):
                refs.append(x.node)
            else:
                from .ir import expr_refs
                stack.extend(expr_refs(x))
        for dep in refs:
            self.run(dep)
        return rewrite_expr(e, None, self.memo)

    def push(self, n, pending):
        if isinstance(n, P.Filter):
            pred = self.fix_expr(n.pred)
            items = [("f", c) for c in conjuncts(pred)]
            return self.child(n.children[0], items + pending)
        if isinstance(n, (P.Semi, P.Anti)):
            build = self.run(n.build)
            item = ("s", type(n), n.on, n.build_on, build)
            return self.child(n.probe, [item] + pending)
        if isinstance(n, (P.Select, P.Shrink)):
            c = self.child(n.children[0], pending)
            return clone_with(n, (c,), self.memo)
        if isinstance(n, P.WithCol):
            new = set(n.exprs)
            passable = [it for it in pending if not (_item_cols(it) & new)]
            stuck = [it for it in pending if _item_cols(it) & new]
            c = self.child(n.children[0], passable)
            node = clone_with(n, (c,), self.memo)
            return self.deposit(node, stuck)
        if isinstance(n, P.Rename):
            inv = {v: k for k, v in n.mapping.items()}
            mapped = []
            for it in pending:
                if it[0] == "f":
                    mapped.append(("f", rewrite_expr(
                        it[1], lambda c: inv.get(c, c), self.memo)))
                else:
                    _, cls, on, bon, build = it
                    on2 = tuple(inv.get(c, c) for c in on) \
                        if isinstance(on, tuple) else inv.get(on, on)
                    mapped.append(("s", cls, on2, bon, build))
            c = self.child(n.children[0], mapped)
            return clone_with(n, (c,), self.memo)
        if isinstance(n, (P.Join, P.Left)):
            probe_out = set(output_columns(n.probe))
            take = set(n.take)
            probe_items, build_items, stuck = [], [], []
            for it in pending:
                cols = _item_cols(it)
                if cols and cols <= probe_out:
                    probe_items.append(it)
                elif cols and isinstance(n, P.Join) and cols <= take:
                    build_items.append(it)
                else:
                    stuck.append(it)
            p = self.child(n.probe, probe_items)
            b = self.child(n.build, build_items)
            node = clone_with(n, (p, b), self.memo)
            return self.deposit(node, stuck)
        if isinstance(n, P.GroupBy):
            keys = set(n.keys)
            passable = [it for it in pending if _item_cols(it) and
                        _item_cols(it) <= keys]
            stuck = [it for it in pending if it not in passable]
            c = self.child(n.children[0], passable)
            node = clone_with(n, (c,), self.memo)
            return self.deposit(node, stuck)
        # Scan / AggScalar / Finalize / ScalarResult / exchanges: barrier
        for d in scalar_deps(n):
            self.run(d)
        children = tuple(self.run(c) for c in n.children)
        node = clone_with(n, children, self.memo)
        return self.deposit(node, pending)


def _merge_filters(root):
    def fn(n):
        if isinstance(n, P.Filter) and isinstance(n.children[0], P.Filter):
            inner = n.children[0]
            return P.Filter(inner.children[0],
                            conjoin(conjuncts(inner.pred) +
                                    conjuncts(n.pred)))
        return n
    out = root
    while True:
        new = rewrite(out, fn)
        if new is out:
            return out
        out = new


# ---------------------------------------------------------------------------
# pass 3: shared shuffle (Q17)
# ---------------------------------------------------------------------------

def _shared_shuffle(root):
    ctx = _Ctx(root)
    for n in ctx.nodes:
        if not isinstance(n, P.GroupBy) or len(n.keys) != 1 or \
                n.exchange != "local":
            continue
        k = n.keys[0]
        x = n.children[0]
        cons = ctx.consumers.get(id(x), [])
        if len(cons) < 2:
            continue
        part = _static_part(x)
        if part == REPL or (isinstance(part, tuple) and set(part) <= {k}):
            continue
        join_probe = any(isinstance(p, (P.Join, P.Left)) and role == 0 and
                         any(pc == k for pc, _ in p.on_pairs())
                         for p, role in cons)
        if not join_probe:
            continue
        shuf = P.Shuffle(x, k)

        def fn(m, _x=x, _s=shuf):
            return _s if m is _x else m
        return rewrite(root, fn)
    return root


def _static_part(n):
    """Partitioning of a pre-placement subtree (mirrors planner.part)."""
    if isinstance(n, P.Scan):
        k = C.PARTITION.get(n.table)
        return REPL if k is None else (k,)
    if isinstance(n, (P.Filter, P.Select, P.Shrink)):
        return _static_part(n.children[0])
    if isinstance(n, P.WithCol):
        p = _static_part(n.children[0])
        if isinstance(p, tuple) and any(c in n.exprs for c in p):
            return None
        return p
    if isinstance(n, P.Rename):
        p = _static_part(n.children[0])
        if isinstance(p, tuple):
            return tuple(n.mapping.get(c, c) for c in p)
        return p
    if isinstance(n, P.Shuffle):
        return (n.key,)
    if isinstance(n, P.Broadcast):
        return REPL
    if isinstance(n, (P.Join, P.Left, P.Semi, P.Anti)):
        pp = _static_part(n.children[0])
        bp = _static_part(n.children[1])
        if pp is None or bp is None:
            return pp
        if bp == REPL:
            return pp
        if pp == REPL:
            if isinstance(n, P.Join):
                return _translate(bp, n.on_pairs())
            return None
        return pp
    if isinstance(n, P.GroupBy):
        if n.exchange == "local":
            return _static_part(n.children[0])
        if n.exchange == "shuffle":
            return tuple(n.keys)
        return REPL
    return None


def _translate(build_part, pairs):
    m = {b: pr for pr, b in pairs}
    if all(c in m for c in build_part):
        return tuple(m[c] for c in build_part)
    return None


# ---------------------------------------------------------------------------
# pass 4: group-key packing
# ---------------------------------------------------------------------------

def _would_sort(keys, sch, groups_hint) -> bool:
    """Mirror of planner hint inference: True when the runtime method for
    these keys would be the sorted path."""
    bits, card = [], 1
    for k in keys:
        s = sch.get(k, _UNK)
        if bits is not None and s.lo is not None and s.lo >= 0 \
                and s.hi is not None:
            bits.append(max(1, int(s.hi).bit_length()))
        else:
            bits = None
        card = None if (card is None or s.card is None) else card * s.card
    if bits is not None and sum(bits) <= PL._direct_bits_max():
        return False                                    # direct path
    gh = card
    if groups_hint is not None:
        gh = groups_hint if gh is None else min(gh, groups_hint)
    if gh is not None and gh <= PL._hash_groups_max() and \
            1 <= len(keys) <= 2:
        return False                                    # hash compaction
    return True


def _pack_wins(keys, sch, groups_hint):
    """The packed key when packing strictly improves on the unpacked
    method, else None.  Packing wins when it unlocks the DIRECT path the
    unpacked keys cannot prove (the direct path's static widths beat the
    hash path's trace-time dictionary — Q9: nationkey x year packs into
    9 bits where the raw columns need 16), or failing that, when the
    unpacked keys would take the sorted path at all."""
    bits = []
    for k in keys:
        s = sch.get(k, _UNK)
        if bits is not None and s.lo is not None and s.lo >= 0 \
                and s.hi is not None:
            bits.append(max(1, int(s.hi).bit_length()))
        else:
            bits = None
    if bits is not None and sum(bits) <= PL._direct_bits_max():
        return None                 # already direct without packing
    grp, hi = _pack_expr(keys, sch)
    if grp is None:
        return None                 # unprovable domain (Q13) — can't pack
    if hi.bit_length() <= PL._direct_bits_max():
        return grp                  # pack unlocks the direct path
    if _would_sort(keys, sch, groups_hint):
        return grp                  # pack at least collapses the sort
    return None                     # hash path is already sortless


def _pack_expr(keys, sch):
    """Strided int64 key over invariant domains; None when any key's bounds
    are not provable at every scale."""
    spans = []
    for k in keys:
        s = sch.get(k, _UNK)
        if not s.inv or s.lo is None or s.hi is None:
            return None, None
        spans.append((s.lo, s.hi - s.lo + 1))
    acc = P.Cast(P.Col(keys[0]), "int64")
    lo0, span0 = spans[0]
    if lo0:
        acc = P.BinOp("-", acc, P.Lit(lo0))
    hi = span0 - 1
    for k, (lo, span) in zip(keys[1:], spans[1:]):
        term = P.Col(k)
        if lo:
            term = P.BinOp("-", term, P.Lit(lo))
        acc = P.BinOp("+", P.BinOp("*", acc, P.Lit(span)), term)
        hi = hi * span + span - 1
    return acc, hi


def _pack_groups(root):
    ctx = _Ctx(root)

    def eligible(n):
        return (isinstance(n, P.GroupBy) and len(n.keys) >= 2 and
                n.exchange == "local")

    def fn(n):
        if not eligible(n):
            return n
        # nested dedup (Q16): this is the OUTER group-by over an inner
        # dedup group-by on a key superset — pack the shared subset once
        inner = n.children[0]
        if eligible(inner) and set(n.keys) < set(inner.keys) and \
                len(ctx.consumers.get(id(inner), [])) == 1:
            sch = ctx.schema(inner.children[0])
            grp = _pack_wins(n.keys, sch, n.groups_hint)
            if grp is None:
                return n
            packed = tuple(n.keys)
            rest = tuple(k for k in inner.keys if k not in packed)
            rec = tuple((k, "max", k) for k in packed)
            wc = P.WithCol(inner.children[0], {"__grp": grp})
            inner2 = P.GroupBy(wc, ("__grp",) + rest, inner.aggs + rec,
                               "local", False, inner.groups_hint)
            outer = P.GroupBy(inner2, ("__grp",), n.aggs + rec, "local",
                              False, n.groups_hint)
            return P.Select(outer, output_columns(n))
        if not ctx.final_chain(n):
            return n
        sch = ctx.schema(n.children[0])
        grp = _pack_wins(n.keys, sch, n.groups_hint)
        if grp is None:
            return n
        rec = tuple((k, "max", k) for k in n.keys)
        wc = P.WithCol(n.children[0], {"__grp": grp})
        gb = P.GroupBy(wc, ("__grp",), n.aggs + rec, "local", False,
                       n.groups_hint)
        return P.Select(gb, output_columns(n))

    return rewrite(root, fn)


# ---------------------------------------------------------------------------
# pass 5: projection pruning
# ---------------------------------------------------------------------------

def _required(ctx: _Ctx) -> dict:
    """Per-node required output columns, flowed root-to-leaves."""
    req: dict[int, set] = {}

    def need(n, cols):
        req.setdefault(id(n), set()).update(cols)

    for n in reversed(ctx.nodes):
        r = req.get(id(n), set())
        if isinstance(n, (P.Finalize, P.ScalarResult, P.AggScalar)) or \
                not ctx.consumers.get(id(n)):
            r = set(output_columns(n.children[0])) \
                if isinstance(n, P.Finalize) else r
            if isinstance(n, P.Finalize):
                req[id(n)] = set(r)
        if isinstance(n, P.Finalize):
            need(n.children[0], req[id(n)])
        elif isinstance(n, P.ScalarResult):
            pass                    # ScalarRef deps seed AggScalar below
        elif isinstance(n, P.AggScalar):
            cols = set()
            for _name, _op, v in n.aggs:
                if isinstance(v, P.Expr):
                    cols |= expr_cols(v)
                elif isinstance(v, str):
                    cols.add(v)
            need(n.children[0], cols)
        elif isinstance(n, P.Filter):
            need(n.children[0], r | expr_cols(n.pred))
        elif isinstance(n, P.Select):
            need(n.children[0], set(n.names))
        elif isinstance(n, P.WithCol):
            cols = set(r) - set(n.exprs)
            for name, e in n.exprs.items():
                if name in r:
                    cols |= expr_cols(e)
            need(n.children[0], cols)
        elif isinstance(n, P.Rename):
            inv = {v: k for k, v in n.mapping.items()}
            need(n.children[0], {inv.get(c, c) for c in r})
        elif isinstance(n, P.Shuffle):
            need(n.children[0], r | {n.key})
        elif isinstance(n, (P.Broadcast, P.Shrink)):
            need(n.children[0], r)
        elif isinstance(n, (P.Join, P.Left)):
            pairs = n.on_pairs()
            need(n.children[0], (r - set(n.take)) | {pc for pc, _ in pairs})
            need(n.children[1], (r & set(n.take)) | {bc for _, bc in pairs})
        elif isinstance(n, (P.Semi, P.Anti)):
            pairs = n.on_pairs()
            need(n.children[0], r | {pc for pc, _ in pairs})
            need(n.children[1], {bc for _, bc in pairs})
        elif isinstance(n, P.GroupBy):
            keep = [(name, op, v) for name, op, v in n.aggs
                    if name in r or not ctx.consumers.get(id(n))]
            cols = set(n.keys)
            for _name, op, v in keep:
                if isinstance(v, P.Expr):
                    cols |= expr_cols(v)
                elif isinstance(v, str):
                    cols.add(v)
            need(n.children[0], cols)
    return req


def _prune(root):
    ctx = _Ctx(root)
    req = _required(ctx)
    memo: dict[int, object] = {}

    def narrow(orig, n):
        # req is keyed by the ORIGINAL node's id; n is the rebuilt node
        r = req.get(id(orig))
        if isinstance(n, P.Scan) and r is not None:
            names = [c for c in output_columns(n) if c in r]
            if names and len(names) < len(output_columns(n)):
                return P.Select(n, names)
            return n
        if isinstance(n, (P.Join, P.Left)) and r is not None:
            take = tuple(c for c in n.take if c in r)
            if take == n.take:
                return n
            if isinstance(n, P.Left):
                defaults = {c: n.defaults[c] for c in take}
                return P.Left(n.children[0], n.children[1], n.on,
                              n.build_on, take, defaults)
            return P.Join(n.children[0], n.children[1], n.on, n.build_on,
                          take)
        if isinstance(n, P.GroupBy) and r is not None and \
                ctx.consumers.get(id(orig)):
            aggs = tuple(a for a in n.aggs if a[0] in r)
            if aggs != n.aggs and aggs:
                return P.GroupBy(n.children[0], n.keys, aggs, n.exchange,
                                 n.final, n.groups_hint)
            return n
        if isinstance(n, P.WithCol) and r is not None and \
                ctx.consumers.get(id(orig)):
            exprs = {k: v for k, v in n.exprs.items() if k in r}
            if not exprs:
                return n.children[0]
            if len(exprs) < len(n.exprs):
                return P.WithCol(n.children[0], exprs)
            return n
        return n

    def go(n):
        got = memo.get(id(n))
        if got is not None:
            return got
        for d in scalar_deps(n):
            go(d)
        children = tuple(go(c) for c in n.children)
        new = narrow(n, clone_with(n, children, memo))
        memo[id(n)] = new
        return new

    return go(root)


# ---------------------------------------------------------------------------
# pass 6: exchange placement
# ---------------------------------------------------------------------------

class _Place:
    def __init__(self, root):
        self.ctx = _Ctx(root)
        self.req = _required(self.ctx)
        self._part: dict[int, object] = {}

    def part(self, n):
        got = self._part.get(id(n), "_miss")
        if got == "_miss":
            got = self._derive(n)
            self._part[id(n)] = got
        return got

    def _derive(self, n):
        if isinstance(n, P.Scan):
            k = C.PARTITION.get(n.table)
            return REPL if k is None else (k,)
        if isinstance(n, (P.Filter, P.Select, P.Shrink)):
            return self.part(n.children[0])
        if isinstance(n, P.WithCol):
            p = self.part(n.children[0])
            if isinstance(p, tuple) and any(c in n.exprs for c in p):
                return None
            return p
        if isinstance(n, P.Rename):
            p = self.part(n.children[0])
            return tuple(n.mapping.get(c, c) for c in p) \
                if isinstance(p, tuple) else p
        if isinstance(n, P.Shuffle):
            return (n.key,)
        if isinstance(n, P.Broadcast):
            return REPL
        if isinstance(n, (P.Join, P.Left, P.Semi, P.Anti)):
            pp, bp = self.part(n.children[0]), self.part(n.children[1])
            pairs = n.on_pairs()
            if pp is None or bp is None:
                return pp
            if bp == REPL:
                return pp
            if pp == REPL:
                return _translate(bp, pairs) if isinstance(n, P.Join) \
                    else None
            if _translate(bp, pairs) == pp:
                return pp
            return pp
        if isinstance(n, P.GroupBy):
            if n.exchange == "local":
                return self.part(n.children[0])
            if n.exchange == "shuffle":
                return tuple(n.keys)
            return REPL
        return None

    # -- helpers -----------------------------------------------------------
    @staticmethod
    def _narrow(node, needed):
        out = output_columns(node)
        names = [c for c in out if c in needed]
        if len(names) < len(out):
            return P.Select(node, names)
        return node

    def _bcast_build(self, b, needed):
        return P.Broadcast(self._narrow(b, needed), False)

    @staticmethod
    def _gb_cols(n):
        """Columns a GroupBy reads: keys plus aggregate operands."""
        cols = set(n.keys)
        for _name, _op, v in n.aggs:
            if isinstance(v, P.Expr):
                cols |= expr_cols(v)
            elif isinstance(v, str):
                cols.add(v)
        return cols

    # -- join/semi placement ----------------------------------------------
    def join(self, orig, n):
        pairs = n.on_pairs()
        pp, bp = self.part(n.children[0]), self.part(n.children[1])
        if bp == REPL:
            return n
        if pp is not None and bp is not None and \
                _translate(bp, pairs) == pp:
            return n
        if pp == REPL and isinstance(n, P.Join) and bp is not None:
            return n                      # replicated probe, exact (Q18 tail)
        probe_o, build_o = orig.children
        if isinstance(n, (P.Semi, P.Anti)):
            bon = n.build_on
            needed = set(bon) if isinstance(bon, tuple) else {bon}
            if self.ctx.est(build_o) <= _BCAST:
                b = self._bcast_build(n.children[1], needed)
                return type(n)(n.children[0], b, n.on, n.build_on)
            # dedup to key membership, then broadcast or shuffle the keys
            cols = sorted(needed)
            sel = self._narrow(n.children[1], needed)
            if self.ctx.keyspace(build_o, n.build_on) <= _BCAST:
                g = P.GroupBy(sel, tuple(cols), (("__n", "count", None),),
                              "local", False, None)
                b = P.Broadcast(P.Select(g, cols), False)
                return type(n)(n.children[0], b, n.on, n.build_on)
            if len(cols) == 1:
                g = P.GroupBy(sel, tuple(cols), (("__n", "count", None),),
                              "shuffle", False, None)
                b = P.Select(g, cols)
                if _translate((cols[0],), pairs) == pp:
                    return type(n)(n.children[0], b, n.on, n.build_on)
                r = self.req.get(id(orig), set())
                p = self._narrow(n.children[0],
                                 r | {pc for pc, _ in pairs})
                return type(n)(P.Shuffle(p, pairs[0][0]), b,
                               n.on, n.build_on)
            return type(n)(n.children[0],
                           P.Broadcast(sel, False), n.on, n.build_on)
        # inner / left joins
        needed = set(n.take) | {bc for _, bc in pairs}
        if self.ctx.est(build_o) <= _BCAST:
            return self._rebuild_join(
                n, n.children[0], self._bcast_build(n.children[1], needed))
        cap = self.ctx.cap(probe_o)
        if isinstance(n, P.Join) and cap is not None and cap <= _BCAST \
                and bp is not None:
            return self._rebuild_join(n, P.Broadcast(n.children[0], False),
                                      n.children[1])
        r = self.req.get(id(orig), set())
        p_need = (r - set(n.take)) | {pc for pc, _ in pairs}
        b_need = (r & set(n.take)) | {bc for _, bc in pairs}
        if bp is not None and len(bp) == 1:
            t = _translate(bp, pairs)
            if t is not None:
                p = self._narrow(n.children[0], p_need)
                return self._rebuild_join(
                    n, P.Shuffle(p, t[0]), n.children[1])
        # generic fallback: co-partition both sides on the first pair
        pc, bc = pairs[0]
        return self._rebuild_join(
            n, P.Shuffle(self._narrow(n.children[0], p_need), pc),
            P.Shuffle(self._narrow(n.children[1], b_need), bc))

    @staticmethod
    def _rebuild_join(n, p, b):
        if isinstance(n, P.Left):
            return P.Left(p, b, n.on, n.build_on, n.take, n.defaults)
        return P.Join(p, b, n.on, n.build_on, n.take)

    def _feeds_join(self, orig):
        """Follow a sole-consumer Select/Rename chain from ``orig`` to a
        join build input; returns (join, {group key -> name at join})."""
        node, names = orig, {k: k for k in orig.keys}
        while True:
            cons = self.ctx.consumers.get(id(node), [])
            if len(cons) != 1:
                return None
            p, role = cons[0]
            if isinstance(p, P.Select):
                node = p
            elif isinstance(p, P.Rename):
                names = {k: p.mapping.get(v, v) for k, v in names.items()}
                node = p
            elif isinstance(p, (P.Join, P.Left, P.Semi, P.Anti)) and \
                    role == 1:
                return p, names
            else:
                return None

    # -- group-by placement -------------------------------------------------
    def groupby(self, orig, n):
        cp = self.part(n.children[0])
        keys = set(n.keys)
        if cp == REPL or (isinstance(cp, tuple) and set(cp) <= keys):
            return n
        if self.ctx.membership_only(orig):
            return n
        # nested dedup: sole consumer is a group-by on a key subset — one
        # shuffle on a shared key makes both local (Q16's composite dedup)
        cons = self.ctx.consumers.get(id(orig), [])
        if len(cons) == 1 and isinstance(cons[0][0], P.GroupBy):
            outer = cons[0][0]
            shared = [k for k in outer.keys if k in keys]
            if shared and set(outer.keys) < keys:
                sel = self._narrow(n.children[0], self._gb_cols(n))
                return P.GroupBy(P.Shuffle(sel, shared[0]),
                                 n.keys, n.aggs, "local", False,
                                 n.groups_hint)
        # feeding a join build: co-partition with the probe
        feed = self._feeds_join(orig)
        if feed is not None:
            parent, names = feed
            pp = _static_part(parent.children[0])
            pairs = parent.on_pairs()
            mapped = tuple(names[k] for k in n.keys)
            if pp is not None and _translate(mapped, pairs) == pp:
                return P.GroupBy(n.children[0], n.keys, n.aggs, "shuffle",
                                 False, n.groups_hint)
            inv = {v: k for k, v in names.items()}
            for pc, bc in pairs:
                if bc in inv and isinstance(pp, tuple) and pc in pp:
                    sel = self._narrow(n.children[0], self._gb_cols(n))
                    return P.GroupBy(P.Shuffle(sel, inv[bc]),
                                     n.keys, n.aggs, "local", False,
                                     n.groups_hint)
        if self.ctx.final_chain(orig):
            sch = self.ctx.schema(orig.children[0])
            dom = 1.0
            for k in n.keys:
                card = sch.get(k, _UNK).card
                dom *= card if card else float("inf")
            if n.groups_hint is not None:
                dom = min(dom, float(n.groups_hint))
            if dom <= _GATHER_MAX:
                return P.GroupBy(n.children[0], n.keys, n.aggs, "gather",
                                 True, n.groups_hint)
        return P.GroupBy(n.children[0], n.keys, n.aggs, "shuffle", False,
                         n.groups_hint)

    # -- driver --------------------------------------------------------------
    def run(self, root):
        memo: dict[int, object] = {}

        def go(n):
            got = memo.get(id(n))
            if got is not None:
                return got
            for d in scalar_deps(n):
                go(d)
            children = tuple(go(c) for c in n.children)
            new = clone_with(n, children, memo)
            if isinstance(new, (P.Join, P.Left, P.Semi, P.Anti)):
                new = self.join(n, new)
            elif isinstance(new, P.GroupBy) and new.exchange == "local":
                new = self.groupby(n, new)
            elif isinstance(new, P.Finalize):
                repl = self.part(new.children[0]) == REPL
                if repl != new.replicated:
                    new = P.Finalize(new.children[0], new.sort_keys,
                                     new.limit, repl)
            memo[id(n)] = new
            return new

        return go(root)


# ---------------------------------------------------------------------------
# pass 7: common-subplan elimination
# ---------------------------------------------------------------------------

def _cse(root):
    sigs = PL.subplan_signatures(root)
    by_sig: dict[tuple, object] = {}
    repl: dict[int, object] = {}
    for n in walk(root):
        sig = sigs.get(id(n))
        if sig is None:
            continue
        rep = by_sig.get(sig)
        if rep is None:
            by_sig[sig] = n
        elif rep is not n:
            repl[id(n)] = rep
    if not repl:
        return root

    def fn(n):
        return repl.get(id(n), n)
    # note: fn sees REBUILT nodes; map original ids by rewriting children
    # bottom-up — rebuilt duplicates keep their original id only when
    # untouched, so run to fixpoint on fresh signatures
    out = rewrite(root, fn)
    return out


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def optimize(root):
    """Run the full pass pipeline on a lowered plan root."""
    root = _Push(root).run(root)
    root = _merge_filters(root)
    root = _shared_shuffle(root)
    root = _pack_groups(root)
    root = _prune(root)
    root = _Place(root).run(root)
    for _ in range(3):
        new = _cse(root)
        if new is root:
            break
        root = new
    return root

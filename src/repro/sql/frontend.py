"""SQL entry points: text -> logical plan -> compiled query.

``compile_sql`` is the one-call path (parse -> bind/lower -> optimize ->
``compile_query``); ``plan_sql`` stops at the logical-plan root for callers
that stage compilation themselves (``serve.PlanTemplate.from_sql``).  The
committed TPC-H SQL texts live in ``src/repro/queries/sql/q*.sql`` and load
through ``sql_plans`` / ``sql_queries`` — ``REPRO_FRONTEND=sql`` swaps them
in for the hand-built plan DAGs in :mod:`repro.queries`.
"""
from __future__ import annotations

import pathlib

from repro.core.planner import CompiledQuery, compile_query

from .lower import lower
from .optimizer import optimize
from .parser import parse

__all__ = ["compile_sql", "plan_sql", "sql_plans", "sql_queries", "SQL_DIR"]

# the committed TPC-H SQL suite
SQL_DIR = pathlib.Path(__file__).resolve().parents[1] / "queries" / "sql"


def plan_sql(text: str):
    """Compile SQL ``text`` into an optimized logical-plan root."""
    return optimize(lower(parse(text)))


def compile_sql(text: str, name: str | None = None) -> CompiledQuery:
    """Compile SQL ``text`` into a runnable :class:`CompiledQuery`."""
    return compile_query(lambda: plan_sql(text), name=name or "sql")


def sql_text(qid: int) -> str:
    """The committed SQL text of TPC-H query ``qid``."""
    return (SQL_DIR / f"q{qid}.sql").read_text()


def sql_plans() -> dict:
    """qid -> fresh-plan build function for the committed TPC-H SQL texts."""
    out = {}
    for path in SQL_DIR.glob("q*.sql"):
        text = path.read_text()
        out[int(path.stem[1:])] = (lambda t: lambda: plan_sql(t))(text)
    return dict(sorted(out.items()))


def sql_queries() -> dict:
    """qid -> CompiledQuery for the committed TPC-H SQL texts."""
    return {qid: compile_query(fn, name=f"q{qid}")
            for qid, fn in sql_plans().items()}

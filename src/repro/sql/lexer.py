"""SQL tokenizer with line/column tracking.

Produces a flat token list for the recursive-descent parser.  Comments
(``-- ...`` and ``/* ... */``) are skipped; optimizer hints (``/*+ ... */``)
become ``HINT`` tokens so the parser can attach them to the preceding
predicate or the enclosing SELECT.  All errors are :class:`SqlError` with the
1-based line and column of the offending character.
"""
from __future__ import annotations

import dataclasses

__all__ = ["SqlError", "Token", "tokenize", "KEYWORDS"]


class SqlError(Exception):
    """A lexing/parsing/binding error, carrying source position.

    ``str(e)`` renders ``message (line L, col C)`` so test suites and users
    can pinpoint the offending token without re-deriving offsets.
    """

    def __init__(self, message: str, line: int | None = None,
                 col: int | None = None):
        self.message = message
        self.line = line
        self.col = col
        where = f" (line {line}, col {col})" if line is not None else ""
        super().__init__(f"{message}{where}")


@dataclasses.dataclass(frozen=True)
class Token:
    kind: str       # KEYWORD | NAME | NUMBER | STRING | OP | PARAM | HINT | EOF
    value: str
    line: int
    col: int


KEYWORDS = frozenset("""
    select from where group by having order asc desc limit as and or not in
    exists between like case when then else end is null distinct join inner
    left outer on with interval year month day date cast sum count min max
    avg extract substring declare default int float true false
""".split())

_MULTI_OPS = ("<>", "<=", ">=", "!=", "||")
_SINGLE_OPS = "+-*/%(),.<>=:;"


def tokenize(text: str) -> list[Token]:
    toks: list[Token] = []
    i, line, col = 0, 1, 1
    n = len(text)

    def err(msg: str) -> SqlError:
        return SqlError(msg, line, col)

    while i < n:
        ch = text[i]
        if ch == "\n":
            i += 1
            line += 1
            col = 1
            continue
        if ch in " \t\r":
            i += 1
            col += 1
            continue
        if text.startswith("--", i):
            j = text.find("\n", i)
            i = n if j < 0 else j
            continue
        if text.startswith("/*", i):
            is_hint = text.startswith("/*+", i)
            j = text.find("*/", i)
            if j < 0:
                raise err("unterminated comment")
            if is_hint:
                toks.append(Token("HINT", text[i + 3:j].strip(), line, col))
            skipped = text[i:j + 2]
            nl = skipped.count("\n")
            if nl:
                line += nl
                col = len(skipped) - skipped.rfind("\n")
            else:
                col += len(skipped)
            i = j + 2
            continue
        if ch == "'":
            j = i + 1
            buf = []
            while True:
                if j >= n:
                    raise err("unterminated string literal")
                if text[j] == "'":
                    if j + 1 < n and text[j + 1] == "'":   # '' escape
                        buf.append("'")
                        j += 2
                        continue
                    break
                if text[j] == "\n":
                    raise err("newline in string literal")
                buf.append(text[j])
                j += 1
            toks.append(Token("STRING", "".join(buf), line, col))
            col += j + 1 - i
            i = j + 1
            continue
        if ch.isdigit() or (ch == "." and i + 1 < n and text[i + 1].isdigit()):
            j = i
            seen_dot = False
            while j < n and (text[j].isdigit() or (text[j] == "." and not seen_dot)):
                if text[j] == ".":
                    # "1." followed by non-digit is NUMBER then OP "."
                    if j + 1 >= n or not text[j + 1].isdigit():
                        break
                    seen_dot = True
                j += 1
            # scientific notation: 1e-12, 2.5E+3, 1e6 (exponent digits required)
            if j < n and text[j] in "eE":
                k = j + 1
                if k < n and text[k] in "+-":
                    k += 1
                if k < n and text[k].isdigit():
                    while k < n and text[k].isdigit():
                        k += 1
                    j = k
            toks.append(Token("NUMBER", text[i:j], line, col))
            col += j - i
            i = j
            continue
        if ch.isalpha() or ch == "_":
            j = i
            while j < n and (text[j].isalnum() or text[j] == "_"):
                j += 1
            word = text[i:j]
            kind = "KEYWORD" if word.lower() in KEYWORDS else "NAME"
            toks.append(Token(kind, word.lower() if kind == "KEYWORD" else word,
                              line, col))
            col += j - i
            i = j
            continue
        if ch == ":" and i + 1 < n and (text[i + 1].isalpha() or text[i + 1] == "_"):
            j = i + 1
            while j < n and (text[j].isalnum() or text[j] == "_"):
                j += 1
            toks.append(Token("PARAM", text[i + 1:j], line, col))
            col += j - i
            i = j
            continue
        two = text[i:i + 2]
        if two in _MULTI_OPS:
            toks.append(Token("OP", two, line, col))
            i += 2
            col += 2
            continue
        if ch in _SINGLE_OPS:
            toks.append(Token("OP", ch, line, col))
            i += 1
            col += 1
            continue
        raise err(f"unexpected character {ch!r}")

    toks.append(Token("EOF", "", line, col))
    return toks

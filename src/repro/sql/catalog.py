"""Static TPC-H catalog for the SQL frontend: schemas, domains, cardinalities.

The binder resolves column names against this catalog (it mirrors the tables
:func:`repro.data.tpch.generate` builds — asserted in tests), and the
optimizer's placement / key-packing decisions read the *scale-invariant*
column domains and the nominal SF=1 cardinalities from it.  Two kinds of
knowledge live here:

  * **Scale-invariant domains** (``lo``/``hi`` with ``invariant=True``):
    dictionary code ranges, spec-bounded integers (``p_size`` 1..50), date
    ranges.  Safe inputs for static group-key packing and derived shrink
    caps — the values cannot outgrow them at any scale factor.  (Runtime
    range checks still verify every claim; a violated bound raises
    ``ctx.overflow`` and the fault runner re-executes — never silent wrong
    answers.)
  * **Scale-variant estimates** (key columns, SF=1 ``rows``): inputs to the
    broadcast-vs-shuffle cost rules only.  A wrong estimate can cost
    performance, never correctness — placement choices are all semantically
    valid.

The partition map mirrors the paper's §4.3 layout (``backend.PARTITION_KEYS``,
asserted equal in tests) without importing the jax-heavy backend module.
"""
from __future__ import annotations

import dataclasses

from repro.core.table import days

__all__ = ["Column", "TableDef", "CATALOG", "PARTITION", "table_of",
           "column_table", "BCAST_MAX_ROWS", "ALPHA_CODED"]

# broadcast threshold (SF=1 estimated build rows): dimension slices up to a
# full supplier table / a one-region customer slice broadcast; whole
# customer/part/fact tables never do.  Matches the paper's §4.4 choices.
BCAST_MAX_ROWS = 65536

_DATE_LO = days("1992-01-01")
_ODATE_HI = days("1998-08-02")
_SHIP_HI = _ODATE_HI + 121            # l_shipdate = o_orderdate + [1, 121]
_RECEIPT_HI = _SHIP_HI + 30


@dataclasses.dataclass(frozen=True)
class Column:
    """One physical column: dtype kind + provable value domain.

    ``kind``      "int" | "float" | "dict" (dictionary-encoded string)
    ``lo``/``hi`` inclusive value bounds; ``None`` = unbounded
    ``invariant`` bounds hold at EVERY scale factor (safe for static packing)
    ``dict_name`` dictionary id: for ``kind == "dict"`` it equals the column
                  name; an ``"int"`` column may also carry it when its values
                  ARE codes of that dictionary (every ``*_nationkey`` decodes
                  through ``dicts["n_name"]`` — the generator's invariant), so
                  aliasing the key to the dictionary's name orders
                  alphabetically without a join against ``nation``
    """
    kind: str
    lo: int | None = None
    hi: int | None = None
    invariant: bool = False
    dict_name: str | None = None


def _dict(size: int, name: str) -> Column:
    return Column("dict", 0, size - 1, invariant=True, dict_name=name)


def _key(hi_sf1: int) -> Column:
    """Scale-variant key column: 1..hi at SF=1 (grows with the data)."""
    return Column("int", 1, hi_sf1, invariant=False)


def _int(lo: int, hi: int) -> Column:
    return Column("int", lo, hi, invariant=True)


def _coded(lo: int, hi: int, dict_name: str) -> Column:
    """Plain int column whose values are codes of a foreign dictionary."""
    return Column("int", lo, hi, invariant=True, dict_name=dict_name)


@dataclasses.dataclass(frozen=True)
class TableDef:
    columns: dict[str, Column]
    rows: int                       # nominal SF=1 cardinality
    unique: tuple[str, ...]         # single-column unique keys


CATALOG: dict[str, TableDef] = {
    "region": TableDef({
        "r_regionkey": _int(0, 4),
        "r_name": _dict(5, "r_name"),
    }, rows=5, unique=("r_regionkey",)),
    "nation": TableDef({
        "n_nationkey": _coded(0, 24, "n_name"),
        "n_name": _dict(25, "n_name"),
        "n_regionkey": _int(0, 4),
    }, rows=25, unique=("n_nationkey",)),
    "supplier": TableDef({
        "s_suppkey": _key(10_000),
        "s_nationkey": _coded(0, 24, "n_name"),
        "s_acctbal": Column("float"),
        "s_comment": _dict(512, "s_comment"),
    }, rows=10_000, unique=("s_suppkey",)),
    "customer": TableDef({
        "c_custkey": _key(150_000),
        "c_nationkey": _coded(0, 24, "n_name"),
        "c_acctbal": Column("float"),
        "c_mktsegment": _dict(5, "c_mktsegment"),
        "c_phone_cc": _int(10, 34),
    }, rows=150_000, unique=("c_custkey",)),
    "part": TableDef({
        "p_partkey": _key(200_000),
        "p_name": _dict(2048, "p_name"),
        "p_brand": _dict(25, "p_brand"),
        "p_type": _dict(150, "p_type"),
        "p_size": _int(1, 50),
        "p_container": _dict(40, "p_container"),
        "p_mfgr": _dict(5, "p_mfgr"),
    }, rows=200_000, unique=("p_partkey",)),
    "partsupp": TableDef({
        "ps_partkey": _key(200_000),
        "ps_suppkey": _key(10_000),
        "ps_availqty": _int(1, 9_999),
        "ps_supplycost": Column("float"),
    }, rows=800_000, unique=()),
    "orders": TableDef({
        "o_orderkey": _key(1_500_000),
        "o_custkey": _key(150_000),
        "o_orderdate": _int(_DATE_LO, _ODATE_HI),
        "o_orderpriority": _dict(5, "o_orderpriority"),
        "o_shippriority": _int(0, 0),
        "o_comment": _dict(512, "o_comment"),
        "o_totalprice": Column("float"),
        "o_orderstatus": _dict(3, "o_orderstatus"),
    }, rows=1_500_000, unique=("o_orderkey",)),
    "lineitem": TableDef({
        "l_orderkey": _key(1_500_000),
        "l_partkey": _key(200_000),
        "l_suppkey": _key(10_000),
        "l_linenumber": _int(1, 7),
        "l_quantity": _int(1, 50),
        "l_extendedprice": Column("float"),
        "l_discount": Column("float"),
        "l_tax": Column("float"),
        "l_returnflag": _dict(3, "l_returnflag"),
        "l_linestatus": _dict(2, "l_linestatus"),
        "l_shipdate": _int(_DATE_LO, _SHIP_HI),
        "l_commitdate": _int(_DATE_LO, _ODATE_HI + 90),
        "l_receiptdate": _int(_DATE_LO, _RECEIPT_HI),
        "l_shipinstruct": _dict(4, "l_shipinstruct"),
        "l_shipmode": _dict(8, "l_shipmode"),
    }, rows=6_000_000, unique=()),
}

# paper §4.3 partitioning (mirrors backend.PARTITION_KEYS; None = replicated)
PARTITION: dict[str, str | None] = {
    "lineitem": "l_orderkey",
    "orders": "o_orderkey",
    "partsupp": "ps_partkey",
    "part": "p_partkey",
    "supplier": "s_suppkey",
    "customer": "c_custkey",
    "nation": None,
    "region": None,
}

# dictionaries whose code order IS alphabetical order (tpch.py builds them
# from sorted value lists), so ORDER BY can sort raw codes with no alpha_rank
ALPHA_CODED = frozenset({
    "r_name", "o_orderpriority", "o_orderstatus", "l_returnflag",
    "l_linestatus", "p_brand", "p_mfgr",
})

# column name -> owning table (TPC-H prefixes make every name unique)
_COLUMN_TABLE: dict[str, str] = {}
for _t, _d in CATALOG.items():
    for _c in _d.columns:
        _COLUMN_TABLE[_c] = _t


def table_of(name: str) -> TableDef:
    return CATALOG[name]


def column_table(col: str) -> str | None:
    """Owning base table of a physical column name, if any."""
    return _COLUMN_TABLE.get(col)

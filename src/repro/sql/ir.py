"""Plan-IR traversal and rebuild utilities for the optimizer passes.

`core/plan.py` nodes form an object-identity DAG (shared subtrees ARE the
same Python object, and scalar subqueries are referenced from *expressions*
via ``ScalarRef``).  Every rewrite here is identity-preserving: a node whose
children and expressions are unchanged is returned as-is, so untouched shared
subtrees stay shared and ``subplan_signatures``-based CSE remains valid.
"""
from __future__ import annotations

from typing import Callable, Iterable

from repro.core import plan as P

__all__ = ["expr_refs", "expr_cols", "rewrite_expr", "node_exprs",
           "scalar_deps", "clone_with", "rewrite", "walk", "conjuncts",
           "conjoin", "output_columns"]


# ------------------------------------------------------------- expressions

def expr_refs(e) -> Iterable:
    """Direct sub-expressions of ``e``."""
    if isinstance(e, P.BinOp):
        return (e.a, e.b)
    if isinstance(e, (P.NotE, P.Year)):
        return (e.a,)
    if isinstance(e, P.Cast):
        return (e.a,)
    if isinstance(e, P.Where):
        return (e.cond, e.a, e.b)
    if isinstance(e, P.InSet):
        return (e.a,)
    return ()


def expr_cols(e) -> set[str]:
    """Input column names an expression reads (``CodeLit`` reads none — it
    is a dictionary-resolved constant)."""
    out: set[str] = set()
    stack = [e]
    while stack:
        x = stack.pop()
        if isinstance(x, P.Col):
            out.add(x.name)
        elif isinstance(x, (P.AlphaRank, P.Like, P.StartsWith, P.EndsWith)):
            out.add(x.col)
        else:
            stack.extend(expr_refs(x))
    return out


def _hints_of(e) -> dict:
    return getattr(e, "_sql_hints", None) or {}


def _carry_hints(new, old):
    h = _hints_of(old)
    if h and new is not old:
        new._sql_hints = dict(h)
    return new


def rewrite_expr(e, col_fn: Callable | None = None,
                 node_map: dict | None = None):
    """Rebuild ``e``; ``col_fn(name)`` may substitute column references
    (return an Expr or a new name), ``node_map`` redirects ``ScalarRef``
    targets.  Unchanged sub-expressions are returned as-is."""
    def sub(x):
        return rewrite_expr(x, col_fn, node_map)

    if isinstance(e, P.Col) and col_fn is not None:
        r = col_fn(e.name)
        if r is None or r is e.name:
            return e
        return P.Col(r) if isinstance(r, str) else r
    if isinstance(e, P.BinOp):
        a, b = sub(e.a), sub(e.b)
        if a is e.a and b is e.b:
            return e
        return _carry_hints(P.BinOp(e.op, a, b), e)
    if isinstance(e, P.NotE):
        a = sub(e.a)
        return e if a is e.a else _carry_hints(P.NotE(a), e)
    if isinstance(e, P.Cast):
        a = sub(e.a)
        return e if a is e.a else P.Cast(a, e.dtype)
    if isinstance(e, P.Year):
        a = sub(e.a)
        return e if a is e.a else P.Year(a)
    if isinstance(e, P.Where):
        c, a, b = sub(e.cond), sub(e.a), sub(e.b)
        if c is e.cond and a is e.a and b is e.b:
            return e
        return P.Where(c, a, b)
    if isinstance(e, P.InSet):
        a = sub(e.a)
        return e if a is e.a else _carry_hints(P.InSet(a, e.values), e)
    if isinstance(e, (P.AlphaRank, P.Like, P.StartsWith, P.EndsWith)) \
            and col_fn is not None:
        r = col_fn(e.col)
        if r is not None and isinstance(r, str) and r != e.col:
            if isinstance(e, P.AlphaRank):
                return P.AlphaRank(r)
            if isinstance(e, P.Like):
                return _carry_hints(P.Like(r, e.subs), e)
            if isinstance(e, P.StartsWith):
                return _carry_hints(P.StartsWith(r, e.prefix), e)
            return _carry_hints(P.EndsWith(r, e.suffix), e)
        return e
    if isinstance(e, P.ScalarRef) and node_map is not None:
        tgt = node_map.get(id(e.node))
        if tgt is not None and tgt is not e.node:
            return P.ScalarRef(tgt, e.name)
        return e
    return e


# ------------------------------------------------------------------ nodes

def node_exprs(n) -> list:
    """All expressions a node carries (preds, computed cols, agg values)."""
    if isinstance(n, P.Filter):
        return [n.pred]
    if isinstance(n, P.WithCol):
        return list(n.exprs.values())
    if isinstance(n, P.ScalarResult):
        return list(n.exprs.values())
    if isinstance(n, (P.GroupBy, P.AggScalar)):
        return [v for _, _, v in n.aggs if isinstance(v, P.Expr)]
    return []


def scalar_deps(n) -> list:
    """Plan nodes referenced from ``n``'s expressions via ``ScalarRef``."""
    deps = []
    for e in node_exprs(n):
        stack = [e]
        while stack:
            x = stack.pop()
            if isinstance(x, P.ScalarRef):
                deps.append(x.node)
            else:
                stack.extend(expr_refs(x))
    return deps


def _sub_aggs(aggs, fix):
    out, changed = [], False
    for name, op, v in aggs:
        nv = fix(v) if isinstance(v, P.Expr) else v
        changed |= nv is not v
        out.append((name, op, nv))
    return tuple(out) if changed else aggs


def clone_with(n, children: tuple, node_map: dict | None = None):
    """Rebuild ``n`` with new children; expressions get their ``ScalarRef``
    targets redirected through ``node_map``.  Identity-preserving."""
    def fix(e):
        return rewrite_expr(e, None, node_map)

    if isinstance(n, P.Scan):
        return n
    if isinstance(n, P.Filter):
        pred = fix(n.pred)
        if children[0] is n.children[0] and pred is n.pred:
            return n
        return P.Filter(children[0], pred)
    if isinstance(n, P.Select):
        if children[0] is n.children[0]:
            return n
        return P.Select(children[0], n.names)
    if isinstance(n, P.WithCol):
        exprs = {k: fix(v) for k, v in n.exprs.items()}
        if children[0] is n.children[0] and \
                all(exprs[k] is n.exprs[k] for k in exprs):
            return n
        return P.WithCol(children[0], exprs)
    if isinstance(n, P.Rename):
        if children[0] is n.children[0]:
            return n
        return P.Rename(children[0], n.mapping)
    if isinstance(n, P.Join):
        if children == n.children:
            return n
        return P.Join(children[0], children[1], n.on, n.build_on, n.take)
    if isinstance(n, P.Semi):
        if children == n.children:
            return n
        return P.Semi(children[0], children[1], n.on, n.build_on)
    if isinstance(n, P.Anti):
        if children == n.children:
            return n
        return P.Anti(children[0], children[1], n.on, n.build_on)
    if isinstance(n, P.Left):
        if children == n.children:
            return n
        return P.Left(children[0], children[1], n.on, n.build_on, n.take,
                      n.defaults)
    if isinstance(n, P.GroupBy):
        aggs = _sub_aggs(n.aggs, fix)
        if children[0] is n.children[0] and aggs is n.aggs:
            return n
        return P.GroupBy(children[0], n.keys, aggs, n.exchange, n.final,
                         n.groups_hint)
    if isinstance(n, P.AggScalar):
        aggs = _sub_aggs(n.aggs, fix)
        if children[0] is n.children[0] and aggs is n.aggs:
            return n
        return P.AggScalar(children[0], aggs)
    if isinstance(n, P.Shuffle):
        if children[0] is n.children[0]:
            return n
        return P.Shuffle(children[0], n.key)
    if isinstance(n, P.Broadcast):
        if children[0] is n.children[0]:
            return n
        return P.Broadcast(children[0], n.p2p)
    if isinstance(n, P.Shrink):
        if children[0] is n.children[0]:
            return n
        return P.Shrink(children[0], n.cap)
    if isinstance(n, P.Finalize):
        if children[0] is n.children[0]:
            return n
        return P.Finalize(children[0], n.sort_keys, n.limit, n.replicated)
    if isinstance(n, P.ScalarResult):
        exprs = {k: fix(v) for k, v in n.exprs.items()}
        if all(exprs[k] is n.exprs[k] for k in exprs):
            return n
        return P.ScalarResult(exprs)
    raise TypeError(f"clone_with: unknown node {type(n).__name__}")


def rewrite(root, fn: Callable):
    """Bottom-up memoized rewrite.  ``fn(node)`` returns a replacement node
    (or the node itself); children and ``ScalarRef`` targets are already
    rewritten when ``fn`` sees the node.  Shared subtrees are visited once
    and stay shared."""
    memo: dict[int, object] = {}

    def go(n):
        hit = memo.get(id(n))
        if hit is not None:
            return hit
        for dep in scalar_deps(n):
            memo[id(dep)] = go(dep)
        new_children = tuple(go(c) for c in n.children)
        node_map = {i: v for i, v in memo.items()}
        rebuilt = clone_with(n, new_children, node_map)
        out = fn(rebuilt)
        memo[id(n)] = out
        return out

    return go(root)


def walk(root) -> list:
    """Post-order node list (children before parents), each node once."""
    seen: set[int] = set()
    out: list = []

    def go(n):
        if id(n) in seen:
            return
        seen.add(id(n))
        for dep in scalar_deps(n):
            go(dep)
        for c in n.children:
            go(c)
        out.append(n)

    go(root)
    return out


# ------------------------------------------------------------- predicates

def conjuncts(pred) -> list:
    """Split a predicate on top-level AND (``&``)."""
    if isinstance(pred, P.BinOp) and pred.op == "&":
        return conjuncts(pred.a) + conjuncts(pred.b)
    return [pred]


def conjoin(preds: list):
    out = preds[0]
    for p in preds[1:]:
        out = P.BinOp("&", out, p)
    return out


# ---------------------------------------------------------- output schema

def output_columns(n) -> list[str]:
    """Column names a node produces, in a deterministic order."""
    from . import catalog
    if isinstance(n, P.Scan):
        return list(catalog.table_of(n.table).columns)
    if isinstance(n, (P.Filter, P.Shuffle, P.Broadcast, P.Shrink)):
        return output_columns(n.children[0])
    if isinstance(n, P.Finalize):
        return output_columns(n.children[0])
    if isinstance(n, P.Select):
        return list(n.names)
    if isinstance(n, P.WithCol):
        base = output_columns(n.children[0])
        return base + [k for k in n.exprs if k not in base]
    if isinstance(n, P.Rename):
        return [n.mapping.get(c, c) for c in output_columns(n.children[0])]
    if isinstance(n, (P.Join, P.Left)):
        return output_columns(n.children[0]) + list(n.take)
    if isinstance(n, (P.Semi, P.Anti)):
        return output_columns(n.children[0])
    if isinstance(n, P.GroupBy):
        return list(n.keys) + [name for name, _, _ in n.aggs]
    raise TypeError(f"output_columns: unknown node {type(n).__name__}")

"""Typed AST for the SQL subset, plus a canonical printer.

Every node is a frozen dataclass with structural equality, so the hypothesis
round-trip property ``parse_expr(format_expr(e)) == e`` is a plain ``==``.
Collections are tuples (hashable, immutable).  The printer emits canonical
SQL the parser accepts — it is the other half of that round trip and the
basis of ``PlanTemplate.from_sql`` debugging output.
"""
from __future__ import annotations

import dataclasses as dc

__all__ = [
    "Expr", "Ident", "Number", "String", "DateL", "IntervalL", "ParamE",
    "Star", "Unary", "Binary", "Between", "InList", "InQuery", "ExistsE",
    "LikeE", "CaseE", "Func", "Scalar", "Hinted",
    "SelectItem", "Table", "Derived", "JoinStep", "FromItem",
    "Select", "Declare", "Query", "format_expr", "format_query",
]


class Expr:
    pass


@dc.dataclass(frozen=True)
class Ident(Expr):
    name: str
    qualifier: str | None = None
    # source position for binder errors; excluded from structural equality so
    # the parse/print round trip compares clean
    pos: tuple[int, int] | None = dc.field(default=None, compare=False,
                                           repr=False)


@dc.dataclass(frozen=True)
class Number(Expr):
    value: int | float


@dc.dataclass(frozen=True)
class String(Expr):
    value: str


@dc.dataclass(frozen=True)
class DateL(Expr):
    value: str                  # "YYYY-MM-DD"


@dc.dataclass(frozen=True)
class IntervalL(Expr):
    n: int
    unit: str                   # "day" | "month" | "year"


@dc.dataclass(frozen=True)
class ParamE(Expr):
    name: str


@dc.dataclass(frozen=True)
class Star(Expr):
    pass


@dc.dataclass(frozen=True)
class Unary(Expr):
    op: str                     # "-" | "not"
    a: Expr


@dc.dataclass(frozen=True)
class Binary(Expr):
    op: str                     # or and = <> < <= > >= + - * /
    a: Expr
    b: Expr


@dc.dataclass(frozen=True)
class Between(Expr):
    a: Expr
    lo: Expr
    hi: Expr
    negated: bool = False


@dc.dataclass(frozen=True)
class InList(Expr):
    a: Expr
    items: tuple[Expr, ...]
    negated: bool = False


@dc.dataclass(frozen=True)
class InQuery(Expr):
    a: Expr
    query: "Select"
    negated: bool = False


@dc.dataclass(frozen=True)
class ExistsE(Expr):
    query: "Select"
    negated: bool = False


@dc.dataclass(frozen=True)
class LikeE(Expr):
    a: Expr
    pattern: str
    negated: bool = False


@dc.dataclass(frozen=True)
class CaseE(Expr):
    whens: tuple[tuple[Expr, Expr], ...]
    default: Expr | None


@dc.dataclass(frozen=True)
class Func(Expr):
    name: str                   # lower-case: sum count min max avg year ...
    args: tuple[Expr, ...]
    distinct: bool = False


@dc.dataclass(frozen=True)
class Scalar(Expr):
    """A scalar subquery used as an expression."""
    query: "Select"


@dc.dataclass(frozen=True)
class Hinted(Expr):
    """A predicate carrying an optimizer hint (``expr /*+ shrink(N) */``).

    The hint asserts a data property the optimizer cannot prove (e.g. "at
    most N rows survive this predicate"); lowering turns it into a
    ``Shrink`` cap, and the runtime range checks still verify the claim.
    """
    a: Expr
    hints: tuple[tuple[str, int], ...]


# ---------------------------------------------------------------- queries

@dc.dataclass(frozen=True)
class SelectItem:
    expr: Expr
    alias: str | None = None


@dc.dataclass(frozen=True)
class Table:
    name: str
    alias: str | None = None
    pos: tuple[int, int] | None = dc.field(default=None, compare=False,
                                           repr=False)


@dc.dataclass(frozen=True)
class Derived:
    query: "Select"
    alias: str = ""


@dc.dataclass(frozen=True)
class JoinStep:
    kind: str                   # "inner" | "left"
    ref: "Table | Derived"
    on: Expr


@dc.dataclass(frozen=True)
class FromItem:
    ref: "Table | Derived"
    joins: tuple[JoinStep, ...] = ()


@dc.dataclass(frozen=True)
class Select:
    items: tuple[SelectItem, ...]
    frm: tuple[FromItem, ...]
    where: Expr | None = None
    group: tuple[Expr, ...] = ()
    having: Expr | None = None
    order: tuple[tuple[Expr, bool], ...] = ()       # (expr, ascending)
    limit: int | None = None
    hints: tuple[tuple[str, int], ...] = ()         # e.g. (("groups", 256),)


@dc.dataclass(frozen=True)
class Declare:
    name: str
    dtype: str                  # "int" | "float" | "date"
    lo: Expr
    hi: Expr
    default: Expr


@dc.dataclass(frozen=True)
class Query:
    body: Select
    ctes: tuple[tuple[str, Select], ...] = ()
    declares: tuple[Declare, ...] = ()


# ---------------------------------------------------------------- printer

# binding strength for parenthesization (higher binds tighter)
_PREC = {"or": 1, "and": 2, "=": 4, "<>": 4, "<": 4, "<=": 4, ">": 4,
         ">=": 4, "+": 5, "-": 5, "*": 6, "/": 6}
_NOT_PREC = 3


def _p(e: Expr, parent_prec: int) -> str:
    s, prec = _fmt(e)
    return f"({s})" if prec < parent_prec else s


def _fmt(e: Expr) -> tuple[str, int]:
    """Render ``e``; return (text, binding strength of its top operator)."""
    atom = 9
    if isinstance(e, Ident):
        text = f"{e.qualifier}.{e.name}" if e.qualifier else e.name
        return text, atom
    if isinstance(e, Number):
        return repr(e.value), atom
    if isinstance(e, String):
        return "'" + e.value.replace("'", "''") + "'", atom
    if isinstance(e, DateL):
        return f"date '{e.value}'", atom
    if isinstance(e, IntervalL):
        return f"interval '{e.n}' {e.unit}", atom
    if isinstance(e, ParamE):
        return f":{e.name}", atom
    if isinstance(e, Star):
        return "*", atom
    if isinstance(e, Unary):
        if e.op == "not":
            return f"not {_p(e.a, _NOT_PREC + 1)}", _NOT_PREC
        return f"-{_p(e.a, 7)}", 7
    if isinstance(e, Binary):
        prec = _PREC[e.op]
        # left-assoc: right operand of same precedence needs parens
        return (f"{_p(e.a, prec)} {e.op} {_p(e.b, prec + 1)}", prec)
    if isinstance(e, Between):
        neg = "not " if e.negated else ""
        return (f"{_p(e.a, 5)} {neg}between {_p(e.lo, 5)} and {_p(e.hi, 5)}",
                4)
    if isinstance(e, InList):
        neg = "not " if e.negated else ""
        items = ", ".join(_fmt(x)[0] for x in e.items)
        return f"{_p(e.a, 5)} {neg}in ({items})", 4
    if isinstance(e, InQuery):
        neg = "not " if e.negated else ""
        return f"{_p(e.a, 5)} {neg}in ({format_select(e.query)})", 4
    if isinstance(e, ExistsE):
        neg = "not " if e.negated else ""
        return f"{neg}exists ({format_select(e.query)})", 4
    if isinstance(e, LikeE):
        neg = "not " if e.negated else ""
        pat = e.pattern.replace("'", "''")
        return f"{_p(e.a, 5)} {neg}like '{pat}'", 4
    if isinstance(e, CaseE):
        parts = ["case"]
        for cond, val in e.whens:
            parts.append(f"when {_fmt(cond)[0]} then {_fmt(val)[0]}")
        if e.default is not None:
            parts.append(f"else {_fmt(e.default)[0]}")
        parts.append("end")
        return " ".join(parts), atom
    if isinstance(e, Func):
        if e.name == "count" and e.args == (Star(),):
            return "count(*)", atom
        d = "distinct " if e.distinct else ""
        args = ", ".join(_fmt(a)[0] for a in e.args)
        return f"{e.name}({d}{args})", atom
    if isinstance(e, Scalar):
        return f"({format_select(e.query)})", atom
    if isinstance(e, Hinted):
        s, prec = _fmt(e.a)
        hints = " ".join(f"/*+ {k}({n}) */" for k, n in e.hints)
        return f"{s} {hints}", prec
    raise TypeError(f"cannot format {type(e).__name__}")


def format_expr(e: Expr) -> str:
    return _fmt(e)[0]


def format_select(s: Select) -> str:
    parts = ["select"]
    for kind, n in s.hints:
        parts.append(f"/*+ {kind}({n}) */")
    cols = []
    for it in s.items:
        cols.append(format_expr(it.expr)
                    + (f" as {it.alias}" if it.alias else ""))
    parts.append(", ".join(cols))
    frm = []
    for item in s.frm:
        text = _fmt_ref(item.ref)
        for j in item.joins:
            kw = "left join" if j.kind == "left" else "join"
            text += f" {kw} {_fmt_ref(j.ref)} on {format_expr(j.on)}"
        frm.append(text)
    parts.append("from " + ", ".join(frm))
    if s.where is not None:
        parts.append("where " + format_expr(s.where))
    if s.group:
        parts.append("group by " + ", ".join(format_expr(g) for g in s.group))
    if s.having is not None:
        parts.append("having " + format_expr(s.having))
    if s.order:
        parts.append("order by " + ", ".join(
            format_expr(e) + ("" if asc else " desc") for e, asc in s.order))
    if s.limit is not None:
        parts.append(f"limit {s.limit}")
    return " ".join(parts)


def _fmt_ref(ref: "Table | Derived") -> str:
    if isinstance(ref, Table):
        return ref.name + (f" as {ref.alias}" if ref.alias else "")
    return f"({format_select(ref.query)}) as {ref.alias}"


def format_query(q: Query) -> str:
    lines = []
    for d in q.declares:
        lines.append(f"declare {d.name} {d.dtype} default "
                     f"{format_expr(d.default)} in "
                     f"({format_expr(d.lo)}, {format_expr(d.hi)});")
    if q.ctes:
        ctes = ",\n".join(f"{name} as ({format_select(sel)})"
                          for name, sel in q.ctes)
        lines.append(f"with {ctes}")
    lines.append(format_select(q.body))
    return "\n".join(lines)

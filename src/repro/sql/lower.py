"""AST -> naive logical plan lowering (the binder).

Produces a *correct but exchange-free* plan DAG from a parsed query: every
join is a plain hash join taking all build columns, every ``GroupBy`` is
``exchange="local"``, every ``Finalize`` is non-replicated.  The optimizer
(:mod:`repro.sql.optimizer`) then sinks predicates, prunes columns, packs
group keys and places exchanges; lowering concentrates on *name resolution*
and *typing* against the static catalog.

Design points that matter downstream:

  * **CTEs lower once.**  ``WITH x AS (...)`` produces one plan node reused
    by every reference — the natural expression of the hand plans' shared
    sub-DAGs (Q2's ``j``, Q11's filtered partsupp, Q15's grouped partials),
    and what makes ``subplan_signatures``-based CSE mostly a no-op.
  * **Semi/anti stay relational.**  ``IN (SELECT ...)`` / ``EXISTS`` become
    ``Semi``/``Anti`` nodes immediately (never decorrelated joins), because
    the engine's membership joins are the cheap primitive.
  * **Functional-dependency key reduction.**  ``GROUP BY k, a, b`` where a
    unique-key join proves ``k -> a, b`` groups by ``k`` alone and recovers
    ``a``/``b`` as ``max`` aggregates (TPC-H Q3), matching the hand plans.
  * **Strings exist only against dictionary columns.**  A string literal
    binds as the dictionary *code* of the compared column
    (``P.CodeLit``); anything else is a type error at bind time.
"""
from __future__ import annotations

import dataclasses
import datetime

from repro.core import plan as P
from repro.core.table import days

from . import ast as A
from . import catalog as C
from .ir import output_columns
from .lexer import SqlError

__all__ = ["lower", "Rel"]

_AGG_FUNCS = ("sum", "count", "min", "max", "avg")

# Kind: (base, dict_name) where base is "int" | "float" | "dict"
_INT = ("int", None)
_FLOAT = ("float", None)
_BOOL = ("int", None)


def _pos(e) -> tuple:
    p = getattr(e, "pos", None)
    return p if p is not None else (None, None)


def _err(msg: str, e=None) -> SqlError:
    line, col = _pos(e) if e is not None else (None, None)
    return SqlError(msg, line, col)


@dataclasses.dataclass
class Rel:
    """A bound relation: plan node + name/type environment."""
    node: object
    cols: dict              # name -> (base, dict_name), insertion-ordered
    quals: dict             # alias -> frozenset of column names
    amb: set                # names dropped as ambiguous (join collisions)
    fds: dict               # col -> single join key that determines it
    uniq: set               # columns unique per row of this relation

    def child(self, node) -> "Rel":
        return dataclasses.replace(self, node=node)


class _Env:
    def __init__(self):
        self.ctes: dict[str, Rel] = {}
        self.params: dict[str, P.Param] = {}


# ------------------------------------------------------------- AST helpers

def _ast_conjuncts(e, hints=()) -> list:
    """Split on AND at the AST level, carrying predicate hints along.  A hint
    trailing an AND chain attaches to the chain's last conjunct."""
    if isinstance(e, A.Hinted):
        return _ast_conjuncts(e.a, tuple(hints) + tuple(e.hints))
    if isinstance(e, A.Binary) and e.op == "and":
        return _ast_conjuncts(e.a) + _ast_conjuncts(e.b, hints)
    return [(e, tuple(hints))]


def _a_children(e):
    if isinstance(e, A.Unary):
        return (e.a,)
    if isinstance(e, A.Binary):
        return (e.a, e.b)
    if isinstance(e, A.Between):
        return (e.a, e.lo, e.hi)
    if isinstance(e, (A.InList,)):
        return (e.a,) + tuple(e.items)
    if isinstance(e, (A.LikeE, A.Hinted)):
        return (e.a,)
    if isinstance(e, A.CaseE):
        out = []
        for c, v in e.whens:
            out += [c, v]
        if e.default is not None:
            out.append(e.default)
        return tuple(out)
    if isinstance(e, A.Func):
        return tuple(e.args)
    # InQuery / ExistsE / Scalar: do not descend into subqueries
    if isinstance(e, A.InQuery):
        return (e.a,)
    return ()


def _contains_agg(e) -> bool:
    if isinstance(e, A.Func) and e.name in _AGG_FUNCS:
        return True
    return any(_contains_agg(c) for c in _a_children(e))


def _find_aggs(e) -> list:
    """Top-most aggregate Func nodes inside ``e`` (no aggs nest in TPC-H)."""
    if isinstance(e, A.Func) and e.name in _AGG_FUNCS:
        for a in e.args:
            if _contains_agg(a):
                raise _err("nested aggregates are unsupported")
        return [e]
    out = []
    for c in _a_children(e):
        out += _find_aggs(c)
    return out


def _date_arith(d: A.DateL, iv: A.IntervalL, sign: int):
    try:
        dt = datetime.date.fromisoformat(d.value)
    except ValueError:
        raise _err(f"bad date literal {d.value!r}") from None
    if iv.unit == "day":
        dt = dt + datetime.timedelta(days=sign * iv.n)
    else:
        months = sign * iv.n * (12 if iv.unit == "year" else 1)
        m = dt.month - 1 + months
        y, m = dt.year + m // 12, m % 12 + 1
        try:
            dt = dt.replace(year=y, month=m)
        except ValueError:
            raise _err(f"date {d.value} {'+' if sign > 0 else '-'} interval "
                       f"'{iv.n}' {iv.unit}: day-of-month overflow") from None
    return P.Lit(days(dt.isoformat())), _INT


_FOLD = {"+": lambda a, b: a + b, "-": lambda a, b: a - b,
         "*": lambda a, b: a * b, "/": lambda a, b: a / b}


# --------------------------------------------------------------- the binder

class _Lower:
    def __init__(self):
        self.env = _Env()

    # ------------------------------------------------------- name resolution
    def resolve(self, ident: A.Ident, rel: Rel) -> str:
        name = ident.name
        if ident.qualifier is not None:
            names = rel.quals.get(ident.qualifier)
            if names is None:
                raise _err(f"unknown table alias {ident.qualifier!r}", ident)
            if name not in names:
                raise _err(f"column {name!r} is not in table "
                           f"{ident.qualifier!r}", ident)
        if name in rel.amb:
            raise _err(f"ambiguous column {name!r} (qualify or alias it "
                       f"before the join)", ident)
        if name not in rel.cols:
            raise _err(f"unknown column {name!r}", ident)
        return name

    # ---------------------------------------------------------- expressions
    def expr(self, e, rel: Rel, agg_sub: dict | None = None):
        """Lower an AST expression; returns ``(plan_expr, kind)``."""
        if isinstance(e, A.Hinted):            # hint already consumed upstream
            return self.expr(e.a, rel, agg_sub)
        if isinstance(e, A.Ident):
            name = self.resolve(e, rel)
            return P.Col(name), rel.cols[name]
        if isinstance(e, A.Number):
            return P.Lit(e.value), (_FLOAT if isinstance(e.value, float)
                                    else _INT)
        if isinstance(e, A.DateL):
            return P.Lit(days(e.value)), _INT
        if isinstance(e, A.IntervalL):
            raise _err("INTERVAL is only valid added to / subtracted from a "
                       "DATE literal", e)
        if isinstance(e, A.String):
            raise _err("string literal used outside a dictionary-column "
                       "comparison (=, <>, IN, LIKE)", e)
        if isinstance(e, A.ParamE):
            p = self.env.params.get(e.name)
            if p is None:
                raise _err(f"undeclared parameter :{e.name} (add a DECLARE)",
                           e)
            return p, (_FLOAT if p.dtype == "float64" else _INT)
        if isinstance(e, A.Star):
            raise _err("* is only valid inside COUNT(*)", e)
        if isinstance(e, A.Unary):
            if e.op == "not":
                x, _ = self.expr(e.a, rel, agg_sub)
                return P.NotE(x), _BOOL
            if isinstance(e.a, A.Number):
                v = -e.a.value
                return P.Lit(v), (_FLOAT if isinstance(v, float) else _INT)
            x, k = self.expr(e.a, rel, agg_sub)
            if k[0] == "dict":
                raise _err("arithmetic on a dictionary-encoded column", e.a)
            return P.BinOp("-", P.Lit(0), x), k
        if isinstance(e, A.Binary):
            return self.binary(e, rel, agg_sub)
        if isinstance(e, A.Between):
            lo = A.Binary(">=", e.a, e.lo)
            hi = A.Binary("<=", e.a, e.hi)
            x, _ = self.expr(A.Binary("and", lo, hi), rel, agg_sub)
            return (P.NotE(x) if e.negated else x), _BOOL
        if isinstance(e, A.InList):
            return self.in_list(e, rel, agg_sub)
        if isinstance(e, (A.InQuery, A.ExistsE)):
            raise _err("IN (SELECT ...) / EXISTS is only supported as a "
                       "top-level WHERE or HAVING conjunct", getattr(e, "a",
                                                                     None))
        if isinstance(e, A.LikeE):
            return self.like(e, rel)
        if isinstance(e, A.CaseE):
            if e.default is None:
                raise _err("CASE requires an ELSE branch (columns are "
                           "non-null)")
            out, kind = self.expr(e.default, rel, agg_sub)
            for cond, val in reversed(e.whens):
                cx, _ = self.expr(cond, rel, agg_sub)
                vx, vk = self.expr(val, rel, agg_sub)
                kind = vk if vk[0] == "float" or kind[0] == "float" else kind
                out = P.Where(cx, vx, out)
            return out, kind
        if isinstance(e, A.Func):
            return self.func(e, rel, agg_sub)
        if isinstance(e, A.Scalar):
            return self.scalar_subquery(e.query, rel)
        raise _err(f"cannot lower {type(e).__name__}")

    def binary(self, e: A.Binary, rel, agg_sub):
        op = e.op
        if op in ("or", "and"):
            a, _ = self.expr(e.a, rel, agg_sub)
            b, _ = self.expr(e.b, rel, agg_sub)
            return P.BinOp("|" if op == "or" else "&", a, b), _BOOL
        # date +/- interval folds host-side, calendar-aware
        if op in ("+", "-") and isinstance(e.a, A.DateL) \
                and isinstance(e.b, A.IntervalL):
            return _date_arith(e.a, e.b, 1 if op == "+" else -1)
        if op in ("=", "<>", "!=", "<", "<=", ">", ">="):
            pop = {"=": "==", "<>": "!=", "!=": "!="}.get(op, op)
            if isinstance(e.a, A.String) or isinstance(e.b, A.String):
                s = e.a if isinstance(e.a, A.String) else e.b
                o = e.b if isinstance(e.a, A.String) else e.a
                if op not in ("=", "<>"):
                    raise _err("dictionary columns support only = and <> "
                               "against string literals", o)
                ox, kind = self.expr(o, rel, agg_sub)
                if kind[0] != "dict":
                    raise _err("string literal compared to a non-dictionary "
                               "expression", o)
                return P.BinOp(pop, ox, P.CodeLit(kind[1], s.value)), _BOOL
            ax, ka = self.expr(e.a, rel, agg_sub)
            bx, kb = self.expr(e.b, rel, agg_sub)
            if (ka[0] == "dict") != (kb[0] == "dict") and \
                    not isinstance(bx, P.CodeLit) and \
                    not isinstance(ax, P.CodeLit):
                raise _err("comparison mixes a dictionary column with a "
                           "non-dictionary expression", e.a)
            return P.BinOp(pop, ax, bx), _BOOL
        if op in ("+", "-", "*", "/"):
            ax, ka = self.expr(e.a, rel, agg_sub)
            bx, kb = self.expr(e.b, rel, agg_sub)
            if ka[0] == "dict" or kb[0] == "dict":
                raise _err("arithmetic on a dictionary-encoded column", e.a)
            if isinstance(ax, P.Lit) and isinstance(bx, P.Lit):
                v = _FOLD[op](ax.value, bx.value)
                return P.Lit(v), (_FLOAT if isinstance(v, float) else _INT)
            kind = _FLOAT if (op == "/" or ka[0] == "float"
                              or kb[0] == "float") else _INT
            return P.BinOp(op, ax, bx), kind
        raise _err(f"unsupported operator {op!r}")

    def in_list(self, e: A.InList, rel, agg_sub):
        ax, kind = self.expr(e.a, rel, agg_sub)
        vals = []
        for item in e.items:
            if isinstance(item, A.String):
                if kind[0] != "dict":
                    raise _err("string IN-list against a non-dictionary "
                               "column", item)
                vals.append(P.CodeLit(kind[1], item.value))
            else:
                vx, _ = self.expr(item, rel, agg_sub)
                if not isinstance(vx, (P.Lit, P.CodeLit)):
                    raise _err("IN list items must be literals", item)
                vals.append(vx)
        out = P.InSet(ax, vals)
        return (P.NotE(out) if e.negated else out), _BOOL

    def like(self, e: A.LikeE, rel):
        if not isinstance(e.a, A.Ident):
            raise _err("LIKE requires a plain column on the left", e.a)
        name = self.resolve(e.a, rel)
        kind = rel.cols[name]
        if kind[0] != "dict":
            raise _err(f"LIKE on non-dictionary column {name!r}", e.a)
        pat = e.pattern
        if "%" not in pat:
            out = P.BinOp("==", P.Col(name), P.CodeLit(kind[1], pat))
        elif pat.startswith("%") and pat.endswith("%"):
            subs = tuple(s for s in pat.split("%") if s)
            if not subs:
                raise _err("LIKE pattern matches everything", e.a)
            out = P.Like(name, subs)
        elif pat.endswith("%") and "%" not in pat[:-1]:
            out = P.StartsWith(name, pat[:-1])
        elif pat.startswith("%") and "%" not in pat[1:]:
            out = P.EndsWith(name, pat[1:])
        else:
            raise _err(f"unsupported LIKE pattern {pat!r} (use %...%, "
                       f"prefix%, %suffix or an exact string)", e.a)
        return (P.NotE(out) if e.negated else out), _BOOL

    def func(self, e: A.Func, rel, agg_sub):
        if e.name in _AGG_FUNCS:
            if agg_sub is not None and e in agg_sub:
                return agg_sub[e]
            raise _err(f"aggregate {e.name}() outside GROUP BY / scalar "
                       f"select context")
        if e.name == "year":
            x, k = self.expr(e.args[0], rel, agg_sub)
            if k[0] != "int":
                raise _err("extract(year ...) needs a date expression")
            return P.Year(x), _INT
        if e.name == "code":
            if len(e.args) != 2 or not all(isinstance(a, A.String)
                                           for a in e.args):
                raise _err("code(dict, value) takes two string literals")
            dname, value = e.args[0].value, e.args[1].value
            owner = C.column_table(dname)
            if owner is None or \
                    C.CATALOG[owner].columns[dname].kind != "dict":
                raise _err(f"code(): unknown dictionary {dname!r}")
            return P.CodeLit(dname, value), _INT
        if e.name == "dbscale":
            if e.args:
                raise _err("dbscale() takes no arguments")
            return P.DbScale(), _FLOAT
        raise _err(f"unknown function {e.name!r}")

    # --------------------------------------------------- scalar subqueries
    def agg_kind(self, f: A.Func, rel: Rel):
        if f.name == "count":
            return _INT
        if f.name == "avg":
            return _FLOAT
        arg = f.args[0]
        _, kind = self.expr(arg, rel)
        return kind

    def scalar_subquery(self, sel: A.Select, outer_rel: Rel):
        if sel.group or sel.having or sel.order or sel.limit is not None:
            raise _err("scalar subquery must be a plain aggregate select")
        if len(sel.items) != 1:
            raise _err("scalar subquery must produce exactly one value")
        rel = self.from_clause(sel.frm)
        if sel.where is not None:
            rel = self.where_clause(rel, sel.where)
        item = sel.items[0].expr
        aggs = _find_aggs(item)
        if not aggs:
            raise _err("scalar subquery must aggregate (sum/min/max/avg/"
                       "count)")
        specs, sub = self._intern_scalar_aggs(aggs, rel)
        node = P.AggScalar(rel.node, tuple(specs))
        for f, (name, kind) in sub.items():
            sub[f] = (P.ScalarRef(node, name), kind)
        return self.expr(item, rel, agg_sub=sub)

    def _intern_scalar_aggs(self, aggs, rel):
        specs, sub = [], {}
        for i, f in enumerate(aggs):
            if f in sub:
                continue
            if f.distinct:
                raise _err("DISTINCT aggregates are unsupported in scalar "
                           "subqueries")
            name = f"__s{len(specs)}"
            if f.name == "count":
                specs.append((name, "count", None))
            else:
                vx, _ = self.expr(f.args[0], rel)
                specs.append((name, f.name, vx))
            sub[f] = (name, self.agg_kind(f, rel))
        return specs, sub

    # ------------------------------------------------------------- FROM
    def table_ref(self, ref) -> Rel:
        if isinstance(ref, A.Derived):
            sub = self.select_rel(ref.query)
            return Rel(sub.node, dict(sub.cols),
                       {ref.alias: frozenset(sub.cols)}, set(sub.amb),
                       dict(sub.fds), set(sub.uniq))
        name, alias = ref.name, ref.alias or ref.name
        base = self.env.ctes.get(name)
        if base is not None:
            return Rel(base.node, dict(base.cols),
                       {alias: frozenset(base.cols)}, set(base.amb),
                       dict(base.fds), set(base.uniq))
        td = C.CATALOG.get(name)
        if td is None:
            raise _err(f"unknown table {name!r}", ref)
        cols = {c: (cd.kind, cd.dict_name) for c, cd in td.columns.items()}
        return Rel(P.Scan(name), cols, {alias: frozenset(cols)}, set(), {},
                   set(td.unique))

    def _on_side(self, ident: A.Ident, left: Rel, right: Rel):
        if ident.qualifier is not None:
            if ident.qualifier in left.quals and \
                    ident.name in left.quals[ident.qualifier]:
                return "L", self.resolve(ident, left)
            if ident.qualifier in right.quals and \
                    ident.name in right.quals[ident.qualifier]:
                return "R", self.resolve(ident, right)
            raise _err(f"unknown qualified column "
                       f"{ident.qualifier}.{ident.name}", ident)
        in_l = ident.name in left.cols
        in_r = ident.name in right.cols
        if in_l and in_r:
            raise _err(f"ambiguous ON column {ident.name!r} (qualify it)",
                       ident)
        if in_l:
            return "L", self.resolve(ident, left)
        if in_r:
            return "R", self.resolve(ident, right)
        raise _err(f"unknown column {ident.name!r} in ON", ident)

    def join_step(self, left: Rel, step: A.JoinStep) -> Rel:
        right = self.table_ref(step.ref)
        pairs, residual = [], []
        for conj, hints in _ast_conjuncts(step.on):
            if hints:
                raise _err("hints are not valid inside ON")
            if isinstance(conj, A.Binary) and conj.op == "=" and \
                    isinstance(conj.a, A.Ident) and \
                    isinstance(conj.b, A.Ident):
                sa = self._on_side(conj.a, left, right)
                sb = self._on_side(conj.b, left, right)
                if {sa[0], sb[0]} == {"L", "R"}:
                    pc, bc = (sa[1], sb[1]) if sa[0] == "L" else \
                        (sb[1], sa[1])
                    pairs.append((pc, bc))
                    continue
            residual.append(conj)
        if not pairs:
            raise _err("JOIN ... ON needs at least one cross-side column "
                       "equality")
        on = pairs[0][0] if len(pairs) == 1 else tuple(p for p, _ in pairs)
        build_on = pairs[0][1] if len(pairs) == 1 else \
            tuple(b for _, b in pairs)
        bset = {b for _, b in pairs}

        take, amb = [], set(left.amb) | set(right.amb)
        for c in right.cols:
            if c in left.cols:
                if c in bset and any(pc == c for pc, bc in pairs if bc == c):
                    continue           # natural-key collision: probe side wins
                amb.add(c)
                continue
            take.append(c)

        cols = dict(left.cols)
        for c in take:
            cols[c] = right.cols[c]
        quals = dict(left.quals)
        quals.update(right.quals)

        build_unique = len(pairs) == 1 and pairs[0][1] in right.uniq
        fds = dict(left.fds)
        uniq = set(left.uniq) if build_unique else set()
        if build_unique:
            for c in take:
                fds[c] = pairs[0][0]

        if step.kind == "left":
            if residual:
                raise _err("LEFT JOIN supports only column equalities in ON")
            defaults = {c: (0.0 if right.cols[c][0] == "float" else 0)
                        for c in take}
            node = P.Left(left.node, right.node, on, build_on, tuple(take),
                          defaults)
        else:
            node = P.Join(left.node, right.node, on, build_on, tuple(take))
        rel = Rel(node, cols, quals, amb, fds, uniq)
        for conj in residual:
            pred, _ = self.expr(conj, rel)
            rel = rel.child(P.Filter(rel.node, pred))
        return rel

    def from_clause(self, frm) -> Rel:
        if len(frm) != 1:
            raise _err("comma joins are unsupported: use explicit "
                       "JOIN ... ON")
        rel = self.table_ref(frm[0].ref)
        for step in frm[0].joins:
            rel = self.join_step(rel, step)
        return rel

    # ------------------------------------------------------------- WHERE
    def where_clause(self, rel: Rel, where) -> Rel:
        for conj, hints in _ast_conjuncts(where):
            neg = False
            while isinstance(conj, A.Unary) and conj.op == "not" and \
                    isinstance(conj.a, (A.InQuery, A.ExistsE)):
                neg, conj = not neg, conj.a
            if isinstance(conj, A.InQuery):
                rel = self.semi_anti(rel, conj, conj.negated ^ neg)
            elif isinstance(conj, A.ExistsE):
                rel = self.exists(rel, conj, conj.negated ^ neg)
            else:
                pred, _ = self.expr(conj, rel)
                rel = rel.child(P.Filter(rel.node, pred))
            for hk, hn in hints:
                if hk != "shrink":
                    raise _err(f"hint {hk!r} is not valid on a predicate "
                               f"(only shrink(N))")
                rel = rel.child(P.Shrink(rel.node, hn))
        return rel

    def semi_anti(self, rel: Rel, e: A.InQuery, negated: bool) -> Rel:
        if not isinstance(e.a, A.Ident):
            raise _err("IN (SELECT ...) requires a plain column on the left",
                       e.a)
        pc = self.resolve(e.a, rel)
        sub = self.select_rel(e.query)
        if len(sub.cols) != 1:
            raise _err("IN subquery must produce exactly one column")
        bc = next(iter(sub.cols))
        cls = P.Anti if negated else P.Semi
        return rel.child(cls(rel.node, sub.node, pc, bc))

    def exists(self, rel: Rel, e: A.ExistsE, negated: bool) -> Rel:
        sel = e.query
        if sel.group or sel.having or sel.order or sel.limit is not None:
            raise _err("EXISTS subquery must be a plain filtered select")
        sub = self.from_clause(sel.frm)
        pairs, inner = [], []
        if sel.where is not None:
            for conj, hints in _ast_conjuncts(sel.where):
                if hints:
                    raise _err("hints are not valid inside EXISTS")
                if isinstance(conj, A.Binary) and conj.op == "=" and \
                        isinstance(conj.a, A.Ident) and \
                        isinstance(conj.b, A.Ident):
                    sides = []
                    for ident in (conj.a, conj.b):
                        if ident.name in sub.cols and (
                                ident.qualifier is None or
                                ident.qualifier in sub.quals):
                            sides.append(("I", self.resolve(ident, sub)))
                        elif ident.name in rel.cols:
                            sides.append(("O", self.resolve(ident, rel)))
                        else:
                            sides.append(("?", ident.name))
                    if {sides[0][0], sides[1][0]} == {"I", "O"}:
                        oc, ic = (sides[0][1], sides[1][1]) \
                            if sides[0][0] == "O" else \
                            (sides[1][1], sides[0][1])
                        pairs.append((oc, ic))
                        continue
                inner.append(conj)
        if not pairs:
            raise _err("EXISTS subquery must correlate on at least one "
                       "outer = inner column equality")
        for conj in inner:
            pred, _ = self.expr(conj, sub)
            sub = sub.child(P.Filter(sub.node, pred))
        on = pairs[0][0] if len(pairs) == 1 else tuple(p for p, _ in pairs)
        build_on = pairs[0][1] if len(pairs) == 1 else \
            tuple(b for _, b in pairs)
        cls = P.Anti if negated else P.Semi
        return rel.child(cls(rel.node, sub.node, on, build_on))

    # ---------------------------------------------------------- GROUP BY
    def group_clause(self, rel: Rel, sel: A.Select) -> Rel:
        alias_map = {it.alias: it.expr for it in sel.items if it.alias}
        pre, keys, key_kinds = {}, [], {}
        for g in sel.group:
            if not isinstance(g, A.Ident):
                raise _err("GROUP BY must list column names or select "
                           "aliases")
            if g.qualifier is None and g.name in alias_map and \
                    g.name not in rel.cols:
                src = alias_map[g.name]
                if isinstance(src, A.Ident):
                    keys.append(self.resolve(src, rel))
                else:
                    px, kind = self.expr(src, rel)
                    pre[g.name] = px
                    key_kinds[g.name] = kind
                    keys.append(g.name)
            else:
                keys.append(self.resolve(g, rel))
        if len(set(keys)) != len(keys):
            raise _err("duplicate GROUP BY key")

        node = rel.node
        if pre:
            node = P.WithCol(node, pre)
        work = dataclasses.replace(rel, node=node,
                                   cols={**rel.cols, **key_kinds})

        # collect aggregates from items + having, interned structurally
        agg_nodes: list[A.Func] = []
        for it in sel.items:
            agg_nodes += _find_aggs(it.expr)
        if sel.having is not None:
            agg_nodes += _find_aggs(sel.having)
        distinct = [f for f in agg_nodes if f.distinct]

        # functional-dependency key reduction (Q3): one key determines the
        # rest via unique-build joins -> group on it alone, recover the rest
        recovery = []
        if len(keys) > 1 and not distinct:
            for k in keys:
                others = [k2 for k2 in keys if k2 != k]
                if all(work.fds.get(k2) == k for k2 in others):
                    recovery = others
                    keys = [k]
                    break

        specs, sub = [], {}
        names_used = set(keys) | set(recovery)

        def fresh(base):
            if base not in names_used:
                return base
            i = 0
            while f"{base}_{i}" in names_used:
                i += 1
            return f"{base}_{i}"

        if distinct:
            if len(agg_nodes) != 1 or agg_nodes[0].name != "count":
                raise _err("COUNT(DISTINCT col) cannot mix with other "
                           "aggregates")
            f = agg_nodes[0]
            if not isinstance(f.args[0], A.Ident):
                raise _err("COUNT(DISTINCT ...) requires a plain column")
            dcol = self.resolve(f.args[0], work)
            inner = P.GroupBy(node, tuple(keys) + (dcol,),
                              (("__d", "count", None),), "local", False,
                              None)
            name = self._agg_name(sel, f, fresh)
            specs.append((name, "count", None))
            sub[f] = (P.Col(name), _INT)
            node = inner
        else:
            for f in agg_nodes:
                if f in sub:
                    continue
                name = self._agg_name(sel, f, fresh)
                names_used.add(name)
                if f.name == "count":
                    specs.append((name, "count", None))
                else:
                    vx, _ = self.expr(f.args[0], work)
                    specs.append((name, f.name, vx))
                sub[f] = (P.Col(name), self.agg_kind(f, work))
        for k2 in recovery:
            specs.append((k2, "max", k2))

        groups_hint = None
        for hk, hn in sel.hints:
            if hk == "groups":
                groups_hint = hn
        gb = P.GroupBy(node, tuple(keys), tuple(specs), "local", False,
                       groups_hint)

        cols = {}
        for k in keys:
            cols[k] = key_kinds.get(k) or work.cols[k]
        for name, op, v in gb.aggs:
            if name in recovery:
                cols[name] = work.cols[name]
            else:
                f = next(f for f, (cx, _) in sub.items()
                         if isinstance(cx, P.Col) and cx.name == name)
                cols[name] = sub[f][1]
        out = Rel(gb, cols, {}, set(), {},
                  set(keys) if len(keys) == 1 else set())

        if sel.having is not None:
            for conj, hints in _ast_conjuncts(sel.having):
                if isinstance(conj, (A.InQuery, A.ExistsE)):
                    raise _err("IN/EXISTS subqueries are not supported in "
                               "HAVING")
                pred, _ = self.expr(conj, out, agg_sub=sub)
                out = out.child(P.Filter(out.node, pred))
                for hk, hn in hints:
                    if hk != "shrink":
                        raise _err(f"hint {hk!r} is not valid on a HAVING "
                                   f"predicate")
                    out = out.child(P.Shrink(out.node, hn))
        return self.apply_items(out, sel.items, agg_sub=sub)

    @staticmethod
    def _agg_name(sel: A.Select, f: A.Func, fresh) -> str:
        for it in sel.items:
            if it.expr == f and it.alias:
                return fresh(it.alias)
        return fresh("__a0")

    # --------------------------------------------------------- select items
    def apply_items(self, rel: Rel, items, agg_sub=None) -> Rel:
        renames, withcols, kinds, names_out = {}, {}, {}, []
        for it in items:
            e = it.expr
            if isinstance(e, A.Ident):
                nm = self.resolve(e, rel)
                out = it.alias or nm
                if out != nm:
                    if nm in renames and renames[nm] != out:
                        raise _err(f"column {nm!r} selected under two "
                                   f"aliases", e)
                    renames[nm] = out
                kinds[out] = rel.cols[nm]
            elif agg_sub is not None and isinstance(e, A.Func) \
                    and e in agg_sub:
                cx, kind = agg_sub[e]
                nm = cx.name
                out = it.alias or nm
                if out != nm:
                    renames[nm] = out
                kinds[out] = kind
            elif agg_sub is not None and it.alias and it.alias in rel.cols:
                # computed GROUP BY key (e.g. year(...) as y): group_clause
                # already materialized it pre-aggregation under this alias
                out = it.alias
                kinds[out] = rel.cols[out]
            else:
                if not it.alias:
                    raise _err("computed select item needs AS <alias>")
                px, kind = self.expr(e, rel, agg_sub)
                out = it.alias
                withcols[out] = px
                kinds[out] = kind
            if out in names_out:
                raise _err(f"duplicate output column {out!r}")
            names_out.append(out)

        node = rel.node
        if withcols:
            node = P.WithCol(node, withcols)
        if renames:
            clash = set(renames.values()) & (set(rel.cols) |
                                             set(withcols)) - set(renames)
            if clash:
                raise _err(f"alias collides with an existing column: "
                           f"{sorted(clash)}")
            node = P.Rename(node, renames)
        if output_columns(node) != names_out:
            node = P.Select(node, names_out)
        return Rel(node, {n: kinds[n] for n in names_out}, {}, set(), {},
                   rel.uniq & set(names_out))

    # ----------------------------------------------------------- selects
    def select_rel(self, sel: A.Select, top: bool = False):
        rel = self.from_clause(sel.frm)
        if sel.where is not None:
            rel = self.where_clause(rel, sel.where)
        has_agg = any(_contains_agg(it.expr) for it in sel.items) or (
            sel.having is not None)
        if sel.group:
            rel = self.group_clause(rel, sel)
        elif has_agg:
            if not top:
                raise _err("an aggregate select without GROUP BY is only "
                           "valid as the outermost query or a scalar "
                           "subquery")
            return self.scalar_top(rel, sel)
        else:
            if sel.having is not None:
                raise _err("HAVING requires GROUP BY")
            rel = self.apply_items(rel, sel.items)
        for hk, hn in sel.hints:
            if hk == "shrink":
                rel = rel.child(P.Shrink(rel.node, hn))
            elif hk == "groups" and not sel.group:
                raise _err("groups(N) hint requires GROUP BY")
        if not top and (sel.order or sel.limit is not None):
            raise _err("ORDER BY / LIMIT are only supported in the "
                       "outermost SELECT")
        if not top:
            return rel
        return self.finalize(rel, sel)

    def scalar_top(self, rel: Rel, sel: A.Select):
        if sel.order or sel.limit is not None or sel.having is not None:
            raise _err("a scalar aggregate select takes no HAVING/ORDER/"
                       "LIMIT")
        agg_nodes = []
        for it in sel.items:
            if not it.alias:
                raise _err("scalar select items need AS <alias>")
            agg_nodes += _find_aggs(it.expr)
        specs, sub = self._intern_scalar_aggs(agg_nodes, rel)
        node = P.AggScalar(rel.node, tuple(specs))
        for f, (name, kind) in list(sub.items()):
            sub[f] = (P.ScalarRef(node, name), kind)
        exprs = {}
        for it in sel.items:
            px, _ = self.expr(it.expr, rel, agg_sub=sub)
            exprs[it.alias] = px
        return P.ScalarResult(exprs)

    def finalize(self, rel: Rel, sel: A.Select):
        node = rel.node
        sort_keys = []
        ranks = {}
        out_names = list(rel.cols)
        for oe, asc in sel.order:
            if not isinstance(oe, A.Ident) or oe.qualifier is not None:
                raise _err("ORDER BY must reference a select column or "
                           "alias")
            if oe.name not in rel.cols:
                raise _err(f"ORDER BY column {oe.name!r} is not in the "
                           f"select list", oe)
            kind = rel.cols[oe.name]
            # alpha-rank any column ordered under a dictionary's own name
            # whose codes are not already alphabetical: true dict columns,
            # and int columns carrying dict codes (e.g. ``s_nationkey as
            # n_name`` — no nation join, no extra sort).  A dict column
            # renamed AWAY from its dictionary is an error; a code-carrying
            # int under its own name just sorts by raw code.
            if kind[1] is not None and kind[1] not in C.ALPHA_CODED \
                    and oe.name == kind[1]:
                rk = f"__rank_{oe.name}"
                ranks[rk] = P.AlphaRank(oe.name)
                sort_keys.append((rk, asc))
            elif kind[0] == "dict" and kind[1] not in C.ALPHA_CODED:
                raise _err(f"cannot ORDER BY renamed dictionary column "
                           f"{oe.name!r} (alpha rank needs the "
                           f"dictionary name)", oe)
            else:
                sort_keys.append((oe.name, asc))
        if ranks:
            node = P.WithCol(node, ranks)
            out_names += list(ranks)
            node = P.Select(node, out_names)
        return P.Finalize(node, tuple(sort_keys) if sort_keys else None,
                          sel.limit, False)

    # ------------------------------------------------------------ queries
    def const(self, e) -> object:
        empty = Rel(None, {}, {}, set(), {}, set())
        x, _ = self.expr(e, empty)
        if not isinstance(x, P.Lit):
            raise _err("DECLARE bounds must be literal expressions")
        return x.value

    def query(self, q: A.Query):
        for d in q.declares:
            if d.name in self.env.params:
                raise _err(f"duplicate DECLARE {d.name}")
            lo, hi, dv = self.const(d.lo), self.const(d.hi), \
                self.const(d.default)
            dtype = "float64" if d.dtype == "float" else "int64"
            try:
                self.env.params[d.name] = P.Param(d.name, lo=lo, hi=hi,
                                                  default=dv, dtype=dtype)
            except ValueError as ex:
                raise _err(f"bad DECLARE {d.name}: {ex}") from None
        for name, sel in q.ctes:
            if name in self.env.ctes or name in C.CATALOG:
                raise _err(f"CTE {name!r} shadows an existing table")
            self.env.ctes[name] = self.select_rel(sel)
        return self.select_rel(q.body, top=True)


def lower(q: A.Query):
    """Lower a parsed query to a naive plan root (Finalize/ScalarResult)."""
    return _Lower().query(q)

"""TPC-H Q9-Q15 as lazy logical plans (builder API; see queries/__init__.py)."""
from repro.core.plan import (alpha_rank, col, db_scale, isin, like, result,
                             scan, scode, starts_with, where, year)
from repro.core.table import days
from .q01_08 import _disc

__all__ = ["q9", "q10", "q11", "q12", "q13", "q14", "q15"]


def q9():
    """Product type profit.  1 shuffle (lineitem->partkey) + 2 broadcasts."""
    p = scan("part").filter(like("p_name", "green"))
    pb = p.select("p_partkey").broadcast()                               # b1
    sb = scan("supplier").select("s_suppkey", "s_nationkey").broadcast()  # b2
    l = scan("lineitem").join(scan("orders"), "l_orderkey", "o_orderkey",
                              ["o_orderdate"])                           # co-partitioned
    l = l.semi(pb, "l_partkey", "p_partkey")
    ls = l.select("l_partkey", "l_suppkey", "l_quantity", "l_extendedprice",
                  "l_discount", "o_orderdate").shuffle("l_partkey")      # s1
    j = ls.join(scan("partsupp"), ("l_partkey", "l_suppkey"),
                ("ps_partkey", "ps_suppkey"), ["ps_supplycost"])         # partkey-local
    j = j.join(sb, "l_suppkey", "s_suppkey", ["s_nationkey"])
    j = j.with_col(o_year=year(col("o_orderdate")))
    j = j.with_col(grp=col("s_nationkey") * 16 + (col("o_year") - 1992))
    g = j.group_by(["grp"], [
        ("n_name", "max", "s_nationkey"),
        ("o_year", "max", "o_year"),
        ("sum_profit", "sum",
         _disc - col("ps_supplycost") * col("l_quantity")),
    ], exchange="gather", final=True)
    g = g.with_col(n_rank=alpha_rank("n_name"))
    return g.select("n_name", "n_rank", "o_year", "sum_profit") \
        .finalize(sort_keys=[("n_rank", True), ("o_year", False)],
                  replicated=True)


def q10():
    """Returned item reporting.  1 shuffle to customer partitioning."""
    o = scan("orders").filter((col("o_orderdate") >= days("1993-10-01")) &
                              (col("o_orderdate") < days("1994-01-01")))
    l = scan("lineitem").filter(col("l_returnflag") ==
                                scode("l_returnflag", "R"))
    j = l.join(o, "l_orderkey", "o_orderkey", ["o_custkey"])
    g = j.group_by(["o_custkey"], [("revenue", "sum", _disc)],
                   exchange="shuffle")                                   # s1
    j2 = g.join(scan("customer"), "o_custkey", "c_custkey",
                ["c_acctbal", "c_nationkey"])                            # custkey-local
    return j2.select("o_custkey", "revenue", "c_acctbal", "c_nationkey") \
        .finalize(sort_keys=[("revenue", False)], limit=20)


def q11():
    """Important stock identification.  1 broadcast (DE suppliers) + allreduce.

    Paper counts 1 shuffle + 1 broadcast; under §4.3 partsupp@ps_partkey the
    group-by is local, removing their shuffle (DESIGN.md deviation)."""
    s = scan("supplier").filter(col("s_nationkey") ==
                                scode("n_name", "GERMANY"))
    sb = s.select("s_suppkey").broadcast()                               # b1
    ps = scan("partsupp").semi(sb, "ps_suppkey", "s_suppkey")
    val = col("ps_supplycost") * col("ps_availqty")
    g = ps.group_by(["ps_partkey"], [("value", "sum", val)],
                    exchange="local")                                    # partkey-local
    tot = ps.agg_scalar([("t", "sum", val)])["t"]
    g = g.filter(col("value") > tot * (0.0001 / db_scale()))
    g = g.shrink(1 << 20)   # result rows bounded well below partkeys
    return g.finalize(sort_keys=[("value", False)])


def q12():
    """Shipping modes / order priority.  Fully co-partitioned: no exchange."""
    l = scan("lineitem").filter(
        isin(col("l_shipmode"), [scode("l_shipmode", "MAIL"),
                                 scode("l_shipmode", "SHIP")]) &
        (col("l_commitdate") < col("l_receiptdate")) &
        (col("l_shipdate") < col("l_commitdate")) &
        (col("l_receiptdate") >= days("1994-01-01")) &
        (col("l_receiptdate") < days("1995-01-01")))
    j = l.join(scan("orders"), "l_orderkey", "o_orderkey",
               ["o_orderpriority"])
    hi = isin(col("o_orderpriority"),
              [scode("o_orderpriority", "1-URGENT"),
               scode("o_orderpriority", "2-HIGH")])
    g = j.group_by(["l_shipmode"], [
        ("high_line_count", "sum", where(hi, 1, 0)),
        ("low_line_count", "sum", where(hi, 0, 1)),
    ], exchange="gather", final=True)
    g = g.with_col(m_rank=alpha_rank("l_shipmode"))
    return g.finalize(sort_keys=[("m_rank", True)], replicated=True)


def q13():
    """Customer distribution.  1 shuffle (orders -> custkey) + left join.

    ``groups_hint=256`` on the c_count histogram is a plan-author claim the
    planner cannot prove (orders-per-customer is data-dependent) — exactly
    the case the explicit hint remains for.  The claim buys a sortless
    group-by: the planner's method rule routes it through the hash-compaction
    dictionary (``kernels/hash_group``), and overflow re-executes if a
    customer ever exceeds it."""
    o = scan("orders").filter(~like("o_comment", "special", "requests"))
    go = o.group_by(["o_custkey"], [("c_count", "count", None)],
                    exchange="shuffle")                                  # s1
    lj = scan("customer").left(go, "c_custkey", "o_custkey",
                               ["c_count"], {"c_count": 0})              # custkey-local
    g = lj.group_by(["c_count"], [("custdist", "count", None)],
                    exchange="gather", final=True, groups_hint=256)
    return g.finalize(sort_keys=[("custdist", False), ("c_count", False)],
                      replicated=True)


def q14():
    """Promotion effect.  1 shuffle of the date-filtered lineitem slice."""
    l = scan("lineitem").filter((col("l_shipdate") >= days("1995-09-01")) &
                                (col("l_shipdate") < days("1995-10-01")))
    ls = l.select("l_partkey", "l_extendedprice",
                  "l_discount").shuffle("l_partkey")                     # s1
    j = ls.join(scan("part"), "l_partkey", "p_partkey", ["p_type"])
    s = j.agg_scalar([
        ("promo", "sum", where(starts_with("p_type", "PROMO"), _disc, 0.0)),
        ("total", "sum", _disc)])
    return result(promo_revenue=100.0 * s["promo"] / s["total"])


def q15():
    """Top supplier.  1 shuffle of per-supplier partials + allreduce max."""
    l = scan("lineitem").filter((col("l_shipdate") >= days("1996-01-01")) &
                                (col("l_shipdate") < days("1996-04-01")))
    g = l.group_by(["l_suppkey"], [("total_revenue", "sum", _disc)],
                   exchange="shuffle")                                   # s1
    mx = g.agg_scalar([("mx", "max", "total_revenue")])["mx"]
    top = g.filter(col("total_revenue") >= mx * (1 - 1e-12))
    top = top.shrink(1024)               # max-revenue ties are rare
    j = top.join(scan("supplier"), "l_suppkey", "s_suppkey",
                 ["s_nationkey"])                                        # suppkey-local
    return j.finalize(sort_keys=[("l_suppkey", True)])

"""TPC-H Q9-Q15 tensor plans."""
from repro.core.table import days
from .q01_08 import _disc, _in

__all__ = ["q9", "q10", "q11", "q12", "q13", "q14", "q15"]


def q9(ctx):
    """Product type profit.  1 shuffle (lineitem->partkey) + 2 broadcasts."""
    p = ctx.scan("part")
    p = ctx.filter(p, ctx.like(p, "p_name", "green"))
    pb = ctx.broadcast(ctx.select(p, "p_partkey"))                       # b1
    s = ctx.scan("supplier")
    sb = ctx.broadcast(ctx.select(s, "s_suppkey", "s_nationkey"))        # b2
    l = ctx.scan("lineitem")
    l = ctx.join(l, ctx.scan("orders"), "l_orderkey", "o_orderkey",
                 ["o_orderdate"])                                        # co-partitioned
    l = ctx.semi(l, pb, "l_partkey", "p_partkey")
    ls = ctx.shuffle(ctx.select(l, "l_partkey", "l_suppkey", "l_quantity",
                                "l_extendedprice", "l_discount", "o_orderdate"),
                     "l_partkey")                                        # s1
    j = ctx.join(ls, ctx.scan("partsupp"), ("l_partkey", "l_suppkey"),
                 ("ps_partkey", "ps_suppkey"), ["ps_supplycost"])        # partkey-local
    j = ctx.join(j, sb, "l_suppkey", "s_suppkey", ["s_nationkey"])
    j = ctx.with_col(j, o_year=lambda t: ctx.year(t, "o_orderdate"))
    j = ctx.with_col(j, grp=lambda t: t["s_nationkey"] * 16 + (t["o_year"] - 1992))
    g = ctx.group_by(j, ["grp"], [
        ("n_name", "max", "s_nationkey"),
        ("o_year", "max", "o_year"),
        ("sum_profit", "sum", lambda t: _disc(t) -
         t["ps_supplycost"] * t["l_quantity"]),
    ], exchange="gather", final=True, groups_hint=512,
        key_bits=[9])   # grp = nationkey*16 + (year-1992) < 25*16 = 400
    g = ctx.with_col(g, n_rank=lambda t: ctx.alpha_rank(t, "n_name"))
    return ctx.finalize(ctx.select(g, "n_name", "n_rank", "o_year", "sum_profit"),
                        sort_keys=[("n_rank", True), ("o_year", False)],
                        replicated=True)


def q10(ctx):
    """Returned item reporting.  1 shuffle to customer partitioning."""
    o = ctx.scan("orders")
    o = ctx.filter(o, (o["o_orderdate"] >= days("1993-10-01")) &
                   (o["o_orderdate"] < days("1994-01-01")))
    l = ctx.scan("lineitem")
    l = ctx.filter(l, ctx.eq(l, "l_returnflag", "R"))
    j = ctx.join(l, o, "l_orderkey", "o_orderkey", ["o_custkey"])
    g = ctx.group_by(j, ["o_custkey"], [("revenue", "sum", _disc)],
                     exchange="shuffle")                                 # s1
    j2 = ctx.join(g, ctx.scan("customer"), "o_custkey", "c_custkey",
                  ["c_acctbal", "c_nationkey"])                          # custkey-local
    return ctx.finalize(ctx.select(j2, "o_custkey", "revenue", "c_acctbal",
                                   "c_nationkey"),
                        sort_keys=[("revenue", False)], limit=20)


def q11(ctx):
    """Important stock identification.  1 broadcast (DE suppliers) + allreduce.

    Paper counts 1 shuffle + 1 broadcast; under §4.3 partsupp@ps_partkey the
    group-by is local, removing their shuffle (DESIGN.md deviation)."""
    s = ctx.scan("supplier")
    s = ctx.filter(s, s["s_nationkey"] == ctx.db.code("n_name", "GERMANY"))
    sb = ctx.broadcast(ctx.select(s, "s_suppkey"))                       # b1
    ps = ctx.semi(ctx.scan("partsupp"), sb, "ps_suppkey", "s_suppkey")
    val = lambda t: t["ps_supplycost"] * t["ps_availqty"]
    g = ctx.group_by(ps, ["ps_partkey"], [("value", "sum", val)],
                     exchange="local")                                   # partkey-local
    tot = ctx.agg_scalar(ps, [("t", "sum", val)])["t"]
    g = ctx.filter(g, g["value"] > tot * (0.0001 / ctx.db.scale))
    g = ctx.shrink(g, 1 << 20)   # result rows bounded well below partkeys
    return ctx.finalize(g, sort_keys=[("value", False)])


def q12(ctx):
    """Shipping modes / order priority.  Fully co-partitioned: no exchange."""
    l = ctx.scan("lineitem")
    m = (ctx.isin(l, "l_shipmode", ["MAIL", "SHIP"]) &
         (l["l_commitdate"] < l["l_receiptdate"]) &
         (l["l_shipdate"] < l["l_commitdate"]) &
         (l["l_receiptdate"] >= days("1994-01-01")) &
         (l["l_receiptdate"] < days("1995-01-01")))
    l = ctx.filter(l, m)
    j = ctx.join(l, ctx.scan("orders"), "l_orderkey", "o_orderkey",
                 ["o_orderpriority"])
    hi = [ctx.db.code("o_orderpriority", "1-URGENT"),
          ctx.db.code("o_orderpriority", "2-HIGH")]
    g = ctx.group_by(j, ["l_shipmode"], [
        ("high_line_count", "sum",
         lambda t: ctx.xp.where(_in(t["o_orderpriority"], hi), 1, 0)),
        ("low_line_count", "sum",
         lambda t: ctx.xp.where(_in(t["o_orderpriority"], hi), 0, 1)),
    ], exchange="gather", final=True, groups_hint=16,
        key_bits=[ctx.dict_bits("l_shipmode")])
    g = ctx.with_col(g, m_rank=lambda t: ctx.alpha_rank(t, "l_shipmode"))
    return ctx.finalize(g, sort_keys=[("m_rank", True)], replicated=True)


def q13(ctx):
    """Customer distribution.  1 shuffle (orders -> custkey) + left join."""
    o = ctx.scan("orders")
    o = ctx.filter(o, ~ctx.like(o, "o_comment", "special", "requests"))
    go = ctx.group_by(o, ["o_custkey"], [("c_count", "count", None)],
                      exchange="shuffle")                                # s1
    lj = ctx.left(ctx.scan("customer"), go, "c_custkey", "o_custkey",
                  ["c_count"], {"c_count": 0})                           # custkey-local
    g = ctx.group_by(lj, ["c_count"], [("custdist", "count", None)],
                     exchange="gather", final=True, groups_hint=256)
    return ctx.finalize(g, sort_keys=[("custdist", False), ("c_count", False)],
                        replicated=True)


def q14(ctx):
    """Promotion effect.  1 shuffle of the date-filtered lineitem slice."""
    l = ctx.scan("lineitem")
    l = ctx.filter(l, (l["l_shipdate"] >= days("1995-09-01")) &
                   (l["l_shipdate"] < days("1995-10-01")))
    ls = ctx.shuffle(ctx.select(l, "l_partkey", "l_extendedprice", "l_discount"),
                     "l_partkey")                                        # s1
    j = ctx.join(ls, ctx.scan("part"), "l_partkey", "p_partkey", ["p_type"])
    promo = ctx.starts_with(j, "p_type", "PROMO")
    s = ctx.agg_scalar(j, [
        ("promo", "sum", lambda t: ctx.xp.where(promo, _disc(t), 0.0)),
        ("total", "sum", _disc)])
    return {"promo_revenue": 100.0 * s["promo"] / s["total"]}


def q15(ctx):
    """Top supplier.  1 shuffle of per-supplier partials + allreduce max."""
    l = ctx.scan("lineitem")
    l = ctx.filter(l, (l["l_shipdate"] >= days("1996-01-01")) &
                   (l["l_shipdate"] < days("1996-04-01")))
    g = ctx.group_by(l, ["l_suppkey"], [("total_revenue", "sum", _disc)],
                     exchange="shuffle")                                 # s1
    mx = ctx.agg_scalar(g, [("mx", "max", "total_revenue")])["mx"]
    top = ctx.filter(g, g["total_revenue"] >= mx * (1 - 1e-12))
    top = ctx.shrink(top, 1024)          # max-revenue ties are rare
    j = ctx.join(top, ctx.scan("supplier"), "l_suppkey", "s_suppkey",
                 ["s_nationkey"])                                        # suppkey-local
    return ctx.finalize(j, sort_keys=[("l_suppkey", True)])

"""TPC-H Q16-Q22 tensor plans."""
from repro.core.table import days
from .q01_08 import _disc, _in

__all__ = ["q16", "q17", "q18", "q19", "q20", "q21", "q22"]

_NTYPES = 150
_NSIZES = 51


def q16(ctx):
    """Parts/supplier relationship.  1 shuffle (group key) + 1 broadcast."""
    p = ctx.scan("part")
    keep = ((p["p_brand"] != ctx.db.code("p_brand", "Brand#45")) &
            ~ctx.starts_with(p, "p_type", "MEDIUM POLISHED") &
            _in(p["p_size"], [49, 14, 23, 45, 19, 3, 36, 9]))
    p = ctx.filter(p, keep)
    j = ctx.join(ctx.scan("partsupp"), p, "ps_partkey", "p_partkey",
                 ["p_brand", "p_type", "p_size"])                        # partkey-local
    s = ctx.scan("supplier")
    s = ctx.filter(s, ctx.like(s, "s_comment", "Customer", "Complaints"))
    sb = ctx.broadcast(ctx.select(s, "s_suppkey"))                       # b1
    j = ctx.anti(j, sb, "ps_suppkey", "s_suppkey")
    j = ctx.with_col(j, grp=lambda t: (t["p_brand"].astype(ctx.xp.int64) * _NTYPES
                                       + t["p_type"]) * _NSIZES + t["p_size"])
    js = ctx.shuffle(ctx.select(j, "grp", "ps_suppkey", "p_brand", "p_type",
                                "p_size"), "grp")                        # s1
    d = ctx.group_by(js, ["grp", "ps_suppkey"], [
        ("p_brand", "max", "p_brand"), ("p_type", "max", "p_type"),
        ("p_size", "max", "p_size")], exchange="local")                  # dedup
    g = ctx.group_by(d, ["grp"], [
        ("supplier_cnt", "count", None),
        ("p_brand", "max", "p_brand"), ("p_type", "max", "p_type"),
        ("p_size", "max", "p_size")], exchange="local")
    g = ctx.shrink(g, 1 << 18)   # <= brand x type x size domain (191k)
    g = ctx.with_col(g, t_rank=lambda t: ctx.alpha_rank(t, "p_type"))
    return ctx.finalize(
        ctx.select(g, "p_brand", "p_type", "t_rank", "p_size", "supplier_cnt"),
        sort_keys=[("supplier_cnt", False), ("p_brand", True),
                   ("t_rank", True), ("p_size", True)])


def q17(ctx):
    """Small-quantity-order revenue.  1 broadcast (part) + 1 shuffle."""
    p = ctx.scan("part")
    p = ctx.filter(p, (p["p_brand"] == ctx.db.code("p_brand", "Brand#23")) &
                   (p["p_container"] == ctx.db.code("p_container", "MED BOX")))
    pb = ctx.broadcast(ctx.select(p, "p_partkey"))                       # b1
    l = ctx.semi(ctx.scan("lineitem"), pb, "l_partkey", "p_partkey")
    ls = ctx.shuffle(ctx.select(l, "l_partkey", "l_quantity",
                                "l_extendedprice"), "l_partkey")         # s1
    avg = ctx.group_by(ls, ["l_partkey"], [("avg_qty", "avg", "l_quantity")],
                       exchange="local")
    j = ctx.join(ls, ctx.rename(avg, {"l_partkey": "pk"}), "l_partkey", "pk",
                 ["avg_qty"])
    j = ctx.filter(j, j["l_quantity"] < 0.2 * j["avg_qty"])
    s = ctx.agg_scalar(j, [("s", "sum", "l_extendedprice")])
    return {"avg_yearly": s["s"] / 7.0}


def q18(ctx):
    """Large volume customer.  1 broadcast of the tiny >300-qty order set."""
    l = ctx.scan("lineitem")
    gl = ctx.group_by(l, ["l_orderkey"], [("sum_qty", "sum", "l_quantity")],
                      exchange="local")                                  # orderkey-local
    big = ctx.filter(gl, gl["sum_qty"] > 300)
    j = ctx.join(big, ctx.scan("orders"), "l_orderkey", "o_orderkey",
                 ["o_custkey", "o_orderdate", "o_totalprice"])
    j = ctx.shrink(j, 1 << 14)     # >300-qty orders are ~0.006% of orders;
    jb = ctx.broadcast(j)          # b1 — overflow retriggers with 2x factor
    j2 = ctx.join(jb, ctx.scan("customer"), "o_custkey", "c_custkey", [])
    # probe is replicated, build is partitioned: each order lands on exactly
    # one device (its customer's shard) — globally exact, no dedup needed.
    return ctx.finalize(j2, sort_keys=[("o_totalprice", False),
                                       ("o_orderdate", True)], limit=100)


def q19(ctx):
    """Discounted revenue (the paper's Figure 4 example): 1 broadcast."""
    p = ctx.scan("part")
    b12 = ctx.db.code("p_brand", "Brand#12")
    b23 = ctx.db.code("p_brand", "Brand#23")
    b34 = ctx.db.code("p_brand", "Brand#34")
    c_sm = [ctx.db.code("p_container", c) for c in
            ("SM CASE", "SM BOX", "SM PACK", "SM PKG")]
    c_md = [ctx.db.code("p_container", c) for c in
            ("MED BAG", "MED BOX", "MED PKG", "MED PACK")]
    c_lg = [ctx.db.code("p_container", c) for c in
            ("LG CASE", "LG BOX", "LG PACK", "LG PKG")]
    keep = (((p["p_brand"] == b12) & _in(p["p_container"], c_sm) &
             (p["p_size"] >= 1) & (p["p_size"] <= 5)) |
            ((p["p_brand"] == b23) & _in(p["p_container"], c_md) &
             (p["p_size"] >= 1) & (p["p_size"] <= 10)) |
            ((p["p_brand"] == b34) & _in(p["p_container"], c_lg) &
             (p["p_size"] >= 1) & (p["p_size"] <= 15)))
    p = ctx.filter(p, keep)
    pb = ctx.broadcast(ctx.select(p, "p_partkey", "p_brand"))            # b1
    l = ctx.scan("lineitem")
    l = ctx.filter(l, ctx.eq(l, "l_shipinstruct", "DELIVER IN PERSON") &
                   ctx.isin(l, "l_shipmode", ["AIR", "AIR REG"]))
    j = ctx.join(l, pb, "l_partkey", "p_partkey", ["p_brand"])
    q = j["l_quantity"]
    ok = (((j["p_brand"] == b12) & (q >= 1) & (q <= 11)) |
          ((j["p_brand"] == b23) & (q >= 10) & (q <= 20)) |
          ((j["p_brand"] == b34) & (q >= 20) & (q <= 30)))
    j = ctx.filter(j, ok)
    s = ctx.agg_scalar(j, [("revenue", "sum", _disc)])
    return {"revenue": s["revenue"]}


def q20(ctx):
    """Potential part promotion.  1 shuffle + 2 broadcasts."""
    p = ctx.scan("part")
    p = ctx.filter(p, ctx.starts_with(p, "p_name", "forest"))
    pb = ctx.broadcast(ctx.select(p, "p_partkey"))                       # b1
    l = ctx.scan("lineitem")
    l = ctx.filter(l, (l["l_shipdate"] >= days("1994-01-01")) &
                   (l["l_shipdate"] < days("1995-01-01")))
    l = ctx.semi(l, pb, "l_partkey", "p_partkey")
    ls = ctx.shuffle(ctx.select(l, "l_partkey", "l_suppkey", "l_quantity"),
                     "l_partkey")                                        # s1
    g = ctx.group_by(ls, ["l_partkey", "l_suppkey"],
                     [("sq", "sum", "l_quantity")], exchange="local")
    ps = ctx.semi(ctx.scan("partsupp"), pb, "ps_partkey", "p_partkey")
    j = ctx.join(ps, g, ("ps_partkey", "ps_suppkey"),
                 ("l_partkey", "l_suppkey"), ["sq"])                     # partkey-local
    j = ctx.filter(j, j["ps_availqty"] > 0.5 * j["sq"])
    sk = ctx.group_by(j, ["ps_suppkey"], [("n", "count", None)],
                      exchange="local")
    skb = ctx.broadcast(ctx.select(sk, "ps_suppkey"))                    # b2
    s = ctx.semi(ctx.scan("supplier"), skb, "s_suppkey", "ps_suppkey")
    s = ctx.filter(s, s["s_nationkey"] == ctx.db.code("n_name", "CANADA"))
    s = ctx.shrink(s, 1 << 16)           # <= suppliers of one nation
    return ctx.finalize(ctx.select(s, "s_suppkey", "s_nationkey"),
                        sort_keys=[("s_suppkey", True)])


def q21(ctx):
    """Suppliers who kept orders waiting.  Exists/not-exists via per-order
    distinct-supplier counts (orderkey-local); 1 broadcast (SA suppliers)."""
    l = ctx.scan("lineitem")
    d_all = ctx.group_by(l, ["l_orderkey", "l_suppkey"], [("n", "count", None)],
                         exchange="local")
    g_all = ctx.group_by(d_all, ["l_orderkey"], [("nsupp", "count", None)],
                         exchange="local")
    late = ctx.filter(l, l["l_receiptdate"] > l["l_commitdate"])
    d_late = ctx.group_by(late, ["l_orderkey", "l_suppkey"],
                          [("n", "count", None)], exchange="local")
    g_late = ctx.group_by(d_late, ["l_orderkey"], [("nlate", "count", None)],
                          exchange="local")
    s = ctx.scan("supplier")
    s = ctx.filter(s, s["s_nationkey"] == ctx.db.code("n_name", "SAUDI ARABIA"))
    sb = ctx.broadcast(ctx.select(s, "s_suppkey"))                       # b1
    l1 = ctx.semi(late, sb, "l_suppkey", "s_suppkey")
    o = ctx.scan("orders")
    o = ctx.filter(o, ctx.eq(o, "o_orderstatus", "F"))
    l1 = ctx.semi(l1, o, "l_orderkey", "o_orderkey")
    l1 = _join_same_key(ctx, l1, g_all, "l_orderkey", ["nsupp"])
    l1 = _join_same_key(ctx, l1, g_late, "l_orderkey", ["nlate"])
    l1 = ctx.filter(l1, (l1["nsupp"] >= 2) & (l1["nlate"] == 1))
    g = ctx.group_by(l1, ["l_suppkey"], [("numwait", "count", None)],
                     exchange="gather", final=True, groups_hint=1 << 19)
    return ctx.finalize(g, sort_keys=[("numwait", False), ("l_suppkey", True)],
                        limit=100, replicated=True)


def _join_same_key(ctx, probe, build, key, take):
    """Join where probe and build share the key column name."""
    renamed = ctx.rename(build, {key: "__bk"})
    return ctx.join(probe, renamed, key, "__bk", take)


def q22(ctx):
    """Global sales opportunity.  1 shuffle (orders custkeys) + 2 allreduces."""
    codes = [13, 31, 23, 29, 30, 18, 17]
    c = ctx.scan("customer")
    cs = ctx.filter(c, _in(c["c_phone_cc"], codes))
    pos = ctx.filter(cs, cs["c_acctbal"] > 0.0)
    avg = ctx.agg_scalar(pos, [("a", "avg", "c_acctbal")])["a"]
    go = ctx.group_by(ctx.scan("orders"), ["o_custkey"],
                      [("n", "count", None)], exchange="shuffle")        # s1
    cs2 = ctx.filter(cs, cs["c_acctbal"] > avg)
    cs2 = ctx.anti(cs2, go, "c_custkey", "o_custkey")                    # custkey-local
    g = ctx.group_by(cs2, ["c_phone_cc"], [
        ("numcust", "count", None), ("totacctbal", "sum", "c_acctbal")],
        exchange="gather", final=True, groups_hint=40,
        key_bits=[6])   # c_phone_cc = nationkey + 10 < 35 < 2^6
    return ctx.finalize(g, sort_keys=[("c_phone_cc", True)], replicated=True)

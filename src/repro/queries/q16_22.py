"""TPC-H Q16-Q22 as lazy logical plans (builder API; see queries/__init__.py)."""
from repro.core.plan import (col, isin, like, result, scan, scode,
                             starts_with, alpha_rank)
from repro.core.table import days
from .q01_08 import _disc

__all__ = ["q16", "q17", "q18", "q19", "q20", "q21", "q22"]

# packing strides for Q16's composite group key (dictionary domain sizes;
# part of the key DEFINITION, not a planner hint — the planner derives the
# actual key width from column bounds)
_NTYPES = 150
_NSIZES = 51


def q16():
    """Parts/supplier relationship.  1 shuffle (group key) + 1 broadcast."""
    p = scan("part").filter(
        (col("p_brand") != scode("p_brand", "Brand#45")) &
        ~starts_with("p_type", "MEDIUM POLISHED") &
        isin(col("p_size"), [49, 14, 23, 45, 19, 3, 36, 9]))
    j = scan("partsupp").join(p, "ps_partkey", "p_partkey",
                              ["p_brand", "p_type", "p_size"])           # partkey-local
    s = scan("supplier").filter(like("s_comment", "Customer", "Complaints"))
    sb = s.select("s_suppkey").broadcast()                               # b1
    j = j.anti(sb, "ps_suppkey", "s_suppkey")
    j = j.with_col(grp=(col("p_brand").astype("int64") * _NTYPES +
                        col("p_type")) * _NSIZES + col("p_size"))
    js = j.select("grp", "ps_suppkey", "p_brand", "p_type",
                  "p_size").shuffle("grp")                               # s1
    d = js.group_by(["grp", "ps_suppkey"], [
        ("p_brand", "max", "p_brand"), ("p_type", "max", "p_type"),
        ("p_size", "max", "p_size")], exchange="local")                  # dedup
    g = d.group_by(["grp"], [
        ("supplier_cnt", "count", None),
        ("p_brand", "max", "p_brand"), ("p_type", "max", "p_type"),
        ("p_size", "max", "p_size")], exchange="local")
    g = g.shrink(1 << 18)   # <= brand x type x size domain (191k)
    g = g.with_col(t_rank=alpha_rank("p_type"))
    return g.select("p_brand", "p_type", "t_rank", "p_size",
                    "supplier_cnt") \
        .finalize(sort_keys=[("supplier_cnt", False), ("p_brand", True),
                             ("t_rank", True), ("p_size", True)])


def q17():
    """Small-quantity-order revenue.  1 broadcast (part) + 1 shuffle."""
    p = scan("part").filter(
        (col("p_brand") == scode("p_brand", "Brand#23")) &
        (col("p_container") == scode("p_container", "MED BOX")))
    pb = p.select("p_partkey").broadcast()                               # b1
    l = scan("lineitem").semi(pb, "l_partkey", "p_partkey")
    ls = l.select("l_partkey", "l_quantity",
                  "l_extendedprice").shuffle("l_partkey")                # s1
    avg = ls.group_by(["l_partkey"], [("avg_qty", "avg", "l_quantity")],
                      exchange="local")
    j = ls.join(avg.rename({"l_partkey": "pk"}), "l_partkey", "pk",
                ["avg_qty"])
    j = j.filter(col("l_quantity") < 0.2 * col("avg_qty"))
    s = j.agg_scalar([("s", "sum", "l_extendedprice")])
    return result(avg_yearly=s["s"] / 7.0)


def q18():
    """Large volume customer.  1 broadcast of the tiny >300-qty order set."""
    gl = scan("lineitem").group_by(
        ["l_orderkey"], [("sum_qty", "sum", "l_quantity")],
        exchange="local")                                                # orderkey-local
    big = gl.filter(col("sum_qty") > 300)
    j = big.join(scan("orders"), "l_orderkey", "o_orderkey",
                 ["o_custkey", "o_orderdate", "o_totalprice"])
    j = j.shrink(1 << 14)   # >300-qty orders are ~0.006% of orders;
    jb = j.broadcast()      # b1 — overflow retriggers with 2x factor
    j2 = jb.join(scan("customer"), "o_custkey", "c_custkey", [])
    # probe is replicated, build is partitioned: each order lands on exactly
    # one device (its customer's shard) — globally exact, no dedup needed.
    return j2.finalize(sort_keys=[("o_totalprice", False),
                                  ("o_orderdate", True)], limit=100)


def q19():
    """Discounted revenue (the paper's Figure 4 example): 1 broadcast."""
    b12 = scode("p_brand", "Brand#12")
    b23 = scode("p_brand", "Brand#23")
    b34 = scode("p_brand", "Brand#34")
    c_sm = [scode("p_container", c) for c in
            ("SM CASE", "SM BOX", "SM PACK", "SM PKG")]
    c_md = [scode("p_container", c) for c in
            ("MED BAG", "MED BOX", "MED PKG", "MED PACK")]
    c_lg = [scode("p_container", c) for c in
            ("LG CASE", "LG BOX", "LG PACK", "LG PKG")]
    p = scan("part").filter(
        ((col("p_brand") == b12) & isin(col("p_container"), c_sm) &
         (col("p_size") >= 1) & (col("p_size") <= 5)) |
        ((col("p_brand") == b23) & isin(col("p_container"), c_md) &
         (col("p_size") >= 1) & (col("p_size") <= 10)) |
        ((col("p_brand") == b34) & isin(col("p_container"), c_lg) &
         (col("p_size") >= 1) & (col("p_size") <= 15)))
    pb = p.select("p_partkey", "p_brand").broadcast()                    # b1
    l = scan("lineitem").filter(
        (col("l_shipinstruct") == scode("l_shipinstruct",
                                        "DELIVER IN PERSON")) &
        isin(col("l_shipmode"), [scode("l_shipmode", "AIR"),
                                 scode("l_shipmode", "AIR REG")]))
    j = l.join(pb, "l_partkey", "p_partkey", ["p_brand"])
    q = col("l_quantity")
    j = j.filter(((col("p_brand") == b12) & (q >= 1) & (q <= 11)) |
                 ((col("p_brand") == b23) & (q >= 10) & (q <= 20)) |
                 ((col("p_brand") == b34) & (q >= 20) & (q <= 30)))
    s = j.agg_scalar([("revenue", "sum", _disc)])
    return result(revenue=s["revenue"])


def q20():
    """Potential part promotion.  1 shuffle + 2 broadcasts."""
    p = scan("part").filter(starts_with("p_name", "forest"))
    pb = p.select("p_partkey").broadcast()                               # b1
    l = scan("lineitem").filter((col("l_shipdate") >= days("1994-01-01")) &
                                (col("l_shipdate") < days("1995-01-01")))
    l = l.semi(pb, "l_partkey", "p_partkey")
    ls = l.select("l_partkey", "l_suppkey",
                  "l_quantity").shuffle("l_partkey")                     # s1
    g = ls.group_by(["l_partkey", "l_suppkey"], [("sq", "sum", "l_quantity")],
                    exchange="local")
    ps = scan("partsupp").semi(pb, "ps_partkey", "p_partkey")
    j = ps.join(g, ("ps_partkey", "ps_suppkey"), ("l_partkey", "l_suppkey"),
                ["sq"])                                                  # partkey-local
    j = j.filter(col("ps_availqty") > 0.5 * col("sq"))
    # per-device distinct suppkeys: consumed membership-only (broadcast ->
    # semi), so the partial 'local' group-by is globally exact
    sk = j.group_by(["ps_suppkey"], [("n", "count", None)],
                    exchange="local")
    skb = sk.select("ps_suppkey").broadcast()                            # b2
    s = scan("supplier").semi(skb, "s_suppkey", "ps_suppkey")
    s = s.filter(col("s_nationkey") == scode("n_name", "CANADA"))
    s = s.shrink(1 << 16)                # <= suppliers of one nation
    return s.select("s_suppkey", "s_nationkey") \
        .finalize(sort_keys=[("s_suppkey", True)])


def _join_same_key(probe, build, key, take):
    """Join where probe and build share the key column name."""
    return probe.join(build.rename({key: "__bk"}), key, "__bk", take)


def q21():
    """Suppliers who kept orders waiting.  Exists/not-exists via per-order
    distinct-supplier counts (orderkey-local); 1 broadcast (SA suppliers)."""
    l = scan("lineitem")
    d_all = l.group_by(["l_orderkey", "l_suppkey"], [("n", "count", None)],
                       exchange="local")
    g_all = d_all.group_by(["l_orderkey"], [("nsupp", "count", None)],
                           exchange="local")
    late = l.filter(col("l_receiptdate") > col("l_commitdate"))
    d_late = late.group_by(["l_orderkey", "l_suppkey"],
                           [("n", "count", None)], exchange="local")
    g_late = d_late.group_by(["l_orderkey"], [("nlate", "count", None)],
                             exchange="local")
    s = scan("supplier").filter(col("s_nationkey") ==
                                scode("n_name", "SAUDI ARABIA"))
    sb = s.select("s_suppkey").broadcast()                               # b1
    l1 = late.semi(sb, "l_suppkey", "s_suppkey")
    o = scan("orders").filter(col("o_orderstatus") ==
                              scode("o_orderstatus", "F"))
    l1 = l1.semi(o, "l_orderkey", "o_orderkey")
    l1 = _join_same_key(l1, g_all, "l_orderkey", ["nsupp"])
    l1 = _join_same_key(l1, g_late, "l_orderkey", ["nlate"])
    l1 = l1.filter((col("nsupp") >= 2) & (col("nlate") == 1))
    g = l1.group_by(["l_suppkey"], [("numwait", "count", None)],
                    exchange="gather", final=True)
    return g.finalize(sort_keys=[("numwait", False), ("l_suppkey", True)],
                      limit=100, replicated=True)


def q22():
    """Global sales opportunity.  1 shuffle (orders custkeys) + 2 allreduces."""
    cs = scan("customer").filter(
        isin(col("c_phone_cc"), [13, 31, 23, 29, 30, 18, 17]))
    pos = cs.filter(col("c_acctbal") > 0.0)
    avg = pos.agg_scalar([("a", "avg", "c_acctbal")])["a"]
    go = scan("orders").group_by(["o_custkey"], [("n", "count", None)],
                                 exchange="shuffle")                     # s1
    cs2 = cs.filter(col("c_acctbal") > avg)
    cs2 = cs2.anti(go, "c_custkey", "o_custkey")                         # custkey-local
    g = cs2.group_by(["c_phone_cc"], [
        ("numcust", "count", None), ("totacctbal", "sum", "c_acctbal")],
        exchange="gather", final=True)
    return g.finalize(sort_keys=[("c_phone_cc", True)], replicated=True)

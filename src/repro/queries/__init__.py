"""The 22 TPC-H queries as lazy logical plans (paper §4.4, now compiled).

Each query module function (``q1()`` .. ``q22()``) BUILDS a logical plan —
a plain-data DAG of ``repro.core.plan`` nodes with column-expression trees —
and ``repro.core.planner`` compiles it against the physical ``Context`` API.
``QUERIES[qid]`` is the compiled form: a callable ``query_fn(ctx)`` exactly
like the legacy eager plans, runnable unchanged on ``RefContext`` /
``LocalContext`` / ``DistContext``.  Exchange placement (``.shuffle()`` /
``.broadcast()`` / ``exchange=`` on group_by) remains explicit plan
structure, following the paper's plans under its §4.3 input partitioning:

  lineitem@l_orderkey  orders@o_orderkey  partsupp@ps_partkey  part@p_partkey
  supplier@s_suppkey   customer@c_custkey nation,region replicated

Exchange counts per plan are derived statically from the IR and asserted
against paper Table 4 in tests/test_plan_stats.py — alongside the runtime
counts, which they must equal on every backend (Q11 deviates from the paper:
our partitioning makes the group-by local where the paper shuffles).

Planner contract (replaces the hand hint-threading convention)
--------------------------------------------------------------
The physical engine still takes two static hints on ``group_by`` —
``key_bits`` (provable per-column key widths; sum <= 13 unlocks the sortless
direct-addressing aggregation) and ``groups_hint`` (distinct-group bound that
shrinks partials before an exchange).  Plans NO LONGER state them:

  * ``key_bits`` is ALWAYS inferred — by bound propagation from per-column
    min/max statistics (dictionary domains, generated key ranges) through
    filters and expression arithmetic.  Query code contains zero hand-written
    key widths, and inference runs against the database that executes, so an
    inferred width cannot lie in normal execution.  Stand-in compiles whose
    tables are NOT the analyzed database (the SF=1000 dry-run) must inject
    matching statistics (``launch/dryrun_analytics._sf1000_stats``) or
    compile with inference off.
  * ``groups_hint`` is inferred from key-domain cardinality products where
    provable; a plan may still pass ``groups_hint=`` for bounds the planner
    cannot prove (data-dependent group counts — Q13's orders-per-customer
    histogram is the one remaining case).  When both exist the tighter bound
    wins.  An author claim that undercounts raises ``ctx.overflow``; capacity
    escalation alone cannot fix that, so the fault runner recompiles with
    inference off after a failed escalation (``distributed/fault.py``) —
    groups are never silently dropped either way.
  * The aggregation method follows from the hints per database: direct
    addressing where the key domain proves small, the hash-compaction
    dictionary (``kernels/hash_group``) where only a ``groups_hint`` exists
    (the Q13 shape — zero sorts with no width claim at all), and the
    single-sort path otherwise.  The same plan degrades gracefully across
    scale factors.
  * **Wire widths are inferred too**: every exchange (broadcast / shuffle /
    exchanged group-by / final gather) ships its payload at the lane widths
    the same column statistics prove (``core/wire.py``), with a per-column
    runtime range check feeding ``ctx.overflow``.  Plans carry no wire
    fields; ``REPRO_WIRE=wide`` forces the legacy full-width format (the
    differential leg) and unhinted compilation is wide by construction.

``REPRO_PLANNER=0`` disables all hints (the conservative leg CI runs to pin
that hinted and unhinted compilation agree — byte-identical per aggregation
engine, rtol=1e-9 across engines on the forced-kernel leg; see
tests/test_planner.py); ``QUERIES[qid].with_inference(True/False)`` pins the
mode per call site.

Deferred compaction: intermediate tables a plan sees after filters and joins
may be *masked* (valid-row mask, not front-compacted) — plans must not index
rows positionally; row-positional operators (``finalize``, ``shrink``,
broadcasts) compact internally.  Column expressions run on garbage rows too,
which is safe because garbage values are always drawn from previously valid
rows and therefore stay in-domain for every LUT.
"""
import os

from repro.core.planner import compile_query

from . import q01_08, q09_15, q16_22

# plan builders: call to get a FRESH logical-plan root (benchmarks time this)
PLANS = {}
for _mod in (q01_08, q09_15, q16_22):
    for _name in _mod.__all__:
        PLANS[int(_name[1:])] = getattr(_mod, _name)

# REPRO_FRONTEND=sql swaps in plans compiled from the committed SQL texts
# (src/repro/queries/sql/q*.sql) by the repro.sql frontend + IR optimizer.
# Same Table 4 exchange counts, same wire budgets, byte-identical results —
# asserted by tests/test_sql_frontend.py and the sql CI leg.
if os.environ.get("REPRO_FRONTEND", "").lower() == "sql":
    from repro.sql.frontend import sql_plans as _sql_plans
    PLANS = _sql_plans()
    assert sorted(PLANS) == list(range(1, 23)), sorted(PLANS)

# compiled queries: `query_fn(ctx)` callables, plan built once and shared
QUERIES = {qid: compile_query(fn, name=f"q{qid}")
           for qid, fn in sorted(PLANS.items())}

# Paper Table 4 (legible cells) — (shuffles, broadcasts); final gathers and
# allreduces are excluded, as in the paper.
PAPER_TABLE4 = {
    1: (0, 0), 2: (0, 1), 3: (0, 1), 4: (0, 0), 5: (0, 2), 6: (0, 0),
    7: (0, 2), 8: (0, 3), 9: (1, 2), 10: (1, 0), 11: (1, 1), 12: (0, 0),
    13: (1, None), 14: (1, None), 15: (1, None), 16: (1, None),
    17: (1, None), 18: (0, None), 19: (0, None), 20: (1, None),
    21: (0, None), 22: (1, None),
}

__all__ = ["QUERIES", "PLANS", "PAPER_TABLE4"]

"""The 22 TPC-H queries as manually-optimized tensor programs (paper §4.4).

Each query is a single function against the backend Context API; exchange
placement (shuffle / broadcast / final gather) is explicit and follows the
paper's plans under its §4.3 input partitioning:

  lineitem@l_orderkey  orders@o_orderkey  partsupp@ps_partkey  part@p_partkey
  supplier@s_suppkey   customer@c_custkey nation,region replicated

Exchange counts per plan are asserted against paper Table 4 in
tests/test_plan_stats.py (Q11 deviates: our partitioning makes the group-by
local where the paper shuffles — noted in DESIGN.md).

Deferred compaction: intermediate tables a plan sees after ``ctx.filter`` /
``ctx.join`` / ``ctx.semi`` / ``ctx.anti`` may be *masked* (valid-row mask,
not front-compacted) — plans must not index rows positionally; row-positional
operators (``ctx.finalize``, ``ctx.shrink``, broadcasts) compact internally.
All column expressions (``with_col``, agg lambdas, dictionary lookups) run on
garbage rows too, which is safe because garbage values are always drawn from
previously valid rows and therefore stay in-domain for every LUT.

Hint-threading convention (group_by)
------------------------------------
Plans carry two *independent* static hints on ``ctx.group_by``:

  * ``groups_hint=H`` — upper bound on DISTINCT groups.  Shrinks the output
    capacity to H (before the exchange on the distributed backend, so a
    gather/shuffle moves O(H) rows, not O(scan capacity)).  Wrong hints set
    ``ctx.overflow`` and trigger re-execution; groups are never silently
    dropped.
  * ``key_bits=[b0, b1, ...]`` — PROVABLE per-column bit widths
    (``0 <= key_col[i] < 2^bits[i]``), e.g. from a dictionary domain
    (``ctx.dict_bits(col)``) or an arithmetic bound stated in a comment at
    the call site.  When ``sum(bits) <= 13`` the engine runs the sortless
    direct-addressing aggregation (dense gid = packed key, one-hot MXU
    reduce via ``kernels/segsum``) on both the partial and the
    post-exchange merge; larger or absent widths fall back to the
    single-sort path.  A lying width also sets ``ctx.overflow`` rather than
    corrupting results.  The NumPy reference backend ignores both hints.
"""
from .q01_08 import q1, q2, q3, q4, q5, q6, q7, q8
from .q09_15 import q9, q10, q11, q12, q13, q14, q15
from .q16_22 import q16, q17, q18, q19, q20, q21, q22

QUERIES = {i: fn for i, fn in enumerate(
    [q1, q2, q3, q4, q5, q6, q7, q8, q9, q10, q11, q12, q13, q14, q15,
     q16, q17, q18, q19, q20, q21, q22], start=1)}

# Paper Table 4 (legible cells) — (shuffles, broadcasts); final gathers and
# allreduces are excluded, as in the paper.
PAPER_TABLE4 = {
    1: (0, 0), 2: (0, 1), 3: (0, 1), 4: (0, 0), 5: (0, 2), 6: (0, 0),
    7: (0, 2), 8: (0, 3), 9: (1, 2), 10: (1, 0), 11: (1, 1), 12: (0, 0),
    13: (1, None), 14: (1, None), 15: (1, None), 16: (1, None),
    17: (1, None), 18: (0, None), 19: (0, None), 20: (1, None),
    21: (0, None), 22: (1, None),
}

__all__ = ["QUERIES", "PAPER_TABLE4"]

"""TPC-H Q1-Q8 tensor plans."""
from repro.core.table import days

__all__ = ["q1", "q2", "q3", "q4", "q5", "q6", "q7", "q8"]


def _disc(t):
    return t["l_extendedprice"] * (1 - t["l_discount"])


def _charge(t):
    return t["l_extendedprice"] * (1 - t["l_discount"]) * (1 + t["l_tax"])


def _in(x, vals):
    m = x == vals[0]
    for v in vals[1:]:
        m = m | (x == v)
    return m


def q1(ctx):
    """Pricing summary report.  No exchange: local agg + final gather-merge."""
    l = ctx.scan("lineitem")
    l = ctx.filter(l, l["l_shipdate"] <= days("1998-09-02"))
    g = ctx.group_by(l, ["l_returnflag", "l_linestatus"], [
        ("sum_qty", "sum", "l_quantity"),
        ("sum_base_price", "sum", "l_extendedprice"),
        ("sum_disc_price", "sum", _disc),
        ("sum_charge", "sum", _charge),
        ("avg_qty", "avg", "l_quantity"),
        ("avg_price", "avg", "l_extendedprice"),
        ("avg_disc", "avg", "l_discount"),
        ("count_order", "count", None),
    ], exchange="gather", final=True, groups_hint=8,
        key_bits=[ctx.dict_bits("l_returnflag"), ctx.dict_bits("l_linestatus")])
    return ctx.finalize(g, sort_keys=[("l_returnflag", True), ("l_linestatus", True)],
                        replicated=True)


def _europe_suppliers(ctx):
    nat = ctx.scan("nation")
    reg = ctx.scan("region")
    n = ctx.join(nat, reg, "n_regionkey", "r_regionkey", ["r_name"])
    n = ctx.filter(n, n["r_name"] == ctx.db.code("r_name", "EUROPE"))
    s = ctx.join(ctx.scan("supplier"), n, "s_nationkey", "n_nationkey", ["n_name"])
    return s


def q2(ctx):
    """Minimum-cost supplier.  Broadcast the (small) filtered EU suppliers."""
    part = ctx.scan("part")
    ps = ctx.scan("partsupp")
    p = ctx.filter(part, (part["p_size"] == 15) & ctx.ends_with(part, "p_type", "BRASS"))
    s = _europe_suppliers(ctx)
    sb = ctx.broadcast(ctx.select(s, "s_suppkey", "s_acctbal", "n_name"))
    j = ctx.join(ps, p, "ps_partkey", "p_partkey", ["p_mfgr"])          # co-partitioned
    j = ctx.join(j, sb, "ps_suppkey", "s_suppkey", ["s_acctbal", "n_name"])
    mn = ctx.group_by(j, ["ps_partkey"], [("min_cost", "min", "ps_supplycost")],
                      exchange="local")                                  # partkey-local
    j = ctx.join(j, ctx.rename(mn, {"ps_partkey": "mk"}),
                 "ps_partkey", "mk", ["min_cost"])
    j = ctx.filter(j, j["ps_supplycost"] == j["min_cost"])
    j = ctx.with_col(j, n_rank=lambda t: ctx.alpha_rank(t, "n_name"))
    out = ctx.select(j, "s_acctbal", "n_name", "n_rank", "ps_suppkey",
                     "ps_partkey", "p_mfgr")
    return ctx.finalize(out, sort_keys=[("s_acctbal", False), ("n_rank", True),
                                        ("ps_suppkey", True), ("ps_partkey", True)],
                        limit=100)


def q3(ctx):
    """Shipping priority.  Broadcast BUILDING-segment customer keys."""
    c = ctx.scan("customer")
    o = ctx.scan("orders")
    l = ctx.scan("lineitem")
    c = ctx.filter(c, ctx.eq(c, "c_mktsegment", "BUILDING"))
    cb = ctx.broadcast(ctx.select(c, "c_custkey"))
    o = ctx.filter(o, o["o_orderdate"] < days("1995-03-15"))
    o = ctx.semi(o, cb, "o_custkey", "c_custkey")
    l = ctx.filter(l, l["l_shipdate"] > days("1995-03-15"))
    j = ctx.join(l, o, "l_orderkey", "o_orderkey", ["o_orderdate", "o_shippriority"])
    g = ctx.group_by(j, ["l_orderkey"], [
        ("revenue", "sum", _disc),
        ("o_orderdate", "max", "o_orderdate"),
        ("o_shippriority", "max", "o_shippriority"),
    ], exchange="local")                                                 # orderkey-local
    return ctx.finalize(g, sort_keys=[("revenue", False), ("o_orderdate", True)],
                        limit=10)


def q4(ctx):
    """Order priority checking.  Fully co-partitioned: no exchange."""
    o = ctx.scan("orders")
    l = ctx.scan("lineitem")
    o = ctx.filter(o, (o["o_orderdate"] >= days("1993-07-01")) &
                   (o["o_orderdate"] < days("1993-10-01")))
    lc = ctx.filter(l, l["l_commitdate"] < l["l_receiptdate"])
    o = ctx.semi(o, lc, "o_orderkey", "l_orderkey")
    g = ctx.group_by(o, ["o_orderpriority"], [("order_count", "count", None)],
                     exchange="gather", final=True, groups_hint=8,
                     key_bits=[ctx.dict_bits("o_orderpriority")])
    return ctx.finalize(g, sort_keys=[("o_orderpriority", True)], replicated=True)


def q5(ctx):
    """Local supplier volume.  Two dimension broadcasts (customer, supplier)."""
    nat = ctx.scan("nation")
    reg = ctx.scan("region")
    n = ctx.join(nat, reg, "n_regionkey", "r_regionkey", ["r_name"])
    n = ctx.filter(n, n["r_name"] == ctx.db.code("r_name", "ASIA"))
    c = ctx.semi(ctx.scan("customer"), n, "c_nationkey", "n_nationkey")
    cb = ctx.broadcast(ctx.select(c, "c_custkey", "c_nationkey"))
    o = ctx.scan("orders")
    o = ctx.filter(o, (o["o_orderdate"] >= days("1994-01-01")) &
                   (o["o_orderdate"] < days("1995-01-01")))
    oj = ctx.join(o, cb, "o_custkey", "c_custkey", ["c_nationkey"])
    lj = ctx.join(ctx.scan("lineitem"), oj, "l_orderkey", "o_orderkey",
                  ["c_nationkey"])
    s = ctx.semi(ctx.scan("supplier"), n, "s_nationkey", "n_nationkey")
    sb = ctx.broadcast(ctx.select(s, "s_suppkey", "s_nationkey"))
    lj = ctx.join(lj, sb, "l_suppkey", "s_suppkey", ["s_nationkey"])
    lj = ctx.filter(lj, lj["c_nationkey"] == lj["s_nationkey"])
    g = ctx.group_by(lj, ["s_nationkey"], [("revenue", "sum", _disc)],
                     exchange="gather", final=True, groups_hint=32,
                     key_bits=[ctx.dict_bits("n_name")])   # nationkey < 25
    # n_name dictionary code == nationkey by construction
    return ctx.finalize(g, sort_keys=[("revenue", False)], replicated=True)


def q6(ctx):
    """Forecasting revenue change: pure scan + allreduce."""
    l = ctx.scan("lineitem")
    m = ((l["l_shipdate"] >= days("1994-01-01")) &
         (l["l_shipdate"] < days("1995-01-01")) &
         (l["l_discount"] >= 0.05) & (l["l_discount"] <= 0.07) &
         (l["l_quantity"] < 24))
    l = ctx.filter(l, m)
    s = ctx.agg_scalar(l, [("revenue", "sum",
                            lambda t: t["l_extendedprice"] * t["l_discount"])])
    return {"revenue": s["revenue"]}


def q7(ctx):
    """Volume shipping FRANCE<->GERMANY.  Broadcast both filtered dimensions."""
    fr = ctx.db.code("n_name", "FRANCE")
    de = ctx.db.code("n_name", "GERMANY")
    s = ctx.scan("supplier")
    s = ctx.filter(s, _in(s["s_nationkey"], [fr, de]))
    sb = ctx.broadcast(ctx.select(s, "s_suppkey", "s_nationkey"))
    c = ctx.scan("customer")
    c = ctx.filter(c, _in(c["c_nationkey"], [fr, de]))
    cb = ctx.broadcast(ctx.select(c, "c_custkey", "c_nationkey"))
    o = ctx.scan("orders")
    oj = ctx.join(o, cb, "o_custkey", "c_custkey", ["c_nationkey"])
    l = ctx.scan("lineitem")
    l = ctx.filter(l, (l["l_shipdate"] >= days("1995-01-01")) &
                   (l["l_shipdate"] <= days("1996-12-31")))
    lj = ctx.join(l, oj, "l_orderkey", "o_orderkey", ["c_nationkey"])
    lj = ctx.join(lj, sb, "l_suppkey", "s_suppkey", ["s_nationkey"])
    lj = ctx.filter(lj, ((lj["s_nationkey"] == fr) & (lj["c_nationkey"] == de)) |
                    ((lj["s_nationkey"] == de) & (lj["c_nationkey"] == fr)))
    lj = ctx.with_col(lj, l_year=lambda t: ctx.year(t, "l_shipdate"))
    lj = ctx.with_col(lj, grp=lambda t: (t["s_nationkey"] * 25 + t["c_nationkey"])
                      * 8 + (t["l_year"] - 1992))
    g = ctx.group_by(lj, ["grp"], [
        ("supp_nation", "max", "s_nationkey"),
        ("cust_nation", "max", "c_nationkey"),
        ("l_year", "max", "l_year"),
        ("revenue", "sum", _disc),
    ], exchange="gather", final=True, groups_hint=16,
        key_bits=[13])   # grp < 25*25*8 = 5000 < 2^13
    return ctx.finalize(ctx.select(g, "supp_nation", "cust_nation", "l_year", "revenue"),
                        sort_keys=[("supp_nation", True), ("cust_nation", True),
                                   ("l_year", True)], replicated=True)


def q8(ctx):
    """National market share.  Three broadcasts: part, supplier, customer."""
    br = ctx.db.code("n_name", "BRAZIL")
    nat = ctx.scan("nation")
    reg = ctx.scan("region")
    n = ctx.join(nat, reg, "n_regionkey", "r_regionkey", ["r_name"])
    n = ctx.filter(n, n["r_name"] == ctx.db.code("r_name", "AMERICA"))
    p = ctx.scan("part")
    p = ctx.filter(p, ctx.eq(p, "p_type", "ECONOMY ANODIZED STEEL"))
    pb = ctx.broadcast(ctx.select(p, "p_partkey"))                       # b1
    l = ctx.semi(ctx.scan("lineitem"), pb, "l_partkey", "p_partkey")
    s = ctx.scan("supplier")
    sb = ctx.broadcast(ctx.select(s, "s_suppkey", "s_nationkey"))        # b2
    l = ctx.join(l, sb, "l_suppkey", "s_suppkey", ["s_nationkey"])
    c = ctx.semi(ctx.scan("customer"), n, "c_nationkey", "n_nationkey")
    cb = ctx.broadcast(ctx.select(c, "c_custkey"))                       # b3
    o = ctx.scan("orders")
    o = ctx.filter(o, (o["o_orderdate"] >= days("1995-01-01")) &
                   (o["o_orderdate"] <= days("1996-12-31")))
    o = ctx.semi(o, cb, "o_custkey", "c_custkey")
    lj = ctx.join(l, o, "l_orderkey", "o_orderkey", ["o_orderdate"])
    lj = ctx.with_col(lj, o_year=lambda t: ctx.year(t, "o_orderdate"))
    g = ctx.group_by(lj, ["o_year"], [
        ("total", "sum", _disc),
        ("brazil", "sum", lambda t: ctx.xp.where(t["s_nationkey"] == br,
                                                 _disc(t), 0.0)),
    ], exchange="gather", final=True, groups_hint=16,
        key_bits=[11])   # o_year from the 1970-2005 LUT, < 2^11
    g = ctx.with_col(g, mkt_share=lambda t: t["brazil"] / t["total"])
    return ctx.finalize(ctx.select(g, "o_year", "mkt_share"),
                        sort_keys=[("o_year", True)], replicated=True)

"""TPC-H Q1-Q8 as lazy logical plans (builder API; see queries/__init__.py).

Each ``qN()`` returns the ROOT NODE of a plan DAG; the planner compiles it
against a backend Context and infers every static hint (``key_bits``,
``groups_hint``) the legacy eager plans carried by hand.
"""
from repro.core.plan import (alpha_rank, col, ends_with, isin, result, scan,
                             scode, where, year)
from repro.core.table import days

__all__ = ["q1", "q2", "q3", "q4", "q5", "q6", "q7", "q8"]

# reusable column expressions (plain data — safe to share across plans)
_disc = col("l_extendedprice") * (1 - col("l_discount"))
_charge = _disc * (1 + col("l_tax"))


def q1():
    """Pricing summary report.  No exchange: local agg + final gather-merge."""
    l = scan("lineitem").filter(col("l_shipdate") <= days("1998-09-02"))
    g = l.group_by(["l_returnflag", "l_linestatus"], [
        ("sum_qty", "sum", "l_quantity"),
        ("sum_base_price", "sum", "l_extendedprice"),
        ("sum_disc_price", "sum", _disc),
        ("sum_charge", "sum", _charge),
        ("avg_qty", "avg", "l_quantity"),
        ("avg_price", "avg", "l_extendedprice"),
        ("avg_disc", "avg", "l_discount"),
        ("count_order", "count", None),
    ], exchange="gather", final=True)
    return g.finalize(sort_keys=[("l_returnflag", True),
                                 ("l_linestatus", True)], replicated=True)


def _europe_suppliers():
    n = scan("nation").join(scan("region"), "n_regionkey", "r_regionkey",
                            ["r_name"])
    n = n.filter(col("r_name") == scode("r_name", "EUROPE"))
    return scan("supplier").join(n, "s_nationkey", "n_nationkey", ["n_name"])


def q2():
    """Minimum-cost supplier.  Broadcast the (small) filtered EU suppliers."""
    p = scan("part").filter((col("p_size") == 15) &
                            ends_with("p_type", "BRASS"))
    sb = _europe_suppliers().select("s_suppkey", "s_acctbal",
                                    "n_name").broadcast()
    j = scan("partsupp").join(p, "ps_partkey", "p_partkey", ["p_mfgr"])  # co-partitioned
    j = j.join(sb, "ps_suppkey", "s_suppkey", ["s_acctbal", "n_name"])
    mn = j.group_by(["ps_partkey"], [("min_cost", "min", "ps_supplycost")],
                    exchange="local")                                    # partkey-local
    j = j.join(mn.rename({"ps_partkey": "mk"}), "ps_partkey", "mk",
               ["min_cost"])
    j = j.filter(col("ps_supplycost") == col("min_cost"))
    j = j.with_col(n_rank=alpha_rank("n_name"))
    out = j.select("s_acctbal", "n_name", "n_rank", "ps_suppkey",
                   "ps_partkey", "p_mfgr")
    return out.finalize(sort_keys=[("s_acctbal", False), ("n_rank", True),
                                   ("ps_suppkey", True), ("ps_partkey", True)],
                        limit=100)


def q3():
    """Shipping priority.  Broadcast BUILDING-segment customer keys."""
    c = scan("customer").filter(col("c_mktsegment") ==
                                scode("c_mktsegment", "BUILDING"))
    cb = c.select("c_custkey").broadcast()
    o = scan("orders").filter(col("o_orderdate") < days("1995-03-15"))
    o = o.semi(cb, "o_custkey", "c_custkey")
    l = scan("lineitem").filter(col("l_shipdate") > days("1995-03-15"))
    j = l.join(o, "l_orderkey", "o_orderkey",
               ["o_orderdate", "o_shippriority"])
    g = j.group_by(["l_orderkey"], [
        ("revenue", "sum", _disc),
        ("o_orderdate", "max", "o_orderdate"),
        ("o_shippriority", "max", "o_shippriority"),
    ], exchange="local")                                                 # orderkey-local
    return g.finalize(sort_keys=[("revenue", False), ("o_orderdate", True)],
                      limit=10)


def q4():
    """Order priority checking.  Fully co-partitioned: no exchange."""
    o = scan("orders").filter((col("o_orderdate") >= days("1993-07-01")) &
                              (col("o_orderdate") < days("1993-10-01")))
    lc = scan("lineitem").filter(col("l_commitdate") < col("l_receiptdate"))
    o = o.semi(lc, "o_orderkey", "l_orderkey")
    g = o.group_by(["o_orderpriority"], [("order_count", "count", None)],
                   exchange="gather", final=True)
    return g.finalize(sort_keys=[("o_orderpriority", True)], replicated=True)


def q5():
    """Local supplier volume.  Two dimension broadcasts (customer, supplier)."""
    n = scan("nation").join(scan("region"), "n_regionkey", "r_regionkey",
                            ["r_name"])
    n = n.filter(col("r_name") == scode("r_name", "ASIA"))
    c = scan("customer").semi(n, "c_nationkey", "n_nationkey")
    cb = c.select("c_custkey", "c_nationkey").broadcast()
    o = scan("orders").filter((col("o_orderdate") >= days("1994-01-01")) &
                              (col("o_orderdate") < days("1995-01-01")))
    oj = o.join(cb, "o_custkey", "c_custkey", ["c_nationkey"])
    lj = scan("lineitem").join(oj, "l_orderkey", "o_orderkey",
                               ["c_nationkey"])
    s = scan("supplier").semi(n, "s_nationkey", "n_nationkey")
    sb = s.select("s_suppkey", "s_nationkey").broadcast()
    lj = lj.join(sb, "l_suppkey", "s_suppkey", ["s_nationkey"])
    lj = lj.filter(col("c_nationkey") == col("s_nationkey"))
    g = lj.group_by(["s_nationkey"], [("revenue", "sum", _disc)],
                    exchange="gather", final=True)
    return g.finalize(sort_keys=[("revenue", False)], replicated=True)


def q6():
    """Forecasting revenue change: pure scan + allreduce."""
    l = scan("lineitem").filter(
        (col("l_shipdate") >= days("1994-01-01")) &
        (col("l_shipdate") < days("1995-01-01")) &
        (col("l_discount") >= 0.05) & (col("l_discount") <= 0.07) &
        (col("l_quantity") < 24))
    s = l.agg_scalar([("revenue", "sum",
                       col("l_extendedprice") * col("l_discount"))])
    return result(revenue=s["revenue"])


def q7():
    """Volume shipping FRANCE<->GERMANY.  Broadcast both filtered dimensions."""
    fr = scode("n_name", "FRANCE")
    de = scode("n_name", "GERMANY")
    s = scan("supplier").filter(isin(col("s_nationkey"), [fr, de]))
    sb = s.select("s_suppkey", "s_nationkey").broadcast()
    c = scan("customer").filter(isin(col("c_nationkey"), [fr, de]))
    cb = c.select("c_custkey", "c_nationkey").broadcast()
    oj = scan("orders").join(cb, "o_custkey", "c_custkey", ["c_nationkey"])
    l = scan("lineitem").filter((col("l_shipdate") >= days("1995-01-01")) &
                                (col("l_shipdate") <= days("1996-12-31")))
    lj = l.join(oj, "l_orderkey", "o_orderkey", ["c_nationkey"])
    lj = lj.join(sb, "l_suppkey", "s_suppkey", ["s_nationkey"])
    lj = lj.filter(((col("s_nationkey") == fr) & (col("c_nationkey") == de)) |
                   ((col("s_nationkey") == de) & (col("c_nationkey") == fr)))
    lj = lj.with_col(l_year=year(col("l_shipdate")))
    lj = lj.with_col(grp=(col("s_nationkey") * 25 + col("c_nationkey")) * 8 +
                     (col("l_year") - 1992))
    g = lj.group_by(["grp"], [
        ("supp_nation", "max", "s_nationkey"),
        ("cust_nation", "max", "c_nationkey"),
        ("l_year", "max", "l_year"),
        ("revenue", "sum", _disc),
    ], exchange="gather", final=True)
    return g.select("supp_nation", "cust_nation", "l_year", "revenue") \
        .finalize(sort_keys=[("supp_nation", True), ("cust_nation", True),
                             ("l_year", True)], replicated=True)


def q8():
    """National market share.  Three broadcasts: part, supplier, customer."""
    br = scode("n_name", "BRAZIL")
    n = scan("nation").join(scan("region"), "n_regionkey", "r_regionkey",
                            ["r_name"])
    n = n.filter(col("r_name") == scode("r_name", "AMERICA"))
    p = scan("part").filter(col("p_type") ==
                            scode("p_type", "ECONOMY ANODIZED STEEL"))
    pb = p.select("p_partkey").broadcast()                               # b1
    l = scan("lineitem").semi(pb, "l_partkey", "p_partkey")
    sb = scan("supplier").select("s_suppkey", "s_nationkey").broadcast()  # b2
    l = l.join(sb, "l_suppkey", "s_suppkey", ["s_nationkey"])
    c = scan("customer").semi(n, "c_nationkey", "n_nationkey")
    cb = c.select("c_custkey").broadcast()                               # b3
    o = scan("orders").filter((col("o_orderdate") >= days("1995-01-01")) &
                              (col("o_orderdate") <= days("1996-12-31")))
    o = o.semi(cb, "o_custkey", "c_custkey")
    lj = l.join(o, "l_orderkey", "o_orderkey", ["o_orderdate"])
    lj = lj.with_col(o_year=year(col("o_orderdate")))
    g = lj.group_by(["o_year"], [
        ("total", "sum", _disc),
        ("brazil", "sum", where(col("s_nationkey") == br, _disc, 0.0)),
    ], exchange="gather", final=True)
    g = g.with_col(mkt_share=col("brazil") / col("total"))
    return g.select("o_year", "mkt_share") \
        .finalize(sort_keys=[("o_year", True)], replicated=True)

select sum(l_extendedprice * (1 - l_discount)) as revenue
from lineitem
    join part on l_partkey = p_partkey
where l_shipinstruct = 'DELIVER IN PERSON'
  and l_shipmode in ('AIR', 'AIR REG')
  and (p_brand = 'Brand#12'
         and p_container in ('SM CASE', 'SM BOX', 'SM PACK', 'SM PKG')
         and p_size >= 1 and p_size <= 5
       or p_brand = 'Brand#23'
         and p_container in ('MED BAG', 'MED BOX', 'MED PKG', 'MED PACK')
         and p_size >= 1 and p_size <= 10
       or p_brand = 'Brand#34'
         and p_container in ('LG CASE', 'LG BOX', 'LG PACK', 'LG PKG')
         and p_size >= 1 and p_size <= 15)
  and (p_brand = 'Brand#12' and l_quantity >= 1 and l_quantity <= 11
       or p_brand = 'Brand#23' and l_quantity >= 10 and l_quantity <= 20
       or p_brand = 'Brand#34' and l_quantity >= 20 and l_quantity <= 30)

with ps as (
    select ps_partkey, ps_supplycost * ps_availqty as value
    from partsupp
    where ps_suppkey in (select s_suppkey from supplier
                         where s_nationkey = code('n_name', 'GERMANY'))
)
select ps_partkey, sum(value) as value
from ps
group by ps_partkey
having sum(value) > (select sum(value) from ps) * (0.0001 / dbscale())
       /*+ shrink(1048576) */
order by value desc

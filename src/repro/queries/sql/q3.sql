declare q3_date date default date '1995-03-15'
    in (date '1995-03-01', date '1995-03-31');
select l_orderkey,
       sum(l_extendedprice * (1 - l_discount)) as revenue,
       o_orderdate, o_shippriority
from lineitem
    join orders on l_orderkey = o_orderkey
where o_orderdate < :q3_date
  and l_shipdate > :q3_date
  and o_custkey in (select c_custkey from customer
                    where c_mktsegment = 'BUILDING')
group by l_orderkey, o_orderdate, o_shippriority
order by revenue desc, o_orderdate
limit 10

with cs as (
    select c_custkey, c_acctbal, c_phone_cc
    from customer
    where c_phone_cc in (13, 31, 23, 29, 30, 18, 17)
)
select c_phone_cc, count(*) as numcust, sum(c_acctbal) as totacctbal
from cs
where c_acctbal > (select avg(c_acctbal) from cs where c_acctbal > 0.0)
  and not exists (select o_orderkey from orders where o_custkey = c_custkey)
group by c_phone_cc
order by c_phone_cc

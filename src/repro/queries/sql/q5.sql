declare q5_date_lo date default date '1994-01-01'
    in (date '1993-01-01', date '1997-01-01');
declare q5_date_hi date default date '1995-01-01'
    in (date '1994-01-01', date '1998-01-01');
with asia as (
    select n_nationkey
    from nation
        join region on n_regionkey = r_regionkey
    where r_name = 'ASIA'
)
select s_nationkey, sum(l_extendedprice * (1 - l_discount)) as revenue
from lineitem
    join orders on l_orderkey = o_orderkey
    join customer on o_custkey = c_custkey
    join supplier on l_suppkey = s_suppkey
where o_orderdate >= :q5_date_lo
  and o_orderdate < :q5_date_hi
  and c_nationkey in (select n_nationkey from asia)
  and s_nationkey in (select n_nationkey from asia)
  and c_nationkey = s_nationkey
group by s_nationkey
order by revenue desc

with fp as (
    select p_partkey from part where p_name like 'forest%'
),
g as (
    select l_partkey, l_suppkey, sum(l_quantity) as sq
    from lineitem
    where l_shipdate >= date '1994-01-01'
      and l_shipdate < date '1995-01-01'
      and l_partkey in (select p_partkey from fp)
    group by l_partkey, l_suppkey
)
select s_suppkey, s_nationkey
from supplier
where s_suppkey in (select ps_suppkey
                    from partsupp
                        join g on ps_partkey = l_partkey
                              and ps_suppkey = l_suppkey
                    where ps_partkey in (select p_partkey from fp)
                      and ps_availqty > 0.5 * sq)
  and s_nationkey = code('n_name', 'CANADA') /*+ shrink(65536) */
order by s_suppkey

with eu as (
    select s_suppkey, s_acctbal, n_name
    from supplier
        join nation on s_nationkey = n_nationkey
        join region on n_regionkey = r_regionkey
    where r_name = 'EUROPE'
),
j as (
    select ps_partkey, ps_suppkey, ps_supplycost, p_mfgr, s_acctbal, n_name
    from partsupp
        join part on ps_partkey = p_partkey
        join eu on ps_suppkey = s_suppkey
    where p_size = 15 and p_type like '%BRASS'
),
mn as (
    select ps_partkey as mk, min(ps_supplycost) as min_cost
    from j
    group by ps_partkey
)
select s_acctbal, n_name, ps_suppkey, ps_partkey, p_mfgr
from j
    join mn on ps_partkey = mk
where ps_supplycost = min_cost
order by s_acctbal desc, n_name, ps_suppkey, ps_partkey
limit 100

select 100.0 * sum(case when p_type like 'PROMO%'
                        then l_extendedprice * (1 - l_discount)
                        else 0.0 end)
       / sum(l_extendedprice * (1 - l_discount)) as promo_revenue
from lineitem
    join part on l_partkey = p_partkey
where l_shipdate >= date '1995-09-01'
  and l_shipdate < date '1995-10-01'

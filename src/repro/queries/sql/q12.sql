select l_shipmode,
       sum(case when o_orderpriority in ('1-URGENT', '2-HIGH')
                then 1 else 0 end) as high_line_count,
       sum(case when o_orderpriority in ('1-URGENT', '2-HIGH')
                then 0 else 1 end) as low_line_count
from lineitem
    join orders on l_orderkey = o_orderkey
where l_shipmode in ('MAIL', 'SHIP')
  and l_commitdate < l_receiptdate
  and l_shipdate < l_commitdate
  and l_receiptdate >= date '1994-01-01'
  and l_receiptdate < date '1995-01-01'
group by l_shipmode
order by l_shipmode

select s_nationkey as supp_nation, c_nationkey as cust_nation,
       year(l_shipdate) as l_year,
       sum(l_extendedprice * (1 - l_discount)) as revenue
from lineitem
    join orders on l_orderkey = o_orderkey
    join customer on o_custkey = c_custkey
    join supplier on l_suppkey = s_suppkey
where l_shipdate >= date '1995-01-01'
  and l_shipdate <= date '1996-12-31'
  and s_nationkey in (code('n_name', 'FRANCE'), code('n_name', 'GERMANY'))
  and c_nationkey in (code('n_name', 'FRANCE'), code('n_name', 'GERMANY'))
  and (s_nationkey = code('n_name', 'FRANCE')
         and c_nationkey = code('n_name', 'GERMANY')
       or s_nationkey = code('n_name', 'GERMANY')
         and c_nationkey = code('n_name', 'FRANCE'))
group by supp_nation, cust_nation, l_year
order by supp_nation, cust_nation, l_year

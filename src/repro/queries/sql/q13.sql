with go as (
    select o_custkey, count(*) as c_count
    from orders
    where o_comment not like '%special%requests%'
    group by o_custkey
)
select /*+ groups(256) */ c_count, count(*) as custdist
from customer
    left join go on c_custkey = o_custkey
group by c_count
order by custdist desc, c_count desc

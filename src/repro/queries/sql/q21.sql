with late as (
    select l_orderkey, l_suppkey
    from lineitem
    where l_receiptdate > l_commitdate
),
g_all as (
    select l_orderkey as ok_all, count(distinct l_suppkey) as nsupp
    from lineitem
    group by l_orderkey
),
g_late as (
    select l_orderkey as ok_late, count(distinct l_suppkey) as nlate
    from late
    group by l_orderkey
)
select l_suppkey, count(*) as numwait
from late
    join g_all on l_orderkey = ok_all
    join g_late on l_orderkey = ok_late
where l_suppkey in (select s_suppkey from supplier
                    where s_nationkey = code('n_name', 'SAUDI ARABIA'))
  and l_orderkey in (select o_orderkey from orders where o_orderstatus = 'F')
  and nsupp >= 2 and nlate = 1
group by l_suppkey
order by numwait desc, l_suppkey
limit 100

with gl as (
    select l_orderkey, sum(l_quantity) as sum_qty
    from lineitem
    group by l_orderkey
    having sum(l_quantity) > 300 /*+ shrink(16384) */
)
select l_orderkey, sum_qty, o_custkey, o_orderdate, o_totalprice
from gl
    join orders on l_orderkey = o_orderkey
    join customer on o_custkey = c_custkey
order by o_totalprice desc, o_orderdate
limit 100

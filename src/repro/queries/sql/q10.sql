select o_custkey, revenue, c_acctbal, c_nationkey
from (select o_custkey, sum(l_extendedprice * (1 - l_discount)) as revenue
      from lineitem
          join orders on l_orderkey = o_orderkey
      where o_orderdate >= date '1993-10-01'
        and o_orderdate < date '1994-01-01'
        and l_returnflag = 'R'
      group by o_custkey) as g
    join customer on o_custkey = c_custkey
order by revenue desc
limit 20

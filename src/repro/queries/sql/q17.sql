with ls as (
    select l_partkey, l_quantity, l_extendedprice
    from lineitem
    where l_partkey in (select p_partkey from part
                        where p_brand = 'Brand#23'
                          and p_container = 'MED BOX')
),
agg0 as (
    select l_partkey as pk, avg(l_quantity) as avg_qty
    from ls
    group by l_partkey
)
select sum(l_extendedprice) / 7.0 as avg_yearly
from ls
    join agg0 on l_partkey = pk
where l_quantity < 0.2 * avg_qty

with america as (
    select n_nationkey
    from nation
        join region on n_regionkey = r_regionkey
    where r_name = 'AMERICA'
)
select year(o_orderdate) as o_year,
       sum(case when s_nationkey = code('n_name', 'BRAZIL')
                then l_extendedprice * (1 - l_discount) else 0.0 end)
         / sum(l_extendedprice * (1 - l_discount)) as mkt_share
from lineitem
    join orders on l_orderkey = o_orderkey
    join supplier on l_suppkey = s_suppkey
where l_partkey in (select p_partkey from part
                    where p_type = 'ECONOMY ANODIZED STEEL')
  and o_custkey in (select c_custkey from customer
                    where c_nationkey in (select n_nationkey from america))
  and o_orderdate >= date '1995-01-01'
  and o_orderdate <= date '1996-12-31'
group by o_year
order by o_year

with g as (
    select l_suppkey, sum(l_extendedprice * (1 - l_discount)) as total_revenue
    from lineitem
    where l_shipdate >= date '1996-01-01'
      and l_shipdate < date '1996-04-01'
    group by l_suppkey
)
select l_suppkey, total_revenue, s_nationkey
from g
    join supplier on l_suppkey = s_suppkey
where total_revenue >= (select max(total_revenue) from g)
                       * (1 - 0.000000000001) /*+ shrink(1024) */
order by l_suppkey

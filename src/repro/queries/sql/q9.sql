select s_nationkey as n_name, year(o_orderdate) as o_year,
       sum(l_extendedprice * (1 - l_discount)
           - ps_supplycost * l_quantity) as sum_profit
from lineitem
    join orders on l_orderkey = o_orderkey
    join partsupp on l_partkey = ps_partkey and l_suppkey = ps_suppkey
    join supplier on l_suppkey = s_suppkey
where l_partkey in (select p_partkey from part where p_name like '%green%')
group by n_name, o_year
order by n_name, o_year desc

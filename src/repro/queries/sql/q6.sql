declare q6_date_lo date default date '1994-01-01'
    in (date '1993-01-01', date '1997-01-01');
declare q6_date_hi date default date '1995-01-01'
    in (date '1994-01-01', date '1998-01-01');
declare q6_disc_lo float default 0.05 in (0.01, 0.09);
declare q6_disc_hi float default 0.07 in (0.01, 0.09);
declare q6_qty int default 24 in (20, 30);
select sum(l_extendedprice * l_discount) as revenue
from lineitem
where l_shipdate >= :q6_date_lo
  and l_shipdate < :q6_date_hi
  and l_discount >= :q6_disc_lo
  and l_discount <= :q6_disc_hi
  and l_quantity < :q6_qty

"""TPC-H data generator (dbgen-shaped, tensor-format output).

Follows the TPC-H v3 specification's shapes and relationships where they matter
for query semantics:

  * partsupp suppliers per part follow the spec formula, so every
    (l_partkey, l_suppkey) pair exists in partsupp (Q9's join depends on it);
  * one third of custkeys place no orders (Q13/Q22 depend on it);
  * o_orderstatus / l_linestatus / l_returnflag derive from the 1995-06-17
    "current date" rule; o_totalprice is the actual sum of its lineitems;
  * phone country code = nationkey + 10 (Q22).

Strings are dictionary-encoded (TQP's encoding); comments use small template
dictionaries (DESIGN.md §9 deviation), with the spec's complaint /
special-requests populations represented.
"""
from __future__ import annotations

import numpy as np

from repro.core.table import Database, days

__all__ = ["generate", "FACT_TABLES", "NATIONS", "REGIONS", "NATION_REGION"]

# The big tables worth sampling: the approx ladder (repro.approx) builds its
# stratified rungs over these; dimension tables always run exact.
FACT_TABLES = ("lineitem", "orders", "partsupp")

REGIONS = np.array(["AFRICA", "AMERICA", "ASIA", "EUROPE", "MIDDLE EAST"])
NATIONS = np.array([
    "ALGERIA", "ARGENTINA", "BRAZIL", "CANADA", "EGYPT", "ETHIOPIA", "FRANCE",
    "GERMANY", "INDIA", "INDONESIA", "IRAN", "IRAQ", "JAPAN", "JORDAN", "KENYA",
    "MOROCCO", "MOZAMBIQUE", "PERU", "CHINA", "ROMANIA", "SAUDI ARABIA",
    "VIETNAM", "RUSSIA", "UNITED KINGDOM", "UNITED STATES"])
NATION_REGION = np.array([0, 1, 1, 1, 4, 0, 3, 3, 2, 2, 4, 4, 2, 4, 0,
                          0, 0, 1, 2, 3, 4, 2, 3, 3, 1])

SEGMENTS = np.array(["AUTOMOBILE", "BUILDING", "FURNITURE", "MACHINERY", "HOUSEHOLD"])
PRIORITIES = np.array(["1-URGENT", "2-HIGH", "3-MEDIUM", "4-NOT SPECIFIED", "5-LOW"])
SHIPMODES = np.array(["REG AIR", "AIR", "RAIL", "SHIP", "TRUCK", "MAIL", "FOB",
                      "AIR REG"])  # Q19's second mode parameter
INSTRUCTS = np.array(["DELIVER IN PERSON", "COLLECT COD", "NONE", "TAKE BACK RETURN"])
ORDERSTATUS = np.array(["F", "O", "P"])
RETURNFLAGS = np.array(["A", "N", "R"])
LINESTATUS = np.array(["F", "O"])

_TYPE_S1 = ["STANDARD", "SMALL", "MEDIUM", "LARGE", "ECONOMY", "PROMO"]
_TYPE_S2 = ["ANODIZED", "BURNISHED", "PLATED", "POLISHED", "BRUSHED"]
_TYPE_S3 = ["TIN", "NICKEL", "BRASS", "STEEL", "COPPER"]
TYPES = np.array([f"{a} {b} {c}" for a in _TYPE_S1 for b in _TYPE_S2 for c in _TYPE_S3])

_CONT_S1 = ["SM", "LG", "MED", "JUMBO"]
_CONT_S2 = ["CASE", "BOX", "BAG", "JAR", "PKG", "PACK", "CAN", "DRUM", "BARREL", "BOTTLE"]
CONTAINERS = np.array([f"{a} {b}" for a in _CONT_S1 for b in _CONT_S2])

BRANDS = np.array([f"Brand#{i}{j}" for i in range(1, 6) for j in range(1, 6)])
MFGRS = np.array([f"Manufacturer#{i}" for i in range(1, 6)])

COLORS = np.array("""almond antique aquamarine azure beige bisque black blanched blue
blush brown burlywood burnished chartreuse chiffon chocolate coral cornflower cornsilk
cream cyan dark deep dim dodger drab firebrick floral forest frosted gainsboro ghost
goldenrod green grey honeydew hot indian ivory khaki lace lavender lawn lemon light
lime linen magenta maroon medium metallic midnight mint misty moccasin navajo navy
olive orange orchid pale papaya peach peru pink plum powder puff purple red rose rosy
royal saddle salmon sandy seashell sienna sky slate smoke snow spring steel tan thistle
tomato turquoise violet wheat white yellow""".split())

_CURRENT = "1995-06-17"
N_COMMENT_TEMPLATES = 512


def _comment_dict(rng: np.random.Generator, n: int, specials: list[str],
                  special_frac: float) -> np.ndarray:
    """Small template dictionary with a controlled special-pattern population."""
    words = np.array("""carefully final deposits sleep furiously quick requests
boost blithely ironic packages cajole express accounts haggle silent pinto beans
wake regular theodolites nag slyly bold foxes integrate daring sauternes""".split())
    base = [" ".join(rng.choice(words, size=8)) for _ in range(n)]
    n_special = max(1, int(n * special_frac))
    for i in range(n_special):
        mid = " ".join(rng.choice(words, size=2))
        base[i] = f"{base[i][:20]} {specials[0]}{mid}{specials[1]} {base[i][20:40]}"
    return np.array(base)


def generate(scale: float, seed: int = 7, skew: float = 0.0) -> Database:
    """Generate a TPC-H database at the given scale factor.

    ``skew > 0`` produces the JCC-H-style variant (see repro.data.jcch):
    a fraction of FK references concentrates on a few hot keys, which skews
    both partition sizes and shuffle destinations.
    """
    rng = np.random.default_rng(seed)
    n_part = max(64, int(200_000 * scale))
    n_supp = max(16, int(10_000 * scale))
    n_cust = max(48, int(150_000 * scale))
    n_ord = max(96, int(1_500_000 * scale))

    def hot(n_keys, size, base_draw):
        """Mix uniform draws with a hot-key population (skew knob)."""
        if skew <= 0:
            return base_draw
        n_hot = max(1, n_keys // 200)
        hot_keys = rng.integers(0, n_keys, n_hot)
        take = rng.random(size) < skew
        out = base_draw.copy()
        out[take] = hot_keys[rng.integers(0, n_hot, int(take.sum()))]
        return out

    dicts: dict[str, np.ndarray] = {
        "r_name": REGIONS, "n_name": NATIONS, "c_mktsegment": SEGMENTS,
        "o_orderpriority": PRIORITIES, "l_shipmode": SHIPMODES,
        "l_shipinstruct": INSTRUCTS, "o_orderstatus": ORDERSTATUS,
        "l_returnflag": RETURNFLAGS, "l_linestatus": LINESTATUS,
        "p_type": TYPES, "p_container": CONTAINERS, "p_brand": BRANDS,
        "p_mfgr": MFGRS,
        "o_comment": _comment_dict(rng, N_COMMENT_TEMPLATES,
                                   ["special", "requests"], 32 / 512),
        "s_comment": _comment_dict(rng, N_COMMENT_TEMPLATES,
                                   ["Customer", "Complaints"], 16 / 512),
    }
    # p_name: 5 colors each; dictionary of distinct names
    n_names = min(2048, max(64, n_part // 4))
    pname_dict = np.array([" ".join(rng.choice(COLORS, size=5, replace=False))
                           for _ in range(n_names)])
    dicts["p_name"] = pname_dict

    region = {"r_regionkey": np.arange(5, dtype=np.int64),
              "r_name": np.arange(5, dtype=np.int32)}
    nation = {"n_nationkey": np.arange(25, dtype=np.int64),
              "n_name": np.arange(25, dtype=np.int32),
              "n_regionkey": NATION_REGION.astype(np.int64)}

    supplier = {
        "s_suppkey": np.arange(1, n_supp + 1, dtype=np.int64),
        "s_nationkey": rng.integers(0, 25, n_supp).astype(np.int64),
        "s_acctbal": np.round(rng.uniform(-999.99, 9999.99, n_supp), 2),
        "s_comment": rng.integers(0, N_COMMENT_TEMPLATES, n_supp).astype(np.int32),
    }

    customer = {
        "c_custkey": np.arange(1, n_cust + 1, dtype=np.int64),
        "c_nationkey": rng.integers(0, 25, n_cust).astype(np.int64),
        "c_acctbal": np.round(rng.uniform(-999.99, 9999.99, n_cust), 2),
        "c_mktsegment": rng.integers(0, 5, n_cust).astype(np.int32),
    }
    customer["c_phone_cc"] = (customer["c_nationkey"] + 10).astype(np.int64)

    part = {
        "p_partkey": np.arange(1, n_part + 1, dtype=np.int64),
        "p_name": rng.integers(0, n_names, n_part).astype(np.int32),
        "p_brand": rng.integers(0, 25, n_part).astype(np.int32),
        "p_type": rng.integers(0, len(TYPES), n_part).astype(np.int32),
        "p_size": rng.integers(1, 51, n_part).astype(np.int64),
        "p_container": rng.integers(0, len(CONTAINERS), n_part).astype(np.int32),
    }
    part["p_mfgr"] = (part["p_brand"] // 5).astype(np.int32)
    p_retail = (90000 + (part["p_partkey"] % 20001) +
                100 * (part["p_partkey"] % 1000)) / 100.0

    # partsupp: spec formula — 4 suppliers per part, guaranteed to cover
    # every (l_partkey, l_suppkey) drawn below.
    pk = np.repeat(part["p_partkey"], 4)
    i4 = np.tile(np.arange(4, dtype=np.int64), n_part)
    sk = (pk + i4 * (n_supp // 4 + (pk - 1) // n_supp)) % n_supp + 1
    # the spec stride can wrap to duplicate (pk, sk) pairs at tiny scale
    # factors; partsupp's composite key must stay unique (it is a PK)
    _, keep = np.unique((pk << 32) | sk, return_index=True)
    keep.sort()
    pk, sk = pk[keep], sk[keep]
    n_ps = len(pk)
    partsupp = {
        "ps_partkey": pk,
        "ps_suppkey": sk.astype(np.int64),
        "ps_availqty": rng.integers(1, 10000, n_ps).astype(np.int64),
        "ps_supplycost": np.round(rng.uniform(1.0, 1000.0, n_ps), 2),
    }

    # orders: skip custkeys ≡ 0 (mod 3) — one third of customers never order
    ck = rng.integers(1, n_cust + 1, n_ord).astype(np.int64)
    ck = np.where(ck % 3 == 0, np.maximum(1, ck - 1), ck)
    ck = hot(n_cust, n_ord, ck)
    odate = rng.integers(days("1992-01-01"), days("1998-08-02") + 1,
                         n_ord).astype(np.int64)
    orders = {
        "o_orderkey": np.arange(1, n_ord + 1, dtype=np.int64),
        "o_custkey": ck,
        "o_orderdate": odate,
        "o_orderpriority": rng.integers(0, 5, n_ord).astype(np.int32),
        "o_shippriority": np.zeros(n_ord, dtype=np.int64),
        "o_comment": rng.integers(0, N_COMMENT_TEMPLATES, n_ord).astype(np.int32),
    }

    # lineitem: 1..7 per order
    per = rng.integers(1, 8, n_ord)
    n_li = int(per.sum())
    lok = np.repeat(orders["o_orderkey"], per)
    lod = np.repeat(odate, per)
    lpk = hot(n_part, n_li, rng.integers(1, n_part + 1, n_li).astype(np.int64))
    isup = rng.integers(0, 4, n_li).astype(np.int64)
    lsk = (lpk + isup * (n_supp // 4 + (lpk - 1) // n_supp)) % n_supp + 1
    qty = rng.integers(1, 51, n_li).astype(np.int64)
    eprice = np.round(qty * p_retail[lpk - 1], 2)
    ship = lod + rng.integers(1, 122, n_li)
    commit = lod + rng.integers(30, 91, n_li)
    receipt = ship + rng.integers(1, 31, n_li)
    cur = days(_CURRENT)
    lstat = (ship > cur).astype(np.int32)           # 0=F shipped, 1=O open
    rflag = np.where(receipt <= cur,
                     rng.integers(0, 2, n_li) * 2,   # A(0) or R(2)
                     np.ones(n_li)).astype(np.int32)  # N(1)

    linenumber = (np.arange(n_li, dtype=np.int64) -
                  np.repeat(np.concatenate([[0], np.cumsum(per)[:-1]]), per) + 1)
    lineitem = {
        "l_orderkey": lok,
        "l_partkey": lpk,
        "l_suppkey": lsk.astype(np.int64),
        "l_linenumber": linenumber,
        "l_quantity": qty,
        "l_extendedprice": eprice,
        "l_discount": np.round(rng.uniform(0.0, 0.10, n_li), 2),
        "l_tax": np.round(rng.uniform(0.0, 0.08, n_li), 2),
        "l_returnflag": rflag,
        "l_linestatus": lstat,
        "l_shipdate": ship.astype(np.int64),
        "l_commitdate": commit.astype(np.int64),
        "l_receiptdate": receipt.astype(np.int64),
        "l_shipinstruct": rng.integers(0, 4, n_li).astype(np.int32),
        "l_shipmode": rng.integers(0, len(SHIPMODES), n_li).astype(np.int32),
    }

    # o_totalprice = sum(extendedprice*(1+tax)*(1-discount)); o_orderstatus
    charge = eprice * (1 + lineitem["l_tax"]) * (1 - lineitem["l_discount"])
    tot = np.zeros(n_ord)
    np.add.at(tot, lok - 1, charge)
    orders["o_totalprice"] = np.round(tot, 2)
    n_open = np.zeros(n_ord, dtype=np.int64)
    np.add.at(n_open, lok - 1, lstat)
    n_all = np.zeros(n_ord, dtype=np.int64)
    np.add.at(n_all, lok - 1, 1)
    orders["o_orderstatus"] = np.where(
        n_open == 0, 0, np.where(n_open == n_all, 1, 2)).astype(np.int32)

    return Database(
        tables={"region": region, "nation": nation, "supplier": supplier,
                "customer": customer, "part": part, "partsupp": partsupp,
                "orders": orders, "lineitem": lineitem},
        dicts=dicts, scale=scale)

"""Data substrate: TPC-H / JCC-H generators + partitioned loading."""

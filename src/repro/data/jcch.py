"""JCC-H-style skewed TPC-H variant (Boncz et al., drop-in schema-compatible).

JCC-H adds join-crossing correlations and heavy skew to TPC-H.  We reproduce
the property the paper exercises (§7.2): a small hot-key population receives a
large share of FK references, so (a) hash partitions are unbalanced across
devices, (b) shuffles develop per-node send/recv skew, and (c) some GPUs build
much larger hash tables.  The schema and queries are unchanged.
"""
from __future__ import annotations

from repro.core.table import Database
from . import tpch

DEFAULT_SKEW = 0.25  # fraction of FK draws redirected to the hot population


def generate(scale: float, seed: int = 7, skew: float = DEFAULT_SKEW) -> Database:
    return tpch.generate(scale, seed=seed, skew=skew)

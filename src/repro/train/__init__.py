"""train subpackage."""

"""AdamW with ZeRO-style sharded state and optional gradient compression.

Optimizer state mirrors the parameter PartitionSpecs (FSDP+TP 2-D sharding),
so m/v never materialize unsharded — GSPMD keeps updates local.  Gradient
compression (bf16 / int8 with error feedback) reduces the all-reduce bytes of
the data-parallel gradient reduction; the residual buffer makes it unbiased
over steps.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


def lr_schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init_state(params):
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
    }


def apply_update(cfg: AdamWConfig, params, grads, state):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step.astype(F32))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(F32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, m, v):
        g = g.astype(F32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / (1 - cfg.b1 ** step.astype(F32))
        vh = v / (1 - cfg.b2 ** step.astype(F32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:                      # decoupled weight decay (matrices)
            delta = delta + cfg.weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * delta).astype(p.dtype), m, v

    flat = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"step": step, "m": new_m, "v": new_v}, \
        {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# gradient compression (distributed-optimization trick)
# ---------------------------------------------------------------------------

def compress_bf16(grads):
    """Cast the DP all-reduce payload to bf16 (2x collective bytes saved)."""
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)


def compress_int8_ef(grads, residual):
    """Per-tensor int8 quantization with error feedback.

    Returns (quantized-as-f32 grads, new residual).  The all-reduce payload in
    a real deployment is the int8 tensor + scale; here we model it by rounding
    through int8 so numerics match what the wire would carry."""
    def q(g, r):
        g = g.astype(F32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-9) / 127.0
        qg = jnp.clip(jnp.round(g / scale), -127, 127)
        deq = qg * scale
        return deq, g - deq

    pairs = jax.tree.map(q, grads, residual)
    deq = jax.tree.map(lambda t: t[0], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_r = jax.tree.map(lambda t: t[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_r

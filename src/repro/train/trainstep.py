"""train_step / serve_step factories with sharding + remat + compression."""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.transformer import Model
from . import optimizer as opt

F32 = jnp.float32


def make_train_step(model: Model, ocfg: opt.AdamWConfig,
                    grad_compress: str = "none", microbatches: int = 1):
    """Returns step(params, state, batch) -> (params, state, metrics).

    batch: {"tokens", "labels"} (+ "patches" for VLM).  grad_compress in
    {none, bf16, int8_ef}; int8_ef expects state["ef"] (error feedback).

    ``microbatches`` > 1 accumulates gradients over a scan of batch slices —
    peak activation memory drops ~M-fold at identical math (the fix for
    cells whose per-device working set exceeds HBM; EXPERIMENTS §Perf)."""

    def grads_of(params, batch):
        extra = {k: v for k, v in batch.items()
                 if k not in ("tokens", "labels")} or None

        def loss_fn(p):
            return model.loss(p, batch["tokens"], batch["labels"],
                              extra=extra)

        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def step(params, state, batch):
        if microbatches == 1:
            (loss, aux), grads = grads_of(params, batch)
        else:
            m = microbatches
            sliced = jax.tree.map(
                lambda x: x.reshape(m, x.shape[0] // m, *x.shape[1:]), batch)

            def body(acc, micro):
                (l, a), g = grads_of(params, micro)
                acc_g, acc_l, acc_aux = acc
                acc_g = jax.tree.map(lambda s, gi: s + gi.astype(F32) / m,
                                     acc_g, g)
                acc_aux = jax.tree.map(lambda s, ai: s + ai / m, acc_aux, a)
                return (acc_g, acc_l + l / m, acc_aux), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            zero_aux = {"lb_loss": jnp.zeros((), F32), "ce": jnp.zeros((), F32),
                        "drop_frac": jnp.zeros((), F32)}
            (grads, loss, aux), _ = jax.lax.scan(
                body, (zero_g, jnp.zeros((), F32), zero_aux), sliced)
        new_state = dict(state)
        if grad_compress == "bf16":
            grads = opt.compress_bf16(grads)
        elif grad_compress == "int8_ef":
            grads, new_state["ef"] = opt.compress_int8_ef(grads, state["ef"])
        params, new_state["opt"], om = opt.apply_update(ocfg, params, grads,
                                                        state["opt"])
        metrics = {"loss": loss.astype(F32), **aux, **om}
        return params, new_state, metrics

    return step


def init_train_state(model: Model, params, grad_compress: str = "none"):
    state = {"opt": opt.init_state(params)}
    if grad_compress == "int8_ef":
        state["ef"] = opt.init_error_feedback(params)
    return state


def make_prefill_step(model: Model, batch: int, max_len: int,
                      cache_dtype=jnp.bfloat16):
    def prefill(params, inputs):
        extra = {k: v for k, v in inputs.items() if k != "tokens"} or None
        cache = model.init_cache(batch, max_len, dtype=cache_dtype)
        return model.prefill(params, inputs["tokens"], cache, extra=extra)
    return prefill


def make_decode_step(model: Model):
    def decode(params, token, cache, pos):
        return model.decode(params, token, cache, pos)
    return decode

"""Progressive execution: climb the sample ladder until the CI fits.

The protocol mirrors the fault runner's escalation policies — in fact it
*reuses* them: every rung executes through a :class:`QueryRunner`, so
transient faults back off, overflow climbs ``capacity_factor``, corruption
falls back to the wide wire format, all inside one rung.  What is new is the
outcome BETWEEN rungs: an attempt that ran clean but whose reported
confidence interval exceeds the caller's tolerance is stamped
``FailureKind.TOLERANCE_MISS`` and the runner climbs to the next larger rung,
the way OVERFLOW climbs the capacity factor.

Termination is structural, not statistical: the ladder is finite and its top
rung (``den == 1``) is the full table — the rewrite there is a pure scan
rename with zero-width intervals, so the loop can always end with an exact
answer.  Plans the rewrite pass refuses (min/max, semi/anti-dependent counts,
tiny domains, multi-scan aggregates) skip the ladder entirely and run exact
(``rung == 0``).

``REPRO_APPROX`` (env) sets the default serving tolerance: unset / ``0`` /
``off`` means exact-only; any float (e.g. ``0.05``) makes
``QueryServer.submit`` answer approximately within that relative CI
half-width unless the caller passes an explicit ``tolerance=``.
"""

from __future__ import annotations

import dataclasses
import os

from repro.distributed.chaos import FailureKind
from repro.distributed.fault import QueryRunner, RunReport

from . import estimators as E
from . import rewrite as R
from . import sampling

__all__ = ["approx_default", "ApproxAnswer", "ProgressiveRunner"]


def approx_default() -> float | None:
    """The ``REPRO_APPROX`` default tolerance (None = exact-only serving)."""
    raw = os.environ.get("REPRO_APPROX", "").strip().lower()
    if raw in ("", "0", "off", "none", "false"):
        return None
    return float(raw)


@dataclasses.dataclass
class ApproxAnswer:
    """Result of a progressive run, with its provenance."""

    result: dict          # numpy columns (moment columns stripped)
    rung: int             # ladder denominator answered from; 0 = exact plan
    ci_width: float       # max relative CI half-width (0.0 when exact)
    confidence: float
    tolerance: float
    exact: bool           # rung in (0, 1): no sampling error at all
    escalations: int      # tolerance misses climbed past
    report: RunReport     # merged per-rung attempt audit (rung + ci tagged)


class ProgressiveRunner:
    """Answer from the smallest rung; escalate while CI > tolerance.

    ``mesh=None`` (the default) runs each rung on the single-device engine;
    with a mesh, rungs execute distributed — the sample tables partition on
    the base table's key, and the CLT moments ride the partial-aggregate
    merges, so the error bars are exchange-invariant.
    """

    def __init__(self, db, mesh=None, tolerance: float = 0.05,
                 confidence: float = 0.95, ladder=sampling.LADDER,
                 seed: int = sampling.DEFAULT_SEED,
                 min_rows: int = R.MIN_SAMPLE_ROWS, tables=None,
                 capacity_factor: float = 2.0, max_attempts: int = 4,
                 join_method: str = "sorted", wire_format: str | None = None,
                 policy=None, chaos=None, local_jit: bool = True):
        if tolerance < 0:
            raise ValueError(f"tolerance must be >= 0, got {tolerance}")
        self.db = db
        self.mesh = mesh
        self.tolerance = float(tolerance)
        self.confidence = float(confidence)
        # largest denominator (smallest sample) first; top rung must be 1 so
        # the ladder always ends exact
        self.ladder = tuple(sorted(set(int(d) for d in ladder), reverse=True))
        if not self.ladder or self.ladder[-1] != 1:
            raise ValueError(f"ladder must end at rung 1, got {ladder}")
        bad = [d for d in self.ladder if d not in sampling.LADDER]
        if bad:
            # fail here, not mid-run(): rewrite_for_rung rejects off-ladder
            # denominators, so a bad custom ladder must never start climbing
            raise ValueError(f"ladder rungs {bad} are not on the sampling "
                             f"ladder {sampling.LADDER}")
        self.seed = seed
        self.min_rows = min_rows
        self.tables = tables
        self._runner_kwargs = dict(
            capacity_factor=capacity_factor, max_attempts=max_attempts,
            join_method=join_method, wire_format=wire_format, policy=policy,
            chaos=chaos, local_jit=local_jit)

    def _run_rung(self, db, query_fn):
        runner = QueryRunner(db, self.mesh, **self._runner_kwargs)
        return runner.run(query_fn)

    def run(self, query) -> ApproxAnswer:
        """Execute one compiled query progressively.

        ``query`` must be a ``planner.CompiledQuery`` (bind serve templates
        first, or go through ``QueryServer.submit(tolerance=...)``).
        """
        report = RunReport()
        escalations = 0
        for den in self.ladder:
            rw = R.rewrite_for_rung(query, self.db, den, seed=self.seed,
                                    min_rows=self.min_rows,
                                    tables=self.tables)
            if rw is None:
                break    # non-estimable shape: the honest answer is exact
            rr = self._run_rung(rw.db, rw.query)
            est = rw.finalize(rr.result, self.confidence)
            for a in rr.report.attempts:
                a.rung = den
            rr.report.attempts[-1].ci_width = est.rel_width
            report.attempts.extend(rr.report.attempts)
            report.injected.extend(rr.report.injected)
            if est.rel_width <= self.tolerance or den == 1:
                return ApproxAnswer(
                    result=est.result, rung=den, ci_width=est.rel_width,
                    confidence=self.confidence, tolerance=self.tolerance,
                    exact=(den == 1), escalations=escalations, report=report)
            # clean execution, interval too wide: climb the ladder the way
            # OVERFLOW climbs capacity_factor
            rr.report.attempts[-1].outcome = FailureKind.TOLERANCE_MISS.value
            escalations += 1
        rr = self._run_rung(self.db, query)
        rr.report.attempts[-1].ci_width = 0.0
        report.attempts.extend(rr.report.attempts)
        report.injected.extend(rr.report.injected)
        return ApproxAnswer(
            result=rr.result, rung=0, ci_width=0.0,
            confidence=self.confidence, tolerance=self.tolerance,
            exact=True, escalations=escalations, report=report)

"""Stratified sample ladder over ``Database`` fact tables.

A *rung* is a stratified sample of one fact table at ratio ``1/den`` for
``den`` in the :data:`LADDER` (16, 8, 4, 2, 1).  Row selection is a
deterministic seeded hash rank: every row gets a 64-bit splitmix hash of its
global row index, and within each stratum the ``m_g = max(1, ceil(n_g/den))``
smallest hashes are kept.  Two consequences the estimators and tests rely on:

* **min-1 stratification** — every stratum (the aggregation's group keys, as
  reported by the rewrite pass) keeps at least one row, so small groups
  survive downsampling instead of silently vanishing;
* **nesting** — the hash order does not depend on ``den``, so the rung-16
  sample is a subset of rung 8, which is a subset of rung 4, and so on up to
  rung 1 (the full table).  Escalating a rung only *adds* evidence.

Sample tables carry three bookkeeping columns next to the original ones
(row order preserved):

* ``__sw`` (float64) — the Horvitz-Thompson scale-up weight ``n_g / m_g``,
  constant within a stratum;
* ``__sm`` (int64) — the pre-filter stratum sample size ``m_g``;
* ``__sn`` (int64) — the true stratum size ``n_g``.

Rung databases are cached per source ``Database`` and evicted through the
planner invalidation registry, exactly like ``serve.cache.PlanCache``:
``planner.invalidate_stats(db)`` (or a ``stats_override`` exit) drops every
rung derived from ``db``.
"""

from __future__ import annotations

import weakref

import numpy as np

from repro.core import planner
from repro.core.plan import SAMPLE_M_COL, SAMPLE_N_COL, SAMPLE_WEIGHT_COL
from repro.core.table import Database

__all__ = [
    "LADDER",
    "DEFAULT_SEED",
    "rung_name",
    "stratified_selection",
    "sample_table",
    "rung_database",
    "invalidate",
]

# Denominators, largest (smallest sample) first: the progressive runner climbs
# this left to right.  The final rung 1 is the full table — exact by
# construction, which is what makes the ladder a terminating protocol.
LADDER = (16, 8, 4, 2, 1)

# Fixed default so every layer (rewrite, serve, benchmarks, tests) lands on
# the same cached rung unless a caller deliberately varies the seed.
DEFAULT_SEED = 0x5EED


def rung_name(table: str, den: int) -> str:
    """Name of the rung table derived from ``table`` at ratio ``1/den``."""
    return f"{table}__r{int(den)}"


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """SplitMix64 finalizer — a deterministic 64-bit mix per row index."""
    z = x.astype(np.uint64, copy=True)
    with np.errstate(over="ignore"):
        z += np.uint64(0x9E3779B97F4A7C15)
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        z = z ^ (z >> np.uint64(31))
    return z


def stratified_selection(strata, n_rows, den, seed=DEFAULT_SEED):
    """Pick rows for one rung.

    ``strata`` is a sequence of integer numpy columns (possibly empty for a
    single global stratum).  Returns ``(mask, sid, n_g, m_g)`` where ``mask``
    is the boolean keep-mask over the ``n_rows`` input rows, ``sid`` maps each
    row to its stratum id, and ``n_g`` / ``m_g`` are per-stratum population
    and sample sizes indexed by stratum id.
    """
    den = int(den)
    if den < 1:
        raise ValueError(f"ladder denominator must be >= 1, got {den}")
    if strata:
        key = np.stack([np.asarray(c).astype(np.int64) for c in strata], axis=1)
        _, sid = np.unique(key, axis=0, return_inverse=True)
        sid = sid.reshape(-1)
    else:
        sid = np.zeros(n_rows, dtype=np.int64)
    n_g = np.bincount(sid)
    m_g = np.maximum(1, -(-n_g // den))  # ceil(n_g / den), floor 1
    # Per-row hash is a pure function of (seed, global row index): the same
    # row ranks identically at every den, which is what nests the rungs.
    mixed_seed = np.uint64((int(seed) * 0x2545F4914F6CDD1D) % (1 << 64))
    with np.errstate(over="ignore"):
        h = _splitmix64(np.arange(n_rows, dtype=np.uint64) + mixed_seed)
    order = np.lexsort((h, sid))  # group by stratum, hash-ranked within
    starts = np.concatenate(([0], np.cumsum(n_g)))
    rank = np.empty(n_rows, dtype=np.int64)
    rank[order] = np.arange(n_rows, dtype=np.int64) - np.repeat(starts[:-1], n_g)
    mask = rank < m_g[sid]
    return mask, sid, n_g, m_g


def sample_table(table_cols, strata_names, den, seed=DEFAULT_SEED):
    """Materialize one rung of a plain-numpy table dict.

    Keeps the original row order (boolean-mask selection) and appends the
    ``__sw`` / ``__sm`` / ``__sn`` bookkeeping columns.  ``strata_names``
    must name integer columns of the table; an empty tuple means one global
    stratum (the scalar-aggregate case).
    """
    cols = {c: np.asarray(v) for c, v in table_cols.items()}
    n_rows = len(next(iter(cols.values()))) if cols else 0
    for s in strata_names:
        if s not in cols:
            raise KeyError(f"stratum column {s!r} not in table")
        if cols[s].dtype.kind not in "iu":
            raise TypeError(f"stratum column {s!r} must be integer-typed")
    mask, sid, n_g, m_g = stratified_selection(
        [cols[s] for s in strata_names], n_rows, den, seed)
    out = {c: v[mask] for c, v in cols.items()}
    ssel = sid[mask]
    out[SAMPLE_WEIGHT_COL] = (n_g[ssel] / m_g[ssel]).astype(np.float64)
    out[SAMPLE_M_COL] = m_g[ssel].astype(np.int64)
    out[SAMPLE_N_COL] = n_g[ssel].astype(np.int64)
    return out


# ---------------------------------------------------------------------------
# Rung-database cache, evicted through the planner invalidation registry
# (same pattern as serve.cache.PlanCache: keyed on id(db) with a weakref
# guard against id reuse, dropped by planner.invalidate_stats).

_RUNGS: dict = {}  # (id(db), table, strata, den, seed) -> (weakref(db), rung_db)


def _drop_rung_partition_keys(dead_keys) -> None:
    """Unregister ``backend.PARTITION_KEYS`` entries for invalidated rungs.

    A rung name may be shared by rungs of other live databases (same table
    and den, different ``Database``); the entry stays until the last one is
    evicted — the registered value is the base table's key either way."""
    if not dead_keys:
        return
    from repro.core import backend as B
    live = {rung_name(k[1], k[3]) for k in _RUNGS}
    for k in dead_keys:
        name = rung_name(k[1], k[3])
        if name not in live:
            B.PARTITION_KEYS.pop(name, None)


def _invalidation_hook(db) -> None:
    dead = [k for k, (ref, _) in _RUNGS.items()
            if k[0] == id(db) or ref() is None]
    for k in dead:
        _RUNGS.pop(k, None)
    _drop_rung_partition_keys(dead)


planner.register_invalidation(_invalidation_hook)


def invalidate(db=None) -> None:
    """Drop cached rungs for ``db`` (or all rungs when ``db`` is None)."""
    if db is None:
        dead = list(_RUNGS)
        _RUNGS.clear()
        _drop_rung_partition_keys(dead)
    else:
        _invalidation_hook(db)


def rung_database(db: Database, table: str, strata, den: int,
                  seed: int = DEFAULT_SEED) -> Database:
    """A sibling ``Database`` that adds the rung table next to the originals.

    The rung table is registered in ``backend.PARTITION_KEYS`` under the base
    table's partition key, so distributed execution shards the sample the
    same way it shards the fact table instead of replicating it.
    """
    strata = tuple(strata)
    key = (id(db), table, strata, int(den), int(seed))
    hit = _RUNGS.get(key)
    if hit is not None:
        ref, rdb = hit
        if ref() is db:
            return rdb
        _RUNGS.pop(key, None)
    from repro.core import backend as B  # deferred: keep sampling importable early

    name = rung_name(table, den)
    samp = sample_table(db.tables[table], strata, den, seed)
    rdb = Database(tables={**db.tables, name: samp}, dicts=db.dicts,
                   scale=db.scale)
    # only partitioned base tables register: an explicit name -> None entry
    # would make dryrun analytics classify the rung as replicated
    pkey = B.PARTITION_KEYS.get(table)
    if pkey is not None:
        B.PARTITION_KEYS.setdefault(name, pkey)
    _RUNGS[key] = (weakref.ref(db), rdb)
    return rdb

"""Approximate & progressive query answers (ROADMAP item 3).

A size ladder of stratified samples (:mod:`.sampling`), per-aggregate
scale-up + CLT error bars riding the partial-aggregate machinery
(:mod:`.estimators`), a planner pass rewriting aggregation plans onto a rung
(:mod:`.rewrite`, also reachable as ``CompiledQuery.approximate``), and a
progressive runner that climbs the ladder while the confidence interval
exceeds the caller's tolerance (:mod:`.progressive`;
``QueryServer.submit(tolerance=...)`` is the serving entry point).
"""

from .estimators import ESTIMABLE_OPS, ApproxEstimate, finalize_result
from .progressive import ApproxAnswer, ProgressiveRunner, approx_default
from .rewrite import ApproxRewrite, rewrite_for_rung
from .sampling import DEFAULT_SEED, LADDER, rung_database, sample_table

__all__ = [
    "LADDER", "DEFAULT_SEED", "sample_table", "rung_database",
    "ESTIMABLE_OPS", "ApproxEstimate", "finalize_result",
    "ApproxRewrite", "rewrite_for_rung",
    "ApproxAnswer", "ProgressiveRunner", "approx_default",
]

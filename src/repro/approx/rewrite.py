"""Planner pass: rewrite an aggregation plan onto a sample-ladder rung.

``rewrite_for_rung(query, db, den)`` looks for exactly one *rewrite site* —
a ``GroupBy`` or ``AggScalar`` whose input is a unary chain
(``Filter`` / ``Select`` / ``WithCol`` only) down to a ``Scan`` of a ladder
fact table — and rebuilds the DAG with

* the ``Scan`` retargeted onto the rung table (``<table>__r<den>``, built by
  :mod:`repro.approx.sampling` stratified on the site's group keys);
* every estimable aggregate scale-up rewritten per
  :mod:`repro.approx.estimators` (``sum(x)`` → ``sum(__sw * x)``,
  ``count`` → ``sum(__sw)``, ``avg`` untouched);
* CLT moment columns injected as ordinary aggregates (max/sum/count), so
  they ride the engine's partial-aggregate merges across every exchange.

It **refuses** — returns ``None``, meaning "run exact" — whenever the shape
is not estimable:

* any ``min`` / ``max`` aggregate at the site (an unsampled extreme is
  invisible; no CLT bar covers it);
* the chain from site to scan passes through a join/semi/anti/rename or any
  other non-unary operator (semi/anti-dependent counts cannot be scaled by a
  per-stratum weight);
* the site's output reaches the root through anything but projections and
  ``Finalize`` — a ``Filter`` (SQL HAVING), join, or expression consuming a
  scaled estimate would decide group membership / exact downstream results
  from an un-barred estimate (q18's ``sum_qty > 300`` is the canonical
  refusal);
* zero or multiple candidate sites, or the scan/chain is shared with another
  consumer (the sample would leak into non-aggregate outputs);
* the scanned table is too small (``min_rows``) — tiny inferred domains are
  cheaper exact than estimated;
* a group key that is not a raw integer column of the fact table (it could
  not have been a stratification key).

A ``Select`` between the site and the root (SQL lowering emits one whenever
the SELECT list reorders or omits GroupBy outputs) is rebuilt with the
moment columns appended, so the error-bar evidence is never projected away
between the site and :func:`repro.approx.estimators.finalize_result`.

``den == 1`` is special-cased to a pure scan rename (the rung-1 "sample" is
the full table, row order preserved): no scale-up, no moment columns — the
plan is byte-identical to the exact one on every backend, which is the
differential identity leg ``tests/test_approx.py`` pins.
"""

from __future__ import annotations

import dataclasses

from repro.core import plan as P
from repro.core import planner

from . import estimators as E
from . import sampling

__all__ = ["MIN_SAMPLE_ROWS", "ApproxRewrite", "rewrite_for_rung"]

# Below this row count the exact plan is already interactive — sampling would
# only add variance (ISSUE: "tiny inferred domains" refuse the rewrite).
MIN_SAMPLE_ROWS = 256

# Unary operators the site→scan chain may pass through.  Rename is excluded:
# it would detach the group keys from the stratification columns.
_CHAIN_OK = (P.Filter, P.Select, P.WithCol)


@dataclasses.dataclass
class ApproxRewrite:
    """A sample-rewritten query plus everything needed to run and finalize it."""

    query: "planner.CompiledQuery"   # the rewritten plan, compiled
    db: object                       # rung database (original tables + sample)
    den: int                         # ladder denominator (1 == full table)
    table: str                       # fact table that was sampled
    strata: tuple                    # stratification columns (the group keys)
    targets: tuple                   # (name, op) per estimable aggregate

    def finalize(self, cols, confidence: float = 0.95) -> E.ApproxEstimate:
        # den > 1 means the targets are scale-rewritten: finalize must find
        # the moment columns or raise — never serve an estimate as exact
        return E.finalize_result(cols, self.targets, confidence,
                                 scaled=self.den > 1)


def _default_tables():
    from repro.data import tpch      # deferred: data layer is optional here
    return tpch.FACT_TABLES


def _consumers(nodes):
    """node id -> number of distinct consuming edges (children + ScalarRefs)."""
    count: dict[int, int] = {}
    for n in nodes:
        for c in n.children:
            count[id(c)] = count.get(id(c), 0) + 1
        for e in planner._node_exprs(n):
            for sub in planner._expr_scalar_nodes(e):
                count[id(sub)] = count.get(id(sub), 0) + 1
    return count


def _find_site(root, db, tables, min_rows):
    """The unique (site, chain, scan) rewrite candidate, or None."""
    nodes = planner.walk(root)
    consumers = _consumers(nodes)
    candidates = []
    for site in nodes:
        if not isinstance(site, (P.GroupBy, P.AggScalar)):
            continue
        chain = []
        cur = site.children[0]
        while isinstance(cur, _CHAIN_OK):
            chain.append(cur)
            cur = cur.children[0]
        if not isinstance(cur, P.Scan):
            continue
        if cur.table not in tables or cur.table not in db.tables:
            continue
        t = db.tables[cur.table]
        n_rows = len(next(iter(t.values()))) if t else 0
        if n_rows < min_rows:
            continue
        # exclusivity: the scan and every chain node must feed only this
        # aggregation — a shared subtree would leak sample rows elsewhere
        if any(consumers.get(id(x), 0) != 1 for x in chain + [cur]):
            continue
        # an AggScalar estimate may only surface in the terminal
        # ScalarResult: feeding it into further computation (a filter
        # threshold, another aggregate) would poison exact downstream
        # results with an un-barred estimate
        if isinstance(site, P.AggScalar):
            refs = [n for n in nodes if any(
                site in planner._expr_scalar_nodes(e)
                for e in planner._node_exprs(n))]
            if refs != [root] or not isinstance(root, P.ScalarResult):
                continue
        candidates.append((site, tuple(chain), cur))
    if len(candidates) != 1:
        return None
    return candidates[0]


def _estimate_consumers(root, site):
    """Every node through which ``site``'s output flows on its way to the
    root — child edges and expression-embedded scalar references alike.
    ``site`` itself is excluded."""
    memo: dict[int, bool] = {}

    def reaches(n):
        got = memo.get(id(n))
        if got is not None:
            return got
        memo[id(n)] = False   # guard (plans are DAGs; cheap insurance)
        hit = any(c is site or reaches(c) for c in n.children)
        if not hit:
            for e in planner._node_exprs(n):
                if any(s is site or reaches(s)
                       for s in planner._expr_scalar_nodes(e)):
                    hit = True
                    break
        memo[id(n)] = hit
        return hit

    return [n for n in planner.walk(root) if n is not site and reaches(n)]


def _group_site_path_ok(consumers, site):
    """True iff a GroupBy site's scaled estimates reach the root only through
    non-computing nodes: projections (``Select``, key-only ``Rename``) and
    ``Finalize``.  A ``Filter`` (SQL HAVING), join, ``WithCol``, or any
    downstream aggregate would fold un-barred estimates into exact results —
    group membership decided by a point estimate is not covered by its CI —
    so such shapes refuse and run exact."""
    agg_names = {name for name, _, _ in site.aggs}
    for n in consumers:
        if isinstance(n, (P.Select, P.Finalize)):
            continue
        if isinstance(n, P.Rename) and not (set(n.mapping) & agg_names):
            continue
        return False
    return True


def _strata_for(site, scan_table, chain, db):
    """Group keys as stratification columns, or None if not raw fact columns."""
    keys = tuple(site.keys) if isinstance(site, P.GroupBy) else ()
    cols = db.tables[scan_table]
    import numpy as np
    for k in keys:
        v = cols.get(k)
        if v is None or np.asarray(v).dtype.kind not in "iu":
            return None
    # a WithCol on the chain redefining a key detaches it from the stratum
    for node in chain:
        if isinstance(node, P.WithCol) and any(k in node.exprs for k in keys):
            return None
    return keys


def _rebuild_expr(e, rebuild):
    """Copy an expression iff it embeds a rebuilt scalar sub-query."""
    if isinstance(e, P.ScalarRef):
        node = rebuild(e.node)
        return e if node is e.node else P.ScalarRef(node, e.name)
    if isinstance(e, P.BinOp):
        a, b = _rebuild_expr(e.a, rebuild), _rebuild_expr(e.b, rebuild)
        return e if a is e.a and b is e.b else P.BinOp(e.op, a, b)
    if isinstance(e, P.NotE):
        a = _rebuild_expr(e.a, rebuild)
        return e if a is e.a else P.NotE(a)
    if isinstance(e, P.Cast):
        a = _rebuild_expr(e.a, rebuild)
        return e if a is e.a else P.Cast(a, e.dtype)
    if isinstance(e, P.Year):
        a = _rebuild_expr(e.a, rebuild)
        return e if a is e.a else P.Year(a)
    if isinstance(e, P.Where):
        c = _rebuild_expr(e.cond, rebuild)
        a = _rebuild_expr(e.a, rebuild)
        b = _rebuild_expr(e.b, rebuild)
        return e if (c is e.cond and a is e.a and b is e.b) else P.Where(c, a, b)
    if isinstance(e, P.InSet):
        a = _rebuild_expr(e.a, rebuild)
        vals = tuple(_rebuild_expr(v, rebuild) for v in e.values)
        if a is e.a and all(x is y for x, y in zip(vals, e.values)):
            return e
        return P.InSet(a, vals)
    return e


def _scalar_targets(root, site, targets):
    """Remap AggScalar targets onto the terminal ScalarResult's output names.

    The site's aggregates carry internal names (SQL compilation emits
    ``__s0``-style slots); the answer columns are the ScalarResult's.  Only a
    *bare* ``ScalarRef`` is estimable — an estimate folded into arithmetic
    (a ratio of two aggregates, say) has no attachable error bar, so the
    rewrite refuses (returns None) and the query runs exact.
    """
    ops = dict(targets)
    out = []
    for k, e in root.exprs.items():
        if site not in planner._expr_scalar_nodes(e):
            continue
        if isinstance(e, P.ScalarRef) and e.node is site and e.name in ops:
            out.append((k, e.name, ops[e.name]))
        else:
            return None
    return tuple(out)


def _rewrite_aggs(aggs):
    """Scale-up + moment injection for one site's aggregate list.

    Returns ``(new_aggs, targets)`` or ``None`` when any aggregate is
    non-estimable.  The moment aggregates use only sum/max/count — ops the
    exchange layer already merges — so the error bars survive distribution.
    """
    wcol = P.col(P.SAMPLE_WEIGHT_COL)
    new_aggs, targets, moments = [], [], []
    for name, op, v in aggs:
        if op not in E.ESTIMABLE_OPS:
            return None
        ve = P.col(v) if isinstance(v, str) else v
        if op == "sum":
            new_aggs.append((name, "sum", wcol * ve))
        elif op == "count":
            new_aggs.append((name, "sum", wcol))
        else:  # avg: the plain sample mean is the estimator — unscaled
            new_aggs.append((name, op, v))
        targets.append((name, op))
        if op in ("sum", "avg"):
            moments.append((E.s1_col(name), "sum", ve))
            moments.append((E.s2_col(name), "sum", ve * ve))
    moments.append((E.N_COL, "max", P.col(P.SAMPLE_N_COL)))
    moments.append((E.M_COL, "max", P.col(P.SAMPLE_M_COL)))
    moments.append((E.MF_COL, "count", None))
    return tuple(new_aggs) + tuple(moments), tuple(targets)


def rewrite_for_rung(query, db, den, seed=sampling.DEFAULT_SEED,
                     min_rows=MIN_SAMPLE_ROWS, tables=None):
    """Rewrite ``query`` onto ladder rung ``1/den`` against ``db``.

    Returns an :class:`ApproxRewrite`, or ``None`` when the plan's shape is
    non-estimable and must run exact.  ``tables`` overrides the ladder fact
    tables (default: :data:`repro.data.tpch.FACT_TABLES`).
    """
    den = int(den)
    if den not in sampling.LADDER:
        raise ValueError(f"den={den} not on the ladder {sampling.LADDER}")
    root = query.plan
    if tables is None:
        tables = _default_tables()
    found = _find_site(root, db, tuple(tables), min_rows)
    if found is None:
        return None
    site, chain, scan_node = found
    strata = _strata_for(site, scan_node.table, chain, db)
    if strata is None:
        return None
    consumer_select_ids: set[int] = set()
    moment_names: tuple = ()
    if den > 1:
        if isinstance(site, P.GroupBy):
            consumers = _estimate_consumers(root, site)
            if not _group_site_path_ok(consumers, site):
                return None
            consumer_select_ids = {id(n) for n in consumers
                                   if isinstance(n, P.Select)}
        rewritten = _rewrite_aggs(site.aggs)
        if rewritten is None:
            return None
        new_aggs, targets = rewritten
        moment_names = tuple(n for n, _, _ in new_aggs
                             if n.startswith(E.MOMENT_PREFIX))
    else:
        # rung 1 is the full table: keep the exact aggregate forms (and
        # dtypes) — byte-identity with the exact plan is a tested invariant
        new_aggs = site.aggs
        targets = tuple((name, op) for name, op, _ in site.aggs
                        if op in E.ESTIMABLE_OPS)
    scalar_map = None
    if isinstance(site, P.AggScalar):
        scalar_map = _scalar_targets(root, site, targets)
        if scalar_map is None:
            return None
        targets = tuple((k, op) for k, _, op in scalar_map)
    rdb = sampling.rung_database(db, scan_node.table, strata, den, seed)
    rname = sampling.rung_name(scan_node.table, den)
    chain_ids = {id(c) for c in chain}

    memo: dict[int, P.Node] = {}

    def rebuild(node):
        got = memo.get(id(node))
        if got is not None:
            return got
        new = _rebuild_node(node)
        memo[id(node)] = new
        return new

    def _rebuild_node(node):
        if node is scan_node:
            return P.Scan(rname)
        if id(node) in chain_ids:
            child = rebuild(node.children[0])
            if isinstance(node, P.Filter):
                return P.Filter(child, node.pred)
            if isinstance(node, P.WithCol):
                return P.WithCol(child, node.exprs)
            # Select on the sample chain must keep the bookkeeping columns
            # flowing into the site's scale-up/moment aggregates
            extra = () if den == 1 else tuple(
                c for c in (P.SAMPLE_WEIGHT_COL, P.SAMPLE_M_COL,
                            P.SAMPLE_N_COL) if c not in node.names)
            return P.Select(child, tuple(node.names) + extra)
        if node is site:
            child = rebuild(node.children[0])
            if isinstance(node, P.GroupBy):
                return child.group_by(node.keys, new_aggs,
                                      exchange=node.exchange, final=node.final,
                                      groups_hint=node.groups_hint)
            new_site = child.agg_scalar(new_aggs)
            return new_site
        if isinstance(node, P.Scan):
            return node       # a scan of some other (unsampled) table
        kids = tuple(rebuild(c) for c in node.children)
        same_kids = all(k is c for k, c in zip(kids, node.children))
        if isinstance(node, P.Filter):
            pred = _rebuild_expr(node.pred, rebuild)
            if same_kids and pred is node.pred:
                return node
            return P.Filter(kids[0], pred)
        if isinstance(node, P.Select):
            if id(node) in consumer_select_ids and moment_names:
                # a projection between the site and the root (SQL lowering
                # emits one when the SELECT list reorders or drops GroupBy
                # outputs) must keep the moment columns flowing to finalize
                extra = tuple(c for c in moment_names if c not in node.names)
                return P.Select(kids[0], tuple(node.names) + extra)
            return node if same_kids else P.Select(kids[0], node.names)
        if isinstance(node, P.WithCol):
            exprs = {k: _rebuild_expr(v, rebuild) for k, v in node.exprs.items()}
            if same_kids and all(exprs[k] is node.exprs[k] for k in exprs):
                return node
            return P.WithCol(kids[0], exprs)
        if isinstance(node, P.Rename):
            return node if same_kids else P.Rename(kids[0], node.mapping)
        if isinstance(node, P.Join):
            return node if same_kids else P.Join(
                kids[0], kids[1], node.on, node.build_on, node.take)
        if isinstance(node, P.Semi):
            return node if same_kids else P.Semi(
                kids[0], kids[1], node.on, node.build_on)
        if isinstance(node, P.Anti):
            return node if same_kids else P.Anti(
                kids[0], kids[1], node.on, node.build_on)
        if isinstance(node, P.Left):
            return node if same_kids else P.Left(
                kids[0], kids[1], node.on, node.build_on, node.take,
                node.defaults)
        if isinstance(node, P.GroupBy):
            aggs = tuple((n, op, _rebuild_expr(v, rebuild)
                          if isinstance(v, P.Expr) else v)
                         for n, op, v in node.aggs)
            if same_kids and all(a[2] is b[2]
                                 for a, b in zip(aggs, node.aggs)):
                return node
            return P.GroupBy(kids[0], node.keys, aggs, node.exchange,
                             node.final, node.groups_hint)
        if isinstance(node, P.AggScalar):
            aggs = tuple((n, op, _rebuild_expr(v, rebuild)
                          if isinstance(v, P.Expr) else v)
                         for n, op, v in node.aggs)
            if same_kids and all(a[2] is b[2]
                                 for a, b in zip(aggs, node.aggs)):
                return node
            return P.AggScalar(kids[0], aggs)
        if isinstance(node, P.Shuffle):
            return node if same_kids else P.Shuffle(kids[0], node.key)
        if isinstance(node, P.Broadcast):
            return node if same_kids else P.Broadcast(kids[0], node.p2p)
        if isinstance(node, P.Shrink):
            return node if same_kids else P.Shrink(kids[0], node.cap)
        if isinstance(node, P.Finalize):
            return node if same_kids else P.Finalize(
                kids[0], node.sort_keys, node.limit, node.replicated)
        if isinstance(node, P.ScalarResult):
            exprs = {k: _rebuild_expr(v, rebuild)
                     for k, v in node.exprs.items()}
            changed = any(exprs[k] is not node.exprs[k] for k in exprs)
            if not changed:
                return node
            # surface the injected moment scalars so finalize_result can
            # attach error bars to a scalar (AggScalar) answer; moments are
            # re-keyed from the site's internal agg slots onto the result's
            # output names (SQL compilation emits __s0-style slot names)
            if den > 1 and isinstance(site, P.AggScalar):
                new_site = memo[id(site)]
                for mcol in (E.N_COL, E.M_COL, E.MF_COL):
                    exprs[mcol] = P.ScalarRef(new_site, mcol)
                for out_name, agg_name, op in scalar_map:
                    if op in ("sum", "avg"):
                        exprs[E.s1_col(out_name)] = P.ScalarRef(
                            new_site, E.s1_col(agg_name))
                        exprs[E.s2_col(out_name)] = P.ScalarRef(
                            new_site, E.s2_col(agg_name))
            return P.ScalarResult(exprs)
        raise TypeError(f"unhandled plan node {type(node).__name__}")

    new_root = rebuild(root)
    name = getattr(query, "name", "query")
    compiled = planner.compile_query(lambda: new_root, name=f"{name}~r{den}")
    return ApproxRewrite(query=compiled, db=rdb, den=den,
                         table=scan_node.table, strata=strata,
                         targets=targets)

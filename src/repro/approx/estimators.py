"""Scale-up rules and CLT error bars for sample-rewritten aggregations.

Estimability per aggregate op over a stratified sample whose strata are the
aggregation's own group keys (so each output group is exactly one stratum of
true size ``n``, pre-filter sample size ``m``, and post-filter sample count
``mf``):

* ``sum``   — estimable.  The rewrite emits ``sum(__sw * x)``; with the
  weight ``w = n/m`` constant per stratum that equals ``(n/m) * S1``.
* ``count`` — estimable.  The rewrite emits ``sum(__sw)`` = ``(n/m) * mf``.
* ``avg``   — estimable and *unscaled*: the plain sample mean is the
  estimator (self-weighting, because the weight is constant within the
  group), so the rewrite leaves ``avg`` aggregates untouched.
* ``min`` / ``max`` — **non-estimable**: an extreme that was not sampled is
  invisible and no CLT bar covers it.  The rewrite refuses and the query
  runs exact.

Variance rides the engine's own partial-aggregate machinery: the rewrite
injects moment columns (``__ap_n`` = max ``__sn``, ``__ap_m`` = max ``__sm``,
``__ap_mf`` = count(*), and per target ``__ap_s1_<name>`` = sum(x),
``__ap_s2_<name>`` = sum(x*x)) whose merge ops (sum/max) are exactly the ones
exchanges already combine, so error bars survive local/shuffle/gather
exchanges unchanged.  This module turns those moments into 95 % (by default)
normal-approximation intervals:

* sum:   ``s^2 = (S2 - S1^2/m) / (m-1)``;  ``Var = n^2 (1 - m/n) s^2 / m``
* count: a sum of 0/1 pass indicators — ``S1 = S2 = mf`` in the same formula
* avg:   ``s_x^2`` over the ``mf`` post-filter values; ``Var = s_x^2/mf *
  (1 - m/n)`` (the finite-population correction of the sampling stage)

Honesty gate: a group whose sample cannot support a variance estimate
(``m < 2``, or ``mf < 2`` for avg) reports an **infinite** half-width — it
can never satisfy a tolerance, which forces the progressive runner to climb.
A fully-sampled group (``m == n``) reports half-width 0.  Groups with no
post-filter sample rows are simply absent from the output — never fabricated
as zeros.  And a scale-rewritten result that arrives WITHOUT its moment
columns (a projection dropped them) makes :func:`finalize_result` raise —
a scaled (den > 1) estimate must never be reported as exact.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "ESTIMABLE_OPS",
    "MOMENT_PREFIX",
    "N_COL",
    "M_COL",
    "MF_COL",
    "s1_col",
    "s2_col",
    "z_value",
    "t_value",
    "point_estimate",
    "interval",
    "ApproxEstimate",
    "finalize_result",
]

ESTIMABLE_OPS = frozenset({"sum", "count", "avg"})

MOMENT_PREFIX = "__ap_"
N_COL = MOMENT_PREFIX + "n"    # true stratum size n (max of __sn)
M_COL = MOMENT_PREFIX + "m"    # pre-filter sample size m (max of __sm)
MF_COL = MOMENT_PREFIX + "mf"  # post-filter sample count (count(*))


def s1_col(name: str) -> str:
    return f"{MOMENT_PREFIX}s1_{name}"


def s2_col(name: str) -> str:
    return f"{MOMENT_PREFIX}s2_{name}"


# Two-sided normal quantiles; anything else falls back to scipy-free
# inversion via math.erf bisection (confidence levels used in anger are the
# tabulated ones).
_Z_TABLE = {0.90: 1.6448536269514722,
            0.95: 1.959963984540054,
            0.99: 2.5758293035489004}


def z_value(confidence: float = 0.95) -> float:
    z = _Z_TABLE.get(round(float(confidence), 6))
    if z is not None:
        return z
    p = (1.0 + float(confidence)) / 2.0
    lo, hi = 0.0, 10.0
    for _ in range(80):
        mid = (lo + hi) / 2.0
        if 0.5 * (1.0 + math.erf(mid / math.sqrt(2.0))) < p:
            lo = mid
        else:
            hi = mid
    return (lo + hi) / 2.0


# Student-t two-sided critical values for df 1..30 (then the normal quantile
# is within 2%).  Stratified rungs routinely leave m = 2..5 rows per small
# stratum; a z-interval there badly undercovers — the coverage harness in
# tests/test_approx.py is what forced the t correction.
_T_TABLES = {
    0.90: (6.314, 2.920, 2.353, 2.132, 2.015, 1.943, 1.895, 1.860, 1.833,
           1.812, 1.796, 1.782, 1.771, 1.761, 1.753, 1.746, 1.740, 1.734,
           1.729, 1.725, 1.721, 1.717, 1.714, 1.711, 1.708, 1.706, 1.703,
           1.701, 1.699, 1.697),
    0.95: (12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262,
           2.228, 2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101,
           2.093, 2.086, 2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052,
           2.048, 2.045, 2.042),
    0.99: (63.657, 9.925, 5.841, 4.604, 4.032, 3.707, 3.499, 3.355, 3.250,
           3.169, 3.106, 3.055, 3.012, 2.977, 2.947, 2.921, 2.898, 2.878,
           2.861, 2.845, 2.831, 2.819, 2.807, 2.797, 2.787, 2.779, 2.771,
           2.763, 2.756, 2.750),
}


def t_value(df, confidence: float = 0.95):
    """Vectorized two-sided critical value: Student-t for small df, normal
    beyond the table (df >= 31), normal for untabulated confidences."""
    df = np.asarray(df)
    z = z_value(confidence)
    tab = _T_TABLES.get(round(float(confidence), 6))
    if tab is None:
        return np.full(df.shape, z, dtype=np.float64)
    tab = np.asarray(tab, dtype=np.float64)
    idx = np.clip(df, 1, 30).astype(np.int64) - 1
    return np.where(df >= 31, z, tab[idx])


def point_estimate(op, n, m, mf, s1):
    """Scale-up point estimate from the moments (mirrors the plan rewrite)."""
    n = np.asarray(n, dtype=np.float64)
    m = np.asarray(m, dtype=np.float64)
    mf = np.asarray(mf, dtype=np.float64)
    s1 = np.asarray(s1, dtype=np.float64)
    if op == "sum":
        return n / m * s1
    if op == "count":
        return n / m * mf
    if op == "avg":
        with np.errstate(divide="ignore", invalid="ignore"):
            return np.where(mf > 0, s1 / np.maximum(mf, 1.0), np.nan)
    raise ValueError(f"non-estimable aggregate op {op!r}")


def interval(op, n, m, mf, s1, s2, confidence: float = 0.95):
    """Vectorized ``(estimate, half_width)`` for one aggregate column.

    Inputs are per-group moment arrays (broadcastable scalars accepted).
    Half-width is ``inf`` where the sample cannot support a variance estimate
    and ``0`` where the stratum was fully sampled (``m >= n``).
    """
    if op not in ESTIMABLE_OPS:
        raise ValueError(f"non-estimable aggregate op {op!r}")
    n = np.asarray(n, dtype=np.float64)
    m = np.asarray(m, dtype=np.float64)
    mf = np.asarray(mf, dtype=np.float64)
    if op == "count":
        s1 = mf
        s2 = mf
    s1 = np.asarray(s1, dtype=np.float64)
    s2 = np.asarray(s2, dtype=np.float64)
    est = point_estimate(op, n, m, mf, s1)
    fpc = np.maximum(0.0, 1.0 - m / np.maximum(n, 1.0))
    with np.errstate(divide="ignore", invalid="ignore"):
        if op == "avg":
            crit = t_value(mf - 1, confidence)
            s2x = (s2 - s1 * s1 / np.maximum(mf, 1.0)) / np.maximum(mf - 1.0, 1.0)
            var = np.maximum(s2x, 0.0) / np.maximum(mf, 1.0) * fpc
            hw = crit * np.sqrt(var)
            hw = np.where(mf > 1, hw, np.inf)
        else:
            crit = t_value(m - 1, confidence)
            s2v = (s2 - s1 * s1 / np.maximum(m, 1.0)) / np.maximum(m - 1.0, 1.0)
            var = n * n * fpc * np.maximum(s2v, 0.0) / np.maximum(m, 1.0)
            hw = crit * np.sqrt(var)
            hw = np.where(m > 1, hw, np.inf)
    hw = np.where(m >= n, 0.0, hw)  # fully-sampled stratum is exact
    return est, hw


def _rel_width(est: np.ndarray, hw: np.ndarray) -> np.ndarray:
    """Relative half-width: hw/|est|, 0 when both are 0, inf when only est is."""
    est = np.asarray(est, dtype=np.float64)
    hw = np.asarray(hw, dtype=np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        rel = np.where(est != 0.0, hw / np.abs(est),
                       np.where(hw == 0.0, 0.0, np.inf))
    return rel


@dataclasses.dataclass
class ApproxEstimate:
    """A finalized approximate answer: clean columns + its error bars."""

    result: dict        # moment columns stripped; target columns are estimates
    half_width: dict    # target name -> per-group absolute CI half-width
    rel_width: float    # max relative half-width over all groups and targets
    confidence: float

    @property
    def exact(self) -> bool:
        return self.rel_width == 0.0


def finalize_result(cols, targets, confidence: float = 0.95,
                    scaled: bool = False) -> ApproxEstimate:
    """Turn a raw rewritten-query result into estimates with error bars.

    ``cols`` is the numpy result dict of the rewritten plan; ``targets`` is
    the rewrite's list of ``(name, op)`` pairs.  ``scaled`` says the targets
    were scale-rewritten (``den > 1``): then the moment columns MUST be
    present for every served target — a result that lost them (a projection
    the rewrite failed to guard) raises rather than masquerade a
    Horvitz-Thompson estimate as an exact zero-width answer.  With
    ``scaled=False`` a result without moment columns (the rung-1 / refused
    case) is passed through exact with zero width.  Scalar results arrive as
    length-1 arrays and need no special casing.
    """
    cols = {k: np.asarray(v) for k, v in cols.items()}
    if N_COL not in cols:
        if scaled:
            raise ValueError(
                "approx: targets were scale-rewritten but the __ap_* moment "
                "columns are missing from the result — a projection dropped "
                "them; refusing to report a scaled estimate as exact")
        clean = {k: v for k, v in cols.items()
                 if not k.startswith(MOMENT_PREFIX)}
        return ApproxEstimate(clean, {t[0]: np.zeros(0) for t in targets},
                              0.0, confidence)
    n, m, mf = cols[N_COL], cols[M_COL], cols[MF_COL]
    half = {}
    worst = 0.0
    for name, op in targets:
        if name not in cols:
            continue   # a projection dropped this target: it is not served
        s1 = cols.get(s1_col(name))
        s2 = cols.get(s2_col(name))
        if s1 is None and op != "count":
            if scaled:
                raise ValueError(
                    f"approx: scaled target {name!r} is served but its "
                    f"__ap_s1/__ap_s2 moments were projected away — no "
                    f"error bar is attachable")
            continue   # moments projected away: no bar attachable
        est, hw = interval(op, n, m, mf, s1, s2, confidence)
        half[name] = hw
        rel = _rel_width(cols[name], hw)
        if rel.size:
            worst = max(worst, float(np.max(rel)))
    clean = {k: v for k, v in cols.items() if not k.startswith(MOMENT_PREFIX)}
    return ApproxEstimate(clean, half, worst, confidence)

"""Public wrapper: padding, alignment, interpret switch, CPU fallback.

Dead-slot convention
--------------------
Rows the caller wants excluded (table padding, invalid rows, out-of-domain
keys) are routed to the **dead slot, which is always index ``groups``** — the
first id beyond the real group range.  The padded group width ``gpad`` is
``groups + 1`` rounded up to the 128-lane tile, so the dead slot exists for
every ``groups`` and is never lane-boundary dependent.  (The previous scheme
parked padding rows at ``gpad - 1``; at exact lane boundaries —
``groups == gpad - 1``, e.g. groups = 127/255 — a caller-side sentinel id
``groups`` and the wrapper's dead row could alias real/dead slots depending
on how ``gpad`` was derived.  Pinning the dead slot to ``groups`` removes the
boundary case entirely; see tests/test_aggregate_paths.py.)

Out-of-range gids (negative or > groups) are rerouted to the dead slot before
the kernel runs, so garbage ids can never scribble into a real group.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import segment_minmax_pallas, segment_sum_pallas
from repro.kernels import auto_interpret
from .ref import segment_reduce_ref, segment_sum_ref

_LANES = 128
# one-hot f32 count matmuls are exact while the row count fits the mantissa
_F32_EXACT_ROWS = 1 << 24


def _pad_to(x: int, m: int) -> int:
    return max(m, (x + m - 1) // m * m)


def _route_dead(gids: jax.Array, groups: int) -> jax.Array:
    """Clamp out-of-range ids to the dead slot (= ``groups``)."""
    g = gids.astype(jnp.int32)
    return jnp.where((g < 0) | (g > groups), groups, g)


def _pad_rows(gids: jax.Array, groups: int, blk: int) -> tuple[jax.Array, int, int]:
    """(padded gids, padded length, effective blk); padding rows -> dead slot."""
    n = gids.shape[0]
    blk = min(blk, _pad_to(n, 8))
    npad = _pad_to(n, blk)
    g2 = jnp.full((npad,), groups, jnp.int32).at[:n].set(
        _route_dead(gids, groups))
    return g2, npad, blk


def _sum_kernel(gids: jax.Array, values: jax.Array, groups: int, blk: int,
                interpret: bool) -> jax.Array:
    """values (n, C) float32/float64 -> (groups, C), via the MXU kernel."""
    n, c = values.shape
    gpad = _pad_to(groups + 1, _LANES)
    cpad = _pad_to(c, _LANES)
    g2, npad, blk = _pad_rows(gids, groups, blk)
    v2 = jnp.zeros((npad, cpad), values.dtype).at[:n, :c].set(values)
    out = segment_sum_pallas(g2, v2, gpad, blk=blk, interpret=interpret)
    return out[:groups, :c]


def _minmax_kernel(gids: jax.Array, values: jax.Array, groups: int, op: str,
                   blk: int, interpret: bool) -> jax.Array:
    """values (n,) float -> (groups,) min/max via the masked-reduce kernel."""
    n = values.shape[0]
    gpad = _pad_to(groups + 1, _LANES)
    ident = jnp.asarray(jnp.inf if op == "min" else -jnp.inf, values.dtype)
    g2, npad, blk = _pad_rows(gids, groups, blk)
    v2 = jnp.full((npad,), ident, values.dtype).at[:n].set(values)
    out = segment_minmax_pallas(g2, v2, gpad, is_min=(op == "min"),
                                blk=blk, interpret=interpret)
    return out[:groups]


def _kernel_dtype_ok(dt, interpret: bool) -> bool:
    """float32 everywhere; float64 only under interpret (no f64 MXU)."""
    return dt == jnp.float32 or (dt == jnp.float64 and interpret)


@partial(jax.jit, static_argnames=("groups", "op", "blk", "interpret",
                                   "use_kernel"))
def segment_reduce(gids: jax.Array, values: jax.Array | None, groups: int,
                   op: str = "sum", blk: int = 1024,
                   interpret: bool | None = None,
                   use_kernel: bool = True) -> jax.Array:
    """Sortless grouped reduction: sum / count / min / max, dtype-preserving.

    The TPU fast path is the one-hot MXU matmul (sum/count) or the one-hot
    masked lane reduce (min/max); dtypes the hardware kernels cannot hold
    exactly (integers, float64 outside interpret mode) fall back to jnp
    scatter-reduce — still sortless, still dead-slot routed.  ``op="count"``
    ignores ``values`` and returns int64 row counts per group.
    ``interpret=None`` auto-selects: compiled on TPU, interpret elsewhere.
    """
    if interpret is None:
        interpret = auto_interpret()
    if op == "count":
        n = gids.shape[0]
        if use_kernel and n < _F32_EXACT_ROWS:
            out = _sum_kernel(gids, jnp.ones((n, 1), jnp.float32), groups,
                              blk, interpret)[:, 0]
            return jnp.round(out).astype(jnp.int64)
        return segment_reduce_ref(_route_dead(gids, groups),
                                  jnp.ones((n,), jnp.int64), groups, "sum")
    if op not in ("sum", "min", "max"):
        raise ValueError(f"unknown segment reduce op {op!r}")
    squeeze = values.ndim == 1
    v = values[:, None] if squeeze else values
    kernel_ok = use_kernel and jnp.issubdtype(v.dtype, jnp.floating) and \
        _kernel_dtype_ok(v.dtype, interpret)
    if op == "sum":
        if kernel_ok:
            out = _sum_kernel(gids, v, groups, blk, interpret)
        else:
            out = segment_reduce_ref(_route_dead(gids, groups), v, groups,
                                     "sum")
    else:
        if kernel_ok:
            cols = [_minmax_kernel(gids, v[:, i], groups, op, blk, interpret)
                    for i in range(v.shape[1])]
            out = jnp.stack(cols, axis=1)
        else:
            out = segment_reduce_ref(_route_dead(gids, groups), v, groups, op)
    return out[:, 0] if squeeze else out


@partial(jax.jit, static_argnames=("groups", "blk", "interpret", "use_kernel"))
def segment_sum(gids: jax.Array, values: jax.Array, groups: int,
                blk: int = 1024, interpret: bool | None = None,
                use_kernel: bool = True) -> jax.Array:
    """Grouped float32 sum with the MXU one-hot kernel (legacy entry point).

    values may be (n,) or (n, C); output is float32.  Padding rows and
    out-of-range gids route to the dead slot (see module docstring).  With
    use_kernel=False the jnp oracle runs (the production config flips this on
    non-TPU backends).  ``segment_reduce`` is the dtype-preserving superset.
    """
    if interpret is None:
        interpret = auto_interpret()
    squeeze = values.ndim == 1
    if squeeze:
        values = values[:, None]
    if not use_kernel:
        out = segment_sum_ref(_route_dead(gids, groups), values, groups)
        return out[:, 0] if squeeze else out
    out = _sum_kernel(gids, values.astype(jnp.float32), groups, blk, interpret)
    return out[:, 0] if squeeze else out

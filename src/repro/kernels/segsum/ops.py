"""Public wrapper: padding, alignment, interpret switch, CPU fallback."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import segment_sum_pallas
from .ref import segment_sum_ref

_LANES = 128


def _pad_to(x: int, m: int) -> int:
    return max(m, (x + m - 1) // m * m)


@partial(jax.jit, static_argnames=("groups", "blk", "interpret", "use_kernel"))
def segment_sum(gids: jax.Array, values: jax.Array, groups: int,
                blk: int = 1024, interpret: bool = True,
                use_kernel: bool = True) -> jax.Array:
    """Grouped sum with MXU one-hot kernel; shapes auto-padded to tiles.

    values may be (n,) or (n, C).  Padding rows route to a dead group beyond
    ``groups`` and are sliced away.  With use_kernel=False the jnp oracle runs
    (the production config flips this on non-TPU backends).
    """
    squeeze = values.ndim == 1
    if squeeze:
        values = values[:, None]
    n, c = values.shape
    if not use_kernel:
        return (segment_sum_ref(gids, values, groups)[:, 0] if squeeze
                else segment_sum_ref(gids, values, groups))
    gpad = _pad_to(groups + 1, _LANES)        # +1 dead group for padding rows
    cpad = _pad_to(c, _LANES)
    blk = min(blk, _pad_to(n, 8))
    npad = _pad_to(n, blk)
    g2 = jnp.full((npad,), gpad - 1, jnp.int32).at[:n].set(gids.astype(jnp.int32))
    v2 = jnp.zeros((npad, cpad), jnp.float32).at[:n, :c].set(
        values.astype(jnp.float32))
    out = segment_sum_pallas(g2, v2, gpad, blk=blk, interpret=interpret)
    out = out[:groups, :c]
    return out[:, 0] if squeeze else out

"""One-hot MXU grouped aggregation (TQP's aggregation-as-matmul, TPU-native).

Grouped sum of (n, C) values into (G, C) buckets as a blocked
one-hot(gid) @ values matmul: each (BLK, G) one-hot tile and (BLK, C) value
tile live in VMEM and feed the MXU; the (G, C) accumulator stays resident in
VMEM across the row-block grid (output index_map pins every step to block 0).

This replaces the CUDA hash-table+atomics aggregation of GPU TQP: the TPU has
no fast global atomics, but a 128x128 systolic matmul turns scatter-reduce
into dense compute at ~100% MXU utilization when G is modest (dict-encoded
group domains — exactly TPC-H's shape).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(gid_ref, val_ref, out_ref, *, blk: int, groups: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    gid = gid_ref[...]                                   # (blk, 1) int32
    iota = jax.lax.broadcasted_iota(jnp.int32, (blk, groups), 1)
    onehot = (gid == iota).astype(val_ref.dtype)         # (blk, G)
    out_ref[...] += jax.lax.dot_general(
        onehot, val_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),      # onehot^T @ vals
        preferred_element_type=jnp.float32)


def segment_sum_pallas(gids: jax.Array, values: jax.Array, groups: int,
                       blk: int = 1024, interpret: bool = False) -> jax.Array:
    """gids (n,) int32 in [0, groups); values (n, C) f32 -> (G, C) sums.

    Callers pad n to a multiple of blk and route padding rows to a dead group
    (ops.py handles both).  G and C should be multiples of 128 for MXU
    alignment; VMEM working set = blk*(G + C)*4 + G*C*4 bytes.
    """
    n, c = values.shape
    assert n % blk == 0, (n, blk)
    grid = (n // blk,)
    return pl.pallas_call(
        functools.partial(_kernel, blk=blk, groups=groups),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
            pl.BlockSpec((blk, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((groups, c), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((groups, c), jnp.float32),
        interpret=interpret,
    )(gids.reshape(n, 1).astype(jnp.int32), values)

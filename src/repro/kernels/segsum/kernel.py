"""One-hot MXU grouped aggregation (TQP's aggregation-as-matmul, TPU-native).

Grouped sum of (n, C) values into (G, C) buckets as a blocked
one-hot(gid) @ values matmul: each (BLK, G) one-hot tile and (BLK, C) value
tile live in VMEM and feed the MXU; the (G, C) accumulator stays resident in
VMEM across the row-block grid (output index_map pins every step to block 0).

This replaces the CUDA hash-table+atomics aggregation of GPU TQP: the TPU has
no fast global atomics, but a 128x128 systolic matmul turns scatter-reduce
into dense compute at ~100% MXU utilization when G is modest (dict-encoded
group domains — exactly TPC-H's shape).

``segment_minmax_pallas`` is the masked-reduce sibling for min/max: the same
(BLK, G) one-hot tile selects values (identity elsewhere) and a VPU lane
reduction folds each block into the (1, G) accumulator — grouped min/max with
no sort and no atomics, completing the sortless aggregation operator set.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(gid_ref, val_ref, out_ref, *, blk: int, groups: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    gid = gid_ref[...]                                   # (blk, 1) int32
    iota = jax.lax.broadcasted_iota(jnp.int32, (blk, groups), 1)
    onehot = (gid == iota).astype(val_ref.dtype)         # (blk, G)
    out_ref[...] += jax.lax.dot_general(
        onehot, val_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),      # onehot^T @ vals
        preferred_element_type=out_ref.dtype)


def segment_sum_pallas(gids: jax.Array, values: jax.Array, groups: int,
                       blk: int = 1024, interpret: bool = False) -> jax.Array:
    """gids (n,) int32 in [0, groups); values (n, C) float -> (G, C) sums.

    Callers pad n to a multiple of blk and route padding rows to a dead group
    (ops.py handles both).  G and C should be multiples of 128 for MXU
    alignment; VMEM working set = blk*(G + C)*4 + G*C*4 bytes.  Accumulation
    dtype follows ``values.dtype`` (float32 on hardware; float64 is available
    under interpret mode, where the MXU is emulated by jnp).
    """
    n, c = values.shape
    assert n % blk == 0, (n, blk)
    grid = (n // blk,)
    return pl.pallas_call(
        functools.partial(_kernel, blk=blk, groups=groups),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
            pl.BlockSpec((blk, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((groups, c), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((groups, c), values.dtype),
        interpret=interpret,
    )(gids.reshape(n, 1).astype(jnp.int32), values)


def _minmax_kernel(gid_ref, val_ref, out_ref, *, blk: int, groups: int,
                   is_min: bool):
    step = pl.program_id(0)
    ident = jnp.asarray(jnp.inf if is_min else -jnp.inf, out_ref.dtype)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref[...], ident)

    gid = gid_ref[...]                                   # (blk, 1) int32
    iota = jax.lax.broadcasted_iota(jnp.int32, (blk, groups), 1)
    # one-hot select: group's own rows keep their value, everything else the
    # reduction identity — a (blk, G) tile folded by a VPU lane reduction
    masked = jnp.where(gid == iota, val_ref[...], ident)  # (blk, G)
    red = (jnp.min if is_min else jnp.max)(masked, axis=0, keepdims=True)
    out_ref[...] = (jnp.minimum if is_min else jnp.maximum)(out_ref[...], red)


def segment_minmax_pallas(gids: jax.Array, values: jax.Array, groups: int,
                          is_min: bool, blk: int = 1024,
                          interpret: bool = False) -> jax.Array:
    """gids (n,) int32 in [0, groups); values (n,) float -> (G,) min/max.

    Empty groups hold the reduction identity (+/-inf); callers drop them (the
    relational layer masks empty slots before compaction).
    """
    n = values.shape[0]
    assert n % blk == 0, (n, blk)
    grid = (n // blk,)
    out = pl.pallas_call(
        functools.partial(_minmax_kernel, blk=blk, groups=groups,
                          is_min=is_min),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, groups), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, groups), values.dtype),
        interpret=interpret,
    )(gids.reshape(n, 1).astype(jnp.int32), values.reshape(n, 1))
    return out[0]

"""Pure-jnp oracle for the one-hot MXU grouped aggregation kernels."""
import jax
import jax.numpy as jnp


def segment_sum_ref(gids: jax.Array, values: jax.Array, groups: int) -> jax.Array:
    return jax.ops.segment_sum(values.astype(jnp.float32),
                               gids.astype(jnp.int32), num_segments=groups)


def segment_reduce_ref(gids: jax.Array, values: jax.Array, groups: int,
                       op: str) -> jax.Array:
    """Dtype-preserving scatter-reduce oracle; out-of-range gids are dropped
    (XLA scatter drop semantics — the dead-slot convention of ops.py)."""
    g = gids.astype(jnp.int32)
    if op == "sum":
        return jax.ops.segment_sum(values, g, num_segments=groups)
    if op == "min":
        return jax.ops.segment_min(values, g, num_segments=groups)
    if op == "max":
        return jax.ops.segment_max(values, g, num_segments=groups)
    raise ValueError(f"unknown segment reduce op {op!r}")

"""Pure-jnp oracle for the one-hot MXU grouped aggregation kernel."""
import jax
import jax.numpy as jnp


def segment_sum_ref(gids: jax.Array, values: jax.Array, groups: int) -> jax.Array:
    return jax.ops.segment_sum(values.astype(jnp.float32),
                               gids.astype(jnp.int32), num_segments=groups)

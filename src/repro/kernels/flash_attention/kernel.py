"""Blocked online-softmax attention (FlashAttention) for TPU, with GQA + causal.

Grid: (batch*q_heads, q_blocks, kv_blocks) — the kv loop is innermost so the
(q_blk, d) query tile, f32 accumulator, and running max/sum stay VMEM-resident
while (kv_blk, d) key/value tiles stream through.  GQA maps each query head to
its kv head in the BlockSpec index_map (no KV duplication in HBM or VMEM).

VMEM working set per step: q_blk*d (q) + 2*kv_blk*d (k,v) + q_blk*kv_blk (s)
+ q_blk*d f32 accumulator — with q_blk=kv_blk=512, d=128: ~1.3 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            q_blk: int, kv_blk: int, scale: float, causal: bool):
    kv_step = pl.program_id(2)
    q_step = pl.program_id(1)

    @pl.when(kv_step == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        # skip fully-masked kv blocks (upper triangle)
        run = kv_step * kv_blk <= q_step * q_blk + q_blk - 1

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale          # (q_blk, d)
        k = k_ref[0].astype(jnp.float32)                  # (kv_blk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qi = q_step * q_blk + jax.lax.broadcasted_iota(
                jnp.int32, (q_blk, kv_blk), 0)
            ki = kv_step * kv_blk + jax.lax.broadcasted_iota(
                jnp.int32, (q_blk, kv_blk), 1)
            s = jnp.where(qi >= ki, s, _NEG_INF)
        m_prev = m_ref[...][:, :1]                        # (q_blk, 1)
        m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_cur)                            # (q_blk, kv_blk)
        alpha = jnp.exp(m_prev - m_cur)                   # (q_blk, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)

    @pl.when(kv_step == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...][:, :1], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           q_blk: int = 512, kv_blk: int = 512,
                           causal: bool = True,
                           interpret: bool = False) -> jax.Array:
    """q (BH, Sq, D), k/v (BKV, Skv, D) with BH = BKV * group_size.

    Head-major layout: caller flattens (batch, heads) -> BH and maps query
    head h to kv head h // group_size (done here via index_map).
    """
    bh, sq, d = q.shape
    bkv, skv, _ = k.shape
    assert bh % bkv == 0
    group = bh // bkv
    scale = 1.0 / (d ** 0.5)
    grid = (bh, sq // q_blk, skv // kv_blk)
    return pl.pallas_call(
        functools.partial(_kernel, q_blk=q_blk, kv_blk=kv_blk, scale=scale,
                          causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_blk, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, kv_blk, d), lambda h, i, j: (h // group, j, 0)),
            pl.BlockSpec((1, kv_blk, d), lambda h, i, j: (h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_blk, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            # VMEM scratch: running max, running sum (128-lane padded), f32 acc
            pltpu.VMEM((q_blk, 128), jnp.float32),
            pltpu.VMEM((q_blk, 128), jnp.float32),
            pltpu.VMEM((q_blk, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

"""Public wrapper: (B, H, S, D) layout + GQA flattening + backend switch."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import attention_ref


@partial(jax.jit, static_argnames=("causal", "q_blk", "kv_blk", "interpret",
                                   "use_kernel"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, q_blk: int = 256, kv_blk: int = 256,
                    interpret: bool = True, use_kernel: bool = True):
    """q (B, Hq, Sq, D), k/v (B, Hkv, Skv, D) -> (B, Hq, Sq, D).

    use_kernel=False runs the jnp oracle (the model zoo's default on CPU; the
    kernel is the TPU target and the sweep tests assert equivalence)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if not use_kernel:
        o = attention_ref(q.reshape(b * hq, sq, d),
                          k.reshape(b * hkv, skv, d),
                          v.reshape(b * hkv, skv, d), causal=causal)
        return o.reshape(b, hq, sq, d)
    q_blk = min(q_blk, sq)
    kv_blk = min(kv_blk, skv)
    assert sq % q_blk == 0 and skv % kv_blk == 0
    o = flash_attention_pallas(q.reshape(b * hq, sq, d),
                               k.reshape(b * hkv, skv, d),
                               v.reshape(b * hkv, skv, d),
                               q_blk=q_blk, kv_blk=kv_blk, causal=causal,
                               interpret=interpret)
    return o.reshape(b, hq, sq, d)

"""Pure-jnp oracle: dense softmax attention with causal mask + GQA."""
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    """q (BH, Sq, D), k/v (BKV, Skv, D), BH = BKV * group."""
    bh, sq, d = q.shape
    bkv, skv, _ = k.shape
    group = bh // bkv
    k = jnp.repeat(k, group, axis=0)
    v = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        qi = jnp.arange(sq)[:, None]
        ki = jnp.arange(skv)[None, :]
        s = jnp.where(qi >= ki, s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)

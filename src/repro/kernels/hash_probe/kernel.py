"""Bucketized hash-join probe with a VMEM-resident build table.

GPU TQP probes a global hash table with atomics-built chains; the TPU
adaptation is partition-then-probe: upstream radix partitioning (the shuffle
machinery) bounds each partition's build side so its bucket table fits VMEM,
then this kernel probes row blocks against the whole (B, C) bucket table held
resident in VMEM.

Layout: the build side is arranged (ops.py, sort-based, no atomics) into
  bkeys (B, C) int32 — C-way buckets, empty slots = sentinel
  bvals (B, C) int32 — payload row indices
Probe: bucket = murmur32(key) % B; compare the key against all C candidate
lanes at once (vectorized, fixed probe length — no data-dependent loops);
matched payload or -1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.radix_hist.kernel import murmur32

SENTINEL = jnp.int32(-2147483648)


def _kernel(pk_ref, bk_ref, bv_ref, out_ref, *, blk: int, buckets: int,
            cap: int):
    keys = pk_ref[...][:, 0]                              # (blk,)
    b = (murmur32(keys) % jnp.uint32(buckets)).astype(jnp.int32)
    cand_k = bk_ref[...][b]                               # (blk, C) gather
    cand_v = bv_ref[...][b]                               # (blk, C)
    hit = cand_k == keys[:, None]                         # (blk, C)
    val = jnp.max(jnp.where(hit, cand_v, -1), axis=1)     # unique build keys
    out_ref[...] = val[:, None]


def bucket_of(lo: jax.Array, hi: jax.Array, buckets: int) -> jax.Array:
    """Bucket id of a 64-bit key split into int32 (lo, hi) planes.

    Shared by the pure-JAX build (ops.build_bucket_table64) and the probe
    kernel below — both sides MUST hash identically.  The planes are combined
    through a second murmur round (hash_combine-style): a plain ``lo ^ hi``
    collapses packed two-column keys whose low word spans a small domain
    (e.g. partkey<<32 | suppkey) into few distinct inputs."""
    mixed = jax.lax.bitcast_convert_type(murmur32(hi), jnp.int32) ^ lo
    return (murmur32(mixed) % jnp.uint32(buckets)).astype(jnp.int32)


def _kernel64(plo_ref, phi_ref, bklo_ref, bkhi_ref, bv_ref, out_ref, *,
              blk: int, buckets: int, cap: int):
    lo = plo_ref[...][:, 0]                               # (blk,)
    hi = phi_ref[...][:, 0]
    b = bucket_of(lo, hi, buckets)
    cand_lo = bklo_ref[...][b]                            # (blk, C) gathers
    cand_hi = bkhi_ref[...][b]
    cand_v = bv_ref[...][b]
    hit = (cand_lo == lo[:, None]) & (cand_hi == hi[:, None])
    val = jnp.max(jnp.where(hit, cand_v, -1), axis=1)     # unique build keys
    out_ref[...] = val[:, None]


def hash_probe_pallas(probe_keys: jax.Array, bkeys: jax.Array,
                      bvals: jax.Array, blk: int = 2048,
                      interpret: bool = False) -> jax.Array:
    """probe_keys (n,) int32; bucket table (B, C) -> matched row idx or -1."""
    n = probe_keys.shape[0]
    buckets, cap = bkeys.shape
    assert n % blk == 0
    grid = (n // blk,)
    return pl.pallas_call(
        functools.partial(_kernel, blk=blk, buckets=buckets, cap=cap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
            pl.BlockSpec((buckets, cap), lambda i: (0, 0)),   # resident
            pl.BlockSpec((buckets, cap), lambda i: (0, 0)),   # resident
        ],
        out_specs=pl.BlockSpec((blk, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.int32),
        interpret=interpret,
    )(probe_keys.reshape(n, 1).astype(jnp.int32), bkeys, bvals)[:, 0]


def hash_probe64_pallas(probe_lo: jax.Array, probe_hi: jax.Array,
                        bk_lo: jax.Array, bk_hi: jax.Array,
                        bvals: jax.Array, blk: int = 2048,
                        interpret: bool = False) -> jax.Array:
    """64-bit-key probe: (n,) int32 lo/hi planes vs (B, C) plane pair.

    Same partition-then-probe scheme as ``hash_probe_pallas``; full 64-bit
    equality is checked in-kernel by comparing both planes, so int64 join keys
    (including two-column keys packed by ``combine_keys``) probe exactly."""
    n = probe_lo.shape[0]
    buckets, cap = bk_lo.shape
    assert n % blk == 0
    grid = (n // blk,)
    return pl.pallas_call(
        functools.partial(_kernel64, blk=blk, buckets=buckets, cap=cap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
            pl.BlockSpec((buckets, cap), lambda i: (0, 0)),   # resident
            pl.BlockSpec((buckets, cap), lambda i: (0, 0)),   # resident
            pl.BlockSpec((buckets, cap), lambda i: (0, 0)),   # resident
        ],
        out_specs=pl.BlockSpec((blk, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.int32),
        interpret=interpret,
    )(probe_lo.reshape(n, 1), probe_hi.reshape(n, 1),
      bk_lo, bk_hi, bvals)[:, 0]

"""Public wrapper: sort-based bucket-table build + blocked probe.

Build is pure JAX (stable argsort by bucket — no atomics); overflowing
buckets (> capacity) raise the recorded overflow flag so callers re-bucket
with a bigger table, mirroring the exchange layer's capacity discipline.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.radix_hist.kernel import murmur32
from .kernel import SENTINEL, hash_probe_pallas
from .ref import hash_probe_ref


def _next_pow2(x: int) -> int:
    return 1 << max(3, (x - 1).bit_length())


@partial(jax.jit, static_argnames=("buckets", "cap"))
def build_bucket_table(keys: jax.Array, vals: jax.Array, buckets: int,
                       cap: int = 8):
    """(m,) unique int32 keys -> ((B, C) keys, (B, C) vals, overflowed)."""
    m = keys.shape[0]
    b = (murmur32(keys.astype(jnp.int32)) % jnp.uint32(buckets)).astype(jnp.int32)
    order = jnp.argsort(b, stable=True)
    sb = b[order]
    counts = jax.ops.segment_sum(jnp.ones((m,), jnp.int32), b,
                                 num_segments=buckets)
    start = jnp.concatenate([jnp.zeros(1, jnp.int32),
                             jnp.cumsum(counts, dtype=jnp.int32)[:-1]])
    slot = jnp.arange(m, dtype=jnp.int32) - start[sb]
    flat = sb * cap + jnp.minimum(slot, cap - 1)
    keep = slot < cap
    flat = jnp.where(keep, flat, buckets * cap)
    bk = jnp.full((buckets * cap,), SENTINEL, jnp.int32).at[flat].set(
        keys.astype(jnp.int32)[order], mode="drop").reshape(buckets, cap)
    bv = jnp.full((buckets * cap,), -1, jnp.int32).at[flat].set(
        vals.astype(jnp.int32)[order], mode="drop").reshape(buckets, cap)
    return bk, bv, jnp.any(counts > cap)


@partial(jax.jit, static_argnames=("blk", "cap", "interpret", "use_kernel"))
def hash_join_probe(probe_keys: jax.Array, build_keys: jax.Array,
                    build_vals: jax.Array, blk: int = 2048, cap: int = 8,
                    interpret: bool = True, use_kernel: bool = True):
    """End-to-end probe: returns (matched row idx or -1, build overflowed).

    VMEM budget: the (B, C) tables must fit resident — B*C*8 bytes; with the
    default C=8 and B = 2*next_pow2(m)/C this is ~16 bytes per build row.
    """
    if not use_kernel:
        return hash_probe_ref(probe_keys, build_keys, build_vals), jnp.asarray(False)
    m = build_keys.shape[0]
    buckets = max(128, _next_pow2(2 * max(1, m)) // cap)
    bk, bv, ov = build_bucket_table(build_keys, build_vals, buckets, cap)
    n = probe_keys.shape[0]
    blk = min(blk, max(8, (n + 7) // 8 * 8))
    npad = (n + blk - 1) // blk * blk
    pk = jnp.full((npad,), SENTINEL, jnp.int32).at[:n].set(
        probe_keys.astype(jnp.int32))
    out = hash_probe_pallas(pk, bk, bv, blk=blk, interpret=interpret)
    return out[:n], ov


def hash_join_probe_auto(probe_keys, build_keys, build_vals, cap: int = 8,
                         max_tries: int = 4, **kw):
    """Host-level capacity escalation: double bucket capacity on overflow.

    This is the same re-execution discipline the fault-tolerant query runner
    applies to shuffle overflow (paper §2.4: fault tolerance by re-execution)."""
    for _ in range(max_tries):
        out, ov = hash_join_probe(probe_keys, build_keys, build_vals,
                                  cap=cap, **kw)
        if not bool(ov):
            return out, cap
        cap *= 2
    raise RuntimeError(f"bucket overflow persists at cap={cap}")

"""Public wrapper: sort-based bucket-table build + blocked probe.

Build is pure JAX (stable argsort by bucket — no atomics); overflowing
buckets (> capacity) raise the recorded overflow flag so callers re-bucket
with a bigger table, mirroring the exchange layer's capacity discipline.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.radix_hist.kernel import murmur32
from .kernel import SENTINEL, bucket_of, hash_probe_pallas, hash_probe64_pallas
from .ref import hash_probe_ref


def _next_pow2(x: int) -> int:
    return 1 << max(3, (x - 1).bit_length())


def next_pow2(x: int) -> int:
    """Public alias (relational-layer bucket sizing)."""
    return _next_pow2(x)


def _split64(keys: jax.Array) -> tuple[jax.Array, jax.Array]:
    """int64 keys -> (lo, hi) int32 planes (bit-exact)."""
    k = keys.astype(jnp.int64)
    lo = jax.lax.bitcast_convert_type(
        (k & jnp.int64(0xFFFFFFFF)).astype(jnp.uint32), jnp.int32)
    hi = (k >> 32).astype(jnp.int32)
    return lo, hi


@partial(jax.jit, static_argnames=("buckets", "cap"))
def build_bucket_table(keys: jax.Array, vals: jax.Array, buckets: int,
                       cap: int = 8):
    """(m,) unique int32 keys -> ((B, C) keys, (B, C) vals, overflowed)."""
    m = keys.shape[0]
    b = (murmur32(keys.astype(jnp.int32)) % jnp.uint32(buckets)).astype(jnp.int32)
    order = jnp.argsort(b, stable=True)
    sb = b[order]
    counts = jax.ops.segment_sum(jnp.ones((m,), jnp.int32), b,
                                 num_segments=buckets)
    start = jnp.concatenate([jnp.zeros(1, jnp.int32),
                             jnp.cumsum(counts, dtype=jnp.int32)[:-1]])
    slot = jnp.arange(m, dtype=jnp.int32) - start[sb]
    flat = sb * cap + jnp.minimum(slot, cap - 1)
    keep = slot < cap
    flat = jnp.where(keep, flat, buckets * cap)
    bk = jnp.full((buckets * cap,), SENTINEL, jnp.int32).at[flat].set(
        keys.astype(jnp.int32)[order], mode="drop").reshape(buckets, cap)
    bv = jnp.full((buckets * cap,), -1, jnp.int32).at[flat].set(
        vals.astype(jnp.int32)[order], mode="drop").reshape(buckets, cap)
    return bk, bv, jnp.any(counts > cap)


@partial(jax.jit, static_argnames=("buckets", "cap"))
def build_bucket_table64(keys: jax.Array, vals: jax.Array, buckets: int,
                         cap: int = 16, valid: jax.Array | None = None):
    """(m,) unique int64 keys -> ((B,C) lo, (B,C) hi, (B,C) vals, overflowed).

    Two int32 key planes hold the full 64-bit key so packed two-column join
    keys probe exactly.  ``valid`` masks out padding rows (they are routed to
    a virtual bucket and dropped — deferred-compaction tables index without
    compacting first).  One stable argsort by bucket — no atomics.
    """
    m = keys.shape[0]
    k64 = keys.astype(jnp.int64)
    lo, hi = _split64(k64)
    b = bucket_of(lo, hi, buckets)
    if valid is not None:
        b = jnp.where(valid, b, buckets)          # virtual bucket: dropped
    iota = jnp.arange(m, dtype=jnp.int32)
    # one sort by (bucket, key): bucket ranking AND adjacent exact duplicates.
    # Duplicate keys are kept once — membership probes (semi/anti) then accept
    # non-unique build sides without inflating any bucket; ties pick the
    # smallest key's first row, which is irrelevant under the unique-build
    # contract of join_unique.
    sb, sk, order = jax.lax.sort((b, k64, iota), num_keys=2, is_stable=True)
    in_bucket = sb < buckets
    dup = jnp.concatenate([jnp.zeros((1,), bool),
                           (sb[1:] == sb[:-1]) & (sk[1:] == sk[:-1])])
    keep = in_bucket & ~dup
    counts = jax.ops.segment_sum(keep.astype(jnp.int32), sb,
                                 num_segments=buckets + 1,
                                 indices_are_sorted=True)[:buckets]
    start = jnp.concatenate([jnp.zeros(1, jnp.int32),
                             jnp.cumsum(counts, dtype=jnp.int32)])
    rank = jnp.cumsum(keep.astype(jnp.int32)) - 1  # global rank among kept
    slot = rank - start[jnp.minimum(sb, buckets)]
    flat = sb * cap + jnp.minimum(slot, cap - 1)
    ok = keep & (slot < cap)
    flat = jnp.where(ok, flat, buckets * cap)     # OOB -> dropped
    slo = lo[order]
    shi = hi[order]
    bk_lo = jnp.full((buckets * cap,), SENTINEL, jnp.int32).at[flat].set(
        slo, mode="drop").reshape(buckets, cap)
    bk_hi = jnp.full((buckets * cap,), SENTINEL, jnp.int32).at[flat].set(
        shi, mode="drop").reshape(buckets, cap)
    bv = jnp.full((buckets * cap,), -1, jnp.int32).at[flat].set(
        vals.astype(jnp.int32)[order], mode="drop").reshape(buckets, cap)
    return bk_lo, bk_hi, bv, jnp.any(counts > cap)


_PAD64 = (1 << 62) + 1  # never a real key nor KEY_SENTINEL; pads probe blocks


def hash_probe64(probe_keys: jax.Array, bk_lo: jax.Array, bk_hi: jax.Array,
                 bvals: jax.Array, blk: int = 2048,
                 interpret: bool | None = None) -> jax.Array:
    """(n,) int64 probe keys vs a 64-bit bucket table -> build row idx or -1."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = probe_keys.shape[0]
    blk = min(blk, max(8, (n + 7) // 8 * 8))
    npad = (n + blk - 1) // blk * blk
    pk = jnp.full((npad,), _PAD64, jnp.int64).at[:n].set(
        probe_keys.astype(jnp.int64))
    lo, hi = _split64(pk)
    out = hash_probe64_pallas(lo, hi, bk_lo, bk_hi, bvals, blk=blk,
                              interpret=interpret)
    return out[:n]


@partial(jax.jit, static_argnames=("blk", "cap", "interpret", "use_kernel"))
def hash_join_probe(probe_keys: jax.Array, build_keys: jax.Array,
                    build_vals: jax.Array, blk: int = 2048, cap: int = 8,
                    interpret: bool = True, use_kernel: bool = True):
    """End-to-end probe: returns (matched row idx or -1, build overflowed).

    VMEM budget: the (B, C) tables must fit resident — B*C*8 bytes; with the
    default C=8 and B = 2*next_pow2(m)/C this is ~16 bytes per build row.
    """
    if not use_kernel:
        return hash_probe_ref(probe_keys, build_keys, build_vals), jnp.asarray(False)
    m = build_keys.shape[0]
    buckets = max(128, _next_pow2(2 * max(1, m)) // cap)
    bk, bv, ov = build_bucket_table(build_keys, build_vals, buckets, cap)
    n = probe_keys.shape[0]
    blk = min(blk, max(8, (n + 7) // 8 * 8))
    npad = (n + blk - 1) // blk * blk
    pk = jnp.full((npad,), SENTINEL, jnp.int32).at[:n].set(
        probe_keys.astype(jnp.int32))
    out = hash_probe_pallas(pk, bk, bv, blk=blk, interpret=interpret)
    return out[:n], ov


def hash_join_probe_auto(probe_keys, build_keys, build_vals, cap: int = 8,
                         max_tries: int = 4, **kw):
    """Host-level capacity escalation: double bucket capacity on overflow.

    Standalone-kernel convenience only.  The relational engine does NOT use
    this local retry loop: ``relational.build_index`` surfaces the overflow
    flag, the backends fold it into ``ctx.overflow``, and the fault runner's
    capacity-factor escalation (which also scales the per-bucket capacity via
    ``_BaseContext.bucket_cap``) re-executes the whole query — the same
    re-execution discipline as shuffle overflow (paper §2.4)."""
    for _ in range(max_tries):
        out, ov = hash_join_probe(probe_keys, build_keys, build_vals,
                                  cap=cap, **kw)
        if not bool(ov):
            return out, cap
        cap *= 2
    raise RuntimeError(f"bucket overflow persists at cap={cap}")

"""Pure-jnp oracle: sorted-build searchsorted probe (the engine's own join)."""
import jax.numpy as jnp


def hash_probe_ref(probe_keys, build_keys, build_vals):
    """probe (n,), build (m,) unique int32 -> matched build_vals or -1."""
    order = jnp.argsort(build_keys)
    sk = build_keys[order]
    sv = build_vals[order]
    pos = jnp.clip(jnp.searchsorted(sk, probe_keys), 0, sk.shape[0] - 1)
    hit = sk[pos] == probe_keys
    return jnp.where(hit, sv[pos], -1).astype(jnp.int32)

"""Pallas TPU kernels for the compute hot spots (DESIGN.md §6).

Each kernel package ships:
  kernel.py — pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (padding, layout, interpret switch)
  ref.py    — pure-jnp oracle used by the sweep tests

On this CPU container kernels are validated with interpret=True; the BlockSpecs
are sized for TPU v5e VMEM (~128 MiB/core budgeted conservatively at 64 MiB).
"""
import jax


def auto_interpret() -> bool:
    """Shared interpret=None resolution: compile on TPU, interpret elsewhere.

    Called at trace time (interpret is a static arg everywhere), so the
    backend probe never runs at import.
    """
    return jax.default_backend() != "tpu"

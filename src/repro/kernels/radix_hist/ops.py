"""Public wrapper: padding + global/per-block histograms + skew stats."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import radix_hist_pallas
from .ref import radix_hist_ref

_LANES = 128


@partial(jax.jit, static_argnames=("parts", "blk", "interpret", "use_kernel"))
def radix_hist(keys: jax.Array, parts: int, blk: int = 2048,
               interpret: bool = True, use_kernel: bool = True) -> jax.Array:
    """Per-block partition histograms (ceil(n/blk), parts).

    Padding rows hash to arbitrary partitions, so they are excluded by
    hashing a sentinel lane and subtracting its count — simpler: we pad with
    the first key so totals stay exact after subtracting the pad count from
    that key's partition (done below).
    """
    n = keys.shape[0]
    width = max(_LANES, (parts + _LANES - 1) // _LANES * _LANES)
    blk = min(blk, max(8, (n + 7) // 8 * 8))
    npad = (n + blk - 1) // blk * blk
    pad = npad - n
    k2 = jnp.concatenate([keys.astype(jnp.int32),
                          jnp.broadcast_to(keys[:1].astype(jnp.int32), (pad,))])
    if use_kernel:
        hist = radix_hist_pallas(k2, parts, width=width, blk=blk,
                                 interpret=interpret)
    else:
        hist = radix_hist_ref(k2, parts, blk)
    # subtract the duplicated pad rows from the last block
    if pad:
        from .kernel import murmur32
        p0 = (murmur32(keys[:1].astype(jnp.int32)) %
              jnp.uint32(parts)).astype(jnp.int32)
        hist = hist.at[-1, p0[0]].add(-float(pad))
    return hist[:, :parts]


def skew_stats(keys: jax.Array, parts: int, **kw) -> dict:
    """Paper §3.5 inputs: per-partition totals + max/mean imbalance."""
    h = radix_hist(keys, parts, **kw)
    tot = h.sum(axis=0)
    mean = jnp.maximum(tot.mean(), 1e-9)
    return {"per_partition": tot, "max": tot.max(),
            "imbalance": tot.max() / mean}

"""Public wrapper: padding + global/per-block histograms + skew stats +
counting-rank dispatch (the sortless shuffle ranking primitive)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import _bin, counting_rank_pallas, radix_hist_pallas
from .ref import radix_hist_ref
from repro.kernels import auto_interpret

_LANES = 128


@partial(jax.jit, static_argnames=("parts", "blk", "interpret", "use_kernel",
                                   "hashed"))
def radix_hist(keys: jax.Array, parts: int, blk: int = 2048,
               interpret: bool | None = None, use_kernel: bool = True,
               hashed: bool = True) -> jax.Array:
    """Per-block partition histograms (ceil(n/blk), parts).

    Padding rows hash to arbitrary partitions, so they are excluded by
    hashing a sentinel lane and subtracting its count — simpler: we pad with
    the first key so totals stay exact after subtracting the pad count from
    that key's partition (done below).  ``hashed=False`` bins by ``key %
    parts`` directly (keys are destination ids already).  ``interpret=None``
    auto-selects: compiled on TPU, interpret mode elsewhere.
    """
    if interpret is None:
        interpret = auto_interpret()
    n = keys.shape[0]
    width = max(_LANES, (parts + _LANES - 1) // _LANES * _LANES)
    blk = min(blk, max(8, (n + 7) // 8 * 8))
    npad = (n + blk - 1) // blk * blk
    pad = npad - n
    k2 = jnp.concatenate([keys.astype(jnp.int32),
                          jnp.broadcast_to(keys[:1].astype(jnp.int32), (pad,))])
    if use_kernel:
        hist = radix_hist_pallas(k2, parts, width=width, blk=blk,
                                 interpret=interpret, hashed=hashed)
    else:
        hist = radix_hist_ref(k2, parts, blk, hashed=hashed)
    # subtract the duplicated pad rows from the last block (same binning
    # as the kernel, via the shared _bin, so the two can never diverge)
    if pad:
        p0 = _bin(keys[:1].astype(jnp.int32), parts, hashed)
        hist = hist.at[-1, p0[0]].add(-float(pad))
    return hist[:, :parts]


@partial(jax.jit, static_argnames=("parts", "blk", "interpret", "use_kernel"))
def counting_rank(keys: jax.Array, parts: int, blk: int = 2048,
                  interpret: bool | None = None, use_kernel: bool = True,
                  ) -> tuple[jax.Array, jax.Array]:
    """Stable counting rank — the sortless shuffle-dispatch primitive.

    keys (n,) int in [0, parts) -> (slot, counts) where ``slot[i]`` is row
    i's 0-based rank among earlier rows with the same key (exactly the
    position a stable sort on key would assign within its key group) and
    ``counts[p]`` the total rows with key p.  No sort either way:

      * **kernel leg** (``use_kernel=True``): ONE fused Pallas pass
        (``counting_rank_pallas``) — per-block one-hot histogram, exclusive
        intra-block rank via a strictly-lower-triangular MXU matmul, and the
        cross-block prefix carried in on-chip scratch across the sequential
        grid — the whole dispatch rank stays on-chip, nothing returns to
        host jnp between passes.
      * **oracle leg** (``use_kernel=False``): the differential jnp
        reference — per-block histograms, exclusive prefix sum over blocks,
        then a block-streamed one-hot cumsum (``lax.map``) so the peak
        intermediate is O(blk * parts), not O(n * parts).

    Padding rows go to a reserved bin (``parts``).  Per-block counts are <=
    blk (f32-exact); all cross-block arithmetic is int32, so ranks are exact
    for any n < 2^31 — matching the argsort this replaces.  The rank is
    independent of ``blk``, so the two legs are byte-identical.
    """
    if interpret is None:
        interpret = auto_interpret()
    n = keys.shape[0]
    width = parts + 1                          # + reserved padding bin
    wpad = max(_LANES, (width + _LANES - 1) // _LANES * _LANES)
    if use_kernel:
        blk = min(blk, 512)                    # (blk, blk) triangular tile
    blk = min(blk, max(8, (n + 7) // 8 * 8))
    npad = (n + blk - 1) // blk * blk
    k2 = jnp.concatenate([keys.astype(jnp.int32),
                          jnp.full((npad - n,), parts, jnp.int32)])
    if use_kernel:
        slot, histf = counting_rank_pallas(k2, width, width=wpad, blk=blk,
                                           interpret=interpret)
        counts = histf[:, :width].astype(jnp.int32).sum(axis=0)[:parts]
        return slot[:n], counts

    hist = radix_hist_ref(k2, width, blk, hashed=False)
    hist = hist.astype(jnp.int32)              # exact: per-block counts <= blk
    nb = npad // blk
    base = jnp.concatenate([jnp.zeros((1, width), jnp.int32),
                            jnp.cumsum(hist, axis=0)])[:-1]      # (nb, W)

    def _block_rank(args):
        kb, bb = args                                            # (blk,), (W,)
        oh = (kb[:, None] == jnp.arange(width, dtype=jnp.int32)
              ).astype(jnp.int32)                                # (blk, W)
        rank = bb[None, :] + jnp.cumsum(oh, axis=0) - oh         # exclusive
        return jnp.take_along_axis(rank, kb[:, None], axis=1)[:, 0]

    slot = jax.lax.map(_block_rank, (k2.reshape(nb, blk), base)).reshape(npad)
    counts = hist.sum(axis=0)[:parts]
    return slot[:n], counts


def skew_stats(keys: jax.Array, parts: int, **kw) -> dict:
    """Paper §3.5 inputs: per-partition totals + max/mean imbalance."""
    h = radix_hist(keys, parts, **kw)
    tot = h.sum(axis=0)
    mean = jnp.maximum(tot.mean(), 1e-9)
    return {"per_partition": tot, "max": tot.max(),
            "imbalance": tot.max() / mean}

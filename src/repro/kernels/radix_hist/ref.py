"""Pure-jnp oracle for the radix histogram kernel."""
import jax
import jax.numpy as jnp

from .kernel import murmur32


def radix_hist_ref(keys: jax.Array, parts: int, blk: int,
                   hashed: bool = True) -> jax.Array:
    n = keys.shape[0]
    k = keys.astype(jnp.int32)
    if hashed:
        pid = (murmur32(k) % jnp.uint32(parts)).astype(jnp.int32)
    else:
        pid = (k.astype(jnp.uint32) % jnp.uint32(parts)).astype(jnp.int32)
    blocks = pid.reshape(n // blk, blk)
    return jax.vmap(lambda b: jnp.bincount(b, length=parts))(blocks).astype(
        jnp.float32)

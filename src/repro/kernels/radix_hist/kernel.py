"""Radix-partition histogram kernel — shuffle capacity planning / skew stats
/ counting-rank dispatch.

For each row block, bin the (int32) key and produce a per-block partition
histogram (nblocks, P).  The per-block resolution is what the adaptive
capacity planner, the skew monitor, AND the shuffle dispatch rank consume
(paper §3.5: shuffle time = max over nodes of send/recv bytes — per-block
histograms expose that before any data moves; an exclusive prefix sum over
the same histograms ranks every row within its partition without a sort).

Two binning modes:
  * ``hashed=True``  — bin = murmur32(key) % parts (capacity planning over
    raw join keys; splitmix64 needs 64-bit multiplies the VPU lacks, so the
    in-kernel hash is the murmur3 32-bit finalizer, see DESIGN.md).
  * ``hashed=False`` — bin = key % parts (keys are already destination ids,
    e.g. the shuffle dispatch path where splitmix64 ran outside the kernel).

Histogram accumulation is a one-hot + MXU matmul, like segsum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def murmur32(k: jax.Array) -> jax.Array:
    """murmur3 fmix32 — vector-friendly 32-bit finalizer."""
    k = k.astype(jnp.uint32)
    k = k ^ (k >> 16)
    k = k * jnp.uint32(0x85EBCA6B)
    k = k ^ (k >> 13)
    k = k * jnp.uint32(0xC2B2AE35)
    k = k ^ (k >> 16)
    return k


def _bin(k: jax.Array, parts: int, hashed: bool) -> jax.Array:
    if hashed:
        return (murmur32(k) % jnp.uint32(parts)).astype(jnp.int32)
    return (k.astype(jnp.uint32) % jnp.uint32(parts)).astype(jnp.int32)


def _kernel(key_ref, out_ref, *, blk: int, parts: int, width: int,
            hashed: bool):
    pid = _bin(key_ref[...], parts, hashed)               # (blk, 1) i32
    iota = jax.lax.broadcasted_iota(jnp.int32, (blk, width), 1)
    onehot = (pid == iota).astype(jnp.float32)
    ones = jnp.ones((blk, 1), jnp.float32)
    hist = jax.lax.dot_general(onehot, ones,
                               dimension_numbers=(((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (W, 1)
    out_ref[...] = hist.T                                  # (1, W)


def radix_hist_pallas(keys: jax.Array, parts: int, width: int | None = None,
                      blk: int = 2048, interpret: bool = False,
                      hashed: bool = True) -> jax.Array:
    """keys (n,) int32 -> per-block histograms (n//blk, width) float32.

    ``parts`` is the bin modulo; ``width`` (>= parts, default 128-padded) is
    the lane-aligned output width — columns beyond parts stay zero."""
    n = keys.shape[0]
    width = width or max(128, (parts + 127) // 128 * 128)
    assert n % blk == 0 and width >= parts
    grid = (n // blk,)
    return pl.pallas_call(
        functools.partial(_kernel, blk=blk, parts=parts, width=width,
                          hashed=hashed),
        grid=grid,
        in_specs=[pl.BlockSpec((blk, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n // blk, width), jnp.float32),
        interpret=interpret,
    )(keys.reshape(n, 1).astype(jnp.int32))


# ---------------------------------------------------------------------------
# fused counting rank: histogram + intra-block exclusive rank in ONE kernel
# ---------------------------------------------------------------------------

def _rank_kernel(key_ref, slot_ref, hist_ref, run_ref, *, blk: int,
                 width: int, parts: int):
    """One grid step = one row block, executed SEQUENTIALLY (TPU grid order):

      1. one-hot the block's bins (hashed=False binning: keys are ids);
      2. exclusive intra-block rank per key via a strictly-lower-triangular
         ones matmul on the MXU (row i's rank = earlier same-key rows);
      3. add the running per-key total carried in VMEM scratch across blocks
         (the prefix sum the jnp oracle computes as a separate pass);
      4. extract each row's own rank through the one-hot (lane reduce).

    All counts stay <= blk per block so the f32 matmul is exact; the running
    total is carried in int32, so ranks are exact for any n < 2^31 — exactly
    the oracle's contract.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        run_ref[...] = jnp.zeros_like(run_ref)

    pid = _bin(key_ref[...], parts, False)                     # (blk, 1)
    iota_w = jax.lax.broadcasted_iota(jnp.int32, (blk, width), 1)
    onehot = (pid == iota_w).astype(jnp.float32)               # (blk, W)
    rows = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
    lower = (cols < rows).astype(jnp.float32)                  # strict lower
    excl = jax.lax.dot_general(lower, onehot,
                               dimension_numbers=(((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    rank = run_ref[0:1, :] + excl.astype(jnp.int32)            # (blk, W)
    sel = jnp.where(pid == iota_w, rank, 0)
    slot_ref[...] = jnp.sum(sel, axis=1, keepdims=True,
                            dtype=jnp.int32)                   # (blk, 1)
    bh = jnp.sum(onehot, axis=0, keepdims=True)                # (1, W)
    hist_ref[...] = bh
    run_ref[0:1, :] = run_ref[0:1, :] + bh.astype(jnp.int32)


def counting_rank_pallas(keys: jax.Array, parts: int, width: int,
                         blk: int = 512, interpret: bool = False,
                         ) -> tuple[jax.Array, jax.Array]:
    """keys (n,) int32 ids in [0, parts) -> (slot (n,) int32, hist (n//blk,
    width) f32): the whole shuffle-dispatch rank on-chip in one pass.

    ``blk`` bounds the (blk, blk) triangular tile (512 -> 1 MB VMEM); the
    rank produced is independent of the block size.
    """
    n = keys.shape[0]
    assert n % blk == 0 and width >= parts
    grid = (n // blk,)
    slot, hist = pl.pallas_call(
        functools.partial(_rank_kernel, blk=blk, width=width, parts=parts),
        grid=grid,
        in_specs=[pl.BlockSpec((blk, 1), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((blk, 1), lambda i: (i, 0)),
                   pl.BlockSpec((1, width), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, 1), jnp.int32),
                   jax.ShapeDtypeStruct((n // blk, width), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((8, width), jnp.int32)],
        interpret=interpret,
    )(keys.reshape(n, 1).astype(jnp.int32))
    return slot[:, 0], hist

"""Hash-compaction dictionary build for sortless group-by on unknown domains.

The direct-addressing aggregation (``kernels/segsum``) needs the packed group
key to BE the dense group id, which requires *provable* ``key_bits``.  Q13-style
keys (orders-per-customer) are data-dependent: the domain is small but cannot
be proved at plan time.  GPU engines answer this with a hash aggregation table
built by atomics; the TPU adaptation here is a **write-once open-addressing
dictionary built in VMEM across a sequential row-block grid** — the same
trick ``radix_hist.counting_rank`` uses for its running totals:

  * the dictionary is three ``(cap, 1)`` VMEM scratch planes — two int32 key
    planes holding the full 64-bit key (the ``hash_probe`` two-plane scheme,
    probed with the SAME ``bucket_of`` mix so both kernels hash identically)
    plus an occupancy plane — carried across grid steps;
  * each block's rows probe in lockstep rounds (linear probing from
    ``bucket_of(key)``): a round gathers the candidate slot, resolves rows
    whose key already sits there, and elects ONE writer per empty slot by a
    one-hot minimum over row indices — no atomics, no scatter, and a slot
    transitions empty -> occupied exactly once (write-once), so a resolved
    row's slot can never be stolen by a later key;
  * rows that exhaust ``rounds`` probes stay unresolved (``slot = -1``) — the
    caller raises the overflow flag and the fault runner re-executes with a
    larger dictionary (capacity-factor escalation), never silently merging or
    dropping groups.

The kernel returns hash-ordered slots; the wrapper (``ops.dict_rank``) turns
occupied slots into ascending-key dense ids with an O(cap^2) chunked compare
(cap is the SMALL dictionary, not the row count) so the aggregation output is
ordered identically to the sort path, byte for byte.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.hash_probe.kernel import bucket_of


def _insert_kernel(plo_ref, phi_ref, pv_ref, slot_ref, dlo_ref, dhi_ref,
                   docc_ref, tlo, thi, tocc, *, blk: int, cap: int,
                   rounds: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        tlo[...] = jnp.zeros_like(tlo)
        thi[...] = jnp.zeros_like(thi)
        tocc[...] = jnp.zeros_like(tocc)

    lo = plo_ref[...][:, 0]                                   # (blk,)
    hi = phi_ref[...][:, 0]
    valid = pv_ref[...][:, 0] != 0
    b = bucket_of(lo, hi, cap)
    rows = jax.lax.broadcasted_iota(jnp.int32, (blk, 1), 0)   # (blk, 1)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (blk, cap), 1)
    big = jnp.int32(blk)

    def body(r, carry):
        unres, out = carry
        s = jax.lax.rem(b + r.astype(jnp.int32), jnp.int32(cap))  # linear probe
        cl = tlo[...][s][:, 0]                                # (blk,) gathers
        ch = thi[...][s][:, 0]
        co = tocc[...][s][:, 0]
        hit = unres & (co == 1) & (cl == lo) & (ch == hi)
        out = jnp.where(hit, s, out)
        unres = unres & ~hit
        # elect ONE writer per still-empty slot: min row index attempting
        att = unres & (co == 0)
        m = att[:, None] & (s[:, None] == iota_c)             # (blk, cap)
        win = jnp.min(jnp.where(m, rows, big), axis=0)        # (cap,)
        has = (win < big)[:, None]                            # (cap, 1)
        widx = jnp.minimum(win, blk - 1)
        tlo[...] = jnp.where(has, lo[:, None][widx], tlo[...])
        thi[...] = jnp.where(has, hi[:, None][widx], thi[...])
        tocc[...] = jnp.where(has, jnp.int32(1), tocc[...])
        # losers see the winner's key on the re-gather and probe on
        cl2 = tlo[...][s][:, 0]
        ch2 = thi[...][s][:, 0]
        co2 = tocc[...][s][:, 0]
        hit2 = unres & (co2 == 1) & (cl2 == lo) & (ch2 == hi)
        out = jnp.where(hit2, s, out)
        unres = unres & ~hit2
        return unres, out

    unres0 = valid
    out0 = jnp.full((blk,), -1, jnp.int32)
    _, out = jax.lax.fori_loop(0, rounds, body, (unres0, out0))
    slot_ref[...] = out[:, None]
    # the dictionary outputs are pinned to block 0: the last grid step's write
    # is the final table (cheap — cap is small)
    dlo_ref[...] = tlo[...]
    dhi_ref[...] = thi[...]
    docc_ref[...] = tocc[...]


def hash_insert_pallas(plo: jax.Array, phi: jax.Array, pvalid: jax.Array,
                       cap: int, blk: int = 512, rounds: int = 16,
                       interpret: bool = False):
    """Insert-or-lookup of (n,) int32 key planes into a (cap,) dictionary.

    Returns ``(slot, dict_lo, dict_hi, occupied)``: per-row dictionary slot
    (int32, ``-1`` = invalid or unresolved after ``rounds`` probes) plus the
    final key planes and int32 occupancy of the dictionary.

    VMEM working set: 3 ``(cap, 1)`` scratch planes resident across the
    sequential grid + the ``(blk, cap)`` election tile per round — callers
    bound ``blk * cap`` (``ops.build_group_dict`` does).
    """
    n = plo.shape[0]
    assert n % blk == 0, (n, blk)
    grid = (n // blk,)
    return pl.pallas_call(
        functools.partial(_insert_kernel, blk=blk, cap=cap, rounds=rounds),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
            pl.BlockSpec((cap, 1), lambda i: (0, 0)),         # resident
            pl.BlockSpec((cap, 1), lambda i: (0, 0)),         # resident
            pl.BlockSpec((cap, 1), lambda i: (0, 0)),         # resident
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct((cap, 1), jnp.int32),
            jax.ShapeDtypeStruct((cap, 1), jnp.int32),
            jax.ShapeDtypeStruct((cap, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((cap, 1), jnp.int32),
            pltpu.VMEM((cap, 1), jnp.int32),
            pltpu.VMEM((cap, 1), jnp.int32),
        ],
        interpret=interpret,
    )(plo.reshape(n, 1), phi.reshape(n, 1), pvalid.reshape(n, 1))

"""Oracles for the hash-compaction dictionary.

``hash_insert_ref`` is the pure-jnp leg (``REPRO_AGG_KERNEL=0`` — the shipped
CPU/GPU default): the SAME lockstep write-once probing as the Pallas kernel,
but over all rows at once with int64 keys held directly.  It is deliberately
**sort-free** — the group-by stage must lower to zero HLO sorts on every
aggregation engine, so the oracle may not hide a ``jnp.unique`` argsort.  The
winner of a contended empty slot is elected with a deterministic scatter-min
over row indices (min is commutative, so the scatter is order-independent).

The two legs may assign keys to DIFFERENT slots (block-sequential vs global
lockstep races differ); that is fine by construction — the relational layer
ranks occupied slots by key before anything consumes a group id, so the final
aggregation output is identical either way.

``group_ids_np`` is the NumPy end-to-end oracle (np.unique — allowed here,
this one never traces) the property tests compare both legs against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.hash_probe.kernel import bucket_of
from repro.kernels.hash_probe.ops import _split64


def hash_insert_ref(keys: jax.Array, valid: jax.Array, cap: int,
                    rounds: int):
    """(n,) int64 keys -> (slot, dict_keys (cap,) int64, occupied, unresolved).

    ``slot[i] = -1`` for invalid rows and for rows still unresolved after
    ``rounds`` probes (the caller's overflow signal)."""
    n = keys.shape[0]
    k = keys.astype(jnp.int64)
    lo, hi = _split64(k)
    b = bucket_of(lo, hi, cap)
    iota = jnp.arange(n, dtype=jnp.int32)

    def body(r, carry):
        table, occ, slot, unres = carry
        s = (b + r.astype(jnp.int32)) % cap
        cur_hit = unres & occ[s] & (table[s] == k)
        slot = jnp.where(cur_hit, s, slot)
        unres = unres & ~cur_hit
        att = unres & ~occ[s]
        # deterministic winner per empty slot: scatter-min of row indices
        winner = jnp.full((cap + 1,), n, jnp.int32).at[
            jnp.where(att, s, cap)].min(iota)[:cap]
        has = winner < n
        wkey = k[jnp.minimum(winner, n - 1)]
        table = jnp.where(has, wkey, table)      # has implies the slot empty
        occ = occ | has
        hit2 = unres & occ[s] & (table[s] == k)
        slot = jnp.where(hit2, s, slot)
        unres = unres & ~hit2
        return table, occ, slot, unres

    table, occ, slot, unres = jax.lax.fori_loop(
        0, rounds, body,
        (jnp.zeros((cap,), jnp.int64), jnp.zeros((cap,), bool),
         jnp.full((n,), -1, jnp.int32), valid))
    return slot, table, occ, jnp.any(unres)


def group_ids_np(keys: np.ndarray, valid: np.ndarray
                 ) -> tuple[np.ndarray, np.ndarray]:
    """NumPy oracle: ascending-key dense group ids (-1 invalid) + unique keys."""
    uniq = np.unique(keys[valid])
    gid = np.full(keys.shape[0], -1, np.int64)
    gid[valid] = np.searchsorted(uniq, keys[valid])
    return gid, uniq

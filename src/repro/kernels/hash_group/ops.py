"""Public wrapper: dictionary sizing, row padding, rank derivation.

Capacity discipline (mirrors the hash-join bucket table): the dictionary is
sized ``next_pow2(groups_hint * capacity_factor)`` by the caller, so the fault
runner's capacity-factor escalation genuinely enlarges the dictionary on
re-execution.  Probing is bounded by a static ``rounds`` (full scan for tiny
dictionaries, a fixed window otherwise): a row that exhausts its window —
dictionary full, or an improbable murmur cluster — stays unresolved, which the
relational layer converts into the overflow flag.  Escalation lowers the load
factor, which shortens clusters, so retries converge; an undercounting
``groups_hint`` claim is NOT fixable by capacity (the group count itself
overflows) and falls to the runner's hint-drop recompilation, exactly like a
lying wire bound.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import auto_interpret
from repro.kernels.hash_probe.ops import _split64, next_pow2
from .kernel import hash_insert_pallas
from .ref import hash_insert_ref

# probe-window bound: tiny dictionaries are scanned in full (load factor 1.0
# still resolves); larger ones use a fixed window — at the default load
# factor <= 0.5 a 32-slot linear-probe cluster is vanishingly rare, and the
# overflow/escalation path covers the remainder
_MAX_ROUNDS = 32
# cap the (blk, cap) election tile the kernel holds in VMEM (int32 words)
_ELECT_TILE_MAX = 1 << 21


def default_rounds(cap: int) -> int:
    return min(cap, _MAX_ROUNDS)


def dict_capacity(groups_hint: int, factor: float = 2.0) -> int:
    """Dictionary slots for a claimed group bound under ``factor`` headroom."""
    return next_pow2(max(16, int(round(groups_hint * factor))))


def _merge64(lo: jax.Array, hi: jax.Array) -> jax.Array:
    """Inverse of ``hash_probe.ops._split64`` (bit-exact)."""
    lo_u = jax.lax.bitcast_convert_type(lo, jnp.uint32).astype(jnp.int64)
    return (hi.astype(jnp.int64) << 32) | lo_u


@partial(jax.jit, static_argnames=("cap", "rounds", "use_kernel", "interpret"))
def build_group_dict(keys: jax.Array, valid: jax.Array, cap: int,
                     rounds: int | None = None, use_kernel: bool = True,
                     interpret: bool | None = None):
    """Insert-or-lookup (n,) int64 keys into a ``cap``-slot dictionary.

    Returns ``(slot, dict_keys, occupied, unresolved)``: per-row slot (int32,
    -1 = invalid or unresolved), the (cap,) int64 dictionary keys, the (cap,)
    occupancy mask, and the scalar overflow signal (some valid row could not
    be placed).  Works for ANY int64 key — negative values included — since
    slots carry exact two-plane keys, not a packed domain.
    """
    if interpret is None:
        interpret = auto_interpret()
    if rounds is None:
        rounds = default_rounds(cap)
    n = keys.shape[0]
    if not use_kernel:
        return hash_insert_ref(keys, valid, cap, rounds)
    blk = 512
    while blk > 8 and blk * cap > _ELECT_TILE_MAX:
        blk //= 2
    blk = min(blk, max(8, (n + 7) // 8 * 8))
    npad = (n + blk - 1) // blk * blk
    k = jnp.zeros((npad,), jnp.int64).at[:n].set(keys.astype(jnp.int64))
    v = jnp.zeros((npad,), jnp.int32).at[:n].set(valid.astype(jnp.int32))
    lo, hi = _split64(k)
    slot, dlo, dhi, docc = hash_insert_pallas(lo, hi, v, cap, blk=blk,
                                              rounds=rounds,
                                              interpret=interpret)
    slot = slot[:n, 0]
    dict_keys = _merge64(dlo[:, 0], dhi[:, 0])
    occupied = docc[:, 0] == 1
    unresolved = jnp.any(valid & (slot < 0))
    return slot, dict_keys, occupied, unresolved


def dict_rank(dict_keys: jax.Array, occupied: jax.Array,
              chunk: int = 1024) -> jax.Array:
    """Ascending-key dense rank per occupied slot; ``cap`` for empty slots.

    Sort-free by construction: occupied slots hold DISTINCT keys, so
    ``rank[s] = #{t occupied : key[t] < key[s]}`` is a total order — computed
    as a chunked O(cap^2) compare over the SMALL dictionary (never the rows).
    The group-by output ordered by these ranks matches the sort path row for
    row.
    """
    cap = dict_keys.shape[0]
    parts = []
    for s0 in range(0, cap, chunk):
        ks = dict_keys[s0:s0 + chunk]
        less = (dict_keys[None, :] < ks[:, None]) & occupied[None, :]
        parts.append(jnp.sum(less, axis=1, dtype=jnp.int32))
    rank = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    return jnp.where(occupied, rank, cap)

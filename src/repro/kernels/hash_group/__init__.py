from . import ops, ref, kernel  # noqa: F401

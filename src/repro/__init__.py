"""repro — distributed tensor query processing + multi-pod LM framework in JAX.

Reproduction of "Terabyte-Scale Analytics in the Blink of an Eye" (distributed
TQP on collective communication) adapted to TPU pods, plus the assigned
LM-architecture zoo, training/serving substrate, and multi-pod launch tooling.

x64 is enabled globally: SQL analytics needs real int64 keys (TPC-H SF>=1000
orderkeys exceed int32).  All model code specifies dtypes explicitly, so LM
paths remain bf16/f32/int32.
"""
import jax

jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"

"""Recompute analysis fields of dry-run records from archived HLO (no
recompilation): the perf loop iterates on the analyzer cheaply.

    PYTHONPATH=src python -m repro.launch.reanalyze
"""
from __future__ import annotations

import glob
import gzip
import json
import os

from repro.configs import SHAPES, get_config
from repro.distributed import hlo_analysis as ha

BASE = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results")


def model_flops(cfg, shape_id, batch, seq) -> float:
    n = cfg.active_param_count or cfg.param_count
    kind = SHAPES[shape_id][2]
    if kind == "train":
        return 6.0 * n * batch * seq
    if kind == "prefill":
        return 2.0 * n * batch * seq
    return 2.0 * n * batch


def main():
    for jf in sorted(glob.glob(os.path.join(BASE, "dryrun", "*.json"))):
        rec = json.load(open(jf))
        if not rec.get("ok"):
            continue
        base = os.path.basename(jf)[:-5]
        parts = base.split("__")
        mesh_tag = parts[2] if len(parts) > 2 else ""
        tag = ""
        for m in ("2x16x16", "16x16"):
            if mesh_tag.startswith(m):
                tag = mesh_tag[len(m):].lstrip("_")
                break
        sfx = f"_{tag}" if tag else ""
        hf = os.path.join(BASE, "hlo",
                          f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
                          f"{sfx}.hlo.gz")
        if not os.path.exists(hf):
            print("no hlo for", jf)
            continue
        with gzip.open(hf, "rt") as f:
            hlo = f.read()
        cfg = get_config(rec["arch"])
        seq, batch, _ = SHAPES[rec["shape"]]
        seq_dims = {seq, seq + cfg.n_prefix} if cfg.n_prefix else {seq}
        mod = ha.analyze_module(hlo, seq_dims=seq_dims)
        rec["hlo_flops"] = mod["flops"]
        rec["hlo_bytes"] = mod["traffic_bytes"]
        rec["scores_traffic_bytes"] = mod["scores_traffic_bytes"]
        rec["collective_bytes"] = mod["collective_bytes"]
        rec["collective_count"] = mod["collective_count"]
        n_dev = rec["n_devices"]
        mf = model_flops(cfg, rec["shape"], batch, seq)
        rec["roofline"] = ha.roofline_terms(
            mod["flops"], mod["traffic_bytes"],
            sum(mod["collective_bytes"].values()), n_dev, model_flops=mf)
        # flash-kernel-adjusted variant: the Pallas kernel keeps the seq x seq
        # scores/mask chain in VMEM (validated by the kernel's BlockSpecs);
        # HBM traffic drops by exactly that attributed portion.
        rec["roofline_flash"] = ha.roofline_terms(
            mod["flops"],
            mod["traffic_bytes"] - mod["scores_traffic_bytes"],
            sum(mod["collective_bytes"].values()), n_dev, model_flops=mf)
        json.dump(rec, open(jf, "w"), indent=1)
        rf = rec["roofline"]
        print(f"{rec['arch']:24s}{rec['shape']:14s}{rec['mesh']:9s}{tag:9s}"
              f"{rf['bottleneck']:11s}"
              f"c={rf['compute_s']*1e3:9.1f}ms m={rf['memory_s']*1e3:9.1f}ms "
              f"x={rf['collective_s']*1e3:8.1f}ms "
              f"roofline={100*rf.get('roofline_frac',0):6.2f}%")


if __name__ == "__main__":
    main()

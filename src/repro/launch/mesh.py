"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
jax initialization.
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False, tp: int = 16):
    """16x16 chips per pod; 2 pods when multi_pod (512 chips).

    ``tp`` re-splits the 256-chip pod between data and model axes — serving
    prefers small TP (per-token all-reduce latency scales with TP)."""
    assert 256 % tp == 0
    dp = 256 // tp
    shape = (2, dp, tp) if multi_pod else (dp, tp)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(devices, axes)


def make_analytics_mesh(*, multi_pod: bool = False):
    """Analytics uses a flat exchange axis: pod x data for multi-pod."""
    shape = (2, 256) if multi_pod else (256,)
    axes = ("pod", "data") if multi_pod else ("data",)
    n = int(np.prod(shape))
    devices = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(devices, axes)


def make_local_mesh(n: int | None = None, axis: str = "data"):
    devs = jax.devices()
    n = n or len(devs)
    return jax.sharding.Mesh(np.asarray(devs[:n]), (axis,))

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import (see dryrun.py).
"""Analytics dry-run: compile TPC-H query plans at SF=1000 on the pod mesh.

The paper's headline artifact — 22 queries over ~6B-row lineitem across the
cluster — lowered and compiled as real SPMD programs: table stand-ins are
ShapeDtypeStructs with SF=1000 row counts sharded over 256 (or 512) devices;
dictionaries/metadata come from a tiny generated database (they are
host-side).  Reports per-query roofline terms + exchange bytes, and compares
the measured-from-HLO collective volume against the paper's Eq. 1/2 models.

    PYTHONPATH=src python -m repro.launch.dryrun_analytics [--queries 1,6,9]
"""
import argparse
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import backend as B
from repro.core import compat
from repro.core import perfmodel as pm
from repro.core import relational as rel
from repro.core.table import Table
from repro.data import tpch
from repro.distributed import hlo_analysis as ha
from repro.launch.mesh import make_analytics_mesh
from repro.queries import QUERIES

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "analytics_dryrun")

# SF=1000 row counts (paper §4.3 tables)
SF1000_ROWS = {
    "region": 5, "nation": 25, "supplier": 10_000_000,
    "customer": 150_000_000, "part": 200_000_000, "partsupp": 800_000_000,
    "orders": 1_500_000_000, "lineitem": 6_000_000_000,
}

# Scale-DEPENDENT key columns: their domain at SF=1000 is the PK row count of
# the owning table (our generator draws dense 1..n keys).  The tiny metadata
# database's min/max for these would let the planner infer hints valid only
# at the tiny scale (e.g. a 256-slot direct group-by over 150M custkeys), so
# the stand-in compile overwrites their stats before analysis.  Date, dict,
# and quantity columns keep the tiny db's stats — those domains are
# scale-independent, exactly like the hand hints they replaced.
_SCALE_KEYS = {
    "o_orderkey": "orders", "l_orderkey": "orders",
    "c_custkey": "customer", "o_custkey": "customer",
    "p_partkey": "part", "l_partkey": "part", "ps_partkey": "part",
    "s_suppkey": "supplier", "l_suppkey": "supplier",
    "ps_suppkey": "supplier",
}


def _sf1000_stats(db):
    """Scoped override of the planner's column stats with the SF=1000 key
    domains (planner.stats_override restores the actual-scale stats on exit,
    so later real executions of the same tiny database re-infer correctly)."""
    from repro.core import planner as PL
    stats = dict(PL.column_stats(db))
    for cname, table in _SCALE_KEYS.items():
        hi = SF1000_ROWS[table]
        stats[cname] = PL.ColStats(1, hi, hi)
    return PL.stats_override(db, stats)


def build_specs(db, n_dev: int):
    """ShapeDtypeStruct stand-ins shaped like partition_database's output."""
    specs = {}
    caps = {}
    for name, cols in db.tables.items():
        rows = SF1000_ROWS[name]
        if B.PARTITION_KEYS.get(name) is None:
            cap = max(8, math.ceil(rows / 8) * 8)          # replicated dims
        else:
            cap = max(8, math.ceil(rows / n_dev * 1.02 / 8) * 8)
        caps[name] = cap
        tcols = {}
        for cname, arr in cols.items():
            tcols[cname] = jax.ShapeDtypeStruct((n_dev * cap,), arr.dtype)
        tcols["__count"] = jax.ShapeDtypeStruct((n_dev,), np.int32)
        specs[name] = tcols
    return specs, caps


def dryrun_query(qid: int, db, mesh, capacity_factor=1.02,
                 packed=True) -> dict:
    n = mesh.shape["data"] * mesh.shape.get("pod", 1)
    # multi-pod: the exchange axis spans (pod, data) — collectives cross pods
    axis = ("pod", "data") if "pod" in mesh.shape else "data"
    specs, caps = build_specs(db, n)
    holder = {}

    def spmd(tree):
        tables = {}
        for name, cols in tree.items():
            cols = dict(cols)
            cnt = cols.pop("__count").reshape(())
            tables[name] = Table(cols, cnt)
        ctx = B.DistContext(db, tables, axis, n, capacity_factor, packed)
        out = QUERIES[qid](ctx)
        holder["stats"] = ctx.stats
        if isinstance(out, dict):
            out = Table({k: jnp.asarray(v).reshape(1) for k, v in out.items()},
                        jnp.asarray(1, jnp.int32))
        out = rel.ensure_compact(out)
        return (Table(dict(out.columns), out.count.reshape(1)),
                ctx.overflow.reshape(1))

    # hints traced during lowering must model SF=1000 key domains, not the
    # tiny metadata db's; scoped so later real runs of db re-infer correctly
    with mesh, _sf1000_stats(db):
        fn = jax.jit(compat.shard_map(
            spmd, mesh=mesh, in_specs=P(axis), out_specs=P(axis)))
        t0 = time.time()
        lowered = fn.lower(specs)
        compiled = lowered.compile()
        compile_s = time.time() - t0

    hlo = compiled.as_text()
    mod = ha.analyze_module(hlo)
    stats = holder["stats"]
    rec = {
        "query": qid, "n_devices": n, "compile_s": round(compile_s, 1),
        "sf": 1000,
        "plan": stats.counts(),
        "hlo_flops": mod["flops"], "hlo_bytes": mod["traffic_bytes"],
        "collective_bytes": mod["collective_bytes"],
        "collective_count": mod["collective_count"],
        "lineitem_rows_per_dev": caps["lineitem"],
    }
    rec["roofline"] = ha.roofline_terms(
        mod["flops"], mod["traffic_bytes"],
        sum(mod["collective_bytes"].values()), n)
    # paper-model cross-check: predicted exchange time for the plan's
    # logged exchange volumes on the v5e cluster spec.  message_bytes are
    # WIRE bytes (stats-narrowed lanes + fused counts header), so the model
    # prices what actually crosses the interconnect; wire_savings records
    # the per-exchange compression the narrow format bought.
    spec = pm.CLUSTERS["tpu_v5e"]
    t_model = sum(pm.exchange_time_from_stats(e, spec, n_devices=n)
                  for e in stats.log)
    rec["model_exchange_s"] = t_model
    rec["wire_savings"] = [round(pm.wire_savings(e), 3) for e in stats.log]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", default="1,4,6,9,13,18")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    os.makedirs(RESULTS, exist_ok=True)
    db = tpch.generate(0.001, seed=7)      # dictionaries/metadata only
    db.scale = 1000.0                      # plans see SF=1000 (Q11 fraction)
    mesh = make_analytics_mesh(multi_pod=args.multi_pod)
    for qid in [int(q) for q in args.queries.split(",")]:
        print(f"=== TPC-H Q{qid} @ SF=1000 on {mesh.devices.size} devices",
              flush=True)
        try:
            rec = dryrun_query(qid, db, mesh)
            rf = rec["roofline"]
            print(f"  compile={rec['compile_s']}s plan={rec['plan']} "
                  f"c={rf['compute_s']*1e3:.1f}ms m={rf['memory_s']*1e3:.1f}ms "
                  f"x={rf['collective_s']*1e3:.1f}ms "
                  f"model_exchange={rec['model_exchange_s']*1e3:.1f}ms",
                  flush=True)
        except Exception as e:
            import traceback
            rec = {"query": qid, "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-1500:]}
            print("  FAILED:", rec["error"][:200], flush=True)
        sfx = "_2x256" if args.multi_pod else "_256"
        with open(os.path.join(RESULTS, f"q{qid}{sfx}.json"), "w") as f:
            json.dump(rec, f, indent=1, default=float)


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:  eval_shape the train/serve step against ShapeDtypeStruct
inputs (no allocation), attach the production shardings, .lower().compile(),
then extract memory_analysis / cost_analysis / collective bytes (HLO parse)
into results/dryrun/<cell>.json for EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --all               # every cell, resumable
  python -m repro.launch.dryrun --arch qwen1_5_110b --shape train_4k --multi-pod
"""
import argparse
import gzip
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, cell_enabled, get_config, input_specs
from repro.distributed import hlo_analysis as ha
from repro.distributed.shardings import (MeshAxes, batch_specs, cache_specs,
                                         make_constrain, named, param_specs)
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import Model
from repro.train import optimizer as optim
from repro.train.trainstep import (init_train_state, make_decode_step,
                                   make_prefill_step, make_train_step)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun")


def model_flops(cfg, shape_id: int, batch: int, seq: int) -> float:
    n = cfg.active_param_count or cfg.param_count
    kind = SHAPES[shape_id][2]
    if kind == "train":
        return 6.0 * n * batch * seq
    if kind == "prefill":
        return 2.0 * n * batch * seq
    return 2.0 * n * batch          # decode: one token


def _spec_tree_to_named(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def dryrun_cell(arch_id: str, shape_id: str, multi_pod: bool,
                remat: str = "full", constrain_acts: bool = True,
                bf16_norms: bool = False, seq_parallel: bool = False,
                grad_compress: str = "none", microbatches: int = 1,
                tp: int = 16, serve_sharding: bool = False,
                tag: str = "") -> dict:
    import dataclasses as _dc
    seq, batch, kind = SHAPES[shape_id]
    cfg = get_config(arch_id)
    if bf16_norms:
        cfg = _dc.replace(cfg, norms_f32=False)
    mesh = make_production_mesh(multi_pod=multi_pod, tp=tp)
    axes = MeshAxes(fsdp=("pod", "data") if multi_pod else ("data",),
                    tp="model")
    tp_size = mesh.shape["model"]
    model = Model(cfg, expert_pad=tp_size, vocab_pad=128, remat=remat,
                  constrain=make_constrain(mesh, axes, seq_parallel)
                  if constrain_acts else (lambda x, k: x))

    key = jax.random.PRNGKey(0)
    p_struct = jax.eval_shape(lambda: model.init(key, dtype=jnp.bfloat16))
    # serving: weight-stationary params (TP-only; no per-step FSDP gathers)
    p_axes = MeshAxes(fsdp=(), tp="model") if serve_sharding else axes
    p_specs = param_specs(p_struct, p_axes)
    in_spec = input_specs(cfg, shape_id)
    rec = {"arch": arch_id, "shape": shape_id,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "n_devices": mesh.devices.size, "kind": kind}

    with mesh:
        if kind == "train":
            ocfg = optim.AdamWConfig()
            step = make_train_step(model, ocfg, grad_compress,
                                   microbatches=microbatches)
            s_struct = jax.eval_shape(
                lambda p: init_train_state(model, p, grad_compress), p_struct)
            s_specs = {"opt": {"step": P(),
                               "m": param_specs(s_struct["opt"]["m"], axes),
                               "v": param_specs(s_struct["opt"]["v"], axes)}}
            if grad_compress == "int8_ef":
                s_specs["ef"] = param_specs(s_struct["ef"], axes)
            b_specs = batch_specs(axes, in_spec)
            fn = jax.jit(
                step,
                in_shardings=(_spec_tree_to_named(mesh, p_specs),
                              _spec_tree_to_named(mesh, s_specs),
                              _spec_tree_to_named(mesh, b_specs)),
                out_shardings=(_spec_tree_to_named(mesh, p_specs),
                               _spec_tree_to_named(mesh, s_specs),
                               None),
                donate_argnums=(0, 1))
            args = (p_struct, s_struct, in_spec)
        elif kind == "prefill":
            cache_len = seq + (cfg.n_prefix if cfg.frontend == "vision_patches"
                               else 0)
            fn_ = make_prefill_step(model, batch, cache_len)
            c_struct = jax.eval_shape(
                lambda: model.init_cache(batch, cache_len, dtype=jnp.bfloat16))
            c_specs = cache_specs(cfg, c_struct, axes, batch,
                                  dict(mesh.shape))
            b_specs = batch_specs(axes, in_spec)
            dp = axes.dp() if len(axes.dp()) > 1 else axes.dp()[0]
            fn = jax.jit(
                fn_,
                in_shardings=(_spec_tree_to_named(mesh, p_specs),
                              _spec_tree_to_named(mesh, b_specs)),
                out_shardings=(NamedSharding(mesh, P(dp, None, "model")),
                               _spec_tree_to_named(mesh, c_specs)))
            args = (p_struct, in_spec)
        else:  # decode
            fn_ = make_decode_step(model)
            c_struct = jax.eval_shape(
                lambda: model.init_cache(batch, seq, dtype=jnp.bfloat16))
            c_specs = cache_specs(cfg, c_struct, axes, batch,
                                  dict(mesh.shape))
            dp = axes.dp() if len(axes.dp()) > 1 else axes.dp()[0]
            tok_spec = P(dp, None) if batch >= mesh.devices.size // tp_size \
                else P(None, None)
            pos = jax.ShapeDtypeStruct((), np.int32)
            fn = jax.jit(
                fn_,
                in_shardings=(_spec_tree_to_named(mesh, p_specs),
                              NamedSharding(mesh, tok_spec),
                              _spec_tree_to_named(mesh, c_specs),
                              NamedSharding(mesh, P())),
                out_shardings=(NamedSharding(mesh, P(*tok_spec, "model")),
                               _spec_tree_to_named(mesh, c_specs)),
                donate_argnums=(2,))
            args = (p_struct, in_spec["token"], c_struct, pos)

        t0 = time.time()
        lowered = fn.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)

        try:
            mem = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes":
                    getattr(mem, "generated_code_size_in_bytes", None),
            }
        except Exception as e:   # backend may not support it
            rec["memory"] = {"error": str(e)}
        cost = compiled.cost_analysis() or {}
        rec["cost_analysis_flops"] = float(cost.get("flops", 0.0))
        rec["cost_analysis_bytes"] = float(cost.get("bytes accessed", 0.0))

        hlo = compiled.as_text()
        # loop-aware accounting (cost_analysis counts while bodies once)
        mod = ha.analyze_module(hlo)
        rec["hlo_flops"] = mod["flops"]
        rec["hlo_bytes"] = mod["traffic_bytes"]
        rec["collective_bytes"] = mod["collective_bytes"]
        rec["collective_count"] = mod["collective_count"]
        rec["op_histogram"] = ha.op_histogram(hlo)
        rec["hlo_len"] = len(hlo)
        hlo_dir = os.path.join(RESULTS, "..", "hlo")
        os.makedirs(hlo_dir, exist_ok=True)
        sfx = f"_{tag}" if tag else ""
        with gzip.open(os.path.join(
                hlo_dir, f"{arch_id}__{shape_id}__{rec['mesh']}{sfx}.hlo.gz"),
                "wt") as f:
            f.write(hlo)

        mf = model_flops(cfg, shape_id, batch, seq)
        rec["roofline"] = ha.roofline_terms(
            rec["hlo_flops"], rec["hlo_bytes"],
            sum(mod["collective_bytes"].values()),
            mesh.devices.size, model_flops=mf)
    rec["ok"] = True
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--bf16-norms", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--tp", type=int, default=16)
    ap.add_argument("--serve-sharding", action="store_true")
    ap.add_argument("--grad-compress", default="none")
    ap.add_argument("--no-constrain", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(RESULTS, exist_ok=True)

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for shape in SHAPES:
                ok, why = cell_enabled(cfg, shape)
                if ok:
                    cells.append((arch, shape, False))
                else:
                    _write(arch, shape, "16x16", {"ok": False, "skipped": why,
                                                  "arch": arch, "shape": shape,
                                                  "mesh": "16x16"}, args.tag)
                    _write(arch, shape, "2x16x16",
                           {"ok": False, "skipped": why, "arch": arch,
                            "shape": shape, "mesh": "2x16x16"}, args.tag)
        # multi-pod pass: every enabled cell again on the 2x16x16 mesh
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for shape in SHAPES:
                ok, _ = cell_enabled(cfg, shape)
                if ok:
                    cells.append((arch, shape, True))
    else:
        cells.append((args.arch, args.shape, args.multi_pod))

    for arch, shape, mp in cells:
        mesh_name = "2x16x16" if mp else "16x16"
        out = _path(arch, shape, mesh_name, args.tag)
        if os.path.exists(out) and not args.force:
            print(f"skip (exists): {out}", flush=True)
            continue
        print(f"=== {arch} x {shape} x {mesh_name}", flush=True)
        try:
            rec = dryrun_cell(arch, shape, mp, remat=args.remat,
                              constrain_acts=not args.no_constrain,
                              bf16_norms=args.bf16_norms,
                              seq_parallel=args.seq_parallel,
                              grad_compress=args.grad_compress,
                              microbatches=args.microbatch,
                              tp=args.tp, serve_sharding=args.serve_sharding,
                              tag=args.tag)
            print(json.dumps({k: rec[k] for k in
                              ("hlo_flops", "hlo_bytes", "compile_s")},
                             indent=None), flush=True)
        except Exception as e:
            rec = {"ok": False, "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:],
                   "arch": arch, "shape": shape, "mesh": mesh_name}
            print("FAILED:", rec["error"], flush=True)
        _write(arch, shape, mesh_name, rec, args.tag)


def _path(arch, shape, mesh_name, tag=""):
    sfx = f"_{tag}" if tag else ""
    return os.path.join(RESULTS, f"{arch}__{shape}__{mesh_name}{sfx}.json")


def _write(arch, shape, mesh_name, rec, tag=""):
    os.makedirs(RESULTS, exist_ok=True)
    with open(_path(arch, shape, mesh_name, tag), "w") as f:
        json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()

"""launch subpackage."""

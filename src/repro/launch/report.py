"""Reduce results/dryrun/*.json into the EXPERIMENTS.md §Dry-run/§Roofline
tables (markdown on stdout).

    PYTHONPATH=src python -m repro.launch.report [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun")


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def _file_tag(path: str) -> str:
    base = os.path.basename(path)[:-5]
    parts = base.split("__")
    mesh_tag = parts[2] if len(parts) > 2 else ""
    for m in ("2x16x16", "16x16"):
        if mesh_tag.startswith(m):
            return mesh_tag[len(m):].lstrip("_")
    return ""


def load(mesh: str | None = None, tag: str = ""):
    recs = []
    for f in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        if _file_tag(f) != tag:
            continue
        r = json.load(open(f))
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def roofline_table(recs):
    print("| arch | shape | mesh | bottleneck | compute | memory | collective"
          " | step LB | roofline | useful FLOPs | collectives |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("skipped"):
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"*skipped* | - | - | - | - | - | - | {r['skipped'][:46]} |")
            continue
        if not r.get("ok"):
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | **FAILED** "
                  f"| - | - | - | - | - | - | {r.get('error', '')[:40]} |")
            continue
        rf = r["roofline"]
        cc = r.get("collective_count", {})
        cstr = " ".join(f"{k.split('-')[-1][:4]}:{v}" for k, v in
                        sorted(cc.items()))
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {rf['bottleneck']} "
              f"| {rf['compute_s'] * 1e3:.1f}ms "
              f"| {rf['memory_s'] * 1e3:.1f}ms "
              f"| {rf['collective_s'] * 1e3:.1f}ms "
              f"| {rf['step_lower_bound_s'] * 1e3:.1f}ms "
              f"| {100 * rf.get('roofline_frac', 0):.1f}% "
              f"| {100 * rf.get('useful_flop_frac', 0):.0f}% "
              f"| {cstr} |")


def dryrun_table(recs):
    print("| arch | shape | mesh | compile | HLO flops/dev | traffic/dev |"
          " collective bytes/dev | temp bytes | arg bytes |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if not r.get("ok"):
            continue
        mem = r.get("memory", {})
        cb = sum(r.get("collective_bytes", {}).values())
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {r.get('compile_s', 0):.0f}s "
              f"| {r['hlo_flops']:.2e} | {fmt_bytes(r['hlo_bytes'])} "
              f"| {fmt_bytes(cb)} "
              f"| {fmt_bytes(mem.get('temp_bytes'))} "
              f"| {fmt_bytes(mem.get('argument_bytes'))} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--section", default="roofline",
                    choices=["roofline", "dryrun"])
    args = ap.parse_args()
    recs = load(args.mesh, args.tag)
    if args.section == "roofline":
        roofline_table(recs)
    else:
        dryrun_table(recs)


if __name__ == "__main__":
    main()

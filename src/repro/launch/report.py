"""Reduce results/dryrun/*.json into the EXPERIMENTS.md §Dry-run/§Roofline
tables, and results/runs/*.json (fault-runner RunReports) into the
per-attempt audit table (markdown on stdout).

    PYTHONPATH=src python -m repro.launch.report [--mesh 16x16]
    PYTHONPATH=src python -m repro.launch.report --section runs
"""
from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun")
RUNS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                    "results", "runs")


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def _file_tag(path: str) -> str:
    base = os.path.basename(path)[:-5]
    parts = base.split("__")
    mesh_tag = parts[2] if len(parts) > 2 else ""
    for m in ("2x16x16", "16x16"):
        if mesh_tag.startswith(m):
            return mesh_tag[len(m):].lstrip("_")
    return ""


def load(mesh: str | None = None, tag: str = ""):
    recs = []
    for f in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        if _file_tag(f) != tag:
            continue
        r = json.load(open(f))
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def roofline_table(recs):
    print("| arch | shape | mesh | bottleneck | compute | memory | collective"
          " | step LB | roofline | useful FLOPs | collectives |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("skipped"):
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"*skipped* | - | - | - | - | - | - | {r['skipped'][:46]} |")
            continue
        if not r.get("ok"):
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | **FAILED** "
                  f"| - | - | - | - | - | - | {r.get('error', '')[:40]} |")
            continue
        rf = r["roofline"]
        cc = r.get("collective_count", {})
        cstr = " ".join(f"{k.split('-')[-1][:4]}:{v}" for k, v in
                        sorted(cc.items()))
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {rf['bottleneck']} "
              f"| {rf['compute_s'] * 1e3:.1f}ms "
              f"| {rf['memory_s'] * 1e3:.1f}ms "
              f"| {rf['collective_s'] * 1e3:.1f}ms "
              f"| {rf['step_lower_bound_s'] * 1e3:.1f}ms "
              f"| {100 * rf.get('roofline_frac', 0):.1f}% "
              f"| {100 * rf.get('useful_flop_frac', 0):.0f}% "
              f"| {cstr} |")


def dryrun_table(recs):
    print("| arch | shape | mesh | compile | HLO flops/dev | traffic/dev |"
          " collective bytes/dev | temp bytes | arg bytes |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if not r.get("ok"):
            continue
        mem = r.get("memory", {})
        cb = sum(r.get("collective_bytes", {}).values())
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {r.get('compile_s', 0):.0f}s "
              f"| {r['hlo_flops']:.2e} | {fmt_bytes(r['hlo_bytes'])} "
              f"| {fmt_bytes(cb)} "
              f"| {fmt_bytes(mem.get('temp_bytes'))} "
              f"| {fmt_bytes(mem.get('argument_bytes'))} |")


def run_report_record(query, report) -> dict:
    """JSON-able record of one ``QueryRunner.run`` audit trail
    (:class:`repro.distributed.fault.RunReport`) for results/runs/."""
    return {"query": str(query), "attempts": report.rows(),
            "injected": [dataclasses.asdict(f) for f in report.injected]}


def load_runs():
    return [json.load(open(f))
            for f in sorted(glob.glob(os.path.join(RUNS, "*.json")))]


def _fmt_ci(ci) -> str:
    """CI half-width cell: '-' when not an approx attempt, 'inf' when the
    sample could not support a variance estimate."""
    if ci is None:
        return "-"
    ci = float(ci)
    if ci != ci or ci == float("inf"):
        return "inf"
    return f"{100 * ci:.2f}%"


def run_report_table(recs):
    """Per-attempt audit of fault-runner executions: what failed, where the
    chaos harness injected it, which sample-ladder rung answered (approx
    runs), and how the policy recovered."""
    print("| query | attempt | outcome | cut | factor | wire | inference |"
          " rung | ci | wall | backoff | snapshots | devices | gen |"
          " error |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        for a in r.get("attempts", []):
            rung = a.get("rung", 0)
            print(f"| {r.get('query', '?')} | {a['attempt']} "
                  f"| {a['outcome']} "
                  f"| {a.get('cut') or '-'} "
                  f"| {a['capacity_factor']:.2f} "
                  f"| {a.get('wire_format') or 'env'} "
                  f"| {'on' if a.get('inference', True) else 'off'} "
                  f"| {f'1/{rung}' if rung else 'exact'} "
                  f"| {_fmt_ci(a.get('ci_width'))} "
                  f"| {a['wall_s'] * 1e3:.0f}ms "
                  f"| {a['backoff_s'] * 1e3:.0f}ms "
                  f"| {a.get('snapshots_reused', 0)} "
                  f"| {a.get('devices', 0) or '-'} "
                  f"| {a.get('generation', 0)} "
                  f"| {a.get('error', '')[:40]} |")


def serve_table():
    """One-line markdown digest of ``BENCH_serve.json`` (repo root)."""
    path = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "BENCH_serve.json")
    r = json.load(open(path))
    print("| sf | requests | templates | recompiles | cache hits |"
          " shared hits | cold | warm q/s | batch q/s | pass |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    print(f"| {r['sf']} | {r['requests']} | {r['templates']} "
          f"| {r['recompiles']} | {r['cache_hits']} | {r['shared_hits']} "
          f"| {r['cold_s']:.2f}s | {r['serve_qps']} | {r['batch_qps']} "
          f"| {'yes' if r['pass'] else 'NO'} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--section", default="roofline",
                    choices=["roofline", "dryrun", "runs", "serve"])
    args = ap.parse_args()
    if args.section == "runs":
        run_report_table(load_runs())
        return
    if args.section == "serve":
        serve_table()
        return
    recs = load(args.mesh, args.tag)
    if args.section == "roofline":
        roofline_table(recs)
    else:
        dryrun_table(recs)


if __name__ == "__main__":
    main()

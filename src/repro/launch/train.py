"""Production training driver: mesh + shardings + fault-tolerant train loop.

    PYTHONPATH=src python -m repro.launch.train --arch granite_moe_3b_a800m \
        --smoke          # reduced config on the local device(s)

Full-scale flags mirror the dry-run (--tp, --seq-parallel, --microbatch,
--grad-compress); on a real pod remove --smoke and point --ckpt-dir at
durable storage.  The loop checkpoints asynchronously, restores (with
resharding) on restart, and re-raises after bounded retries on transient
step failures — the re-execution discipline of the paper's §2.4 applied to
training steps.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.shardings import (MeshAxes, batch_specs,
                                         make_constrain, param_specs)
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.transformer import Model
from repro.train import optimizer as optim
from repro.train.trainstep import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_moe_3b_a800m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--tp", type=int, default=16)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--grad-compress", default="none")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--max-retries", type=int, default=3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
        mesh = make_local_mesh(axis="data")
        # single local axis: treat it as data; tp is trivial
        mesh = jax.sharding.Mesh(mesh.devices.reshape(-1, 1),
                                 ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod, tp=args.tp)
    axes = MeshAxes(fsdp=("pod", "data") if args.multi_pod else ("data",),
                    tp="model")
    model = Model(cfg, expert_pad=mesh.shape["model"],
                  vocab_pad=128 if not args.smoke else 1,
                  remat="full" if not args.smoke else "none",
                  constrain=make_constrain(mesh, axes, args.seq_parallel))

    params = model.init(jax.random.PRNGKey(0),
                        dtype=jnp.float32 if args.smoke else jnp.bfloat16)
    state = init_train_state(model, params, args.grad_compress)
    p_specs = param_specs(params, axes)
    named_p = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                           is_leaf=lambda x: isinstance(x, P))
    params = jax.device_put(params, named_p)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M mesh={dict(mesh.shape)}")

    ocfg = optim.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, ocfg, args.grad_compress,
                                      args.microbatch))
    mgr = CheckpointManager(args.ckpt_dir, keep_last=2, async_save=True)
    start, restored, _ = mgr.restore_latest({"params": params,
                                             "state": state})
    if start is not None:
        params, state = restored["params"], restored["state"]
        print(f"restored step {start}")
    start = start or 0

    rng = np.random.default_rng(0)
    for step in range(start + 1, start + args.steps + 1):
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (args.batch, args.seq)), jnp.int32)}
        batch["labels"] = batch["tokens"]
        if cfg.frontend == "vision_patches":
            batch["patches"] = jnp.zeros(
                (args.batch, cfg.n_prefix, cfg.d_model), jnp.float32)
        for attempt in range(args.max_retries):
            try:
                params, state, metrics = step_fn(params, state, batch)
                break
            except Exception as e:     # transient device failure -> retry
                if attempt == args.max_retries - 1:
                    raise
                print(f"step {step} attempt {attempt + 1} failed: {e};"
                      " retrying")
        if step % 5 == 0 or step == start + 1:
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.2f}")
        if step % args.ckpt_every == 0:
            mgr.save(step, {"params": params, "state": state},
                     {"loss": float(metrics["loss"])})
    mgr.wait()
    print("done")


if __name__ == "__main__":
    main()

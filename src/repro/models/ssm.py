"""Sub-quadratic mixers: Mamba2 (zamba2 hybrid) and RWKV6 "Finch".

Both are O(S) in sequence length with O(1) decode state — the two assigned
architectures that run the long_500k shape.  Training uses lax.scan over time
(a chunked Pallas kernel is the obvious TPU follow-up; the scan keeps HLO size
flat and the roofline honest); decode is a single fused state update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ArchConfig, KeyGen, dense_init

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Mamba2
# ---------------------------------------------------------------------------

def _m2_dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    hd = 64
    heads = d_inner // hd
    return d_inner, heads, hd


def init_mamba2(cfg: ArchConfig, kg: KeyGen, dtype):
    d = cfg.d_model
    d_inner, heads, hd = _m2_dims(cfg)
    n = cfg.ssm_state
    conv_ch = d_inner + 2 * n
    return {
        "w_in": dense_init(kg(), (d, 2 * d_inner + 2 * n + heads), dtype),
        "conv_w": dense_init(kg(), (cfg.ssm_conv, conv_ch), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.zeros((heads,), F32),
        "dt_bias": jnp.zeros((heads,), F32),
        "d_skip": jnp.ones((heads,), F32),
        "w_out": dense_init(kg(), (d_inner, d), dtype),
    }


def _causal_depthwise_conv(x, w, b, state=None):
    """x (B, S, C); w (K, C) depthwise causal; state (B, K-1, C) carry-in."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(k))
    return jax.nn.silu(out + b), xp[:, -(k - 1):]


def _m2_split(cfg, zxbcdt):
    d_inner, heads, hd = _m2_dims(cfg)
    n = cfg.ssm_state
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + d_inner + 2 * n]
    dt = zxbcdt[..., -heads:]
    return z, xbc, dt


def mamba2_forward(p, cfg: ArchConfig, x, conv_state=None, ssm_state=None):
    """x (B, S, D) -> (B, S, D); returns (y, (conv_state, ssm_state))."""
    b, s, d = x.shape
    d_inner, heads, hd = _m2_dims(cfg)
    n = cfg.ssm_state
    z, xbc, dt = _m2_split(cfg, x @ p["w_in"])
    xbc, conv_out = _causal_depthwise_conv(xbc, p["conv_w"], p["conv_b"],
                                           conv_state)
    xs = xbc[..., :d_inner].reshape(b, s, heads, hd)
    bmat = xbc[..., d_inner:d_inner + n]                     # (B,S,N)
    cmat = xbc[..., d_inner + n:]                            # (B,S,N)
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])      # (B,S,H)
    a = -jnp.exp(p["a_log"])                                 # (H,)
    decay = jnp.exp(dt * a)                                  # (B,S,H)

    def step(h, inp):
        xs_t, b_t, c_t, dt_t, dec_t = inp
        # h (B,H,hd,N): h' = dec*h + dt * xs ⊗ b
        h = h * dec_t[..., None, None] + \
            (dt_t[..., None] * xs_t.astype(F32))[..., None] * \
            b_t[:, None, None, :].astype(F32)
        y = jnp.einsum("bhdn,bn->bhd", h, c_t.astype(F32))
        return h, y

    h0 = ssm_state if ssm_state is not None else \
        jnp.zeros((b, heads, hd, n), F32)
    xseq = (xs.transpose(1, 0, 2, 3), bmat.transpose(1, 0, 2),
            cmat.transpose(1, 0, 2), dt.transpose(1, 0, 2),
            decay.transpose(1, 0, 2))
    h_final, ys = jax.lax.scan(step, h0, xseq)
    y = ys.transpose(1, 0, 2, 3)                             # (B,S,H,hd)
    y = y + p["d_skip"][None, None, :, None] * xs.astype(F32)
    y = (y.reshape(b, s, d_inner) * jax.nn.silu(z.astype(F32))).astype(x.dtype)
    return y @ p["w_out"], (conv_out, h_final)


def init_mamba2_state(cfg: ArchConfig, batch: int, dtype):
    d_inner, heads, hd = _m2_dims(cfg)
    n = cfg.ssm_state
    conv_ch = d_inner + 2 * n
    return (jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
            jnp.zeros((batch, heads, hd, n), F32))


def mamba2_decode(p, cfg: ArchConfig, x, state):
    """x (B, 1, D); state from init_mamba2_state; O(1) per token."""
    y, state = mamba2_forward(p, cfg, x, conv_state=state[0], ssm_state=state[1])
    return y, state


# ---------------------------------------------------------------------------
# RWKV6 (Finch) — data-dependent decay
# ---------------------------------------------------------------------------

_RWKV_HD = 64


def init_rwkv6(cfg: ArchConfig, kg: KeyGen, dtype):
    d = cfg.d_model
    heads = d // _RWKV_HD
    lora = 64
    return {
        # token-shift mixing coefficients per stream
        "mu_r": jnp.zeros((d,), dtype), "mu_k": jnp.zeros((d,), dtype),
        "mu_v": jnp.zeros((d,), dtype), "mu_w": jnp.zeros((d,), dtype),
        "mu_g": jnp.zeros((d,), dtype),
        "wr": dense_init(kg(), (d, d), dtype),
        "wk": dense_init(kg(), (d, d), dtype),
        "wv": dense_init(kg(), (d, d), dtype),
        "wg": dense_init(kg(), (d, d), dtype),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((d,), -5.0, F32),
        "w_a": dense_init(kg(), (d, lora), dtype),
        "w_b": dense_init(kg(), (lora, d), dtype, scale=0.02),
        "u": jnp.zeros((heads, _RWKV_HD), F32),   # bonus for current token
        "ln_scale": jnp.ones((d,), F32),
        "wo": dense_init(kg(), (d, d), dtype),
    }


def _rwkv_streams(p, x, x_prev):
    """Token shift: mix current and previous token per channel."""
    b, s, d = x.shape
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1]], axis=1)
    def mix(mu):
        return x + (shifted - x) * mu[None, None, :]
    r = mix(p["mu_r"]) @ p["wr"]
    k = mix(p["mu_k"]) @ p["wk"]
    v = mix(p["mu_v"]) @ p["wv"]
    g = mix(p["mu_g"]) @ p["wg"]
    wx = mix(p["mu_w"])
    w = jnp.exp(-jnp.exp(p["w0"][None, None, :] +
                         (jnp.tanh(wx @ p["w_a"]) @ p["w_b"]).astype(F32)))
    return r, k, v, g, w, x[:, -1]


def rwkv6_forward(p, cfg: ArchConfig, x, state=None):
    """x (B, S, D) -> (B, S, D); state = (x_prev (B,D), wkv (B,H,hd,hd))."""
    b, s, d = x.shape
    heads, hd = d // _RWKV_HD, _RWKV_HD
    x_prev = state[0] if state is not None else jnp.zeros((b, d), x.dtype)
    wkv0 = state[1] if state is not None else jnp.zeros((b, heads, hd, hd), F32)
    r, k, v, g, w, x_last = _rwkv_streams(p, x, x_prev)
    rh = r.reshape(b, s, heads, hd).astype(F32)
    kh = k.reshape(b, s, heads, hd).astype(F32)
    vh = v.reshape(b, s, heads, hd).astype(F32)
    wh = w.reshape(b, s, heads, hd)

    def step(wkv, inp):
        r_t, k_t, v_t, w_t = inp                 # (B,H,hd) each
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,hd,hd)
        y = jnp.einsum("bhk,bhkv->bhv", r_t,
                       wkv + p["u"][None, :, :, None] * kv)
        wkv = w_t[..., :, None] * wkv + kv
        return wkv, y

    seq = (rh.transpose(1, 0, 2, 3), kh.transpose(1, 0, 2, 3),
           vh.transpose(1, 0, 2, 3), wh.transpose(1, 0, 2, 3))
    wkv_final, ys = jax.lax.scan(step, wkv0, seq)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d)
    # group norm per head then output gate
    y = y.reshape(b, s, heads, hd)
    mean = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = ((y - mean) * jax.lax.rsqrt(var + 1e-5)).reshape(b, s, d) * p["ln_scale"]
    y = (y * jax.nn.silu(g.astype(F32))).astype(x.dtype)
    return y @ p["wo"], (x_last, wkv_final)


def init_rwkv_ffn(cfg: ArchConfig, kg: KeyGen, dtype):
    # param names distinct from time-mix (fk/fv/fr) so sharding rules can
    # pattern-match orientation by name
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": jnp.zeros((d,), dtype), "mu_r": jnp.zeros((d,), dtype),
        "fk": dense_init(kg(), (d, f), dtype),
        "fv": dense_init(kg(), (f, d), dtype),
        "fr": dense_init(kg(), (d, d), dtype),
    }


def rwkv_ffn_forward(p, cfg: ArchConfig, x, x_prev=None):
    """RWKV channel-mix: squared-relu FFN with token shift."""
    b, s, d = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((b, d), x.dtype)
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1]], axis=1)
    xk = x + (shifted - x) * p["mu_k"][None, None, :]
    xr = x + (shifted - x) * p["mu_r"][None, None, :]
    k = jnp.square(jax.nn.relu(xk @ p["fk"]))
    return jax.nn.sigmoid(xr @ p["fr"]) * (k @ p["fv"]), x[:, -1]


def init_rwkv6_state(cfg: ArchConfig, batch: int, dtype):
    d = cfg.d_model
    heads = d // _RWKV_HD
    return (jnp.zeros((batch, d), dtype),
            jnp.zeros((batch, heads, _RWKV_HD, _RWKV_HD), F32))


def rwkv6_decode(p, cfg: ArchConfig, x, state):
    return rwkv6_forward(p, cfg, x, state)

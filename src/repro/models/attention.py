"""Attention variants: GQA/MQA (+RoPE, optional bias), MLA, prefix-LM masks.

Train/prefill operate on full (B, S, D); decode consumes one token against a
static-capacity KV cache (B, L, KV, hd) updated in place — the cache layout
keeps the sequence dim explicit so the serving layer can shard it across the
``data`` axis for long-context flash-decode (GSPMD inserts the partial-softmax
all-reduce).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ops as fa
from .common import ArchConfig, KeyGen, apply_rope, dense_init, rms_norm

F32 = jnp.float32


def _at_pos(cache_arr, update, pos):
    """dynamic_update_slice at (0, pos, 0, ...) with int32-safe indices."""
    idx = [jnp.asarray(0, jnp.int32)] * cache_arr.ndim
    idx[1] = jnp.asarray(pos, jnp.int32)
    return jax.lax.dynamic_update_slice(cache_arr,
                                        update.astype(cache_arr.dtype), idx)


# ---------------------------------------------------------------------------
# GQA / MQA
# ---------------------------------------------------------------------------

def init_gqa(cfg: ArchConfig, kg: KeyGen, dtype):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = {
        "wq": dense_init(kg(), (d, h * hd), dtype),
        "wk": dense_init(kg(), (d, kv * hd), dtype),
        "wv": dense_init(kg(), (d, kv * hd), dtype),
        "wo": dense_init(kg(), (h * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def _qkv(p, cfg: ArchConfig, x, positions):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, scale):
    """q (B,S,H,hd), k/v (B,L,KV,hd), mask (B,S,L) or None broadcastable."""
    b, s, h, hd = q.shape
    _, l, kv, _ = k.shape
    g = h // kv
    q = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bskgd,blkd->bkgsl", q.astype(F32), k.astype(F32)) * scale
    if mask is not None:
        scores = jnp.where(mask[:, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgsl,blkd->bskgd", w, v.astype(F32))
    return out.reshape(b, s, h, hd).astype(q.dtype)


def causal_mask(b, s, n_prefix: int = 0):
    i = jnp.arange(s, dtype=jnp.int32)[:, None]
    j = jnp.arange(s, dtype=jnp.int32)[None, :]
    m = j <= i
    if n_prefix:
        m = m | (j < n_prefix)          # prefix-LM: bidirectional prefix
    return jnp.broadcast_to(m, (b, s, s))


def gqa_forward(p, cfg: ArchConfig, x, positions, n_prefix: int = 0,
                use_flash_kernel: bool = False):
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    if use_flash_kernel and n_prefix == 0:
        # TPU path: Pallas blocked online-softmax kernel (DESIGN.md §6)
        o = fa.flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3), causal=True)
        o = o.transpose(0, 2, 1, 3)
    else:
        o = _sdpa(q, k, v, causal_mask(b, s, n_prefix), 1.0 / (cfg.hd ** 0.5))
    return o.reshape(b, s, -1) @ p["wo"]


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    kv, hd = cfg.n_kv_heads, cfg.hd
    return {"k": jnp.zeros((batch, max_len, kv, hd), dtype),
            "v": jnp.zeros((batch, max_len, kv, hd), dtype)}


def gqa_prefill(p, cfg: ArchConfig, x, positions, cache, n_prefix: int = 0):
    """Full forward + write the cache prefix."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    cache = {"k": _at_pos(cache["k"], k, 0), "v": _at_pos(cache["v"], v, 0)}
    o = _sdpa(q, k, v, causal_mask(b, s, n_prefix), 1.0 / (cfg.hd ** 0.5))
    return o.reshape(b, s, -1) @ p["wo"], cache


def gqa_decode(p, cfg: ArchConfig, x, cache, pos):
    """x (B, 1, D); pos scalar int32 — attend over cache[: pos+1]."""
    b, _, _ = x.shape
    l = cache["k"].shape[1]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _qkv(p, cfg, x, positions)
    ck = _at_pos(cache["k"], k, pos)
    cv = _at_pos(cache["v"], v, pos)
    mask = (jnp.arange(l, dtype=jnp.int32)[None, None, :] <= pos)
    mask = jnp.broadcast_to(mask, (b, 1, l))
    o = _sdpa(q, ck, cv, mask, 1.0 / (cfg.hd ** 0.5))
    return o.reshape(b, 1, -1) @ p["wo"], {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank KV compression; cache stores the latent only
# ---------------------------------------------------------------------------

def init_mla(cfg: ArchConfig, kg: KeyGen, dtype):
    d, h = cfg.d_model, cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    p = {
        "wq_a": dense_init(kg(), (d, cfg.q_lora_rank), dtype),
        "q_norm": jnp.zeros((cfg.q_lora_rank,), dtype),
        "wq_b": dense_init(kg(), (cfg.q_lora_rank, h * qd), dtype),
        "wkv_a": dense_init(kg(), (d, cfg.kv_lora_rank + cfg.qk_rope_dim), dtype),
        "kv_norm": jnp.zeros((cfg.kv_lora_rank,), dtype),
        "wkv_b": dense_init(kg(), (cfg.kv_lora_rank,
                                   h * (cfg.qk_nope_dim + cfg.v_head_dim)), dtype),
        "wo": dense_init(kg(), (h * cfg.v_head_dim, d), dtype),
    }
    return p


def _mla_qkv(p, cfg: ArchConfig, x, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps,
                 cfg.norms_f32) @ p["wq_b"]
    q = q.reshape(b, s, h, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = x @ p["wkv_a"]
    c_kv = rms_norm(kv_a[..., :cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps,
                    cfg.norms_f32)
    k_rope = apply_rope(kv_a[..., None, cfg.kv_lora_rank:], positions,
                        cfg.rope_theta)                     # (B,S,1,rd) shared
    return q_nope, q_rope, c_kv, k_rope


def _mla_attend(p, cfg, q_nope, q_rope, c_kv, k_rope, mask):
    """Expand latent -> per-head k/v and attend (B,S,*) vs (B,L,*)."""
    b, s, h = q_nope.shape[0], q_nope.shape[1], cfg.n_heads
    l = c_kv.shape[1]
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kv = (c_kv @ p["wkv_b"]).reshape(b, l, h, nd + vd)
    k_nope, v = kv[..., :nd], kv[..., nd:]
    scale = 1.0 / ((nd + rd) ** 0.5)
    s_nope = jnp.einsum("bshd,blhd->bhsl", q_nope.astype(F32),
                        k_nope.astype(F32))
    s_rope = jnp.einsum("bshd,blkd->bhsl", q_rope.astype(F32),
                        k_rope.astype(F32))                 # k broadcast (kv=1)
    scores = (s_nope + s_rope) * scale
    if mask is not None:
        scores = jnp.where(mask[:, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhsl,blhd->bshd", w, v.astype(F32)).astype(q_nope.dtype)
    return o.reshape(b, s, h * vd) @ p["wo"]


def mla_forward(p, cfg: ArchConfig, x, positions, n_prefix: int = 0,
                use_flash_kernel: bool = False):
    b, s, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)
    return _mla_attend(p, cfg, q_nope, q_rope, c_kv, k_rope,
                       causal_mask(b, s, n_prefix))


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    return {"ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_len, 1, cfg.qk_rope_dim), dtype)}


def mla_prefill(p, cfg, x, positions, cache, n_prefix: int = 0):
    b, s, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)
    cache = {"ckv": _at_pos(cache["ckv"], c_kv, 0),
             "krope": _at_pos(cache["krope"], k_rope, 0)}
    o = _mla_attend(p, cfg, q_nope, q_rope, c_kv, k_rope,
                    causal_mask(b, s, n_prefix))
    return o, cache


def mla_decode(p, cfg, x, cache, pos):
    b = x.shape[0]
    l = cache["ckv"].shape[1]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)
    ckv = _at_pos(cache["ckv"], c_kv, pos)
    krope = _at_pos(cache["krope"], k_rope, pos)
    mask = jnp.broadcast_to(
        jnp.arange(l, dtype=jnp.int32)[None, None, :] <= pos, (b, 1, l))
    o = _mla_attend(p, cfg, q_nope, q_rope, ckv, krope, mask)
    return o, {"ckv": ckv, "krope": krope}

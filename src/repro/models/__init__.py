"""Assigned-architecture model zoo (functional JAX; see transformer.Model)."""
from .common import ArchConfig
from .transformer import Model

__all__ = ["ArchConfig", "Model"]

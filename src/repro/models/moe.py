"""Mixture-of-Experts with capacity-bounded counting-rank dispatch.

The dispatch is the SAME primitive as the SQL shuffle
(``repro.core.exchange._dispatch_offsets``): rank tokens by destination
(expert) with a sortless radix-histogram counting rank (stable-sort-order
equivalent), place into (E, C) capacity buckets, drop on overflow.  This is
the deepest contact between the paper's technique and the
MoE architectures — a distributed SQL shuffle *is* a token dispatch with a
data-dependent routing function (DESIGN.md §3).  With experts sharded over the
``model`` axis, GSPMD lowers the gather->expert-matmul->scatter into the same
all-to-all pattern NCCL would run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.exchange import _dispatch_offsets
from .common import ArchConfig, KeyGen, dense_init, glu_act

F32 = jnp.float32


def init_moe(cfg: ArchConfig, kg: KeyGen, dtype, padded_experts: int):
    d, fe = cfg.d_model, cfg.d_ff_expert
    e = padded_experts
    p = {
        "router": dense_init(kg(), (d, e), dtype, scale=0.02),
        "w_gate": dense_init(kg(), (e, d, fe), dtype),
        "w_up": dense_init(kg(), (e, d, fe), dtype),
        "w_down": dense_init(kg(), (e, fe, d), dtype),
    }
    if cfg.n_shared_experts:
        fs = cfg.d_ff_expert * cfg.n_shared_experts
        p["shared_gate"] = dense_init(kg(), (d, fs), dtype)
        p["shared_up"] = dense_init(kg(), (d, fs), dtype)
        p["shared_down"] = dense_init(kg(), (fs, d), dtype)
    return p


def moe_forward(p, cfg: ArchConfig, x: jax.Array, padded_experts: int,
                capacity_factor: float = 1.25):
    """x (B, S, D) -> (B, S, D).  Top-k routing, capacity drop, shared experts.

    Returns (out, aux) where aux carries the load-balancing loss terms and the
    drop fraction (the skew statistic — same role as the shuffle's overflow)."""
    b, s, d = x.shape
    e, k = padded_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt @ p["router"]).astype(F32)
    if e > cfg.n_experts:   # mask padding experts (divisibility padding)
        pad_mask = jnp.arange(e) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                       # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # -- capacity dispatch (shared machinery with the SQL shuffle) ----------
    cap = max(8, int(t * k * capacity_factor / e + 0.999) // 8 * 8 + 8)
    dest = top_e.reshape(t * k).astype(jnp.int32)                # (T*k,)
    slot, counts = _dispatch_offsets(dest, e)
    keep = slot < cap
    flat = jnp.where(keep, dest * cap + jnp.minimum(slot, cap - 1), e * cap)
    token_of = jnp.arange(t * k, dtype=jnp.int32) // k
    # token index per (expert, capacity) slot; empty slots -> token 0, weight 0
    slot_token = jnp.zeros((e * cap,), jnp.int32).at[flat].set(
        token_of, mode="drop")
    slot_used = jnp.zeros((e * cap,), jnp.bool_).at[flat].set(
        keep, mode="drop")
    gathered = xt[slot_token].reshape(e, cap, d)
    gathered = jnp.where(slot_used.reshape(e, cap, 1), gathered, 0.0)

    h = glu_act(jnp.einsum("ecd,edf->ecf", gathered, p["w_gate"]),
                jnp.einsum("ecd,edf->ecf", gathered, p["w_up"]), cfg.act)
    out_ec = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(e * cap, d)

    w_flat = top_w.reshape(t * k)
    slot_w = jnp.zeros((e * cap,), F32).at[flat].set(
        jnp.where(keep, w_flat, 0.0), mode="drop")
    out = jnp.zeros((t, d), x.dtype).at[slot_token].add(
        (out_ec.astype(F32) * slot_w[:, None]).astype(x.dtype),
        mode="drop")
    # note: empty slots carry weight 0 so their token-0 scatter is a no-op

    if cfg.n_shared_experts:
        out = out + glu_act(xt @ p["shared_gate"], xt @ p["shared_up"],
                            cfg.act) @ p["shared_down"]

    # load-balancing aux (GShard): E * sum_e f_e * p_e
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), F32).at[dest].add(1.0 / (t * k))
    aux = {"lb_loss": e * jnp.sum(me * ce),
           "drop_frac": 1.0 - keep.mean(),
           "expert_load": counts}
    return out.reshape(b, s, d), aux

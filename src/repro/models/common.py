"""Shared model substrate: config, norms, RoPE, activations, init.

Plain functional style (params are nested dicts of jnp arrays) so the
distribution layer can attach PartitionSpecs by tree path.  All constructors
take explicit dtypes — x64 is globally enabled for the SQL engine, so nothing
here may rely on default dtype promotion.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

DType = Any


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture (exact public config; see repro.configs)."""
    name: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    act: str = "swiglu"           # swiglu | geglu
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    embed_scale: bool = False     # gemma-style sqrt(d) embedding multiplier
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0   # deepseek: first layer is dense
    # MLA (deepseek)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    shared_attn_every: int = 0    # zamba2: shared attn block cadence
    # modality frontend stubs
    frontend: str | None = None   # None | "vision_patches" | "audio_frames"
    n_prefix: int = 0             # vision: number of patch embeddings
    # attention variant
    prefix_lm: bool = False       # paligemma: bidirectional prefix
    sub_quadratic: bool = False   # eligible for long_500k
    param_count: float = 0.0      # nominal N for MODEL_FLOPS (6ND)
    active_param_count: float = 0.0  # MoE: active params per token
    # numerics: f32 norm chains are the baseline; bf16 norms halve the
    # activation-sized collective/HBM traffic (perf-iteration lever)
    norms_f32: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 2 + (2 if self.shared_attn_every else 0)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            head_dim=32,
            d_ff=256,
            d_ff_expert=min(self.d_ff_expert, 64) if self.d_ff_expert else 0,
            vocab=min(self.vocab, 512),
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            q_lora_rank=min(self.q_lora_rank, 64) if self.q_lora_rank else 0,
            kv_lora_rank=min(self.kv_lora_rank, 32) if self.kv_lora_rank else 0,
            qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            shared_attn_every=min(self.shared_attn_every, 2)
            if self.shared_attn_every else 0,
            n_prefix=min(self.n_prefix, 8) if self.n_prefix else 0,
        )


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float,
             in_f32: bool = True) -> jax.Array:
    dt = x.dtype
    if in_f32:
        x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = (x * jax.lax.rsqrt(var + jnp.asarray(eps, x.dtype))) * \
        (1.0 + scale.astype(x.dtype))
    return out.astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, D); positions (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                   # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def glu_act(x_gate: jax.Array, x_up: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        return jax.nn.silu(x_gate) * x_up
    if kind == "geglu":
        return jax.nn.gelu(x_gate, approximate=True) * x_up
    raise ValueError(kind)


def dense_init(key, shape: Sequence[int], dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


class KeyGen:
    """Split keys by name for readable param init."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits (B, S, V) any float dtype; labels (B, S) int32; mean nats."""
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    gold = jnp.take_along_axis(shifted, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    return jnp.mean(lse - gold)

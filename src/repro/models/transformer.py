"""Block assembly, stacked-layer scan, and the Model API for all 10 archs.

Layers are stacked (leading L axis) and run under ``jax.lax.scan`` so HLO size
stays flat at 512 devices; hybrid architectures run a python loop over
homogeneous segments (zamba2: mamba2 runs with a shared attention block applied
between segments).  Remat policy wraps the scan body.

Model API (all architectures):
  init(key, dtype)                      -> params
  forward(params, tokens, extra)       -> logits (train path)
  loss(params, batch)                  -> (scalar, aux)
  init_cache(batch, max_len, dtype)    -> cache
  prefill(params, tokens, extra)      -> (logits, cache)
  decode(params, token, cache, pos)   -> (logits, cache)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import attention as A
from . import ssm as S
from .common import (ArchConfig, KeyGen, dense_init, glu_act, rms_norm,
                     softmax_cross_entropy)
from .moe import init_moe, moe_forward

F32 = jnp.float32


def _segments(cfg: ArchConfig) -> list[tuple[str, int]]:
    if cfg.family in ("dense", "audio", "vlm"):
        return [("dense", cfg.n_layers)]
    if cfg.family == "moe":
        segs = []
        if cfg.first_dense_layers:
            segs.append(("dense", cfg.first_dense_layers))
        segs.append(("moe", cfg.n_layers - cfg.first_dense_layers))
        return segs
    if cfg.family == "hybrid":
        k = cfg.shared_attn_every
        segs, left = [], cfg.n_layers
        while left > 0:
            segs.append(("mamba2", min(k, left)))
            left -= k
        return segs
    if cfg.family == "ssm":
        return [("rwkv6", cfg.n_layers)]
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _init_glu(cfg, kg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    return {"w_gate": dense_init(kg(), (d, f), dtype),
            "w_up": dense_init(kg(), (d, f), dtype),
            "w_down": dense_init(kg(), (f, d), dtype)}


def _glu(p, cfg, x):
    return glu_act(x @ p["w_gate"], x @ p["w_up"], cfg.act) @ p["w_down"]


def _init_block(kind: str, cfg: ArchConfig, kg: KeyGen, dtype, padded_e: int):
    d = cfg.d_model
    z = lambda: jnp.zeros((d,), dtype)
    if kind == "dense":
        attn = A.init_mla(cfg, kg, dtype) if cfg.use_mla else \
            A.init_gqa(cfg, kg, dtype)
        return {"ln1": z(), "attn": attn, "ln2": z(),
                "mlp": _init_glu(cfg, kg, dtype)}
    if kind == "moe":
        attn = A.init_mla(cfg, kg, dtype) if cfg.use_mla else \
            A.init_gqa(cfg, kg, dtype)
        return {"ln1": z(), "attn": attn, "ln2": z(),
                "moe": init_moe(cfg, kg, dtype, padded_e)}
    if kind == "mamba2":
        return {"ln": z(), "mixer": S.init_mamba2(cfg, kg, dtype)}
    if kind == "rwkv6":
        return {"ln1": z(), "tm": S.init_rwkv6(cfg, kg, dtype),
                "ln2": z(), "ffn": S.init_rwkv_ffn(cfg, kg, dtype)}
    raise ValueError(kind)


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    expert_pad: int = 16          # pad experts to a multiple (EP divisibility)
    vocab_pad: int = 1            # pad vocab to a multiple (Megatron-style)
    use_flash_kernel: bool = False
    remat: str = "none"           # none | full
    capacity_factor: float = 1.25
    constrain: Callable = staticmethod(lambda x, kind: x)  # sharding hook

    # -- helpers -----------------------------------------------------------
    @property
    def padded_experts(self) -> int:
        e = self.cfg.n_experts
        m = self.expert_pad
        return (e + m - 1) // m * m if e else 0

    @property
    def padded_vocab(self) -> int:
        v, m = self.cfg.vocab, self.vocab_pad
        return (v + m - 1) // m * m

    def _mask_vocab_pad(self, logits):
        if self.padded_vocab == self.cfg.vocab:
            return logits
        iota = jnp.arange(self.padded_vocab, dtype=jnp.int32)
        return jnp.where(iota < self.cfg.vocab, logits,
                         jnp.asarray(-1e30, logits.dtype))

    def _block_fwd(self, kind, p, x, positions, n_prefix):
        """Returns (x, (lb_loss_delta, drop_frac_delta)) — aux is threaded
        through the scan carry, never mutated across the scan boundary."""
        cfg = self.cfg
        zero = (jnp.zeros((), F32), jnp.zeros((), F32))
        if kind in ("dense", "moe"):
            attn = A.mla_forward if cfg.use_mla else A.gqa_forward
            h = attn(p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps, cfg.norms_f32),
                     positions, n_prefix, self.use_flash_kernel)
            x = x + self.constrain(h, "residual")
            y = rms_norm(x, p["ln2"], cfg.norm_eps, cfg.norms_f32)
            if kind == "dense":
                return x + self.constrain(_glu(p["mlp"], cfg, y),
                                          "residual"), zero
            out, a = moe_forward(p["moe"], cfg, y, self.padded_experts,
                                 self.capacity_factor)
            return x + self.constrain(out, "residual"), \
                (a["lb_loss"], a["drop_frac"].astype(F32))
        if kind == "mamba2":
            y, _ = S.mamba2_forward(p["mixer"], cfg,
                                    rms_norm(x, p["ln"], cfg.norm_eps, cfg.norms_f32))
            return x + self.constrain(y, "residual"), zero
        if kind == "rwkv6":
            y, _ = S.rwkv6_forward(p["tm"], cfg,
                                   rms_norm(x, p["ln1"], cfg.norm_eps, cfg.norms_f32))
            x = x + y
            y, _ = S.rwkv_ffn_forward(p["ffn"], cfg,
                                      rms_norm(x, p["ln2"], cfg.norm_eps, cfg.norms_f32))
            return x + y, zero
        raise ValueError(kind)

    def _block_step(self, kind, p, x, cache, pos, positions, n_prefix, decode):
        """Single-layer prefill/decode with cache; returns (x, new_cache)."""
        cfg = self.cfg
        if kind in ("dense", "moe"):
            y = rms_norm(x, p["ln1"], cfg.norm_eps, cfg.norms_f32)
            if cfg.use_mla:
                h, cache_a = (A.mla_decode(p["attn"], cfg, y, cache, pos)
                              if decode else
                              A.mla_prefill(p["attn"], cfg, y, positions,
                                            cache, n_prefix))
            else:
                h, cache_a = (A.gqa_decode(p["attn"], cfg, y, cache, pos)
                              if decode else
                              A.gqa_prefill(p["attn"], cfg, y, positions,
                                            cache, n_prefix))
            x = x + h
            y = rms_norm(x, p["ln2"], cfg.norm_eps, cfg.norms_f32)
            if kind == "dense":
                x = x + _glu(p["mlp"], cfg, y)
            else:
                out, _ = moe_forward(p["moe"], cfg, y, self.padded_experts,
                                     self.capacity_factor)
                x = x + out
            return x, cache_a
        if kind == "mamba2":
            y, st = S.mamba2_forward(p["mixer"], cfg,
                                     rms_norm(x, p["ln"], cfg.norm_eps, cfg.norms_f32),
                                     conv_state=cache[0], ssm_state=cache[1])
            return x + y, st
        if kind == "rwkv6":
            y, tm = S.rwkv6_forward(p["tm"], cfg,
                                    rms_norm(x, p["ln1"], cfg.norm_eps, cfg.norms_f32),
                                    state=cache[0])
            x = x + y
            y, xp = S.rwkv_ffn_forward(p["ffn"], cfg,
                                       rms_norm(x, p["ln2"], cfg.norm_eps, cfg.norms_f32),
                                       x_prev=cache[1])
            return x + y, (tm, xp)
        raise ValueError(kind)

    def _init_cache_layer(self, kind, batch, max_len, dtype):
        cfg = self.cfg
        if kind in ("dense", "moe"):
            return (A.init_mla_cache(cfg, batch, max_len, dtype) if cfg.use_mla
                    else A.init_kv_cache(cfg, batch, max_len, dtype))
        if kind == "mamba2":
            return S.init_mamba2_state(cfg, batch, dtype)
        if kind == "rwkv6":
            st = S.init_rwkv6_state(cfg, batch, dtype)
            return (st, jnp.zeros((batch, cfg.d_model), dtype))
        raise ValueError(kind)

    # -- init ----------------------------------------------------------------
    def init(self, key, dtype=jnp.bfloat16):
        cfg = self.cfg
        kg = KeyGen(key)
        params: dict[str, Any] = {
            "embed": dense_init(kg(), (self.padded_vocab, cfg.d_model), dtype,
                                scale=0.02),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(kg(),
                                           (cfg.d_model, self.padded_vocab),
                                           dtype)
        segs = []
        for kind, count in _segments(cfg):   # kind is derived from cfg, not
            layers = [_init_block(kind, cfg, kg, dtype, self.padded_experts)
                      for _ in range(count)]         # stored in the pytree
            segs.append(jax.tree.map(lambda *xs: jnp.stack(xs), *layers))
        params["segments"] = segs
        if cfg.shared_attn_every:
            params["shared"] = _init_block("dense", cfg, kg, dtype, 0)
        return params

    # -- train forward -------------------------------------------------------
    def forward(self, params, tokens, extra=None):
        logits, _ = self._forward_aux(params, tokens, extra)
        return logits

    def _embed(self, params, tokens, extra):
        cfg = self.cfg
        x = params["embed"][tokens]
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        n_prefix = 0
        if cfg.frontend == "vision_patches":
            patches = extra["patches"].astype(x.dtype)   # stub frontend
            x = jnp.concatenate([patches, x], axis=1)
            n_prefix = patches.shape[1]
        return self.constrain(x, "activation"), n_prefix

    def _forward_aux(self, params, tokens, extra=None):
        cfg = self.cfg
        x, n_prefix = self._embed(params, tokens, extra)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        lb = jnp.zeros((), F32)
        drop = jnp.zeros((), F32)
        seg_kinds = _segments(cfg)

        for i, (kind, _) in enumerate(seg_kinds):

            def body(carry, layer_p, kind=kind):
                xc, lb_c, dr_c = carry
                out, (dlb, ddr) = self._block_fwd(kind, layer_p, xc,
                                                  positions, n_prefix)
                return (out, lb_c + dlb, dr_c + ddr), None

            if self.remat == "full":
                body = jax.checkpoint(body)
            (x, lb, drop), _ = jax.lax.scan(body, (x, lb, drop),
                                            params["segments"][i])
            if cfg.shared_attn_every and i < len(seg_kinds) - 1:
                x, _ = self._block_fwd("dense", params["shared"], x,
                                       positions, n_prefix)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps, cfg.norms_f32)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = self.constrain(self._mask_vocab_pad(x @ head), "logits")
        return logits, {"lb_loss": lb, "drop_frac": drop}

    def loss(self, params, tokens, labels, extra=None):
        logits, aux = self._forward_aux(params, tokens, extra)
        n_prefix = logits.shape[1] - labels.shape[1]
        if n_prefix:
            logits = logits[:, n_prefix:]
        ce = softmax_cross_entropy(logits[:, :-1], labels[:, 1:])
        total = ce + 0.01 * aux.get("lb_loss", 0.0)
        aux["ce"] = ce
        return total, aux

    # -- serving ---------------------------------------------------------------
    def init_cache(self, batch, max_len, dtype=jnp.bfloat16):
        cache = {"segments": [], "shared": []}
        segs = _segments(self.cfg)
        for kind, count in segs:
            layers = [self._init_cache_layer(kind, batch, max_len, dtype)
                      for _ in range(count)]
            cache["segments"].append(jax.tree.map(lambda *xs: jnp.stack(xs),
                                                  *layers))
        if self.cfg.shared_attn_every:
            for _ in range(max(0, len(segs) - 1)):
                cache["shared"].append(
                    self._init_cache_layer("dense", batch, max_len, dtype))
        return cache

    def _with_cache(self, params, x, cache, pos, positions, n_prefix, decode):
        cfg = self.cfg
        new_cache = {"segments": [], "shared": []}
        seg_kinds = _segments(cfg)
        for i, (kind, _) in enumerate(seg_kinds):

            def body(xc, inp, kind=kind):
                layer_p, layer_c = inp
                out, c = self._block_step(kind, layer_p, xc, layer_c, pos,
                                          positions, n_prefix, decode)
                return out, c

            x, seg_cache = jax.lax.scan(
                body, x, (params["segments"][i], cache["segments"][i]))
            new_cache["segments"].append(seg_cache)
            if cfg.shared_attn_every and i < len(seg_kinds) - 1:
                x, c = self._block_step("dense", params["shared"], x,
                                        cache["shared"][i], pos, positions,
                                        n_prefix, decode)
                new_cache["shared"].append(c)
        return x, new_cache

    def prefill(self, params, tokens, cache, extra=None):
        cfg = self.cfg
        x, n_prefix = self._embed(params, tokens, extra)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x, cache = self._with_cache(params, x, cache, 0, positions, n_prefix,
                                    decode=False)
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps, cfg.norms_f32)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        return self._mask_vocab_pad(x @ head), cache

    def decode(self, params, token, cache, pos):
        """token (B, 1) int32; pos scalar int32 — one new token."""
        cfg = self.cfg
        x = params["embed"][token]
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        positions = None
        x, cache = self._with_cache(params, x, cache, pos, positions, 0,
                                    decode=True)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps, cfg.norms_f32)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        return self._mask_vocab_pad(x @ head), cache

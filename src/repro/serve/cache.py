"""Compiled-plan cache: content-signature keys, FIFO bound, stats-coupled.

A :class:`PlanCache` maps ``(database, key)`` to any compiled artifact —
the serving layer stores jitted executables keyed by (plan content
signature, configuration).  Three disciplines, all inherited from the
planner's ``_planinfo_cache``:

  * **Content keys.**  The caller keys on ``plan_signature`` — same logical
    program, same entry; any structural difference (columns, literals,
    parameter specs, DAG wiring) splits.  Bindings are NOT part of the key:
    one entry serves every binding of a template.
  * **FIFO bound.**  At most ``max_entries`` live entries; a process
    compiling throwaway templates against one long-lived database cannot
    grow without bound.
  * **Stats-coupled invalidation.**  Every cache registers with the
    planner's invalidation registry at import: ``invalidate_stats(db)`` —
    called on table mutation, and by ``stats_override`` on BOTH entry and
    exit — evicts every entry compiled against ``db``.  A compiled template
    embeds statistics-derived claims (key_bits, wire bounds); serving it
    after the statistics changed would at best overflow-and-retry on every
    request, at worst (a widened domain) return a wrong answer — eviction at
    the one doorway closes that gap for every cache at once.

Entries hold a weakref to their database: a dead database's entries are
unreachable garbage and are dropped on sight, and an ``id()`` reused by a
new database can never hit an old entry.
"""
from __future__ import annotations

import weakref
from typing import Any

from repro.core import planner

__all__ = ["PlanCache"]


class PlanCache:
    """FIFO-bounded ``(database, key) -> artifact`` cache (see module doc)."""

    def __init__(self, max_entries: int = 256):
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.max_entries = max_entries
        # (id(db), key) -> (weakref(db), artifact); dict order = FIFO
        self._entries: dict[tuple, tuple] = {}
        self.evictions = 0
        _REGISTRY.add(self)

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, db, key) -> Any | None:
        entry = self._entries.get((id(db), key))
        if entry is None:
            return None
        ref, artifact = entry
        if ref() is not db:          # id() reused after gc: not our entry
            del self._entries[(id(db), key)]
            return None
        return artifact

    def put(self, db, key, artifact) -> None:
        k = (id(db), key)
        self._entries.pop(k, None)   # re-put moves to the back of the FIFO
        while len(self._entries) >= self.max_entries:
            self._entries.pop(next(iter(self._entries)))
            self.evictions += 1
        self._entries[k] = (weakref.ref(db), artifact)

    def evict_db(self, db) -> int:
        """Drop every entry compiled against ``db`` (and any dead entries);
        returns the count.  Fired through the planner invalidation registry."""
        dead = [k for k, (ref, _) in self._entries.items()
                if ref() is db or ref() is None]
        for k in dead:
            del self._entries[k]
        self.evictions += len(dead)
        return len(dead)

    def clear(self) -> None:
        self.evictions += len(self._entries)
        self._entries.clear()


# every live PlanCache, weakly — one registered dispatcher serves them all,
# and a collected cache needs no unregistration
_REGISTRY: "weakref.WeakSet[PlanCache]" = weakref.WeakSet()


def _invalidation_hook(db) -> None:
    for cache in list(_REGISTRY):
        cache.evict_db(db)


planner.register_invalidation(_invalidation_hook)

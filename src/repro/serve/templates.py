"""Parameterized plan templates: one logical DAG serves every binding.

A :class:`PlanTemplate` wraps a plan builder whose literals have been lifted
into :class:`repro.core.plan.Param` placeholders.  The template's plan is
built ONCE; the planner analyzes it ONCE against each database (refinement
uses the parameter DOMAINS, so the cached ``PlanInfo`` is sound for every
admissible binding); and the serving layer (:mod:`repro.serve.server`)
compiles it ONCE per configuration — re-binding never re-plans, re-analyzes
or re-traces.  :meth:`PlanTemplate.bind` validates a binding against the
declared domains host-side and returns a :class:`BoundQuery`, a plain
``query_fn(ctx)`` the whole existing machinery (backends, fault runner,
lineage) accepts unchanged.

``TEMPLATES`` covers all 22 TPC-H queries and is built entirely from the
committed SQL texts (``src/repro/queries/sql/q*.sql``) via
:meth:`PlanTemplate.from_sql`: Q1/Q3/Q5/Q6 carry genuine parameters (the
TPC-H substitution parameters: dates, discount window, quantity threshold)
as ``declare .. in (lo, hi)`` headers whose domains span the spec's
substitution ranges and whose defaults equal the validation literals of
:mod:`repro.queries`; the rest compile to zero-parameter templates, so a
mixed serving stream can interleave every query shape.  Each template ships
``samples`` — admissible bindings (``samples[0]`` is the canonical/default
one) — used by the differential tests and ``benchmarks/bench_serve.py`` to
synthesize parameterized traffic.
"""
from __future__ import annotations

from typing import Any, Callable

from repro.core import plan as P
from repro.core.planner import (CompiledQuery, compile_query, params_of,
                                subplan_signatures)
from repro.core.table import days

__all__ = ["PlanTemplate", "BoundQuery", "resolve_bindings", "TEMPLATES",
           "template_for"]


def resolve_bindings(params: dict[str, P.Param],
                     bindings: dict[str, Any]) -> dict[str, Any]:
    """Validate ``bindings`` against the template's parameter specs and return
    the COMPLETE canonical binding (every declared parameter present, values
    coerced to plain int/float per the pinned dtype).

    Host-side rejection is the first line of the soundness story: a binding
    outside a parameter's declared domain could outrun the domain-derived
    ``PlanInfo``, so it never reaches the engine.  (Stale statistics that
    slip past still trip the runtime range checks into ``ctx.overflow``.)
    """
    unknown = set(bindings) - set(params)
    if unknown:
        raise ValueError(f"unknown parameter(s) {sorted(unknown)}; "
                         f"template declares {sorted(params)}")
    out: dict[str, Any] = {}
    for name, spec in sorted(params.items()):
        if name in bindings:
            v = bindings[name]
        elif spec.default is not None:
            v = spec.default
        else:
            raise ValueError(f"parameter {name!r} has no binding and no "
                             "default")
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            raise ValueError(f"parameter {name!r}: expected a number, got "
                             f"{type(v).__name__}")
        if spec.dtype == "int64":
            if isinstance(v, float) and not v.is_integer():
                raise ValueError(f"parameter {name!r} is int64; got {v!r}")
            v = int(v)
        else:
            v = float(v)
        if spec.lo is not None and not (spec.lo <= v <= spec.hi):
            raise ValueError(f"parameter {name!r}={v!r} outside its declared "
                             f"domain [{spec.lo}, {spec.hi}]")
        out[name] = v
    return out


class BoundQuery:
    """A template with values bound: a plain ``query_fn(ctx)``.

    Carries the fault runner's recovery protocol (``_infer`` /
    ``with_inference``) so capacity escalation and hint-drop recompilation
    work on served queries exactly as on static ones — always against the
    SAME bindings."""

    def __init__(self, template: "PlanTemplate", values: dict[str, Any],
                 infer: bool | None = None):
        self.template = template
        self.values = values
        self._infer = infer          # None = environment default

    def __call__(self, ctx):
        return self.template.query.run(ctx, infer=self._infer,
                                       params=self.values)

    def with_inference(self, on: bool) -> "BoundQuery":
        return BoundQuery(self.template, self.values, bool(on))

    @property
    def plan(self) -> P.Node:
        return self.template.query.plan

    def static_counts(self) -> dict[str, int]:
        return self.template.query.static_counts()


class PlanTemplate:
    """A compiled, parameterized logical plan plus its parameter specs."""

    def __init__(self, build_fn: Callable[[], P.Node],
                 name: str | None = None,
                 samples: list[dict] | None = None):
        self.query: CompiledQuery = compile_query(build_fn, name=name)
        self.name = self.query.name
        self.samples = [dict(s) for s in (samples or [{}])]

    @property
    def params(self) -> dict[str, P.Param]:
        got = self.__dict__.get("_params")
        if got is None:
            got = self.__dict__["_params"] = params_of(self.query.plan)
        return got

    def signature(self) -> str:
        """Content signature of the plan — parameters appear by SPEC, never
        by binding, so every binding shares one signature (one cache entry,
        one jit trace) while any structural difference splits it."""
        return self.query.signature()

    def subplan_signatures(self) -> dict[int, tuple[str, frozenset]]:
        """Per-node subtree content hashes + reachable parameter names (the
        batch executor's cross-query memo keys); computed once per template."""
        got = self.__dict__.get("_subsigs")
        if got is None:
            got = self.__dict__["_subsigs"] = \
                subplan_signatures(self.query.plan)
        return got

    def bind(self, **bindings) -> BoundQuery:
        return BoundQuery(self, resolve_bindings(self.params, bindings))

    @classmethod
    def from_sql(cls, text: str, name: str | None = None,
                 samples: list[dict] | None = None) -> "PlanTemplate":
        """Compile SQL ``text`` into a template.  ``declare`` headers become
        the template's parameters (name, dtype, domain, default)."""
        from repro.sql.frontend import plan_sql
        return cls(lambda: plan_sql(text), name=name, samples=samples)


# ---------------------------------------------------------------------------
# the 22 TPC-H templates, compiled from the committed SQL texts
# ---------------------------------------------------------------------------

def _q6_template() -> P.Node:
    """The Q6 template's plan builder (SQL-compiled); kept addressable so
    tests can construct a structural twin of ``TEMPLATES[6]``."""
    from repro.sql.frontend import plan_sql, sql_text
    return plan_sql(sql_text(6))


# sample bindings the tests/bench stream with; samples[0] = {} binds every
# default, reproducing the literal query exactly
_SAMPLES: dict[int, list[dict]] = {
    1: [{},
        {"q1_cutoff": days("1998-08-15")},
        {"q1_cutoff": days("1998-09-20")}],
    3: [{},
        {"q3_date": days("1995-03-07")},
        {"q3_date": days("1995-03-25")}],
    5: [{},
        {"q5_date_lo": days("1995-01-01"),
         "q5_date_hi": days("1996-01-01")}],
    6: [{},
        {"q6_disc_lo": 0.03, "q6_disc_hi": 0.05, "q6_qty": 25},
        {"q6_date_lo": days("1995-01-01"),
         "q6_date_hi": days("1996-01-01")}],
}


def _make_templates() -> dict[int, PlanTemplate]:
    from repro.sql.frontend import sql_text
    return {qid: PlanTemplate.from_sql(sql_text(qid), name=f"q{qid}",
                                       samples=_SAMPLES.get(qid))
            for qid in range(1, 23)}


TEMPLATES: dict[int, PlanTemplate] = _make_templates()


def template_for(qid: int) -> PlanTemplate:
    """The standing template for TPC-H query ``qid`` (1-22)."""
    return TEMPLATES[qid]

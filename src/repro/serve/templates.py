"""Parameterized plan templates: one logical DAG serves every binding.

A :class:`PlanTemplate` wraps a plan builder whose literals have been lifted
into :class:`repro.core.plan.Param` placeholders.  The template's plan is
built ONCE; the planner analyzes it ONCE against each database (refinement
uses the parameter DOMAINS, so the cached ``PlanInfo`` is sound for every
admissible binding); and the serving layer (:mod:`repro.serve.server`)
compiles it ONCE per configuration — re-binding never re-plans, re-analyzes
or re-traces.  :meth:`PlanTemplate.bind` validates a binding against the
declared domains host-side and returns a :class:`BoundQuery`, a plain
``query_fn(ctx)`` the whole existing machinery (backends, fault runner,
lineage) accepts unchanged.

``TEMPLATES`` covers all 22 TPC-H queries: Q1/Q3/Q5/Q6 carry genuine
parameters (the TPC-H substitution parameters: dates, discount window,
quantity threshold) with domains spanning the spec's substitution ranges and
defaults equal to the validation literals of :mod:`repro.queries`; the rest
wrap the literal builders as zero-parameter templates, so a mixed serving
stream can interleave every query shape.  Each template ships ``samples`` —
admissible bindings (``samples[0]`` is the canonical/default one) — used by
the differential tests and ``benchmarks/bench_serve.py`` to synthesize
parameterized traffic.
"""
from __future__ import annotations

from typing import Any, Callable

from repro.core import plan as P
from repro.core.plan import col, param, result, scan, scode
from repro.core.planner import (CompiledQuery, compile_query, params_of,
                                subplan_signatures)
from repro.core.table import days
from repro.queries import PLANS

__all__ = ["PlanTemplate", "BoundQuery", "resolve_bindings", "TEMPLATES",
           "template_for"]


def resolve_bindings(params: dict[str, P.Param],
                     bindings: dict[str, Any]) -> dict[str, Any]:
    """Validate ``bindings`` against the template's parameter specs and return
    the COMPLETE canonical binding (every declared parameter present, values
    coerced to plain int/float per the pinned dtype).

    Host-side rejection is the first line of the soundness story: a binding
    outside a parameter's declared domain could outrun the domain-derived
    ``PlanInfo``, so it never reaches the engine.  (Stale statistics that
    slip past still trip the runtime range checks into ``ctx.overflow``.)
    """
    unknown = set(bindings) - set(params)
    if unknown:
        raise ValueError(f"unknown parameter(s) {sorted(unknown)}; "
                         f"template declares {sorted(params)}")
    out: dict[str, Any] = {}
    for name, spec in sorted(params.items()):
        if name in bindings:
            v = bindings[name]
        elif spec.default is not None:
            v = spec.default
        else:
            raise ValueError(f"parameter {name!r} has no binding and no "
                             "default")
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            raise ValueError(f"parameter {name!r}: expected a number, got "
                             f"{type(v).__name__}")
        if spec.dtype == "int64":
            if isinstance(v, float) and not v.is_integer():
                raise ValueError(f"parameter {name!r} is int64; got {v!r}")
            v = int(v)
        else:
            v = float(v)
        if spec.lo is not None and not (spec.lo <= v <= spec.hi):
            raise ValueError(f"parameter {name!r}={v!r} outside its declared "
                             f"domain [{spec.lo}, {spec.hi}]")
        out[name] = v
    return out


class BoundQuery:
    """A template with values bound: a plain ``query_fn(ctx)``.

    Carries the fault runner's recovery protocol (``_infer`` /
    ``with_inference``) so capacity escalation and hint-drop recompilation
    work on served queries exactly as on static ones — always against the
    SAME bindings."""

    def __init__(self, template: "PlanTemplate", values: dict[str, Any],
                 infer: bool | None = None):
        self.template = template
        self.values = values
        self._infer = infer          # None = environment default

    def __call__(self, ctx):
        return self.template.query.run(ctx, infer=self._infer,
                                       params=self.values)

    def with_inference(self, on: bool) -> "BoundQuery":
        return BoundQuery(self.template, self.values, bool(on))

    @property
    def plan(self) -> P.Node:
        return self.template.query.plan

    def static_counts(self) -> dict[str, int]:
        return self.template.query.static_counts()


class PlanTemplate:
    """A compiled, parameterized logical plan plus its parameter specs."""

    def __init__(self, build_fn: Callable[[], P.Node],
                 name: str | None = None,
                 samples: list[dict] | None = None):
        self.query: CompiledQuery = compile_query(build_fn, name=name)
        self.name = self.query.name
        self.samples = [dict(s) for s in (samples or [{}])]

    @property
    def params(self) -> dict[str, P.Param]:
        got = self.__dict__.get("_params")
        if got is None:
            got = self.__dict__["_params"] = params_of(self.query.plan)
        return got

    def signature(self) -> str:
        """Content signature of the plan — parameters appear by SPEC, never
        by binding, so every binding shares one signature (one cache entry,
        one jit trace) while any structural difference splits it."""
        return self.query.signature()

    def subplan_signatures(self) -> dict[int, tuple[str, frozenset]]:
        """Per-node subtree content hashes + reachable parameter names (the
        batch executor's cross-query memo keys); computed once per template."""
        got = self.__dict__.get("_subsigs")
        if got is None:
            got = self.__dict__["_subsigs"] = \
                subplan_signatures(self.query.plan)
        return got

    def bind(self, **bindings) -> BoundQuery:
        return BoundQuery(self, resolve_bindings(self.params, bindings))


# ---------------------------------------------------------------------------
# the 22 TPC-H templates
# ---------------------------------------------------------------------------

_disc = col("l_extendedprice") * (1 - col("l_discount"))
_charge = _disc * (1 + col("l_tax"))


def _q1_template() -> P.Node:
    """Q1 with the DELTA-substituted ship-date cutoff as a parameter."""
    cutoff = param("q1_cutoff", lo=days("1998-08-01"), hi=days("1998-10-01"),
                   default=days("1998-09-02"))
    l = scan("lineitem").filter(col("l_shipdate") <= cutoff)
    g = l.group_by(["l_returnflag", "l_linestatus"], [
        ("sum_qty", "sum", "l_quantity"),
        ("sum_base_price", "sum", "l_extendedprice"),
        ("sum_disc_price", "sum", _disc),
        ("sum_charge", "sum", _charge),
        ("avg_qty", "avg", "l_quantity"),
        ("avg_price", "avg", "l_extendedprice"),
        ("avg_disc", "avg", "l_discount"),
        ("count_order", "count", None),
    ], exchange="gather", final=True)
    return g.finalize(sort_keys=[("l_returnflag", True),
                                 ("l_linestatus", True)], replicated=True)


def _q3_template() -> P.Node:
    """Q3 with the order/ship DATE pivot as a parameter."""
    d = param("q3_date", lo=days("1995-03-01"), hi=days("1995-03-31"),
              default=days("1995-03-15"))
    c = scan("customer").filter(col("c_mktsegment") ==
                                scode("c_mktsegment", "BUILDING"))
    cb = c.select("c_custkey").broadcast()
    o = scan("orders").filter(col("o_orderdate") < d)
    o = o.semi(cb, "o_custkey", "c_custkey")
    l = scan("lineitem").filter(col("l_shipdate") > d)
    j = l.join(o, "l_orderkey", "o_orderkey",
               ["o_orderdate", "o_shippriority"])
    g = j.group_by(["l_orderkey"], [
        ("revenue", "sum", _disc),
        ("o_orderdate", "max", "o_orderdate"),
        ("o_shippriority", "max", "o_shippriority"),
    ], exchange="local")
    return g.finalize(sort_keys=[("revenue", False), ("o_orderdate", True)],
                      limit=10)


def _q5_template() -> P.Node:
    """Q5 with the order-date year window as parameters."""
    lo = param("q5_date_lo", lo=days("1993-01-01"), hi=days("1997-01-01"),
               default=days("1994-01-01"))
    hi = param("q5_date_hi", lo=days("1994-01-01"), hi=days("1998-01-01"),
               default=days("1995-01-01"))
    n = scan("nation").join(scan("region"), "n_regionkey", "r_regionkey",
                            ["r_name"])
    n = n.filter(col("r_name") == scode("r_name", "ASIA"))
    c = scan("customer").semi(n, "c_nationkey", "n_nationkey")
    cb = c.select("c_custkey", "c_nationkey").broadcast()
    o = scan("orders").filter((col("o_orderdate") >= lo) &
                              (col("o_orderdate") < hi))
    oj = o.join(cb, "o_custkey", "c_custkey", ["c_nationkey"])
    lj = scan("lineitem").join(oj, "l_orderkey", "o_orderkey",
                               ["c_nationkey"])
    s = scan("supplier").semi(n, "s_nationkey", "n_nationkey")
    sb = s.select("s_suppkey", "s_nationkey").broadcast()
    lj = lj.join(sb, "l_suppkey", "s_suppkey", ["s_nationkey"])
    lj = lj.filter(col("c_nationkey") == col("s_nationkey"))
    g = lj.group_by(["s_nationkey"], [("revenue", "sum", _disc)],
                    exchange="gather", final=True)
    return g.finalize(sort_keys=[("revenue", False)], replicated=True)


def _q6_template() -> P.Node:
    """Q6 with every TPC-H substitution parameter lifted: date window,
    discount band (bound directly — no float arithmetic on a parameter, so
    byte-identity with literal plans is exact) and quantity threshold."""
    dlo = param("q6_date_lo", lo=days("1993-01-01"), hi=days("1997-01-01"),
                default=days("1994-01-01"))
    dhi = param("q6_date_hi", lo=days("1994-01-01"), hi=days("1998-01-01"),
                default=days("1995-01-01"))
    disc_lo = param("q6_disc_lo", lo=0.01, hi=0.09, default=0.05)
    disc_hi = param("q6_disc_hi", lo=0.01, hi=0.09, default=0.07)
    qty = param("q6_qty", lo=20, hi=30, default=24)
    l = scan("lineitem").filter(
        (col("l_shipdate") >= dlo) & (col("l_shipdate") < dhi) &
        (col("l_discount") >= disc_lo) & (col("l_discount") <= disc_hi) &
        (col("l_quantity") < qty))
    s = l.agg_scalar([("revenue", "sum",
                       col("l_extendedprice") * col("l_discount"))])
    return result(revenue=s["revenue"])


# parameterized builders + the sample bindings the tests/bench stream with;
# samples[0] = {} binds every default, reproducing the literal query exactly
_PARAMETERIZED: dict[int, tuple[Callable[[], P.Node], list[dict]]] = {
    1: (_q1_template, [{},
                       {"q1_cutoff": days("1998-08-15")},
                       {"q1_cutoff": days("1998-09-20")}]),
    3: (_q3_template, [{},
                       {"q3_date": days("1995-03-07")},
                       {"q3_date": days("1995-03-25")}]),
    5: (_q5_template, [{},
                       {"q5_date_lo": days("1995-01-01"),
                        "q5_date_hi": days("1996-01-01")}]),
    6: (_q6_template, [{},
                       {"q6_disc_lo": 0.03, "q6_disc_hi": 0.05,
                        "q6_qty": 25},
                       {"q6_date_lo": days("1995-01-01"),
                        "q6_date_hi": days("1996-01-01")}]),
}


def _make_templates() -> dict[int, PlanTemplate]:
    out = {}
    for qid, build in sorted(PLANS.items()):
        if qid in _PARAMETERIZED:
            fn, samples = _PARAMETERIZED[qid]
            out[qid] = PlanTemplate(fn, name=f"q{qid}", samples=samples)
        else:
            out[qid] = PlanTemplate(build, name=f"q{qid}", samples=[{}])
    return out


TEMPLATES: dict[int, PlanTemplate] = _make_templates()


def template_for(qid: int) -> PlanTemplate:
    """The standing template for TPC-H query ``qid`` (1-22)."""
    return TEMPLATES[qid]

"""Multi-tenant serving: jit-once-per-template execution + batch sharing.

Two execution paths, one correctness story:

  * :class:`QueryServer` — the compiled path.  Each template is traced ONCE
    per (database, configuration): parameter bindings enter the jitted
    program as dtype-pinned traced scalars, so serving a new binding is a
    cache hit and a device call, never a re-trace.  ``recompiles`` counts
    actual traces (incremented INSIDE the traced body, so an accidental
    re-trace — dtype drift, structure drift — is counted and the bench gate
    ``benchmarks/bench_serve.py --check`` catches it).  Executables live in
    a :class:`repro.serve.cache.PlanCache`, so ``invalidate_stats`` /
    ``stats_override`` / table mutation evict them with the statistics they
    were derived from.  A served request whose domain-derived claims prove
    too tight for its binding surfaces as ``ctx.overflow``; the server
    re-runs it on a conservative entry (inference off, escalated capacity,
    its own cache key) — degraded latency, never a wrong answer.
  * :class:`BatchExecutor` — the eager batch path.  Admits N bound queries
    and extends the planner executor's per-plan DAG memo into a CROSS-QUERY
    memo keyed by (subtree content hash, relevant bindings): scans and
    common subplans — every query touching ``lineitem``, Q3/Q5 sharing a
    filtered-orders fragment — execute once per batch.  Execution is eager,
    so results are byte-identical to sequential one-query-at-a-time eager
    execution (pinned by ``tests/test_serve.py`` on both planner and both
    wire legs); an overflowing request forfeits its memo contributions and
    re-runs conservatively in isolation, so a lying bound can never poison a
    neighbour.

Topology awareness: the server carries a logical device width and a
monotonically increasing ``topology_generation``.  Losing devices
(:meth:`QueryServer.degrade`) bumps the generation — which is part of the
executable cache key, so every template re-traces exactly ONCE per
(template, generation), never per request — and re-prices the per-device
footprint.  With an :class:`AdmissionGate` configured,
:meth:`QueryServer.submit_guarded` returns structured outcomes instead of
opaque errors: :class:`Served` (full-width topology), :class:`Degraded`
(answered, but on a shrunken topology), or :class:`Shed` (declined or
queued because the estimated per-device footprint no longer fits the
degraded cluster; queued requests re-admit via :meth:`drain_backlog`).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as B
from repro.core import planner
from repro.core import relational as rel
from repro.core.table import Table, to_numpy
from repro.core.wire import CorruptPayload
from .cache import PlanCache
from .templates import BoundQuery, PlanTemplate, TEMPLATES

__all__ = ["QueryServer", "BatchExecutor", "AdmissionGate",
           "Served", "Degraded", "Shed"]

_PDTYPE = {"int64": jnp.int64, "float64": jnp.float64}


def _as_table(out):
    if isinstance(out, dict):        # ScalarResult: one-row table
        out = Table({k: jnp.asarray(v).reshape(1) for k, v in out.items()},
                    jnp.asarray(1, jnp.int32))
    return rel.ensure_compact(out)


@dataclasses.dataclass(frozen=True)
class AdmissionGate:
    """Per-device memory budget for admission control.

    ``hbm_bytes`` is the accelerator memory per device; a request is
    admitted while the server's estimated per-device footprint — database
    partition plus capacity-scaled working buffers — stays within
    ``headroom * hbm_bytes``.  After a topology shrink N -> N' the
    footprint grows by N/N', which is exactly what pushes oversized
    requests into :class:`Shed`."""
    hbm_bytes: float
    headroom: float = 0.8

    @property
    def budget_bytes(self) -> float:
        return self.headroom * self.hbm_bytes


@dataclasses.dataclass
class Served:
    """Request answered on the full-width (boot) topology."""
    name: str
    result: dict
    devices: int
    generation: int = 0


@dataclasses.dataclass
class Degraded:
    """Request answered correctly, but on a shrunken topology — the caller
    sees degraded capacity/latency, never a degraded answer."""
    name: str
    result: dict
    devices: int
    generation: int
    lost: int = 0                 # devices below boot width


@dataclasses.dataclass
class Shed:
    """Request NOT executed: its estimated footprint does not fit the
    current (degraded) cluster.  ``queued`` means it sits in the server
    backlog and re-admits via :meth:`QueryServer.drain_backlog` once
    capacity returns."""
    name: str
    reason: str
    estimated_bytes: float
    budget_bytes: float
    devices: int
    generation: int
    queued: bool = False


class QueryServer:
    """Serve parameterized queries from jit-compiled template executables."""

    def __init__(self, db, capacity_factor: float = 2.0,
                 join_method: str = "sorted", use_kernel: bool | None = None,
                 wire_format: str | None = None,
                 cache: PlanCache | None = None,
                 devices: int = 1, gate: AdmissionGate | None = None):
        self.db = db
        self.capacity_factor = capacity_factor
        self.join_method = join_method
        self.use_kernel = use_kernel
        self.wire_format = wire_format
        self.cache = cache if cache is not None else PlanCache()
        self.recompiles = 0          # jit traces (counted inside the trace)
        self.cache_hits = 0
        self.overflow_reruns = 0
        self.approx_served = 0       # answers served off a sample rung
        self.approx_escalations = 0  # tolerance misses climbed past
        self.approx_refused = 0      # non-estimable shapes served exact
        self._tables = B._np_db_to_tables(db)
        # topology state: logical width this server answers on behalf of
        if devices < 1:
            raise ValueError(f"devices must be >= 1, got {devices}")
        self.boot_devices = int(devices)
        self.devices = int(devices)
        self.topology_generation = 0
        self.gate = gate
        self.shed_count = 0
        self.backlog: list[tuple[PlanTemplate, dict | None, bool | None]] = []
        self._db_bytes = float(sum(
            np.asarray(col).nbytes
            for t in db.tables.values() for col in t.values()))

    # -- topology -----------------------------------------------------------
    def degrade(self, devices: int) -> int:
        """Shrink the logical topology to ``devices`` survivors.  Bumps the
        topology generation — every template re-traces exactly once against
        the new generation (the generation is in the executable cache key).
        Returns the new generation."""
        if not 1 <= devices <= self.devices:
            raise ValueError(
                f"degrade to {devices} from {self.devices} devices")
        if devices != self.devices:
            self.devices = int(devices)
            self.topology_generation += 1
        return self.topology_generation

    def restore(self, devices: int | None = None) -> int:
        """Recovered capacity (default: back to boot width).  A new
        generation as well — the topology changed."""
        devices = self.boot_devices if devices is None else int(devices)
        if devices < 1:
            raise ValueError(f"restore to {devices} devices")
        if devices != self.devices:
            self.devices = devices
            self.topology_generation += 1
        return self.topology_generation

    def footprint_bytes(self, factor: float | None = None) -> float:
        """Estimated per-device footprint at the live width: the device's
        database partition plus exchange/join working buffers, which the
        engine sizes as ``capacity_factor`` x the partition."""
        factor = self.capacity_factor if factor is None else factor
        return self._db_bytes / self.devices * (1.0 + float(factor))

    def _executable(self, template: PlanTemplate, infer: bool, factor: float):
        key = ("exe", template.signature(), bool(infer), self.wire_format,
               float(factor), self.join_method, self.use_kernel,
               self.topology_generation)
        fn = self.cache.get(self.db, key)
        if fn is None:
            fn = self._compile(template, infer, factor)
            self.cache.put(self.db, key, fn)
        else:
            self.cache_hits += 1
        return fn

    def _compile(self, template: PlanTemplate, infer: bool, factor: float):
        query = template.query
        # host-side, once per (template, db): domain-sound hints/wire bounds
        info = query.info(self.db) if infer else None

        def run(tables, pvals):
            # trace-time side effect: every (re)trace of this executable is
            # a counted recompile — the bench gate's ground truth
            self.recompiles += 1
            ctx = B.LocalContext(self.db, tables, capacity_factor=factor,
                                 join_method=self.join_method,
                                 use_kernel=self.use_kernel,
                                 wire_format=self.wire_format)
            out = planner._Executor(ctx, info, params=pvals).run(query.plan)
            return _as_table(out), ctx.overflow, ctx.corrupt

        return jax.jit(run)

    def submit(self, template: PlanTemplate | int,
               bindings: dict[str, Any] | None = None,
               infer: bool | None = None,
               tolerance: float | None = None,
               confidence: float = 0.95) -> dict:
        """Execute one parameterized request; returns the numpy result.

        ``tolerance=`` opts into approximate serving: the answer comes from
        the smallest sample rung whose relative CI half-width (at
        ``confidence``) fits the tolerance, escalating up the ladder
        otherwise — each rung a separately-cached executable (the rung is in
        the cache key, so approximate and exact artifacts never collide).
        Plans the rewrite pass refuses run exact.  Default tolerance comes
        from ``REPRO_APPROX`` (unset = exact serving).
        """
        if isinstance(template, int):
            template = TEMPLATES[template]
        if infer is None:
            infer = planner.planner_default()
        bound = template.bind(**(bindings or {}))
        # dtype-pinned traced scalars; every declared parameter is always
        # present, so the pytree structure (and hence the trace) is stable
        pvals = {name: jnp.asarray(v, _PDTYPE[template.params[name].dtype])
                 for name, v in bound.values.items()}
        if tolerance is None:
            from repro.approx.progressive import approx_default
            tolerance = approx_default()
        if tolerance is not None:
            res = self._submit_approx(template, pvals, infer,
                                      float(tolerance), confidence)
            if res is not None:
                return res
            self.approx_refused += 1
        fn = self._executable(template, infer, self.capacity_factor)
        out, overflow, corrupt = fn(self._tables, pvals)
        if bool(overflow):
            # a domain-derived claim was too tight for this binding (or the
            # statistics lied): re-run conservatively — no hints, escalated
            # capacity, full-width wire — under its own cache key so healthy
            # traffic keeps the fast entry
            self.overflow_reruns += 1
            fn = self._executable(template, False,
                                  self.capacity_factor * 4.0)
            out, overflow, corrupt = fn(self._tables, pvals)
        if bool(corrupt):
            raise CorruptPayload("serve: payload integrity check failed")
        if bool(overflow):
            raise RuntimeError(
                f"{template.name}: overflow persists on the conservative "
                f"rerun (capacity_factor={self.capacity_factor * 4.0})")
        return to_numpy(out)

    # -- approximate serving (repro.approx) --------------------------------
    def _approx_rewrite(self, template: PlanTemplate, den: int):
        """Rung rewrite of a template, cached (and invalidated) with the
        statistics it was derived from."""
        from repro.approx import rewrite as AR
        from repro.approx import sampling as AS
        key = ("approx-rw", template.signature(), int(den), AS.DEFAULT_SEED)
        got = self.cache.get(self.db, key)
        if got is None:
            rw = AR.rewrite_for_rung(template.query, self.db, den)
            self.cache.put(self.db, key, ("rw", rw))
        else:
            self.cache_hits += 1
            rw = got[1]
        return rw

    def _approx_executable(self, template: PlanTemplate, rw, infer: bool,
                           factor: float):
        from repro.approx import sampling as AS
        tkey = ("approx-tables", rw.table, rw.strata, int(rw.den),
                AS.DEFAULT_SEED)
        tables = self.cache.get(self.db, tkey)
        if tables is None:
            tables = B._np_db_to_tables(rw.db)
            self.cache.put(self.db, tkey, tables)
        # the rung is part of the key: approximate and exact executables
        # (and different rungs) never collide in the cache
        key = ("exe-approx", template.signature(), int(rw.den), bool(infer),
               self.wire_format, float(factor), self.join_method,
               self.use_kernel, self.topology_generation)
        fn = self.cache.get(self.db, key)
        if fn is None:
            query, rdb = rw.query, rw.db
            info = query.info(rdb) if infer else None

            def run(tables, pvals):
                self.recompiles += 1
                ctx = B.LocalContext(rdb, tables, capacity_factor=factor,
                                     join_method=self.join_method,
                                     use_kernel=self.use_kernel,
                                     wire_format=self.wire_format)
                out = planner._Executor(ctx, info, params=pvals).run(
                    query.plan)
                return _as_table(out), ctx.overflow, ctx.corrupt

            fn = jax.jit(run)
            self.cache.put(self.db, key, fn)
        else:
            self.cache_hits += 1
        return fn, tables

    def _submit_approx(self, template: PlanTemplate, pvals: dict,
                       infer: bool, tolerance: float,
                       confidence: float) -> dict | None:
        """Climb the sample ladder; None means the shape refused (go exact)."""
        from repro.approx import sampling as AS
        for den in AS.LADDER:
            rw = self._approx_rewrite(template, den)
            if rw is None:
                return None
            fn, tables = self._approx_executable(
                template, rw, infer, self.capacity_factor)
            out, overflow, corrupt = fn(tables, pvals)
            if bool(overflow):
                self.overflow_reruns += 1
                fn, tables = self._approx_executable(
                    template, rw, False, self.capacity_factor * 4.0)
                out, overflow, corrupt = fn(tables, pvals)
            if bool(corrupt):
                raise CorruptPayload(
                    "serve: payload integrity check failed")
            if bool(overflow):
                raise RuntimeError(
                    f"{template.name}~r{den}: overflow persists on the "
                    f"conservative rerun")
            est = rw.finalize(to_numpy(out), confidence)
            if est.rel_width <= tolerance or den == 1:
                self.approx_served += 1
                return est.result
            self.approx_escalations += 1
        return None    # unreachable: the den == 1 rung always answers

    def serve(self, requests, infer: bool | None = None,
              tolerance: float | None = None) -> list[dict]:
        """Submit a stream of ``(template_or_qid, bindings)`` requests."""
        return [self.submit(t, b, infer=infer, tolerance=tolerance)
                for t, b in requests]

    # -- capacity-aware admission ------------------------------------------
    def submit_guarded(self, template: PlanTemplate | int,
                       bindings: dict[str, Any] | None = None,
                       infer: bool | None = None,
                       queue: bool = True) -> Served | Degraded | Shed:
        """Admission-gated submit with structured outcomes.

        With no :class:`AdmissionGate` configured every request is admitted.
        Otherwise a request whose estimated per-device footprint exceeds the
        gate's budget at the LIVE width is not executed: it is queued on the
        server backlog (``queue=True``, the default) or declined outright —
        both surfaced as :class:`Shed`, never as an opaque error.  Admitted
        requests on a shrunken topology come back :class:`Degraded`."""
        if isinstance(template, int):
            template = TEMPLATES[template]
        if self.gate is not None:
            est = self.footprint_bytes()
            if est > self.gate.budget_bytes:
                self.shed_count += 1
                if queue:
                    self.backlog.append((template, bindings, infer))
                return Shed(
                    name=template.name, queued=queue,
                    reason=(f"estimated per-device footprint "
                            f"{est / 1e6:.1f} MB exceeds budget "
                            f"{self.gate.budget_bytes / 1e6:.1f} MB at "
                            f"{self.devices} devices"),
                    estimated_bytes=est,
                    budget_bytes=self.gate.budget_bytes,
                    devices=self.devices,
                    generation=self.topology_generation)
        result = self.submit(template, bindings, infer=infer)
        if self.devices < self.boot_devices:
            return Degraded(name=template.name, result=result,
                            devices=self.devices,
                            generation=self.topology_generation,
                            lost=self.boot_devices - self.devices)
        return Served(name=template.name, result=result,
                      devices=self.devices,
                      generation=self.topology_generation)

    def drain_backlog(self) -> list[Served | Degraded | Shed]:
        """Re-admit queued requests (after :meth:`restore` or a capacity
        change).  Requests that still do not fit go back on the backlog."""
        pending, self.backlog = self.backlog, []
        return [self.submit_guarded(t, b, infer=i, queue=True)
                for t, b, i in pending]


class _SharedMemoExecutor(planner._Executor):
    """Planner executor whose node memo extends across queries.

    Key = (subtree content hash, the bindings of the parameters that subtree
    can observe, inference leg).  Content-addressing makes distinct plan
    objects with identical logical subtrees share; restricting the key to
    the REACHABLE parameters lets two bindings share every subtree that
    doesn't depend on where they differ (all scans, for one).  Sound because
    per-subtree planner decisions (hints, wire bounds) depend only on the
    subtree's content and the database statistics — identical key, identical
    table."""

    def __init__(self, ctx, info, params, subsigs, shared, added, owner):
        super().__init__(ctx, info, params=params)
        self._subsigs = subsigs
        self._shared = shared
        self._added = added
        self._owner = owner

    def _exec(self, node):
        got = self.memo.get(id(node))
        if got is not None:
            return got
        sig, pnames = self._subsigs[id(node)]
        key = (sig, tuple(sorted((p, self.params.get(p)) for p in pnames)),
               self.info is not None)
        out = self._shared.get(key)
        if out is not None:
            self._owner.shared_hits += 1
            self.memo[id(node)] = out
            return out
        out = super()._exec(node)    # recursion re-enters this override
        self._shared[key] = out
        self._added.append(key)
        return out


class BatchExecutor:
    """Execute a batch of bound queries eagerly with cross-query sharing."""

    def __init__(self, db, capacity_factor: float = 2.0,
                 join_method: str = "sorted", use_kernel: bool | None = None,
                 wire_format: str | None = None):
        self.db = db
        self.capacity_factor = capacity_factor
        self.join_method = join_method
        self.use_kernel = use_kernel
        self.wire_format = wire_format
        self.shared_hits = 0         # cross-query memo hits
        self.overflow_reruns = 0
        self._tables = B._np_db_to_tables(db)

    def _ctx(self, factor: float):
        return B.LocalContext(self.db, self._tables, capacity_factor=factor,
                              join_method=self.join_method,
                              use_kernel=self.use_kernel,
                              wire_format=self.wire_format)

    def run_batch(self, requests, infer: bool | None = None) -> list[dict]:
        """``requests``: (template, bindings) pairs (or BoundQuery directly).

        Returns per-request numpy results, byte-identical to running each
        request alone (eager) in submission order.
        """
        if infer is None:
            infer = planner.planner_default()
        shared: dict = {}
        ctx = self._ctx(self.capacity_factor)
        results: list[dict] = []
        for req in requests:
            bound = req if isinstance(req, BoundQuery) else \
                req[0].bind(**(req[1] or {}))
            template = bound.template
            info = template.query.info(self.db) if infer else None
            added: list = []
            ex = _SharedMemoExecutor(ctx, info, bound.values,
                                     template.subplan_signatures(), shared,
                                     added, self)
            out = _as_table(ex.run(template.query.plan))
            if bool(ctx.corrupt):
                raise CorruptPayload(
                    "batch: payload integrity check failed")
            if bool(ctx.overflow):
                # this request's claims lied: its memo contributions are not
                # trustworthy state — forfeit them, re-run the request alone
                # conservatively, and start the NEXT request on a fresh
                # context (the overflow flag is sticky by design)
                for k in added:
                    shared.pop(k, None)
                results.append(self._conservative(bound))
                ctx = self._ctx(self.capacity_factor)
                continue
            results.append(to_numpy(out))
        return results

    def _conservative(self, bound: BoundQuery) -> dict:
        self.overflow_reruns += 1
        ctx = self._ctx(self.capacity_factor * 4.0)
        out = _as_table(bound.with_inference(False)(ctx))
        if bool(ctx.corrupt):
            raise CorruptPayload("batch: payload integrity check failed")
        if bool(ctx.overflow):
            raise RuntimeError(
                f"{bound.template.name}: overflow persists on the "
                f"conservative rerun")
        return to_numpy(out)

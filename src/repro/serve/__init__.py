"""Serving substrate: cache layouts live in models/; step factories in
train.trainstep (make_prefill_step / make_decode_step); sequence-sharded
flash-decode specs in distributed.shardings.cache_specs."""
from repro.train.trainstep import make_decode_step, make_prefill_step  # noqa

"""Multi-tenant query serving (ROADMAP item 1): parameterized plan
templates, a content-signature compiled-plan cache, and batch execution
with cross-query sharing.

Entry points:

  * :class:`PlanTemplate` / :class:`BoundQuery` / ``TEMPLATES`` /
    ``template_for`` — plans whose literals are ``Param`` placeholders;
    one DAG + one analysis + one jit trace per template, domain-validated
    binding per request (``templates.py``).
  * :class:`PlanCache` — FIFO-bounded compiled-artifact cache keyed on plan
    content signatures, evicted through the planner's stats-invalidation
    registry (``cache.py``).
  * :class:`QueryServer` / :class:`BatchExecutor` — the compiled serving
    path (jit once per template, bindings as traced scalars) and the eager
    batch path (cross-query subplan memo), both overflow-recovering
    (``server.py``).
  * :class:`AdmissionGate` + :class:`Served` / :class:`Degraded` /
    :class:`Shed` — capacity-aware admission on a degraded topology: one
    re-trace per (template, topology generation), oversized requests shed
    or queued as structured outcomes (``server.py``).

    PYTHONPATH=src python benchmarks/bench_serve.py --check
"""
from .cache import PlanCache
from .server import (AdmissionGate, BatchExecutor, Degraded, QueryServer,
                     Served, Shed)
from .templates import (BoundQuery, PlanTemplate, TEMPLATES,
                        resolve_bindings, template_for)

__all__ = [
    "PlanTemplate", "BoundQuery", "TEMPLATES", "template_for",
    "resolve_bindings", "PlanCache", "QueryServer", "BatchExecutor",
    "AdmissionGate", "Served", "Degraded", "Shed",
]

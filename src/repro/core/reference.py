"""NumPy reference executor — independent oracle + the paper's CPU baseline.

Implements the same relational API as :mod:`repro.core.relational` but with
exact-size arrays and *different* algorithms (boolean indexing, ``np.unique``
based group-by, dictionary-free joins) so that agreement with the JAX engine is
meaningful validation, not shared bugs.  Also serves as the single-node CPU
baseline for the paper's DuckDB comparison (§6.7).

Tables here are plain ``dict[str, np.ndarray]`` with no padding.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

RTable = dict  # dict[str, np.ndarray]


def filter_rows(t: RTable, mask: np.ndarray) -> RTable:
    return {k: v[mask] for k, v in t.items()}


def limit(t: RTable, n: int) -> RTable:
    return {k: v[:n] for k, v in t.items()}


def _nrows(t: RTable) -> int:
    return len(next(iter(t.values())))


def combine_keys(cols: Sequence[np.ndarray]) -> np.ndarray:
    if len(cols) > 2:
        raise ValueError("pack >2 keys explicitly in the plan (collision safety)")
    k = cols[0].astype(np.int64)
    for c in cols[1:]:
        k = (k << 32) | c.astype(np.int64)
    return k


def join_unique(probe: RTable, build: RTable, probe_on: np.ndarray,
                build_on: np.ndarray, take: Sequence[str]) -> RTable:
    build_on = np.asarray(build_on, dtype=np.int64)
    if len(np.unique(build_on)) != len(build_on):
        raise ValueError("build side keys are not unique")
    lut = {int(k): i for i, k in enumerate(build_on)}
    idx = np.array([lut.get(int(k), -1) for k in probe_on], dtype=np.int64)
    matched = idx >= 0
    out = {k: v[matched] for k, v in probe.items()}
    for name in take:
        out[name] = build[name][idx[matched]]
    return out


def semi_join(probe: RTable, build: RTable, probe_on, build_on) -> RTable:
    keys = set(np.asarray(build_on, dtype=np.int64).tolist())
    matched = np.array([int(k) in keys for k in probe_on], dtype=bool)
    return filter_rows(probe, matched)


def anti_join(probe: RTable, build: RTable, probe_on, build_on) -> RTable:
    keys = set(np.asarray(build_on, dtype=np.int64).tolist())
    matched = np.array([int(k) in keys for k in probe_on], dtype=bool)
    return filter_rows(probe, ~matched)


def left_join(probe: RTable, build: RTable, probe_on, build_on,
              take: Sequence[str], defaults) -> RTable:
    build_on = np.asarray(build_on, dtype=np.int64)
    lut = {int(k): i for i, k in enumerate(build_on)}
    idx = np.array([lut.get(int(k), -1) for k in probe_on], dtype=np.int64)
    matched = idx >= 0
    out = dict(probe)
    for name in take:
        col = build[name]
        vals = np.full(len(idx), defaults[name], dtype=col.dtype)
        vals[matched] = col[idx[matched]]
        out[name] = vals
    out["__matched"] = matched
    return out


def group_aggregate(t: RTable, key_cols: Sequence[str],
                    aggs: Sequence[tuple[str, str, np.ndarray | str | None]]) -> RTable:
    n = _nrows(t)
    if key_cols:
        key = combine_keys([t[k] for k in key_cols])
    else:
        key = np.zeros(n, dtype=np.int64)
    uniq, inv = np.unique(key, return_inverse=True)
    g = len(uniq)
    out: RTable = {}
    for k in key_cols:
        first = np.zeros(g, dtype=np.int64)
        # last writer wins; all rows in a group share the key value
        first[inv] = np.arange(n)
        out[k] = t[k][first]
    for out_name, op, values in aggs:
        if values is None:
            v = np.ones(n, dtype=np.int64)
        elif isinstance(values, str):
            v = t[values]
        else:
            v = np.asarray(values)
        if op == "count":
            out[out_name] = np.bincount(inv, minlength=g).astype(np.int64)
        elif op == "sum":
            out[out_name] = np.bincount(inv, weights=v.astype(np.float64), minlength=g) \
                if np.issubdtype(v.dtype, np.floating) else \
                np.bincount(inv, weights=v.astype(np.float64), minlength=g).astype(np.int64)
        elif op == "min":
            acc = np.full(g, np.inf if np.issubdtype(v.dtype, np.floating)
                          else np.iinfo(v.dtype).max, dtype=v.dtype)
            np.minimum.at(acc, inv, v)
            out[out_name] = acc
        elif op == "max":
            acc = np.full(g, -np.inf if np.issubdtype(v.dtype, np.floating)
                          else np.iinfo(v.dtype).min, dtype=v.dtype)
            np.maximum.at(acc, inv, v)
            out[out_name] = acc
        else:
            raise ValueError(op)
    if g == 0:  # preserve dtypes for empty results
        for out_name, op, values in aggs:
            if out_name not in out:
                out[out_name] = np.zeros(0)
    return out


def sort_by(t: RTable, keys: Sequence[tuple[str, bool]]) -> RTable:
    order = np.arange(_nrows(t))
    for col, asc in reversed(list(keys)):
        k = t[col][order]
        k = k if asc else (-k if np.issubdtype(k.dtype, np.number) else k)
        step = np.argsort(k, kind="stable")
        order = order[step]
    return {k: v[order] for k, v in t.items()}

"""Data-exchange operators on JAX collectives — the paper's core contribution.

GPU/NCCL -> TPU/XLA mapping (DESIGN.md §2):

  shuffle    NCCL N^2 ncclSend/Recv (variable sizes)  ->  capacity-bounded
             ``jax.lax.all_to_all`` with per-destination fixed-size row buffers
             and validity counts (the MoE-dispatch idiom).
  broadcast  ncclBroadcast one-to-all ring             ->  ``jax.lax.all_gather``
             (XLA lowers to the ICI ring — exactly the paper's Eq. 1 model).
             A deliberately-naive p2p ring variant (``broadcast_table_p2p``)
             reproduces §7.1 / Figure 19.
  allreduce  ncclAllReduce                             ->  ``jax.lax.psum`` etc.

Wire format (packed exchanges)
------------------------------
Columns are exchanged either one at a time (paper-faithful, §2.3 "we exchange
one column at a time") or packed into a single int32 buffer so the whole
table moves in ONE collective.  The packed layout is a planner-statistics-
driven **wire format** (:mod:`repro.core.wire`):

  * **Lane layout** — with per-column ``(lo, hi)`` bounds (the same min/max
    statistics that feed ``key_bits``), integer columns ship biased at their
    inferred width: 8/16-bit lanes share int32 words via shift/or, a 64-bit
    column whose span fits 32 bits ships as one biased word, a provably
    constant column is not shipped at all, and bool is always an 8-bit lane.
    float64 stays split across two words — mantissas cannot be range-
    compressed — and anything unbounded ships verbatim.  ``REPRO_WIRE=wide``
    forces the legacy full-width layout (the differential leg); without
    planner bounds the format is wide by construction.
  * **Header row** — the paper's pre-exchange size-metadata round is FUSED
    into the payload: row 0 of each per-destination block (word 0) carries
    the sender's row count, so a packed ``shuffle``/``broadcast_table`` is
    ONE collective, not a counts round plus a payload round.  The per-column
    mode keeps the separate metadata round (it is the §2.3 baseline).
  * **Overflow contract** — a narrowed column is range-checked per valid row
    at pack time; a value outside its claimed bounds sets the returned
    overflow flag (ORed into ``ctx.overflow`` -> the fault runner re-executes,
    dropping inference and hence the narrow format).  Lying bounds can
    therefore cost a retry but can never silently truncate a value.
  * **Integrity word** — packed exchanges fold an integrity checksum of each
    per-sender payload block into the same fused header row
    (:func:`repro.core.wire.header_mode`); receivers verify every block and
    raise the ``corrupt`` flag on mismatch (ORed into ``ctx.corrupt`` -> the
    fault runner re-executes on the wide format).  The ``tamper`` hook lets
    the chaos harness flip received payload bits inside the traced program.

``ExchangeStats`` reports both actual wire bytes (packed words incl. the
header row) and logical dtype-true bytes, so the compression ratio is visible
per exchange and the §3.6 Hockney model consumes what actually moves
(:func:`repro.core.perfmodel.exchange_time_from_stats`).

Deferred compaction: exchange OUTPUTS are masked tables (received rows are
front-packed per sender block; the validity mask exposes them without a sort).
``broadcast_table`` INPUTS are compacted first — the gathered payload is
reconstructed from per-shard counts alone, a true contiguity boundary;
``shuffle`` inputs may stay masked (invalid rows route to a dropped bucket).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from . import wire as wi
from .table import Table
from .relational import agg_kernel_default, ensure_compact, hash_partition_ids
# imported at module scope (not lazily inside traced code): the kernel module
# materializes constants at import time, which must not happen under a trace
from repro.kernels.radix_hist import ops as _rh_ops

__all__ = [
    "ExchangeStats",
    "pack_columns",
    "unpack_columns",
    "shuffle",
    "broadcast_table",
    "broadcast_table_p2p",
    "partial_to_global",
]


@dataclasses.dataclass
class ExchangeStats:
    """Static (trace-time) descriptor of one exchange — feeds the perf models.

    ``message_bytes``/``total_bytes`` are ACTUAL wire bytes (packed words x 4,
    including the fused counts header row and, in per-column mode, the
    separate metadata round); ``logical_bytes`` is the dtype-true payload
    size per message, so ``logical_bytes / message_bytes`` approaches the
    wire-compression ratio as capacity padding amortizes.  The per-row pair
    (``row_wire_bytes``, ``row_logical_bytes``) is capacity-independent and
    equals the IR-derived static numbers on every backend
    (``planner.static_wire_stats``).
    """
    kind: str                 # "shuffle" | "broadcast" | "broadcast_p2p" | "gather"
    participants: int         # N
    message_bytes: int        # wire bytes per p2p message / per-shard payload
    total_bytes: int          # wire bytes leaving each device
    collectives: int          # number of collective ops issued
    logical_bytes: int = 0    # dtype-true payload bytes per message
    row_wire_bytes: int = 0   # packed row width on the wire
    row_logical_bytes: int = 0  # dtype-true row width
    wire: str = "wide"        # "narrow" | "wide"

    @property
    def compression(self) -> float:
        """Logical-to-wire row compression ratio (>= 1 when narrowing wins)."""
        return self.row_logical_bytes / max(1, self.row_wire_bytes)


# ---------------------------------------------------------------------------
# column packing
# ---------------------------------------------------------------------------

def _table_format(t: Table, bounds: Mapping | None, narrow: bool | None,
                  ) -> wi.WireFormat:
    if narrow is None:
        narrow = wi.wire_default() == "narrow"
    return wi.plan_wire_format(
        t.names, {n: np.dtype(t[n].dtype) for n in t.names},
        bounds=bounds, narrow=narrow)


def pack_columns(t: Table, wire: Mapping | None = None,
                 narrow: bool | None = None,
                 ) -> tuple[jax.Array, wi.WireFormat, jax.Array]:
    """Table columns -> ((capacity, words) int32 buffer, format, overflow).

    ``wire`` maps column names to provable ``(lo, hi)`` bounds (planner
    statistics); ``narrow=None`` follows ``REPRO_WIRE``.  Without bounds the
    layout is the legacy full-width format and overflow is statically False.
    """
    fmt = _table_format(t, wire, narrow)
    buf, overflow = wi.pack_table(t, fmt)
    return buf, fmt, overflow


def unpack_columns(buf: jax.Array, fmt: wi.WireFormat) -> dict[str, jax.Array]:
    return wi.unpack_table(buf, fmt)


# ---------------------------------------------------------------------------
# shuffle
# ---------------------------------------------------------------------------

def _dispatch_offsets(dest: jax.Array, num_partitions: int,
                      use_kernel: bool | None = None):
    """Per-row (destination, slot) for capacity-bounded dispatch.

    Returns (slot, counts): ``slot[i]`` is row i's index within its destination
    bucket, ``counts[d]`` the number of rows headed to d.  Rows are ranked by
    a radix-histogram counting rank (``kernels/radix_hist.counting_rank``:
    one fused Pallas pass — per-block histogram, triangular-matmul exclusive
    rank, running-total carry — or the block-streamed jnp oracle) —
    byte-identical slot assignment to the previous stable destination sort,
    with ZERO sorts.  Destinations may include the drop bucket
    ``num_partitions`` (padding / invalid rows); its rows are ranked too but
    excluded from ``counts``.
    """
    if use_kernel is None:
        use_kernel = agg_kernel_default()
    slot, counts = _rh_ops.counting_rank(dest, num_partitions + 1,
                                         use_kernel=use_kernel)
    return slot, counts[:num_partitions]


def shuffle(t: Table, key: jax.Array, axis_name: str, num_partitions: int,
            cap_per_dest: int, packed: bool = True,
            dest_ids: jax.Array | None = None,
            use_kernel: bool | None = None,
            wire: Mapping | None = None, narrow: bool | None = None,
            tamper=None,
            ) -> tuple[Table, jax.Array, jax.Array, jax.Array, ExchangeStats]:
    """Repartition ``t`` by ``hash(key) % N`` across the mesh axis.

    Returns (table, overflowed, corrupt, per-sender recv counts, stats).  The
    output table has capacity ``N * cap_per_dest``; ``overflowed`` is True on
    any device whose bucket exceeded ``cap_per_dest`` (rows are dropped — the
    fault-tolerant runner re-executes with a larger capacity factor, the
    static-shape analogue of re-allocating NCCL receive buffers) OR whose
    narrowed wire lanes saw an out-of-bounds value (re-execution recompiles
    at full width).  In packed mode the per-destination counts ride as a
    header row of the payload buffer, so the whole exchange — size metadata
    included — is ONE ``all_to_all``; each block also carries its integrity
    checksum in the header row, verified on receive into ``corrupt`` (the
    per-column baseline ships unchecked: statically False).  ``tamper``, if
    given, maps the received payload sub-buffer to a corrupted copy (chaos
    injection — applied before verification, so injected flips are caught).
    """
    N, cap = num_partitions, t.capacity
    dest = jnp.where(t.valid_mask(),
                     hash_partition_ids(key, N) if dest_ids is None else dest_ids,
                     N)  # padding rows -> virtual bucket N (dropped)
    slot, counts = _dispatch_offsets(dest, N, use_kernel=use_kernel)
    overflow = jnp.any(counts > cap_per_dest)
    counts_capped = jnp.minimum(counts, cap_per_dest).astype(jnp.int32)

    if packed:
        # rows scatter into per-destination blocks of cap_per_dest+1 rows:
        # row 0 is the counts header (word 0 = sender's row count for that
        # destination), rows 1.. are the payload — one collective total.
        blk = cap_per_dest + 1
        flat_idx = dest * blk + 1 + jnp.minimum(slot, cap_per_dest - 1)
        keep = (slot < cap_per_dest) & (dest < N)
        flat_idx = jnp.where(keep, flat_idx, N * blk)  # OOB -> dropped
        buf, fmt, ov_wire = pack_columns(t, wire=wire, narrow=narrow)
        overflow = overflow | ov_wire
        send = jnp.zeros((N * blk, fmt.words), jnp.int32) \
            .at[flat_idx].set(buf, mode="drop") \
            .reshape(N, blk, fmt.words)
        cmode = wi.header_mode(fmt.words, cap_per_dest)
        csum = jax.vmap(wi.payload_checksum)(send[:, 1:, :])
        send = send.at[:, 0, 0].set(
            wi.encode_header_word0(counts_capped, csum, cmode))
        if cmode == "word":
            send = send.at[:, 0, 1].set(
                wi.encode_checksum_word(counts_capped, csum))
        recv = jax.lax.all_to_all(send, axis_name, 0, 0)
        if tamper is not None:
            recv = recv.at[:, 1:, :].set(tamper(recv[:, 1:, :]))
        recv_counts = wi.decode_header_word0(recv[:, 0, 0], cmode)
        corrupt = jnp.any(jax.vmap(
            lambda h, p: wi.verify_block_checksum(h, p, cmode))(
                recv[:, 0, :], recv[:, 1:, :]))
        cols = unpack_columns(recv[:, 1:, :].reshape(N * cap_per_dest,
                                                     fmt.words), fmt)
        n_coll = 1
        words = fmt.words
        msg_rows = blk
        row_wire, row_logical = fmt.row_wire_bytes, fmt.row_logical_bytes
        wire_tag = "narrow" if fmt.narrow else "wide"
    else:  # paper-faithful: one collective per column + the metadata round
        corrupt = jnp.asarray(False)   # §2.3 baseline ships unchecked
        flat_idx = dest * cap_per_dest + jnp.minimum(slot, cap_per_dest - 1)
        keep = (slot < cap_per_dest) & (dest < N)
        flat_idx = jnp.where(keep, flat_idx, N * cap_per_dest)

        recv_counts = jax.lax.all_to_all(
            counts_capped.reshape(N, 1), axis_name, 0, 0)[:, 0]

        def _exchange(col2d: jax.Array) -> jax.Array:
            send = jnp.zeros((N * cap_per_dest, col2d.shape[1]), col2d.dtype) \
                .at[flat_idx].set(col2d, mode="drop") \
                .reshape(N, cap_per_dest, col2d.shape[1])
            return jax.lax.all_to_all(send, axis_name, 0, 0).reshape(
                N * cap_per_dest, col2d.shape[1])

        cols = {}
        words = 0
        for name in t.names:
            v = t[name]
            if v.dtype == jnp.bool_:
                v = v.astype(jnp.int32)
            part = jax.lax.bitcast_convert_type(v, jnp.int32)
            if part.ndim == 1:
                part = part[:, None]
            got = _exchange(part)
            cols[name] = _unbitcast(got, t[name].dtype)
            words += part.shape[1]
        n_coll = len(t.names) + 1              # + metadata round
        msg_rows = cap_per_dest
        row_wire = words * 4
        row_logical = sum(np.dtype(t[n].dtype).itemsize for n in t.names)
        wire_tag = "wide"

    # received rows are front-packed within each per-sender block; expose them
    # through the deferred-compaction mask instead of paying a full sort here
    valid = (jnp.arange(N * cap_per_dest) % cap_per_dest) < \
        jnp.repeat(recv_counts, cap_per_dest)
    out = Table(cols, recv_counts.sum().astype(jnp.int32), valid)

    msg = msg_rows * words * 4 + (4 if not packed else 0)  # + metadata ints
    stats = ExchangeStats(
        kind="shuffle", participants=N,
        message_bytes=msg,
        total_bytes=N * msg,
        collectives=n_coll,
        logical_bytes=cap_per_dest * row_logical,
        row_wire_bytes=row_wire,
        row_logical_bytes=row_logical,
        wire=wire_tag,
    )
    return out, overflow, corrupt, recv_counts, stats


def _unbitcast(part: jax.Array, dt) -> jax.Array:
    if dt == jnp.bool_:
        return part[:, 0].astype(jnp.bool_)
    if part.shape[1] == 1:
        return jax.lax.bitcast_convert_type(part[:, 0], dt)
    return jax.lax.bitcast_convert_type(part, dt)


# ---------------------------------------------------------------------------
# broadcast
# ---------------------------------------------------------------------------

def broadcast_table(t: Table, axis_name: str, num_partitions: int,
                    packed: bool = True, wire: Mapping | None = None,
                    narrow: bool | None = None, tamper=None,
                    ) -> tuple[Table, jax.Array, jax.Array, ExchangeStats]:
    """Replicate a distributed table on every device (paper Fig. 3).

    all_gather == the ring broadcast of Eq. 1 on the ICI torus: every device
    streams its shard around the ring; N-1 hops of S/N bytes each.  Returns
    (table, overflow, corrupt, stats); in packed mode the per-shard row count
    AND payload checksum ride as a header row of the gathered buffer (ONE
    collective), ``overflow`` reports narrowed-lane range violations (always
    False when wide) and ``corrupt`` reports a per-shard checksum mismatch
    after the optional ``tamper`` hook (per-column mode: statically False).
    """
    # the gathered payload is reconstructed from per-shard counts alone, so the
    # payload must be front-compacted — this is a true contiguity boundary
    t = ensure_compact(t)
    N, cap = num_partitions, t.capacity
    overflow = jnp.asarray(False)
    corrupt = jnp.asarray(False)
    if packed:
        buf, fmt, overflow = pack_columns(t, wire=wire, narrow=narrow)
        cmode = wi.header_mode(fmt.words, cap)
        csum = wi.payload_checksum(buf)
        count32 = t.count.astype(jnp.int32)
        hdr = jnp.zeros((1, fmt.words), jnp.int32) \
            .at[0, 0].set(wi.encode_header_word0(count32, csum, cmode))
        if cmode == "word":
            hdr = hdr.at[0, 1].set(wi.encode_checksum_word(count32, csum))
        recv = jax.lax.all_gather(jnp.concatenate([hdr, buf]), axis_name,
                                  tiled=True).reshape(N, cap + 1, fmt.words)
        if tamper is not None:
            recv = recv.at[:, 1:, :].set(tamper(recv[:, 1:, :]))
        counts = wi.decode_header_word0(recv[:, 0, 0], cmode)
        corrupt = jnp.any(jax.vmap(
            lambda h, p: wi.verify_block_checksum(h, p, cmode))(
                recv[:, 0, :], recv[:, 1:, :]))
        cols = unpack_columns(recv[:, 1:, :].reshape(N * cap, fmt.words), fmt)
        n_coll, words, msg_rows = 1, fmt.words, cap + 1
        row_wire, row_logical = fmt.row_wire_bytes, fmt.row_logical_bytes
        wire_tag = "narrow" if fmt.narrow else "wide"
    else:
        counts = jax.lax.all_gather(t.count.reshape(1), axis_name, tiled=True)
        cols, words = {}, 0
        for name in t.names:
            v = t[name]
            if v.dtype == jnp.bool_:
                v = v.astype(jnp.int32)
            part = jax.lax.bitcast_convert_type(v, jnp.int32)
            if part.ndim == 1:
                part = part[:, None]
            got = jax.lax.all_gather(part, axis_name, tiled=True)
            cols[name] = _unbitcast(got, t[name].dtype)
            words += part.shape[1]
        n_coll, msg_rows = len(t.names) + 1, cap
        row_wire = words * 4
        row_logical = sum(np.dtype(t[n].dtype).itemsize for n in t.names)
        wire_tag = "wide"

    valid = (jnp.arange(N * cap) % cap) < jnp.repeat(counts, cap)
    out = Table(cols, counts.sum().astype(jnp.int32), valid)
    msg = msg_rows * words * 4 + (4 if not packed else 0)
    stats = ExchangeStats(kind="broadcast", participants=N,
                          message_bytes=msg,
                          total_bytes=msg * (N - 1),
                          collectives=n_coll,
                          logical_bytes=cap * row_logical,
                          row_wire_bytes=row_wire,
                          row_logical_bytes=row_logical,
                          wire=wire_tag)
    return out, overflow, corrupt, stats


def broadcast_table_p2p(t: Table, axis_name: str, num_partitions: int,
                        ) -> tuple[Table, ExchangeStats]:
    """§7.1 baseline: emulate broadcast with N-1 p2p ring forwards of the FULL
    buffer — each shard transits every link once per hop instead of being
    pipelined, duplicating inter-node traffic exactly as the paper describes.
    Shows up in HLO as N-1 collective-permutes of the full shard.  Stays on
    the WIDE wire format deliberately: it is the paper's unoptimized baseline."""
    t = ensure_compact(t)
    N, cap = num_partitions, t.capacity
    buf, fmt, _ = pack_columns(t, narrow=False)
    counts = jax.lax.all_gather(t.count.reshape(1), axis_name, tiled=True)
    parts = [buf]
    cur = buf
    perm = [(i, (i + 1) % N) for i in range(N)]
    for _ in range(N - 1):
        cur = jax.lax.ppermute(cur, axis_name, perm)
        parts.append(cur)
    me = jax.lax.axis_index(axis_name)
    # parts[s] came from device (me - s) % N; reorder to device order 0..N-1
    recv = jnp.stack(parts)                       # (N, cap, words)
    src = (me - jnp.arange(N)) % N
    order = jnp.zeros(N, jnp.int32).at[src].set(jnp.arange(N, dtype=jnp.int32))
    recv = recv[order].reshape(N * cap, -1)
    cols = unpack_columns(recv, fmt)
    valid = (jnp.arange(N * cap) % cap) < jnp.repeat(counts, cap)
    out = Table(cols, counts.sum().astype(jnp.int32), valid)
    stats = ExchangeStats(kind="broadcast_p2p", participants=N,
                          message_bytes=cap * fmt.words * 4 + 4,
                          total_bytes=(cap * fmt.words * 4 + 4) * (N - 1),
                          collectives=N,  # N-1 permutes + counts gather
                          logical_bytes=cap * fmt.row_logical_bytes,
                          row_wire_bytes=fmt.row_wire_bytes,
                          row_logical_bytes=fmt.row_logical_bytes,
                          wire="wide")
    return out, stats


# ---------------------------------------------------------------------------
# reductions
# ---------------------------------------------------------------------------

def partial_to_global(partials: dict[str, jax.Array], ops: dict[str, str],
                      axis_name: str) -> dict[str, jax.Array]:
    """ncclAllReduce equivalent for final scalar aggregation."""
    out = {}
    for k, v in partials.items():
        op = ops[k]
        if op in ("sum", "count"):
            out[k] = jax.lax.psum(v, axis_name)
        elif op == "min":
            out[k] = jax.lax.pmin(v, axis_name)
        elif op == "max":
            out[k] = jax.lax.pmax(v, axis_name)
        else:
            raise ValueError(op)
    return out

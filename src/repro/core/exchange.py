"""Data-exchange operators on JAX collectives — the paper's core contribution.

GPU/NCCL -> TPU/XLA mapping (DESIGN.md §2):

  shuffle    NCCL N^2 ncclSend/Recv (variable sizes)  ->  capacity-bounded
             ``jax.lax.all_to_all`` with per-destination fixed-size row buffers
             and validity counts (the MoE-dispatch idiom).  The pre-exchange
             size-metadata round becomes an all_to_all of per-destination
             counts, used for valid-row reconstruction, skew statistics, and
             overflow-triggered re-execution.
  broadcast  ncclBroadcast one-to-all ring             ->  ``jax.lax.all_gather``
             (XLA lowers to the ICI ring — exactly the paper's Eq. 1 model).
             A deliberately-naive p2p ring variant (``broadcast_table_p2p``)
             reproduces §7.1 / Figure 19.
  allreduce  ncclAllReduce                             ->  ``jax.lax.psum`` etc.

Columns are exchanged either one at a time (paper-faithful, §2.3 "we exchange
one column at a time") or packed into a single 32-bit-word buffer so the whole
table moves in ONE collective (beyond-paper optimization; the paper's own
Hockney model §3.6 predicts the win for small messages).

Deferred compaction: exchange OUTPUTS are masked tables (received rows are
front-packed per sender block; the validity mask exposes them without a sort).
``broadcast_table`` INPUTS are compacted first — the gathered payload is
reconstructed from per-shard counts alone, a true contiguity boundary;
``shuffle`` inputs may stay masked (invalid rows route to a dropped bucket).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .table import Table
from .relational import agg_kernel_default, ensure_compact, hash_partition_ids
# imported at module scope (not lazily inside traced code): the kernel module
# materializes constants at import time, which must not happen under a trace
from repro.kernels.radix_hist import ops as _rh_ops

__all__ = [
    "ExchangeStats",
    "pack_columns",
    "unpack_columns",
    "shuffle",
    "broadcast_table",
    "broadcast_table_p2p",
    "partial_to_global",
]


@dataclasses.dataclass
class ExchangeStats:
    """Static (trace-time) descriptor of one exchange — feeds the perf models."""
    kind: str                 # "shuffle" | "broadcast" | "broadcast_p2p" | "gather"
    participants: int         # N
    message_bytes: int        # per p2p message (shuffle) / per-shard payload (bcast)
    total_bytes: int          # bytes leaving each device
    collectives: int          # number of collective ops issued


# ---------------------------------------------------------------------------
# column packing
# ---------------------------------------------------------------------------

def _words(dt) -> int:
    return max(1, np.dtype(dt).itemsize // 4)


def pack_columns(t: Table) -> tuple[jax.Array, list[tuple[str, np.dtype, int]]]:
    """Table columns -> (capacity, total_words) int32 buffer + unpack spec."""
    bufs, spec = [], []
    for name in t.names:
        v = t[name]
        if v.dtype == jnp.bool_:
            v = v.astype(jnp.int32)
        w = _words(v.dtype)
        part = jax.lax.bitcast_convert_type(v, jnp.int32)
        if part.ndim == 1:
            part = part[:, None]
        bufs.append(part)
        spec.append((name, np.dtype(t[name].dtype), w))
    return jnp.concatenate(bufs, axis=1), spec


def unpack_columns(buf: jax.Array, spec) -> dict[str, jax.Array]:
    cols, off = {}, 0
    for name, dt, w in spec:
        part = buf[:, off:off + w]
        if dt == np.bool_:
            cols[name] = part[:, 0].astype(jnp.bool_)
        elif w == 1:
            cols[name] = jax.lax.bitcast_convert_type(part[:, 0], dt)
        else:
            cols[name] = jax.lax.bitcast_convert_type(part, dt)
        off += w
    return cols


# ---------------------------------------------------------------------------
# shuffle
# ---------------------------------------------------------------------------

def _dispatch_offsets(dest: jax.Array, num_partitions: int,
                      use_kernel: bool | None = None):
    """Per-row (destination, slot) for capacity-bounded dispatch.

    Returns (slot, counts): ``slot[i]`` is row i's index within its destination
    bucket, ``counts[d]`` the number of rows headed to d.  Rows are ranked by
    a radix-histogram counting rank (``kernels/radix_hist.counting_rank``:
    per-block histogram + prefix sum + per-row offset) — byte-identical slot
    assignment to the previous stable destination sort, with ZERO sorts.
    Destinations may include the drop bucket ``num_partitions`` (padding /
    invalid rows); its rows are ranked too but excluded from ``counts``.
    """
    if use_kernel is None:
        use_kernel = agg_kernel_default()
    slot, counts = _rh_ops.counting_rank(dest, num_partitions + 1,
                                         use_kernel=use_kernel)
    return slot, counts[:num_partitions]


def shuffle(t: Table, key: jax.Array, axis_name: str, num_partitions: int,
            cap_per_dest: int, packed: bool = True,
            dest_ids: jax.Array | None = None,
            use_kernel: bool | None = None,
            ) -> tuple[Table, jax.Array, jax.Array, ExchangeStats]:
    """Repartition ``t`` by ``hash(key) % N`` across the mesh axis.

    Returns (table, overflowed, per-sender recv counts, stats).  The output
    table has capacity ``N * cap_per_dest``; ``overflowed`` is True on any
    device whose bucket exceeded ``cap_per_dest`` (rows are dropped — the
    fault-tolerant runner re-executes with a larger capacity factor, the
    static-shape analogue of re-allocating NCCL receive buffers).
    """
    N, cap = num_partitions, t.capacity
    dest = jnp.where(t.valid_mask(),
                     hash_partition_ids(key, N) if dest_ids is None else dest_ids,
                     N)  # padding rows -> virtual bucket N (dropped)
    slot, counts = _dispatch_offsets(dest, N, use_kernel=use_kernel)
    overflow = jnp.any(counts > cap_per_dest)

    flat_idx = dest * cap_per_dest + jnp.minimum(slot, cap_per_dest - 1)
    keep = (slot < cap_per_dest) & (dest < N)
    flat_idx = jnp.where(keep, flat_idx, N * cap_per_dest)  # OOB -> dropped

    # metadata round: who sends me how much (the paper's size exchange)
    recv_counts = jax.lax.all_to_all(
        jnp.minimum(counts, cap_per_dest).reshape(N, 1), axis_name, 0, 0)[:, 0]

    def _exchange(col2d: jax.Array) -> jax.Array:
        send = jnp.zeros((N * cap_per_dest, col2d.shape[1]), col2d.dtype) \
            .at[flat_idx].set(col2d, mode="drop") \
            .reshape(N, cap_per_dest, col2d.shape[1])
        return jax.lax.all_to_all(send, axis_name, 0, 0).reshape(
            N * cap_per_dest, col2d.shape[1])

    if packed:
        buf, spec = pack_columns(t)
        recv = _exchange(buf)
        cols = unpack_columns(recv, spec)
        n_coll = 1
        words = buf.shape[1]
    else:  # paper-faithful: one collective per column
        cols = {}
        words = 0
        for name in t.names:
            v = t[name]
            if v.dtype == jnp.bool_:
                v = v.astype(jnp.int32)
            part = jax.lax.bitcast_convert_type(v, jnp.int32)
            if part.ndim == 1:
                part = part[:, None]
            got = _exchange(part)
            cols[name] = _unbitcast(got, t[name].dtype)
            words += part.shape[1]
        n_coll = len(t.names)

    # received rows are front-packed within each per-sender block; expose them
    # through the deferred-compaction mask instead of paying a full sort here
    valid = (jnp.arange(N * cap_per_dest) % cap_per_dest) < \
        jnp.repeat(recv_counts, cap_per_dest)
    out = Table(cols, recv_counts.sum().astype(jnp.int32), valid)

    stats = ExchangeStats(
        kind="shuffle", participants=N,
        message_bytes=cap_per_dest * words * 4,
        total_bytes=N * cap_per_dest * words * 4,
        collectives=n_coll + 1,  # +1 metadata round
    )
    return out, overflow, recv_counts, stats


def _unbitcast(part: jax.Array, dt) -> jax.Array:
    if dt == jnp.bool_:
        return part[:, 0].astype(jnp.bool_)
    if part.shape[1] == 1:
        return jax.lax.bitcast_convert_type(part[:, 0], dt)
    return jax.lax.bitcast_convert_type(part, dt)


# ---------------------------------------------------------------------------
# broadcast
# ---------------------------------------------------------------------------

def broadcast_table(t: Table, axis_name: str, num_partitions: int,
                    packed: bool = True) -> tuple[Table, ExchangeStats]:
    """Replicate a distributed table on every device (paper Fig. 3).

    all_gather == the ring broadcast of Eq. 1 on the ICI torus: every device
    streams its shard around the ring; N-1 hops of S/N bytes each.
    """
    # the gathered payload is reconstructed from per-shard counts alone, so the
    # payload must be front-compacted — this is a true contiguity boundary
    t = ensure_compact(t)
    N, cap = num_partitions, t.capacity
    counts = jax.lax.all_gather(t.count.reshape(1), axis_name, tiled=True)
    if packed:
        buf, spec = pack_columns(t)
        recv = jax.lax.all_gather(buf, axis_name, tiled=True)
        cols = unpack_columns(recv, spec)
        n_coll, words = 1, buf.shape[1]
    else:
        cols, words = {}, 0
        for name in t.names:
            v = t[name]
            if v.dtype == jnp.bool_:
                v = v.astype(jnp.int32)
            part = jax.lax.bitcast_convert_type(v, jnp.int32)
            if part.ndim == 1:
                part = part[:, None]
            got = jax.lax.all_gather(part, axis_name, tiled=True)
            cols[name] = _unbitcast(got, t[name].dtype)
            words += part.shape[1]
        n_coll = len(t.names)

    valid = (jnp.arange(N * cap) % cap) < jnp.repeat(counts, cap)
    out = Table(cols, counts.sum().astype(jnp.int32), valid)
    stats = ExchangeStats(kind="broadcast", participants=N,
                          message_bytes=cap * words * 4,
                          total_bytes=cap * words * 4 * (N - 1),
                          collectives=n_coll + 1)
    return out, stats


def broadcast_table_p2p(t: Table, axis_name: str, num_partitions: int,
                        ) -> tuple[Table, ExchangeStats]:
    """§7.1 baseline: emulate broadcast with N-1 p2p ring forwards of the FULL
    buffer — each shard transits every link once per hop instead of being
    pipelined, duplicating inter-node traffic exactly as the paper describes.
    Shows up in HLO as N-1 collective-permutes of the full shard."""
    t = ensure_compact(t)
    N, cap = num_partitions, t.capacity
    buf, spec = pack_columns(t)
    counts = jax.lax.all_gather(t.count.reshape(1), axis_name, tiled=True)
    parts = [buf]
    cnt_parts = [t.count.reshape(1)]
    cur = buf
    perm = [(i, (i + 1) % N) for i in range(N)]
    for _ in range(N - 1):
        cur = jax.lax.ppermute(cur, axis_name, perm)
        parts.append(cur)
    me = jax.lax.axis_index(axis_name)
    # parts[s] came from device (me - s) % N; reorder to device order 0..N-1
    recv = jnp.stack(parts)                       # (N, cap, words)
    src = (me - jnp.arange(N)) % N
    order = jnp.zeros(N, jnp.int32).at[src].set(jnp.arange(N, dtype=jnp.int32))
    recv = recv[order].reshape(N * cap, -1)
    cols = unpack_columns(recv, spec)
    valid = (jnp.arange(N * cap) % cap) < jnp.repeat(counts, cap)
    out = Table(cols, counts.sum().astype(jnp.int32), valid)
    stats = ExchangeStats(kind="broadcast_p2p", participants=N,
                          message_bytes=cap * buf.shape[1] * 4,
                          total_bytes=cap * buf.shape[1] * 4 * (N - 1),
                          collectives=N)  # N-1 permutes + counts gather
    return out, stats


# ---------------------------------------------------------------------------
# reductions
# ---------------------------------------------------------------------------

def partial_to_global(partials: dict[str, jax.Array], ops: dict[str, str],
                      axis_name: str) -> dict[str, jax.Array]:
    """ncclAllReduce equivalent for final scalar aggregation."""
    out = {}
    for k, v in partials.items():
        op = ops[k]
        if op in ("sum", "count"):
            out[k] = jax.lax.psum(v, axis_name)
        elif op == "min":
            out[k] = jax.lax.pmin(v, axis_name)
        elif op == "max":
            out[k] = jax.lax.pmax(v, axis_name)
        else:
            raise ValueError(op)
    return out

"""Version-compat shims for jax APIs that moved between releases.

The repo targets both current jax (``jax.shard_map``, ``jax.make_mesh`` with
``axis_types=jax.sharding.AxisType``) and the 0.4.x line (no ``AxisType``,
``shard_map`` under ``jax.experimental``, ``check_rep`` instead of
``check_vma``).  Everything mesh/shard_map-shaped goes through here so no
call site hard-codes one API generation.
"""
from __future__ import annotations

import jax

__all__ = ["make_mesh", "shard_map"]


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with explicit Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=(axis_type.Auto,) * len(axis_names))
        except TypeError:
            pass  # make_mesh predates the axis_types kwarg
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(axis_shapes, axis_names)
    # pre-0.4.35: no jax.make_mesh at all
    from jax.experimental import mesh_utils
    devices = mesh_utils.create_device_mesh(tuple(axis_shapes))
    return jax.sharding.Mesh(devices, tuple(axis_names))


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` with per-shard replication checking disabled."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
        except TypeError:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)

"""Analytical performance models for data exchange (paper §3).

Implements, verbatim:
  Eq. 1  ring-broadcast throughput      Thpt_b = N/(N-1) * min(Bn, Bg)
  Eq. 2  shuffle throughput             Thpt_s = V^2/(V-1) * Bn          (V>1)
  Eq. 3  broadcast-vs-shuffle           |S|/|R| > (N-1)/(N-k) * V - 1
  §3.5   skew model                     T = max_i(S_i, R_i) / Bn
  §3.6   Hockney small-message model    B(m) = m / (L + c*m)
  §6.3   projections I/II (+ compute-scaling fits)

Cluster parameterizations cover the paper's three GPU clusters (Table 3) and
the TPU v5e target of this reproduction — on a TPU torus the roles map as
  Bg := aggregate intra-pod ICI bandwidth per chip, Bn := inter-pod DCI share.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "ClusterSpec", "CLUSTERS", "Hockney", "hockney_from_env",
    "broadcast_throughput", "shuffle_throughput", "broadcast_beats_shuffle",
    "shuffle_time_skewed", "fit_hockney", "exchange_time",
    "exchange_time_from_stats", "wire_savings", "project_workload",
]


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Per-machine topology (paper Table 3 + our TPU target).

    ``n_devices`` is the LIVE participant count.  It defaults to None, which
    means "fully populated": every closed form below then derives N = k*V
    from the boot-time shape.  After a mid-query device loss the runner pins
    the surviving width with :meth:`with_devices`, and all Hockney / Eq.1-3
    pricing uses N' instead of the boot-time N."""
    name: str
    k: int            # accelerators per machine / chips per pod
    bg: float         # intra-machine per-device unidirectional bw, bytes/s
    bn: float         # inter-machine per-machine unidirectional bw, bytes/s
    hbm: float        # bytes per device
    peak_flops: float = 0.0
    hbm_bw: float = 0.0
    price_hr: float = 0.0
    n_devices: int | None = None   # live width; None = boot-time k*V

    def with_devices(self, n: int) -> "ClusterSpec":
        """Pin the live device count (e.g. after a topology shrink)."""
        if n < 1:
            raise ValueError(f"n_devices must be >= 1, got {n}")
        return dataclasses.replace(self, n_devices=int(n))

    def live_n(self, v: int) -> int:
        """Participant count for a V-machine job: N' when pinned, else k*V."""
        return self.n_devices if self.n_devices is not None else self.k * v


GBs = 1e9
CLUSTERS = {
    # paper Table 3
    "a100_eth": ClusterSpec("a100_eth", 8, 300 * GBs, 50 / 8 * GBs, 80e9,
                            312e12, 2.0e12, 32.77),
    "h100_eth": ClusterSpec("h100_eth", 8, 450 * GBs, 100 / 8 * GBs, 79.6e9,
                            989e12, 3.35e12, 98.32),
    "h100_ib": ClusterSpec("h100_ib", 8, 450 * GBs, 8 * 400 / 8 * GBs, 79.6e9,
                           989e12, 3.35e12, 98.32),
    "mi300x_ib": ClusterSpec("mi300x_ib", 8, 448 * GBs, 8 * 400 / 8 * GBs,
                             191.5e9, 1307e12, 5.3e12, 63.6),
    # our deployment target: v5e pod = 16x16 torus; per-chip ICI ~4 links x
    # 50 GB/s is Bg; inter-pod DCI modeled at 25 GB/s per chip share.
    "tpu_v5e": ClusterSpec("tpu_v5e", 256, 4 * 50 * GBs, 256 * 25 * GBs,
                           16e9, 197e12, 819e9, 0.0),
}


# ---------------------------------------------------------------------------
# §3.2-3.4 closed forms
# ---------------------------------------------------------------------------

def broadcast_throughput(spec: ClusterSpec, v: int) -> float:
    """Eq. 1.  Total bytes / time for an all-to-all-nodes table replication."""
    n = spec.live_n(v)
    if v == 1:
        return n / (n - 1) * spec.bg if n > 1 else float("inf")
    return n / (n - 1) * min(spec.bn / spec.k, spec.bg)


def shuffle_throughput(spec: ClusterSpec, v: int) -> float:
    """Eq. 2 (per-GPU network share Bn/k folded in, as in the paper)."""
    n = spec.live_n(v)
    if v == 1:
        return n * n / (n - 1) * spec.bg if n > 1 else float("inf")
    return v * v / (v - 1) * spec.bn


def broadcast_beats_shuffle(spec: ClusterSpec, v: int, size_r: float,
                            size_s: float) -> bool:
    """Eq. 3: broadcast table R vs shuffling R and S both."""
    n = spec.live_n(v)
    if n == spec.k:   # V=1: |S|/|R| > N-1
        return size_s / size_r > n - 1
    return size_s / size_r > (n - 1) / (n - spec.k) * v - 1


# ---------------------------------------------------------------------------
# §3.5 skew
# ---------------------------------------------------------------------------

def shuffle_time_skewed(send_bytes_per_node: np.ndarray,
                        recv_bytes_per_node: np.ndarray, bn: float) -> float:
    """T = max(S_0..S_V-1, R_0..R_V-1) / Bn — the PXN observation: skew is
    visible per NODE, not per device."""
    return float(max(np.max(send_bytes_per_node), np.max(recv_bytes_per_node))
                 / bn)


def node_send_recv(message_matrix: np.ndarray, k: int):
    """(N, N) per-device message bytes -> per-node off-node send/recv totals."""
    n = message_matrix.shape[0]
    v = n // k
    m = message_matrix.reshape(v, k, v, k)
    send = np.zeros(v)
    recv = np.zeros(v)
    for i in range(v):
        send[i] = m[i].sum() - m[i, :, i, :].sum()
        recv[i] = m[:, :, i, :].sum() - m[i, :, i, :].sum()
    return send, recv


# ---------------------------------------------------------------------------
# §3.6 Hockney
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Hockney:
    """t(m) = L + c*m;  B(m) = m / (L + c*m)."""
    latency: float     # seconds
    inv_bw: float      # seconds per byte

    def bandwidth(self, m: float) -> float:
        return m / (self.latency + self.inv_bw * m)

    def time(self, m: float) -> float:
        return self.latency + self.inv_bw * m

    def latency_bound(self, m: float) -> bool:
        """True when a message of ``m`` bytes sits below the half-bandwidth
        point m* = L/c: the transfer term c*m is no larger than the constant
        L, so shrinking the payload cannot materially shorten the exchange."""
        return self.inv_bw * m <= self.latency


def hockney_from_env(env: str | None = None) -> Hockney | None:
    """Hockney link model from ``REPRO_HOCKNEY="<latency_s>,<inv_bw_s/B>"``.

    Unset/empty means no model (returns None).  A trailing third field is
    permitted and ignored here (:mod:`repro.core.wire` reads it as the
    nominal per-message row count for its packing-skip policy)."""
    import os
    spec = os.environ.get("REPRO_HOCKNEY", "") if env is None else env
    parts = [p.strip() for p in spec.split(",") if p.strip()]
    if len(parts) < 2:
        return None
    return Hockney(latency=float(parts[0]), inv_bw=float(parts[1]))


def fit_hockney(msg_bytes: np.ndarray, times_s: np.ndarray) -> Hockney:
    """Least-squares fit of t = L + c*m (the paper fits V=2 microbenchmarks)."""
    a = np.stack([np.ones_like(msg_bytes, dtype=np.float64),
                  msg_bytes.astype(np.float64)], axis=1)
    (l, c), *_ = np.linalg.lstsq(a, times_s.astype(np.float64), rcond=None)
    return Hockney(latency=max(l, 0.0), inv_bw=max(c, 1e-18))


# ---------------------------------------------------------------------------
# exchange-time predictions (feed the roofline + projections)
# ---------------------------------------------------------------------------

def exchange_time(kind: str, spec: ClusterSpec, v: int, total_bytes: float,
                  hockney_n: Hockney | None = None,
                  hockney_g: Hockney | None = None) -> float:
    """Predicted wall time of one exchange of a table of ``total_bytes``.

    Projection I ignores message sizes (peak Bn/Bg); Projection II passes the
    Hockney fits so B(m) reflects the actual per-message size (§6.3)."""
    n = spec.live_n(v)
    if kind == "broadcast":
        m = total_bytes / n                     # ring step payload
        if hockney_n is not None and v > 1:
            bw = min(hockney_n.bandwidth(m / spec.k), hockney_g.bandwidth(m)
                     if hockney_g else float("inf"))
            return (n - 1) * m / max(bw, 1e-9)
        return total_bytes / broadcast_throughput(spec, v)
    if kind == "shuffle":
        m = total_bytes / (n * n)               # p2p message size
        if hockney_n is not None and v > 1:
            bw = hockney_n.bandwidth(m)
            eff = v * v / (v - 1) * bw * spec.k  # scale Eq.2 by fitted per-msg bw
            return total_bytes / max(eff, 1e-9)
        return total_bytes / shuffle_throughput(spec, v)
    if kind in ("gather", "broadcast_p2p"):
        # p2p emulation: each device sends its shard to all N-1 peers
        per_dev = total_bytes / n
        if v == 1:
            return (n - 1) * per_dev / spec.bg
        return (n - 1) * per_dev / (spec.bn / spec.k)
    raise ValueError(kind)


def exchange_time_from_stats(stats, spec: ClusterSpec, v: int = 1,
                             n_devices: int | None = None,
                             hockney_n: Hockney | None = None,
                             hockney_g: Hockney | None = None) -> float:
    """Predicted wall time of one logged exchange, from what ACTUALLY moves.

    ``stats`` is an :class:`repro.core.exchange.ExchangeStats`: its
    ``message_bytes`` are wire bytes — the packed words including the fused
    counts header, at the narrow lane widths when the planner's statistics
    narrowed the payload — so the Hockney model (§3.6) prices the compressed
    message size, not the logical table size.  The narrow-vs-wide delta is
    ``wire_savings(stats)``: the model's predicted benefit of shipping at
    inferred bit widths.  Explicit ``n_devices`` wins; a pinned
    ``spec.n_devices`` (degraded mesh) wins over the logged participant
    count, which reflects the width the stats were CAPTURED at.
    """
    n = n_devices or spec.n_devices or stats.participants
    if stats.kind.startswith("broadcast") or stats.kind == "gather":
        total = stats.message_bytes * n          # per-shard payload x N
        return exchange_time("broadcast", spec, v, total, hockney_n, hockney_g)
    total = stats.message_bytes * n * n          # p2p msg = S/N^2
    return exchange_time("shuffle", spec, v, total, hockney_n, hockney_g)


def wire_savings(stats) -> float:
    """Fraction of logical payload bytes the wire format did NOT move
    (0.0 = full width; e.g. 0.6 = 60% fewer bytes per row than dtype-true)."""
    if stats.row_logical_bytes <= 0:
        return 0.0
    return max(0.0, 1.0 - stats.row_wire_bytes / stats.row_logical_bytes)


def project_workload(spec: ClusterSpec, v_range, compute_v1: float,
                     exchanges: list[tuple[str, float]],
                     hockney_n: Hockney | None = None,
                     hockney_g: Hockney | None = None,
                     compute_power: float = -1.0) -> dict[int, dict]:
    """§6.3 'best-effort' projection from V=1 measurements.

    compute scales as a*V^b (b=-1 is the perfect-linear 'best-effort' form);
    exchange terms come from the models above.  Returns per-V breakdowns."""
    out = {}
    for v in v_range:
        comp = compute_v1 * (v ** compute_power)
        sh = sum(exchange_time("shuffle", spec, v, b, hockney_n, hockney_g)
                 for kind, b in exchanges if kind == "shuffle")
        bc = sum(exchange_time("broadcast", spec, v, b, hockney_n, hockney_g)
                 for kind, b in exchanges if kind == "broadcast")
        out[v] = {"compute": comp, "shuffle": sh, "broadcast": bc,
                  "total": comp + sh + bc}
    return out

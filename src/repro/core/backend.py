"""Execution backends for tensor query plans.

Queries are written ONCE against the :class:`Context` API and run on three
engines:

  * :class:`RefContext`   — NumPy oracle / CPU baseline (exact shapes).
  * :class:`LocalContext` — single-device JAX, static shapes, no exchanges.
  * :class:`DistContext`  — SPMD under ``shard_map``; exchange operators are
    real mesh collectives (the paper's distributed TQP model §2.4: every
    process runs the same tensor program on its partition, no driver).

Exchange placement is explicit in query code (``ctx.shuffle`` / ``ctx.broadcast``
/ ``exchange=`` on group_by) — mirroring the paper's manually-optimized tensor
programs (§4.4) — and is counted identically on every backend so plan statistics
(paper Table 4) can be produced without a cluster.

``join_method`` selects the per-device join engine on the JAX backends:
``"sorted"`` (searchsorted probe, always available) or ``"hash"`` (Pallas
bucket-table probe); both paths are byte-identical (tests/test_sort_tax.py)
and share the per-plan build-side cache on ``_BaseContext``.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import compat
from . import exchange as ex
from . import reference as ref
from . import relational as rel
from . import wire as wi
from .table import Database, Table, from_numpy, to_numpy

__all__ = [
    "PlanStats", "RefContext", "LocalContext", "DistContext",
    "run_reference", "run_local", "run_distributed",
    "partition_database", "hash_partition_np",
]

AggSpec = Sequence[tuple]  # (out_name, op, col | callable | None)

_YEAR_LUT = None


def _year_lut() -> np.ndarray:
    """epoch-day -> calendar year, for days 1970-01-01 .. 2005-12-31."""
    global _YEAR_LUT
    if _YEAR_LUT is None:
        d = np.arange(0, 13150).astype("timedelta64[D]") + np.datetime64("1970-01-01")
        _YEAR_LUT = d.astype("datetime64[Y]").astype(np.int64) + 1970
    return _YEAR_LUT


@dataclasses.dataclass
class PlanStats:
    shuffles: int = 0
    broadcasts: int = 0
    final_gathers: int = 0
    allreduces: int = 0
    overflow_checks: int = 0
    log: list = dataclasses.field(default_factory=list)

    def counts(self):
        return {"shuffles": self.shuffles, "broadcasts": self.broadcasts,
                "final_gathers": self.final_gathers, "allreduces": self.allreduces}


def _eval_aggs(ctx, t, aggs):
    """Materialize callable agg expressions into arrays."""
    out = []
    for name, op, v in aggs:
        if callable(v):
            v = v(t)
        out.append((name, op, v))
    return out


def _expand_avg(aggs):
    """avg -> (sum, count) pairs + postprocessing recipe."""
    expanded, post = [], []
    for name, op, v in aggs:
        if op == "avg":
            expanded.append((f"__{name}_s", "sum", v))
            expanded.append((f"__{name}_c", "count", None))
            post.append(name)
        else:
            expanded.append((name, op, v))
    return expanded, post


_MERGE = {"sum": "sum", "count": "sum", "min": "min", "max": "max"}


class _BaseContext:
    """Shared bookkeeping + derived helpers.

    ``_join_cache`` is the per-query build-side cache: a (build table, key)
    pair is indexed (sorted or bucket-hashed) at most once per plan, however
    many joins probe it — dimension tables stop paying one build sort per
    join.  The cache holds a strong reference to the build table so ``id()``
    keys stay unique for the context's (= one plan's) lifetime.
    """

    join_method = "sorted"  # "sorted" (searchsorted) | "hash" (Pallas probe)

    def __init__(self, db: Database, capacity_factor: float = 2.0,
                 wire_format: str | None = None):
        self.db = db
        self.dicts = db.dicts
        self.stats = PlanStats()
        self.capacity_factor = capacity_factor
        self.wire_format = wire_format or wi.wire_default()
        self._join_cache: dict[tuple, tuple] = {}

    @property
    def wire_narrow(self) -> bool:
        return self.wire_format == "narrow"

    def _wire_entry(self, kind: str, t, wire, narrow: bool | None = None,
                    ) -> ex.ExchangeStats:
        """Trace-time per-row wire descriptor of an exchange payload.

        Every backend logs one of these per exchange — the non-distributed
        backends with the per-row fields only — so the IR-derived static
        report (``planner.static_wire_stats``) can be asserted equal to
        runtime stats on all three engines."""
        names = sorted(t) if isinstance(t, dict) else t.names
        dtypes = {n: np.dtype(t[n].dtype) for n in names}
        if narrow is None:
            narrow = self.wire_narrow
        fmt = wi.plan_wire_format(names, dtypes, bounds=wire, narrow=narrow)
        return ex.ExchangeStats(
            kind=kind, participants=1, message_bytes=0, total_bytes=0,
            collectives=0, row_wire_bytes=fmt.row_wire_bytes,
            row_logical_bytes=fmt.row_logical_bytes,
            wire="narrow" if fmt.narrow else "wide")

    def bucket_cap(self) -> int:
        """Per-bucket capacity of the Pallas hash-join table, scaled by the
        runner's capacity factor: the default factor (2.0) gives the historic
        cap of 16, and the fault runner's escalation (factor *= 2 on
        overflow) genuinely enlarges the buckets on re-execution instead of
        retrying the same doomed layout (ROADMAP open item)."""
        return max(2, int(round(8 * self.capacity_factor)))

    # -- dictionary-encoded string predicates (TQP-style) ------------------
    def str_lookup(self, col: str, pred: Callable[[np.ndarray], np.ndarray]):
        """Host-evaluated predicate over dictionary -> per-row boolean."""
        return self.db.dict_mask(col, pred)

    def like(self, t, col: str, *substrings: str):
        """col LIKE '%a%b%' -> ordered substring match on the dictionary."""
        def pred(d):
            m = np.ones(len(d), dtype=bool)
            for i, s in enumerate(d):
                pos = 0
                ok = True
                for sub in substrings:
                    j = s.find(sub, pos)
                    if j < 0:
                        ok = False
                        break
                    pos = j + len(sub)
                m[i] = ok
            return m
        lut = self.xp.asarray(self.str_lookup(col, pred))
        return lut[t[col]]

    def rename(self, t, mapping: dict):
        if isinstance(t, dict):
            return {mapping.get(k, k): v for k, v in t.items()}
        return t.rename(mapping)

    def starts_with(self, t, col: str, prefix: str):
        lut = self.xp.asarray(self.str_lookup(
            col, lambda d: np.char.startswith(d.astype(str), prefix)))
        return lut[t[col]]

    def ends_with(self, t, col: str, suffix: str):
        lut = self.xp.asarray(self.str_lookup(
            col, lambda d: np.char.endswith(d.astype(str), suffix)))
        return lut[t[col]]

    def alpha_rank(self, t, col: str):
        """Alphabetical rank of a dictionary-encoded column (for ORDER BY on
        strings: code order != lexicographic order)."""
        d = self.dicts[col]
        rank = np.empty(len(d), dtype=np.int64)
        rank[np.argsort(d)] = np.arange(len(d))
        return self.xp.asarray(rank)[t[col]]

    def dict_bits(self, col: str) -> int:
        """Provable bit width of a dictionary-encoded column: codes lie in
        ``[0, len(dict))``, so ``ceil(log2(len(dict)))`` bits bound the domain
        — the host-side fact plans cite in ``key_bits=`` to unlock the
        sortless direct-addressing group-by (see queries/__init__.py)."""
        return max(1, math.ceil(math.log2(max(2, len(self.dicts[col])))))

    _YEAR_BASE = 0  # epoch day 0

    def year(self, t_or_col, col: str | None = None):
        """Extract calendar year from an epoch-days column via a host LUT."""
        v = t_or_col[col] if col is not None else t_or_col
        lut = _year_lut()
        return self.xp.asarray(lut)[v]

    def isin(self, t, col: str, values: Sequence[str]):
        codes = self.db.codes(col, values)
        x = t[col]
        m = self.xp.zeros(x.shape, dtype=bool)
        for c in codes:
            m = m | (x == c)
        return m

    def eq(self, t, col: str, value: str):
        return t[col] == self.db.code(col, value)

    # -- exchange bookkeeping ----------------------------------------------
    def _count(self, kind: str, stats=None):
        if kind == "shuffle":
            self.stats.shuffles += 1
        elif kind in ("broadcast", "broadcast_p2p"):
            self.stats.broadcasts += 1
        elif kind == "gather":
            self.stats.final_gathers += 1
        elif kind == "allreduce":
            self.stats.allreduces += 1
        if stats is not None:
            self.stats.log.append(stats)

    # -- chaos fault injection ---------------------------------------------
    # a ChaosInjector (distributed/chaos.py), attached by the run_* drivers;
    # None (the default) makes every cut point a no-op
    chaos = None

    def _chaos_point(self, cut: str, tamperable: bool = False):
        """Named failure-domain cut point (scan / exchange / group_by /
        finalize).  Asks the armed injector for a fault due here this
        attempt: TRANSIENT/DETERMINISTIC faults raise (aborting the trace),
        STRAGGLER sleeps, OVERFLOW ORs the traced ``ctx.overflow`` flag, and
        CORRUPT returns a payload tamper callable when the call site can
        route it into a checksummed exchange (``tamperable``) — otherwise it
        ORs ``ctx.corrupt`` directly, simulating the detection."""
        if self.chaos is None:
            return None
        return self.chaos.fire(cut, self, tamperable=tamperable)


# ===========================================================================
# NumPy reference backend
# ===========================================================================

class RefContext(_BaseContext):
    xp = np
    distributed = False

    def scan(self, name):
        return dict(self.db.tables[name])  # RTable = dict of np arrays

    def filter(self, t, mask):
        return ref.filter_rows(t, np.asarray(mask))

    def with_col(self, t, **exprs):
        out = dict(t)
        for k, fn in exprs.items():
            out[k] = fn(t) if callable(fn) else fn
        return out

    def select(self, t, *names):
        return {n: t[n] for n in names}

    def _key(self, t, on):
        if isinstance(on, str):
            return t[on]
        return ref.combine_keys([t[c] for c in on])

    def join(self, probe, build, probe_on, build_on, take):
        return ref.join_unique(probe, build, self._key(probe, probe_on),
                               self._key(build, build_on), take)

    def semi(self, probe, build, probe_on, build_on):
        return ref.semi_join(probe, build, self._key(probe, probe_on),
                             self._key(build, build_on))

    def anti(self, probe, build, probe_on, build_on):
        return ref.anti_join(probe, build, self._key(probe, probe_on),
                             self._key(build, build_on))

    def left(self, probe, build, probe_on, build_on, take, defaults):
        return ref.left_join(probe, build, self._key(probe, probe_on),
                             self._key(build, build_on), take, defaults)

    def group_by(self, t, keys, aggs, exchange="local", final=False,
                 groups_hint=None, key_bits=None, wire=None, method="auto"):
        # key_bits / method are JAX-engine planning hints; the oracle ignores
        # them (np.unique-based group-by regardless of path)
        aggs, avg_post = _expand_avg(list(aggs))
        out = ref.group_aggregate(t, keys, _eval_aggs(self, t, aggs))
        # the exchange (were this distributed) moves the expanded partial —
        # the entry is logged AFTER agg-expression scalar sub-queries ran,
        # matching the distributed backend's partial-then-exchange order
        if exchange == "shuffle":
            self._count("shuffle", self._wire_entry("shuffle", out, wire))
        elif exchange == "gather":
            kind = "gather" if final else "broadcast"
            self._count(kind, self._wire_entry(kind, out, wire))
        for name in avg_post:
            out[name] = out[f"__{name}_s"] / np.maximum(out[f"__{name}_c"], 1)
            del out[f"__{name}_s"], out[f"__{name}_c"]
        return out

    def agg_scalar(self, t, aggs):
        self._count("allreduce")
        aggs, avg_post = _expand_avg(list(aggs))
        g = ref.group_aggregate(t, [], _eval_aggs(self, t, aggs))
        out = {k: (v[0] if len(v) else np.asarray(0.0)) for k, v in g.items()}
        for name in avg_post:
            out[name] = out[f"__{name}_s"] / max(out[f"__{name}_c"], 1)
            del out[f"__{name}_s"], out[f"__{name}_c"]
        return out

    def shuffle(self, t, key, wire=None):
        self._count("shuffle", self._wire_entry("shuffle", t, wire))
        return t

    def broadcast(self, t, p2p=False, wire=None):
        kind = "broadcast_p2p" if p2p else "broadcast"
        # the p2p variant is the §7.1 baseline and deliberately stays wide
        self._count(kind, self._wire_entry(kind, t, wire,
                                           narrow=False if p2p else None))
        return t

    def shrink(self, t, cap):
        self.stats.overflow_checks += 1
        return t

    def finalize(self, t, sort_keys=None, limit=None, replicated=False,
                 wire=None):
        if not replicated:
            self._count("gather", self._wire_entry("gather", t, wire))
        if sort_keys:
            t = ref.sort_by(t, sort_keys)
        if limit is not None:
            t = ref.limit(t, limit)
        return t

    def nrows(self, t):
        return len(next(iter(t.values())))


# ===========================================================================
# Single-device JAX backend (static shapes, exchanges are identity)
# ===========================================================================

class LocalContext(_BaseContext):
    xp = jnp
    distributed = False

    def __init__(self, db, tables: dict[str, Table], capacity_factor=2.0,
                 join_method: str = "sorted", use_kernel: bool | None = None,
                 wire_format: str | None = None):
        super().__init__(db, capacity_factor, wire_format)
        self._tables = tables
        self.overflow = jnp.asarray(False)
        self.corrupt = jnp.asarray(False)
        self.join_method = join_method
        # use_kernel=False runs aggregation/dispatch through the jnp oracle
        # (the CI matrix leg); None -> REPRO_AGG_KERNEL env default
        self.use_kernel = rel.agg_kernel_default() if use_kernel is None \
            else use_kernel

    def scan(self, name):
        self._chaos_point("scan")
        return self._tables[name]

    def filter(self, t, mask):
        return rel.filter_rows(t, mask)

    def with_col(self, t, **exprs):
        return t.replace(**{k: (fn(t) if callable(fn) else fn)
                            for k, fn in exprs.items()})

    def select(self, t, *names):
        return t.select(*names)

    def _key(self, t, on):
        if isinstance(on, str):
            return t[on]
        return rel.combine_keys([t[c] for c in on])

    def _build_index(self, build, build_on) -> rel.BuildIndex:
        """Per-plan build cache: index each (build table, key) pair once."""
        if isinstance(build_on, str):
            on_desc = build_on
        elif isinstance(build_on, (list, tuple)) and \
                all(isinstance(c, str) for c in build_on):
            on_desc = tuple(build_on)
        else:  # raw key arrays etc. — build fresh rather than key by id()
            idx = rel.build_index(build, self._key(build, build_on),
                                  method=self.join_method,
                                  bucket_cap=self.bucket_cap())
            self.overflow = self.overflow | idx.overflow
            return idx
        ck = (id(build), on_desc)
        hit = self._join_cache.get(ck)
        if hit is not None:
            return hit[1]
        idx = rel.build_index(build, self._key(build, build_on),
                              method=self.join_method,
                              bucket_cap=self.bucket_cap())
        self.overflow = self.overflow | idx.overflow
        self._join_cache[ck] = (build, idx)  # keep build alive: id() stability
        return idx

    def join(self, probe, build, probe_on, build_on, take):
        return rel.join_unique(probe, build, self._key(probe, probe_on),
                               self._key(build, build_on), take,
                               index=self._build_index(build, build_on))

    def semi(self, probe, build, probe_on, build_on):
        return rel.semi_join(probe, build, self._key(probe, probe_on),
                             self._key(build, build_on),
                             index=self._build_index(build, build_on))

    def anti(self, probe, build, probe_on, build_on):
        return rel.anti_join(probe, build, self._key(probe, probe_on),
                             self._key(build, build_on),
                             index=self._build_index(build, build_on))

    def left(self, probe, build, probe_on, build_on, take, defaults):
        return rel.left_join(probe, build, self._key(probe, probe_on),
                             self._key(build, build_on), take, defaults,
                             index=self._build_index(build, build_on))

    def group_by(self, t, keys, aggs, exchange="local", final=False,
                 groups_hint=None, key_bits=None, wire=None, method="auto"):
        """``method`` selects the aggregation path (planner rule: ``hash``
        when ``groups_hint`` is claimed but ``key_bits`` is unprovable);
        the dictionary capacity scales with the runner's capacity factor so
        escalation genuinely enlarges it on re-execution."""
        self._chaos_point("group_by")
        aggs, avg_post = _expand_avg(list(aggs))
        out, ov = rel.group_aggregate(t, keys, _eval_aggs(self, t, aggs),
                                      key_bits=key_bits, method=method,
                                      groups_hint=groups_hint,
                                      hash_factor=self.capacity_factor,
                                      use_kernel=self.use_kernel,
                                      return_overflow=True)
        self.overflow = self.overflow | ov
        if groups_hint is not None:
            out, ov = rel.static_shrink(out, min(out.capacity, groups_hint))
            self.overflow = self.overflow | ov
        # log after the partial (and its agg-expression sub-queries), in the
        # same position the distributed engine issues the real exchange
        if exchange == "shuffle":
            self._count("shuffle", self._wire_entry("shuffle", out, wire))
        elif exchange == "gather":
            kind = "gather" if final else "broadcast"
            self._count(kind, self._wire_entry(kind, out, wire))
        for name in avg_post:
            cnt = jnp.maximum(out[f"__{name}_c"], 1)
            out = out.replace(**{name: out[f"__{name}_s"] / cnt})
            out = out.drop(f"__{name}_s", f"__{name}_c")
        return out

    def agg_scalar(self, t, aggs):
        self._chaos_point("group_by")   # scalar aggregation = group_by domain
        self._count("allreduce")
        aggs, avg_post = _expand_avg(list(aggs))
        g = rel.group_aggregate(t, [], _eval_aggs(self, t, aggs),
                                use_kernel=self.use_kernel)
        out = {name: g[name][0] for name in g.names}
        for name in avg_post:
            out[name] = out[f"__{name}_s"] / jnp.maximum(out[f"__{name}_c"], 1)
            del out[f"__{name}_s"], out[f"__{name}_c"]
        return out

    def shuffle(self, t, key, wire=None):
        self._chaos_point("exchange")
        self._count("shuffle", self._wire_entry("shuffle", t, wire))
        return t

    def broadcast(self, t, p2p=False, wire=None):
        self._chaos_point("exchange")
        kind = "broadcast_p2p" if p2p else "broadcast"
        self._count(kind, self._wire_entry(kind, t, wire,
                                           narrow=False if p2p else None))
        return t

    def shrink(self, t, cap):
        self.stats.overflow_checks += 1
        t, ov = rel.static_shrink(t, cap)
        self.overflow = self.overflow | ov
        return t

    def finalize(self, t, sort_keys=None, limit=None, replicated=False,
                 wire=None):
        self._chaos_point("finalize")
        if not replicated:
            self._count("gather", self._wire_entry("gather", t, wire))
        if sort_keys:
            t = rel.sort_by(t, sort_keys)   # sorted output is compact
        else:
            t = rel.ensure_compact(t)       # finalize is a contiguity boundary
        if limit is not None:
            t = rel.limit(t, limit)
        return t

    def nrows(self, t):
        return t.count


# ===========================================================================
# Distributed backend (inside shard_map)
# ===========================================================================

class DistContext(LocalContext):
    """SPMD execution: exchange calls become real collectives."""
    distributed = True

    def __init__(self, db, tables, axis_name: str, num_partitions: int,
                 capacity_factor=2.0, packed_exchange=True,
                 join_method: str = "sorted", use_kernel: bool | None = None,
                 wire_format: str | None = None):
        super().__init__(db, tables, capacity_factor, join_method, use_kernel,
                         wire_format)
        self.axis = axis_name
        self.N = num_partitions
        self.packed = packed_exchange

    # -- exchanges ----------------------------------------------------------
    def shuffle(self, t, key, dest_ids=None, wire=None):
        tamper = self._chaos_point("exchange", tamperable=self.packed)
        self._count("shuffle")
        keyv = t[key] if isinstance(key, str) else self._key(t, key)
        cap_per_dest = max(8, math.ceil(t.capacity * self.capacity_factor / self.N))
        out, ov, cr, _, stats = ex.shuffle(t, keyv, self.axis, self.N,
                                           cap_per_dest,
                                           packed=self.packed, dest_ids=dest_ids,
                                           use_kernel=self.use_kernel,
                                           wire=wire, narrow=self.wire_narrow,
                                           tamper=tamper)
        self.stats.log.append(stats)
        self.overflow = self.overflow | ov
        self.corrupt = self.corrupt | cr
        return out

    def broadcast(self, t, p2p=False, wire=None):
        # the p2p baseline ships unchecked — corrupt faults here are simulated
        tamper = self._chaos_point("exchange",
                                   tamperable=self.packed and not p2p)
        self._count("broadcast_p2p" if p2p else "broadcast")
        if p2p:
            out, stats = ex.broadcast_table_p2p(t, self.axis, self.N)
        else:
            out, ov, cr, stats = ex.broadcast_table(t, self.axis, self.N,
                                                    packed=self.packed,
                                                    wire=wire,
                                                    narrow=self.wire_narrow,
                                                    tamper=tamper)
            self.overflow = self.overflow | ov
            self.corrupt = self.corrupt | cr
        self.stats.log.append(stats)
        return out

    # -- distributed aggregation --------------------------------------------
    def group_by(self, t, keys, aggs, exchange="local", final=False,
                 groups_hint=None, key_bits=None, wire=None, method="auto"):
        """groups_hint: static bound on distinct groups (e.g. a dictionary
        domain) — shrinks the partial aggregate BEFORE the exchange, so a
        gather/shuffle of a wide scan's partial moves O(groups), not
        O(scan capacity).  Overflow feeds the re-execution runner.
        key_bits: provable per-column key bit widths — both the per-device
        partial and the post-exchange merge run the sortless direct path.
        method: aggregation path; ``hash`` (groups_hint claimed, key_bits
        unprovable — the Q13 shape) builds a per-device dictionary sized by
        the capacity factor, and the SAME method runs the post-exchange
        merge, so both sides of the exchange stay sortless.
        wire: provable (lo, hi) bounds per partial column — the exchange
        ships the partial at its inferred lane widths."""
        tamper = self._chaos_point(
            "group_by", tamperable=self.packed and exchange != "local")
        aggs, avg_post = _expand_avg(list(aggs))
        partial, ov = rel.group_aggregate(t, keys, _eval_aggs(self, t, aggs),
                                          key_bits=key_bits, method=method,
                                          groups_hint=groups_hint,
                                          hash_factor=self.capacity_factor,
                                          use_kernel=self.use_kernel,
                                          return_overflow=True)
        self.overflow = self.overflow | ov
        if groups_hint is not None:
            partial, ov = rel.static_shrink(
                partial, min(partial.capacity, groups_hint))
            self.overflow = self.overflow | ov
        if exchange == "local":
            out = partial
        else:
            merge = [(name, _MERGE[op], name) for name, op, _ in aggs]
            if exchange == "shuffle":
                self._count("shuffle")
                keyv = rel.combine_keys([partial[k] for k in keys],
                                        bits=key_bits) if len(keys) > 1 \
                    else partial[keys[0]]
                cap_per_dest = max(8, math.ceil(
                    partial.capacity * self.capacity_factor / self.N))
                moved, ov, cr, _, stats = ex.shuffle(partial, keyv, self.axis,
                                                     self.N, cap_per_dest,
                                                     packed=self.packed,
                                                     use_kernel=self.use_kernel,
                                                     wire=wire,
                                                     narrow=self.wire_narrow,
                                                     tamper=tamper)
                self.stats.log.append(stats)
                self.overflow = self.overflow | ov
                self.corrupt = self.corrupt | cr
            elif exchange == "gather":
                kind = "gather" if final else "broadcast"
                self._count(kind)
                moved, ov, cr, stats = ex.broadcast_table(
                    partial, self.axis, self.N, packed=self.packed,
                    wire=wire, narrow=self.wire_narrow, tamper=tamper)
                self.overflow = self.overflow | ov
                self.corrupt = self.corrupt | cr
                self.stats.log.append(dataclasses.replace(stats, kind=kind))
            else:
                raise ValueError(exchange)
            # the partial->global merge reuses the same provable widths (or
            # the same dictionary bound), so a hinted group-by is sortless on
            # BOTH sides of the exchange
            out, ov = rel.group_aggregate(moved, keys, merge,
                                          key_bits=key_bits, method=method,
                                          groups_hint=groups_hint,
                                          hash_factor=self.capacity_factor,
                                          use_kernel=self.use_kernel,
                                          return_overflow=True)
            self.overflow = self.overflow | ov
        for name in avg_post:
            cnt = jnp.maximum(out[f"__{name}_c"], 1)
            out = out.replace(**{name: out[f"__{name}_s"] / cnt})
            out = out.drop(f"__{name}_s", f"__{name}_c")
        return out

    def agg_scalar(self, t, aggs):
        self._chaos_point("group_by")   # allreduce ships unchecked scalars:
        self._count("allreduce")        # corrupt faults here are simulated
        aggs, avg_post = _expand_avg(list(aggs))
        g = rel.group_aggregate(t, [], _eval_aggs(self, t, aggs),
                                use_kernel=self.use_kernel)
        partials = {name: g[name][0] for name in g.names}
        ops = {name: _MERGE[op] for name, op, _ in aggs}
        out = ex.partial_to_global(partials, ops, self.axis)
        for name in avg_post:
            out[name] = out[f"__{name}_s"] / jnp.maximum(out[f"__{name}_c"], 1)
            del out[f"__{name}_s"], out[f"__{name}_c"]
        return out

    def finalize(self, t, sort_keys=None, limit=None, replicated=False,
                 wire=None):
        """Final result collection: local order/limit, gather, global order.

        ``replicated=True`` marks tables already merged on every device (e.g.
        after group_by(exchange='gather')) — no further collection needed."""
        tamper = self._chaos_point(
            "finalize", tamperable=self.packed and not replicated)
        if replicated:
            if sort_keys:
                t = rel.sort_by(t, sort_keys)
            else:
                t = rel.ensure_compact(t)
            if limit is not None:
                t = rel.limit(t, limit)
            return t
        self._count("gather")
        if sort_keys:
            t = rel.sort_by(t, sort_keys)
        if limit is not None:
            t = rel.limit(t, limit)   # local top-k before the gather
        t, ov, cr, stats = ex.broadcast_table(t, self.axis, self.N,
                                              packed=self.packed, wire=wire,
                                              narrow=self.wire_narrow,
                                              tamper=tamper)
        self.overflow = self.overflow | ov
        self.corrupt = self.corrupt | cr
        self.stats.log.append(dataclasses.replace(stats, kind="gather"))
        if sort_keys:
            t = rel.sort_by(t, sort_keys)
        else:
            t = rel.ensure_compact(t)
        if limit is not None:
            t = rel.limit(t, limit)
        return t


# ===========================================================================
# drivers
# ===========================================================================

def run_reference(query_fn, db: Database, wire_format: str | None = None,
                  ) -> tuple[dict, PlanStats]:
    ctx = RefContext(db, wire_format=wire_format)
    out = query_fn(ctx)
    if isinstance(out, dict) and out and \
            np.ndim(next(iter(out.values()))) == 0:
        out = {k: np.asarray([v]) for k, v in out.items()}
    return out, ctx.stats


def _np_db_to_tables(db: Database, pad: float = 1.0) -> dict[str, Table]:
    out = {}
    for name, t in db.tables.items():
        n = len(next(iter(t.values())))
        cap = max(8, int(math.ceil(n * pad / 8)) * 8)
        out[name] = from_numpy(t, capacity=cap)
    return out


def run_local(query_fn, db: Database, jit: bool = True,
              join_method: str = "sorted", use_kernel: bool | None = None,
              capacity_factor: float = 2.0, wire_format: str | None = None,
              chaos=None, return_overflow: bool = False,
              ) -> tuple[dict, PlanStats] | tuple[dict, PlanStats, bool]:
    tables = _np_db_to_tables(db)
    holder = {}

    def run(tables):
        ctx = LocalContext(db, tables, capacity_factor=capacity_factor,
                           join_method=join_method, use_kernel=use_kernel,
                           wire_format=wire_format)
        ctx.chaos = chaos
        out = query_fn(ctx)
        holder["stats"] = ctx.stats
        if isinstance(out, dict):
            out = Table({k: jnp.asarray(v).reshape(1) for k, v in out.items()},
                        jnp.asarray(1, jnp.int32))
        return rel.ensure_compact(out), ctx.overflow, ctx.corrupt

    fn = jax.jit(run) if jit else run
    out, overflow, corrupt = fn(tables)
    if bool(corrupt):
        raise wi.CorruptPayload("local run: payload integrity check failed")
    if return_overflow:
        # policy-loop callers (QueryRunner on a mesh-less topology) answer
        # overflow with capacity escalation instead of an assert
        return to_numpy(out), holder["stats"], bool(overflow)
    assert not bool(overflow), "capacity overflow in local run"
    return to_numpy(out), holder["stats"]


# -- host-side partitioning (paper §4.3) ------------------------------------

_C1 = np.uint64(0xFF51AFD7ED558CCD)
_C2 = np.uint64(0xC4CEB9FE1A85EC53)


def hash_partition_np(key: np.ndarray, n: int) -> np.ndarray:
    """splitmix64 finalizer — must match relational.hash_partition_ids."""
    with np.errstate(over="ignore"):
        k = key.astype(np.uint64)
        k = (k ^ (k >> np.uint64(33))) * _C1
        k = (k ^ (k >> np.uint64(33))) * _C2
        k = k ^ (k >> np.uint64(33))
        return (k % np.uint64(n)).astype(np.int32)


# Paper §4.3: lineitem by l_orderkey (co-partitioned with orders), partsupp by
# ps_partkey, others by primary key; nation/region replicated (tiny dims).
PARTITION_KEYS = {
    "lineitem": "l_orderkey",
    "orders": "o_orderkey",
    "partsupp": "ps_partkey",
    "part": "p_partkey",
    "supplier": "s_suppkey",
    "customer": "c_custkey",
    "nation": None,      # replicated
    "region": None,      # replicated
}


def partition_database(db: Database, n: int,
                       partition_keys: dict | None = None,
                       ) -> tuple[dict[str, dict], dict[str, int]]:
    """Host-side partitioning -> per-table (stacked shards dict, per-shard cap).

    Returns columns shaped (n*cap,) and counts shaped (n,) ready for shard_map
    with in_specs=P(axis).  Replicated tables (key None) appear whole in every
    shard — the standard treatment for tiny dimension tables.
    """
    pk = dict(PARTITION_KEYS)
    if partition_keys:
        pk.update(partition_keys)
    out, caps = {}, {}
    for name, t in db.tables.items():
        nrows = len(next(iter(t.values())))
        key = pk.get(name)
        if key is None:
            shards = [t] * n
        else:
            dest = hash_partition_np(np.asarray(t[key]), n)
            shards = [{k: v[dest == d] for k, v in t.items()} for d in range(n)]
        cap = max(8, int(math.ceil(max(len(next(iter(s.values()))) for s in shards)
                                   / 8)) * 8)
        cols = {}
        for cname in t:
            stacked = np.zeros((n * cap,), dtype=t[cname].dtype)
            for d, s in enumerate(shards):
                stacked[d * cap: d * cap + len(s[cname])] = s[cname]
            cols[cname] = stacked
        cols["__count"] = np.array(
            [len(next(iter(s.values()))) for s in shards], dtype=np.int32)
        out[name] = cols
        caps[name] = cap
    return out, caps


def run_distributed(query_fn, db: Database, mesh: Mesh, axis: str = "data",
                    capacity_factor: float = 2.0, packed_exchange: bool = True,
                    partition_keys: dict | None = None,
                    join_method: str = "sorted",
                    use_kernel: bool | None = None,
                    wire_format: str | None = None,
                    chaos=None,
                    ) -> tuple[dict, PlanStats, Any]:
    """Run a query SPMD over ``mesh[axis]``; returns (result, stats, overflow).

    One logical process per device, all executing the same tensor program —
    the paper's MPI model realized as a single shard_map program.  A payload
    integrity failure (``ctx.corrupt``, set by the wire checksums — possibly
    via an armed ``chaos`` injector's tamper) raises :class:`CorruptPayload`
    host-side: corrupted buffers are never decoded into served results.
    """
    n = mesh.shape[axis]
    sharded, caps = partition_database(db, n, partition_keys)
    holder = {}

    def spmd(tree):
        tables = {}
        for name, cols in tree.items():
            cnt = cols.pop("__count").reshape(())
            tables[name] = Table(cols, cnt)
        ctx = DistContext(db, tables, axis, n, capacity_factor,
                          packed_exchange, join_method, use_kernel,
                          wire_format)
        ctx.chaos = chaos
        out = query_fn(ctx)
        holder["stats"] = ctx.stats
        if isinstance(out, dict):
            out = Table({k: jnp.asarray(v).reshape(1) for k, v in out.items()},
                        jnp.asarray(1, jnp.int32))
        out = rel.ensure_compact(out)   # host extraction slices [0, count)
        return (Table(dict(out.columns), out.count.reshape(1)),
                ctx.overflow.reshape(1), ctx.corrupt.reshape(1))

    inp = {name: {k: jnp.asarray(v) for k, v in cols.items()}
           for name, cols in sharded.items()}
    fn = jax.jit(compat.shard_map(spmd, mesh=mesh, in_specs=P(axis),
                                  out_specs=P(axis)))
    out, overflow, corrupt = fn(inp)
    if bool(np.any(np.asarray(corrupt))):
        raise wi.CorruptPayload(
            "distributed run: payload integrity check failed")
    result = Table({k: v[: v.shape[0] // n] for k, v in out.columns.items()},
                   out.count[0])
    return to_numpy(result), holder["stats"], bool(np.any(np.asarray(overflow)))

"""Columnar tables with static row capacity — the tensor-format data model of TQP.

A Table is a pytree of equal-length 1-D column arrays plus a dynamic valid-row
``count``.  Static capacity is the TPU/XLA adaptation of TQP's variable-size
tensors (see DESIGN.md §2).

Row validity comes in two representations:

  * **compact** (``valid is None``): rows ``[0, count)`` are valid, rows beyond
    are padding — the invariant the seed engine maintained after every operator.
  * **masked** (``valid`` is a boolean column): row ``i`` is valid iff
    ``valid[i]``; ``count == valid.sum()``.  This is the *deferred compaction*
    representation — filters and joins produce masked tables in O(n) instead of
    paying an O(cap log cap) argsort per operator, and the front-compaction
    runs only at boundaries that truly need contiguity (exchange payload
    packing, ``finalize``, capacity shrink, ``limit``).

``valid_mask()`` abstracts over both; every relational operator consumes either
representation.  String columns are dictionary-encoded int32 codes; the
dictionaries live host-side in the :class:`Database` (they are metadata, never
traced).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Table",
    "Database",
    "from_numpy",
    "to_numpy",
    "days",
    "KEY_SENTINEL",
]

# Sentinel pushed to the back by sorts; larger than any TPC-H key (SF 3000 keys
# stay < 2^63 - 1).
KEY_SENTINEL = np.iinfo(np.int64).max


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Table:
    """Dict of 1-D columns (same static length = capacity) + dynamic valid count.

    ``valid`` is the optional deferred-compaction mask (see module docstring):
    None means rows [0, count) are valid and contiguous.
    """

    columns: dict[str, jax.Array]
    count: jax.Array  # int32 scalar (or int on host)
    valid: jax.Array | None = None  # bool (capacity,) or None = compact

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        children = tuple(self.columns[n] for n in names) + (self.count,)
        if self.valid is not None:
            children = children + (self.valid,)
        return children, (names, self.valid is not None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        names, has_valid = aux
        if has_valid:
            cols, count, valid = children[:-2], children[-2], children[-1]
        else:
            cols, count, valid = children[:-1], children[-1], None
        return cls(dict(zip(names, cols)), count, valid)

    # -- convenience -----------------------------------------------------
    @property
    def capacity(self) -> int:
        if not self.columns:
            return 0
        return next(iter(self.columns.values())).shape[0]

    @property
    def is_compact(self) -> bool:
        return self.valid is None

    def __getitem__(self, name: str) -> jax.Array:
        return self.columns[name]

    def __contains__(self, name: str) -> bool:
        return name in self.columns

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self.columns))

    def valid_mask(self) -> jax.Array:
        if self.valid is not None:
            return self.valid
        return jnp.arange(self.capacity, dtype=jnp.int64) < self.count

    def replace(self, **cols: jax.Array) -> "Table":
        new = dict(self.columns)
        new.update(cols)
        return Table(new, self.count, self.valid)

    def select(self, *names: str) -> "Table":
        return Table({n: self.columns[n] for n in names}, self.count, self.valid)

    def drop(self, *names: str) -> "Table":
        return Table({k: v for k, v in self.columns.items() if k not in names},
                     self.count, self.valid)

    def rename(self, mapping: Mapping[str, str]) -> "Table":
        return Table({mapping.get(k, k): v for k, v in self.columns.items()},
                     self.count, self.valid)

    def with_count(self, count) -> "Table":
        return Table(dict(self.columns), jnp.asarray(count, dtype=jnp.int32)
                     if not isinstance(count, (int, np.integer)) else count,
                     self.valid)


@dataclasses.dataclass
class Database:
    """Host-side container: named tables + string dictionaries + scale metadata.

    ``dicts[col]`` is a numpy array of strings such that code ``i`` in column
    ``col`` decodes to ``dicts[col][i]``.  Dictionaries are shared across tables
    (e.g. every ``*_nationkey`` decodes through ``dicts['nation_name']``).
    """

    tables: dict[str, Table]
    dicts: dict[str, np.ndarray]
    scale: float = 0.0

    def dict_mask(self, col: str, pred: Callable[[np.ndarray], np.ndarray]) -> np.ndarray:
        """Evaluate a host-side predicate over the dictionary of ``col``.

        This is how TQP executes LIKE / IN over dictionary-encoded strings: the
        predicate runs once over the (small) dictionary and becomes a boolean
        lookup tensor gathered per row inside the compiled program.  Returned
        as numpy so callers can embed it as a trace-time constant.
        """
        return np.asarray(pred(self.dicts[col]))

    def code(self, col: str, value: str) -> int:
        """Dictionary code of an exact string value (host-side)."""
        d = self.dicts[col]
        idx = np.nonzero(d == value)[0]
        if idx.size == 0:
            raise KeyError(f"{value!r} not in dictionary for {col!r}")
        return int(idx[0])

    def codes(self, col: str, values) -> list[int]:
        return [self.code(col, v) for v in values]


_EPOCH = np.datetime64("1970-01-01")


def days(date_str: str) -> int:
    """Date literal -> int32 epoch days (host-side; interval math is plain ints)."""
    return int((np.datetime64(date_str) - _EPOCH).astype("timedelta64[D]").astype(np.int64))


def add_months(date_str: str, months: int) -> int:
    d = np.datetime64(date_str, "M") + np.timedelta64(months, "M")
    # preserve day-of-month where TPC-H literals are always day 1 of a month
    day = int(date_str.split("-")[2])
    return days(str(d) + f"-{day:02d}")


def from_numpy(cols: Mapping[str, np.ndarray], capacity: int | None = None) -> Table:
    """Host numpy columns -> padded device Table."""
    n = len(next(iter(cols.values())))
    cap = capacity if capacity is not None else n
    assert cap >= n, (cap, n)
    out = {}
    for k, v in cols.items():
        v = np.asarray(v)
        pad = np.zeros((cap - n,) + v.shape[1:], dtype=v.dtype)
        out[k] = jnp.asarray(np.concatenate([v, pad], axis=0))
    return Table(out, jnp.asarray(n, dtype=jnp.int32))


def to_numpy(t: Table) -> dict[str, np.ndarray]:
    """Device Table -> exact-size host columns (drops padding).

    Masked tables are extracted by boolean indexing (preserving row order);
    compact tables by slicing off the padding tail.
    """
    if t.valid is not None:
        m = np.asarray(t.valid)
        return {k: np.asarray(v)[m] for k, v in t.columns.items()}
    n = int(t.count)
    return {k: np.asarray(v)[:n] for k, v in t.columns.items()}

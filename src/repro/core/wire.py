"""Stats-driven wire formats for exchange payloads (bytes-on-the-wire layer).

The paper's speedup story is dominated by cross-device bytes (§2.3, Hockney
§3.6), yet a capacity-padded exchange buffer that ships every column at full
32-bit word granularity pays 4 bytes for a dictionary code that provably fits
8 bits.  This module turns the planner's per-column min/max bounds — the same
statistics that feed ``key_bits`` — into a **wire format**: a deterministic
per-row layout of int32 words where sub-word columns share words as 8/16-bit
lanes.

Lane modes (``ColWire.mode``)
-----------------------------
  ``lane8`` / ``lane16``  biased sub-word lane: the wire value is
                          ``v - lo`` (guaranteed ``0 <= v-lo <= span`` by the
                          planner's bounds), placed at ``shift`` inside word
                          ``word`` by shift/or.  Bool columns are an
                          unconditional ``lane8`` (1 provable bit, no stats
                          needed, no runtime check).
  ``u32``                 biased full word for a >4-byte integer column whose
                          span fits 32 bits (an int64 key at 8 bytes -> 4).
  ``word``                verbatim 4-byte bitcast (float32/int32 without a
                          useful bound; bool in the wide format).
  ``split``               verbatim 8-byte bitcast into two words (float64
                          always — mantissas cannot be range-compressed —
                          and int64 without a provable 32-bit span).
  ``const``               span == 0: the column is NOT shipped at all and is
                          reconstructed from ``lo`` on unpack.

Safety contract
---------------
A narrowed column is never truncated silently: ``pack_table`` range-checks
``v - lo`` against ``span`` on every VALID row and returns an ``overflow``
flag (ORed into ``ctx.overflow`` by the backends -> the fault runner
re-executes, recompiling without inference — and hence at full width — after
a failed capacity escalation).  Invalid (masked / padding) rows are zeroed in
narrowed lanes and excluded from the check; they are reconstructed as ``lo``
on unpack and remain masked.

The WIDE format (``narrow=False`` or no bounds) reproduces the legacy packing
exactly: one word per 4 logical bytes, bool widened to a word — so
``REPRO_WIRE=wide`` is a byte-identical differential leg for the narrow path.
``plan_wire_format`` is pure host arithmetic over (names, dtypes, bounds), so
the static planner and every runtime backend derive the SAME layout and the
IR-derived wire-byte report equals runtime ``ExchangeStats`` on every backend.

Integrity checksum (corruption-not-wrong)
-----------------------------------------
Packed exchanges fuse a per-block **integrity word** into the existing counts
header row: a position-rotated XOR fold of the payload words
(:func:`payload_checksum`), mixed with the row count so a flipped count is as
detectable as a flipped payload bit.  Formats with >= 2 words per row carry
the full 32-bit checksum in header word 1 (``header_mode == "word"``);
single-word formats fold a 16-bit checksum into the high half of the count
word (``"folded"``, valid while the block's row capacity fits 16 bits —
beyond that the exchange ships unchecked, ``"none"``).  The fold rotates each
word by its flat bit position, so ANY single bit flip in payload, count, or
checksum word changes the verification result — corrupted packed payloads are
flagged on unpack (``ctx.corrupt`` -> :class:`CorruptPayload` at the driver
boundary), never decoded into silent wrong answers.  Both wire legs (narrow
and wide) are checksummed identically; the per-column §2.3 baseline and the
§7.1 p2p ring deliberately are not (they are the paper's unprotected
baselines).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ColWire", "WireFormat", "CorruptPayload", "wire_default",
    "hockney_skip", "plan_wire_format", "pack_table", "unpack_table",
    "row_bytes",
    "payload_checksum", "fold16", "header_mode",
    "encode_header_word0", "encode_checksum_word", "decode_header_word0",
    "verify_block_checksum",
]

_LANE_BITS = {"lane8": 8, "lane16": 16}


class CorruptPayload(RuntimeError):
    """A packed exchange payload failed its integrity checksum (or a chaos
    fault simulated that detection).  Classified CORRUPT by the fault runner:
    the query re-executes on the conservative wide wire format — results are
    never served from a buffer that failed verification."""


def wire_default() -> str:
    """Exchange wire format: ``narrow`` unless REPRO_WIRE selects ``wide``.

    Narrow engages only where the planner supplies bounds (stats-driven by
    construction); with inference off (REPRO_PLANNER=0) every exchange is
    wide regardless of this switch.
    """
    return "wide" if os.environ.get("REPRO_WIRE", "narrow").lower() in \
        ("wide", "0", "off") else "narrow"


# nominal rows per exchange message for the latency-bound test; override with
# the third REPRO_HOCKNEY field
_HOCKNEY_MSG_ROWS = 4096


def hockney_skip(wide_row_bytes: int) -> bool:
    """True when ``REPRO_HOCKNEY="<latency_s>,<inv_bw_s/B>[,<msg_rows>]"``
    prices the exchange message as latency-bound (§3.6): even the un-narrowed
    message of ``wide_row_bytes * msg_rows`` bytes sits below the link's
    half-bandwidth point, so the narrow format's wire saving is dwarfed by
    the constant latency term while its pack/unpack lanes still cost compute
    — narrow packing is skipped.

    Pure host arithmetic on the per-row width and the env-configured model:
    static analysis (``planner.static_wire_stats``) and every backend reach
    the same verdict, so the static report stays equal to runtime stats.
    """
    from . import perfmodel
    model = perfmodel.hockney_from_env()
    if model is None:
        return False
    parts = [p.strip() for p in os.environ.get("REPRO_HOCKNEY", "").split(",")]
    rows = int(parts[2]) if len(parts) > 2 and parts[2] else _HOCKNEY_MSG_ROWS
    return model.latency_bound(wide_row_bytes * rows)


@dataclasses.dataclass(frozen=True)
class ColWire:
    """Wire placement of one column (see module docstring for modes)."""
    name: str
    dtype: np.dtype
    mode: str           # lane8 | lane16 | u32 | word | split | const
    lo: int = 0         # bias (narrowed modes); reconstruction value (const)
    span: int = 0       # provable hi - lo; runtime check bound
    word: int = 0       # first word index in the packed buffer
    shift: int = 0      # bit offset within the word (lane modes)

    @property
    def checked(self) -> bool:
        """True when pack range-checks this column (narrowed int modes)."""
        return self.mode in ("lane8", "lane16", "u32", "const") and \
            self.dtype != np.bool_


@dataclasses.dataclass(frozen=True)
class WireFormat:
    """Deterministic row layout: columns -> (words,) int32 per row."""
    cols: tuple[ColWire, ...]
    words: int
    narrow: bool

    @property
    def row_wire_bytes(self) -> int:
        """Packed bytes per row actually shipped."""
        return self.words * 4

    @property
    def row_logical_bytes(self) -> int:
        """Dtype-true bytes per row (bool = 1 byte), the compression basis."""
        return sum(int(np.dtype(c.dtype).itemsize) for c in self.cols)


def _norm_dtype(dt) -> np.dtype:
    dt = np.dtype(dt)
    if dt == np.bool_ or dt.kind in "iuf":
        return dt
    raise TypeError(f"unsupported wire dtype {dt}")


def plan_wire_format(names: Sequence[str],
                     dtypes: Mapping[str, np.dtype],
                     bounds: Mapping[str, tuple] | None = None,
                     narrow: bool = True) -> WireFormat:
    """Derive the wire layout for a column set.

    ``bounds[col] = (lo, hi)`` are provable inclusive value bounds (planner
    statistics); columns without bounds ship at full width.  Pure host
    arithmetic: static analysis and every backend call this with the same
    inputs and get the same layout.  Column names are processed sorted, lanes
    are placed widest-first first-fit, so the layout is deterministic.
    """
    narrow = bool(narrow and bounds is not None)
    if narrow:
        # Hockney-driven packing skip: a latency-bound message ships wide
        wide_words = sum(2 if _norm_dtype(dtypes[n]).itemsize > 4 else 1
                         for n in names)
        if hockney_skip(max(1, wide_words) * 4):
            narrow = False
    chosen: list[ColWire] = []
    for nm in sorted(names):
        dt = _norm_dtype(dtypes[nm])
        wide_mode = "word" if dt.itemsize <= 4 else "split"
        if not narrow:
            chosen.append(ColWire(nm, dt, wide_mode))
            continue
        if dt == np.bool_:
            chosen.append(ColWire(nm, dt, "lane8", 0, 1))
            continue
        if dt.kind == "f":
            chosen.append(ColWire(nm, dt, wide_mode))
            continue
        b = bounds.get(nm)
        if b is None or b[0] is None or b[1] is None or b[1] < b[0]:
            chosen.append(ColWire(nm, dt, wide_mode))
            continue
        lo, hi = int(b[0]), int(b[1])
        span = hi - lo
        bits = span.bit_length()
        if bits == 0:
            mode = "const"
        elif bits <= 8:
            mode = "lane8"
        elif bits <= 16:
            mode = "lane16"
        elif bits <= 32 and dt.itemsize > 4:
            mode = "u32"
        else:
            mode = wide_mode
        if mode == wide_mode:
            chosen.append(ColWire(nm, dt, mode))
        else:
            chosen.append(ColWire(nm, dt, mode, lo, span))

    # word assignment: lanes first (16-bit then 8-bit, first-fit into shared
    # words), then whole words, then 2-word splits — all in sorted-name order
    # within each class, so both sides of an exchange derive one layout.
    placed: dict[str, tuple[int, int]] = {}
    open_words: list[list[int]] = []     # [used_bits] per lane word
    for width in (16, 8):
        for c in chosen:
            if _LANE_BITS.get(c.mode) != width:
                continue
            for w, used in enumerate(open_words):
                if 32 - used[0] >= width:
                    placed[c.name] = (w, used[0])
                    used[0] += width
                    break
            else:
                placed[c.name] = (len(open_words), 0)
                open_words.append([width])
    next_word = len(open_words)
    cols: list[ColWire] = []
    for c in chosen:
        if c.mode in _LANE_BITS:
            w, sh = placed[c.name]
            cols.append(dataclasses.replace(c, word=w, shift=sh))
        elif c.mode == "const":
            cols.append(c)
        elif c.mode == "split":
            cols.append(dataclasses.replace(c, word=next_word))
            next_word += 2
        else:                                  # word | u32
            cols.append(dataclasses.replace(c, word=next_word))
            next_word += 1
    return WireFormat(tuple(cols), max(1, next_word), narrow)


def row_bytes(names, dtypes, bounds=None, narrow=True) -> tuple[int, int]:
    """(row_wire_bytes, row_logical_bytes) for a column set — the per-row
    numbers ``ExchangeStats`` reports and the static bench derives."""
    fmt = plan_wire_format(names, dtypes, bounds, narrow)
    return fmt.row_wire_bytes, fmt.row_logical_bytes


# ---------------------------------------------------------------------------
# pack / unpack (traced)
# ---------------------------------------------------------------------------

def _as_u32(x: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(x, jnp.uint32)


def pack_table(t, fmt: WireFormat) -> tuple[jax.Array, jax.Array]:
    """Table -> ((capacity, fmt.words) int32 buffer, overflow flag).

    ``overflow`` is True iff any VALID row of a checked column falls outside
    its claimed ``[lo, lo + span]`` — lying bounds surface as a re-execution,
    never as silent truncation.  Invalid rows are zeroed in narrowed lanes
    (their reconstruction is masked anyway); wide words/splits ship verbatim.
    """
    cap = t.capacity
    valid = t.valid_mask() if fmt.narrow else None
    acc: list[jax.Array | None] = [None] * fmt.words
    overflow = jnp.asarray(False)

    def _or(w: int, x: jax.Array):
        acc[w] = x if acc[w] is None else acc[w] | x

    for c in fmt.cols:
        v = t[c.name]
        dt = np.dtype(c.dtype)
        if c.mode in ("lane8", "lane16", "u32", "const"):
            if dt == np.bool_:
                u = v.astype(jnp.uint32)        # 0/1 by construction
            else:
                d = v.astype(jnp.int64) - c.lo
                bad = valid & ((d < 0) | (d > c.span))
                overflow = overflow | jnp.any(bad)
                u = jnp.where(valid, jnp.clip(d, 0, c.span), 0) \
                    .astype(jnp.uint32)
            if c.mode == "const":
                continue                        # reconstructed from lo
            _or(c.word, u << c.shift if c.shift else u)
        elif c.mode == "word":
            if dt == np.bool_ or dt.itemsize < 4:
                x = v.astype(jnp.int32)         # widen (legacy bool behavior)
            else:
                x = jax.lax.bitcast_convert_type(v, jnp.int32)
            _or(c.word, _as_u32(x))
        elif c.mode == "split":
            x = jax.lax.bitcast_convert_type(v, jnp.int32)   # (cap, 2)
            _or(c.word, _as_u32(x[:, 0]))
            _or(c.word + 1, _as_u32(x[:, 1]))
        else:
            raise ValueError(f"unknown wire mode {c.mode!r}")

    parts = [a if a is not None else jnp.zeros((cap,), jnp.uint32)
             for a in acc]
    buf = jax.lax.bitcast_convert_type(jnp.stack(parts, axis=1), jnp.int32)
    return buf, overflow


def unpack_table(buf: jax.Array, fmt: WireFormat) -> dict[str, jax.Array]:
    """Inverse of :func:`pack_table`: int32 buffer -> logical columns."""
    n = buf.shape[0]
    ub = jax.lax.bitcast_convert_type(buf, jnp.uint32)
    out: dict[str, jax.Array] = {}
    for c in fmt.cols:
        dt = np.dtype(c.dtype)
        if c.mode == "const":
            out[c.name] = jnp.full((n,), c.lo, dtype=dt)
        elif c.mode in ("lane8", "lane16"):
            u = ub[:, c.word]
            if c.shift:
                u = u >> c.shift
            u = u & jnp.uint32((1 << _LANE_BITS[c.mode]) - 1)
            if dt == np.bool_:
                out[c.name] = (u & 1).astype(jnp.bool_)
            else:
                out[c.name] = (u.astype(jnp.int64) + c.lo).astype(dt)
        elif c.mode == "u32":
            out[c.name] = (ub[:, c.word].astype(jnp.int64) + c.lo).astype(dt)
        elif c.mode == "word":
            w = buf[:, c.word]
            if dt == np.bool_ or dt.itemsize < 4:
                out[c.name] = w.astype(dt)
            else:
                out[c.name] = jax.lax.bitcast_convert_type(w, dt)
        elif c.mode == "split":
            out[c.name] = jax.lax.bitcast_convert_type(
                buf[:, c.word:c.word + 2], dt)
        else:
            raise ValueError(f"unknown wire mode {c.mode!r}")
    return out


# ---------------------------------------------------------------------------
# integrity checksum (fused into the counts header row)
# ---------------------------------------------------------------------------

def header_mode(words: int, max_count: int) -> str:
    """How a packed block's header row carries its integrity word.

    ``"word"``    words >= 2: the full 32-bit checksum rides in header word 1
                  (payload rows never use the header row, so the slot is free).
    ``"folded"``  single-word formats: a 16-bit fold shares the count word's
                  high half — valid while every possible count fits 16 bits
                  (``max_count`` is the static per-block row capacity).
    ``"none"``    single-word format whose counts may exceed 16 bits: the
                  exchange ships unchecked (statically known; the stats log
                  still records it).
    """
    if words >= 2:
        return "word"
    return "folded" if max_count < (1 << 16) else "none"


def payload_checksum(buf: jax.Array) -> jax.Array:
    """Position-rotated XOR fold of a packed (rows, words) int32 block.

    Word ``i`` (flat order) is rotated left by ``i % 32`` bits before the
    fold, so a single bit flip anywhere in the block flips exactly one bit of
    the uint32 result — single-bit corruption is detected with certainty, not
    probabilistically (k-bit corruption escapes only on an exact 32-bit
    cancellation).  Traced, cheap (one pass over the payload).
    """
    u = jax.lax.bitcast_convert_type(buf, jnp.uint32).reshape(-1)
    r = (jnp.arange(u.shape[0], dtype=jnp.uint32)) & jnp.uint32(31)
    rot = (u << r) | (u >> ((jnp.uint32(32) - r) & jnp.uint32(31)))
    return jax.lax.reduce(rot, jnp.uint32(0), jax.lax.bitwise_xor, (0,))


def fold16(csum: jax.Array) -> jax.Array:
    """uint32 checksum -> 16-bit fold (XOR of halves); a single-bit change of
    the input changes exactly one bit of the fold."""
    return (csum ^ (csum >> jnp.uint32(16))) & jnp.uint32(0xFFFF)


def _mix_count(count: jax.Array) -> jax.Array:
    """Rotate the row count into the checksum so a flipped count word is as
    detectable as a flipped payload bit."""
    c = count.astype(jnp.uint32)
    return (c << jnp.uint32(7)) | (c >> jnp.uint32(25))


def encode_header_word0(count: jax.Array, csum: jax.Array, mode: str,
                        ) -> jax.Array:
    """int32 value of header word 0: the row count, plus (folded mode) the
    16-bit checksum fold in the high half."""
    c = count.astype(jnp.uint32)
    if mode == "folded":
        c = c | (fold16(csum ^ _mix_count(count)) << jnp.uint32(16))
    return jax.lax.bitcast_convert_type(c, jnp.int32)


def encode_checksum_word(count: jax.Array, csum: jax.Array) -> jax.Array:
    """int32 value of header word 1 (``"word"`` mode): checksum mixed with
    the count."""
    return jax.lax.bitcast_convert_type(csum ^ _mix_count(count), jnp.int32)


def decode_header_word0(word0: jax.Array, mode: str) -> jax.Array:
    """Received header word 0 -> row count (int32)."""
    u = jax.lax.bitcast_convert_type(word0, jnp.uint32)
    if mode == "folded":
        u = u & jnp.uint32(0xFFFF)
    return u.astype(jnp.int32)


def verify_block_checksum(hdr_row: jax.Array, payload: jax.Array, mode: str,
                          ) -> jax.Array:
    """True iff one received block (header row + payload rows) FAILS its
    integrity check.  ``hdr_row`` is the (words,) header row, ``payload`` the
    (rows, words) payload block."""
    if mode == "none":
        return jnp.asarray(False)
    count = decode_header_word0(hdr_row[0], mode)
    want = payload_checksum(payload) ^ _mix_count(count)
    if mode == "folded":
        got = jax.lax.bitcast_convert_type(hdr_row[0], jnp.uint32) \
            >> jnp.uint32(16)
        return fold16(want) != got
    got = jax.lax.bitcast_convert_type(hdr_row[1], jnp.uint32)
    # senders zero the unused header tail, so a flip there is detectable too
    return (want != got) | jnp.any(hdr_row[2:] != 0)

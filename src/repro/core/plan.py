"""Lazy logical query plans: a DataFrame-style builder over the Context API.

A query is built ONCE as a plain data structure — a DAG of logical operator
nodes carrying column-expression trees — and compiled by
:mod:`repro.core.planner` into calls against the physical ``Context`` API
(``RefContext`` / ``LocalContext`` / ``DistContext``).  This is the layer the
paper's "manually-optimized tensor programs" (§4.4) were missing: with the
plan as data, the static hints the physical engine wants (``key_bits``,
``groups_hint``, sortless-vs-sorted aggregation) become *planner inferences*
instead of per-query editing conventions (see planner.py for the contract).

Two sub-languages:

  * **Expressions** (:class:`Expr`): column references (``col("l_qty")``),
    literals, arithmetic/comparison/boolean operators, and the TQP-style
    dictionary primitives (``scode`` / ``like`` / ``starts_with`` /
    ``ends_with`` / ``isin`` / ``alpha_rank`` / ``year``).  ``AggScalar[name]``
    yields a :class:`ScalarRef` so scalar sub-query results (Q11's total,
    Q15's max, Q22's average) compose into later expressions.
  * **Plan nodes** (:class:`LogicalTable` subclasses): ``Scan`` / ``Filter`` /
    ``Select`` / ``WithCol`` / ``Rename`` / ``Join`` / ``Semi`` / ``Anti`` /
    ``Left`` / ``GroupBy`` / ``AggScalar`` / ``Shuffle`` / ``Broadcast`` /
    ``Shrink`` / ``Finalize`` / ``ScalarResult``.  Exchange placement stays
    explicit plan structure (the paper's placement is authoritative); the
    planner *validates* it against a derived placement and derives paper
    Table-4 counts from the IR alone.

Node identity is object identity: reusing a builder value twice (Q15's
grouped partials feed both the max sub-query and the filter) makes a DAG, and
the compiler executes each node once — which is also what makes the per-plan
build-side join cache hit.

``GroupBy`` deliberately has NO ``key_bits`` parameter: provable key widths
are planner inferences.  ``groups_hint=`` remains available for bounds the
planner cannot prove (data-dependent group counts, e.g. Q13's orders-per-
customer histogram); everything provable is inferred and the hand hint
deleted.

The same principle extends to the wire: ``Shuffle`` / ``Broadcast`` /
exchanged ``GroupBy`` / ``Finalize`` nodes carry NO wire-format fields.  The
planner derives per-column ``(lo, hi)`` payload bounds from the identical
statistics pipeline (``PlanInfo.wire``) and the exchange layer ships each
column at its inferred lane width (``core/wire.py``), range-checked at pack
time — authors describe WHAT moves, the compiler decides HOW WIDE.
"""
from __future__ import annotations

from typing import Any, Mapping, Sequence

__all__ = [
    # expressions
    "Expr", "Col", "Lit", "BinOp", "NotE", "Cast", "Where", "Year",
    "AlphaRank", "Like", "StartsWith", "EndsWith", "InSet", "CodeLit",
    "DbScale", "ScalarRef", "Param",
    # nodes
    "Node", "LogicalTable", "Scan", "Filter", "Select", "WithCol", "Rename",
    "Join", "Semi", "Anti", "Left", "GroupBy", "AggScalar", "Shuffle",
    "Broadcast", "Shrink", "Finalize", "ScalarResult",
    # builder helpers
    "scan", "col", "lit", "scode", "isin", "like", "starts_with",
    "ends_with", "alpha_rank", "year", "where", "db_scale", "result",
    "param",
    # reserved sample-ladder bookkeeping columns (repro.approx)
    "SAMPLE_WEIGHT_COL", "SAMPLE_M_COL", "SAMPLE_N_COL",
]

# Reserved column names carried by stratified sample tables
# (repro.approx.sampling): the Horvitz-Thompson scale-up weight n_g/m_g, the
# pre-filter per-stratum sample size m_g, and the true stratum size n_g.
# Plan authors must not define columns with these names.
SAMPLE_WEIGHT_COL = "__sw"
SAMPLE_M_COL = "__sm"
SAMPLE_N_COL = "__sn"


# ---------------------------------------------------------------------------
# expression language
# ---------------------------------------------------------------------------

def _wrap(v) -> "Expr":
    return v if isinstance(v, Expr) else Lit(v)


class Expr:
    """Column-expression tree node.  Operators build bigger trees; nothing is
    evaluated until the planner compiles the enclosing plan against a backend.

    ``__eq__``/``__ne__`` build comparison nodes (DataFrame idiom), so
    expressions must never be used as dict keys — plan DAGs key on ``id()``.
    """

    def __add__(self, o): return BinOp("+", self, _wrap(o))
    def __radd__(self, o): return BinOp("+", _wrap(o), self)
    def __sub__(self, o): return BinOp("-", self, _wrap(o))
    def __rsub__(self, o): return BinOp("-", _wrap(o), self)
    def __mul__(self, o): return BinOp("*", self, _wrap(o))
    def __rmul__(self, o): return BinOp("*", _wrap(o), self)
    def __truediv__(self, o): return BinOp("/", self, _wrap(o))
    def __rtruediv__(self, o): return BinOp("/", _wrap(o), self)
    def __lt__(self, o): return BinOp("<", self, _wrap(o))
    def __le__(self, o): return BinOp("<=", self, _wrap(o))
    def __gt__(self, o): return BinOp(">", self, _wrap(o))
    def __ge__(self, o): return BinOp(">=", self, _wrap(o))
    def __eq__(self, o): return BinOp("==", self, _wrap(o))   # type: ignore
    def __ne__(self, o): return BinOp("!=", self, _wrap(o))   # type: ignore
    def __and__(self, o): return BinOp("&", self, _wrap(o))
    def __rand__(self, o): return BinOp("&", _wrap(o), self)
    def __or__(self, o): return BinOp("|", self, _wrap(o))
    def __ror__(self, o): return BinOp("|", _wrap(o), self)
    def __invert__(self): return NotE(self)
    __hash__ = object.__hash__

    def __bool__(self):
        # `a <= x < b` / `p and q` / `x in [...]` would silently truthify an
        # expression node and drop a conjunct; force the explicit operators
        raise TypeError(
            "an Expr has no truth value: use & | ~ instead of and/or/not, "
            "and split chained comparisons into explicit conjuncts")

    def astype(self, dtype: str) -> "Expr":
        return Cast(self, dtype)


class Col(Expr):
    def __init__(self, name: str):
        self.name = name


class Lit(Expr):
    def __init__(self, value):
        self.value = value


class BinOp(Expr):
    def __init__(self, op: str, a: Expr, b: Expr):
        self.op, self.a, self.b = op, a, b


class NotE(Expr):
    def __init__(self, a: Expr):
        self.a = a


class Cast(Expr):
    def __init__(self, a: Expr, dtype: str):
        self.a, self.dtype = a, dtype


class Where(Expr):
    def __init__(self, cond: Expr, a: Expr, b: Expr):
        self.cond, self.a, self.b = cond, _wrap(a), _wrap(b)


class Year(Expr):
    """Calendar year of an epoch-days expression (host LUT at execution)."""
    def __init__(self, a: Expr):
        self.a = a


class AlphaRank(Expr):
    """Alphabetical rank of a dictionary-encoded column (ORDER BY strings)."""
    def __init__(self, col: str):
        self.col = col


class Like(Expr):
    """Ordered-substring LIKE over the dictionary of ``col``."""
    def __init__(self, col: str, subs: tuple):
        self.col, self.subs = col, subs


class StartsWith(Expr):
    def __init__(self, col: str, prefix: str):
        self.col, self.prefix = col, prefix


class EndsWith(Expr):
    def __init__(self, col: str, suffix: str):
        self.col, self.suffix = col, suffix


class InSet(Expr):
    """Membership in a small literal set (ints or dictionary codes)."""
    def __init__(self, a: Expr, values: Sequence):
        values = tuple(_wrap(v) for v in values)
        if not values:
            # fail at the authoring site, not as an IndexError mid-trace
            raise ValueError("isin: empty value set")
        self.a = a
        self.values = values


class CodeLit(Expr):
    """Dictionary code of an exact string value, resolved host-side at
    compile/execution time (``db.code``)."""
    def __init__(self, col: str, value: str):
        self.col, self.value = col, value


class DbScale(Expr):
    """The database scale factor (host metadata) as a scalar literal."""


class ScalarRef(Expr):
    """One named scalar out of an :class:`AggScalar` node's result."""
    def __init__(self, node: "AggScalar", name: str):
        self.node, self.name = node, name


class Param(Expr):
    """Named runtime parameter of a plan *template*.

    A plan containing ``Param`` nodes is a TEMPLATE: one logical DAG (and one
    jit trace, through ``repro.serve``) serves every parameter binding.  The
    placeholder carries its DOMAIN, not a value:

      * ``lo`` / ``hi`` declare the closed interval every future binding must
        fall in.  The planner folds the **domain** — never any single binding
        — into filter refinement, ``key_bits`` and wire bounds, so a cached
        ``PlanInfo`` (and any compiled program derived from it) is sound for
        every admissible binding.  Bindings outside the domain are rejected
        host-side at bind time (``serve.PlanTemplate.bind``); anything that
        slips past stale statistics still trips the engine's runtime range
        checks into ``ctx.overflow`` — never a silent wrong answer.
      * ``default`` serves when a binding omits the parameter.
      * ``dtype`` ("int64" / "float64") pins the traced scalar's dtype so
        re-binding never re-traces a compiled template; inferred from
        ``lo``/``hi``/``default`` when omitted (float anywhere -> float64).

    Domainless parameters are allowed and simply contribute no provable
    bounds (filters over them refine nothing — the conservative, always-sound
    degradation).
    """

    def __init__(self, name: str, lo=None, hi=None, default=None,
                 dtype: str | None = None):
        if not isinstance(name, str) or not name:
            raise ValueError("param: name must be a non-empty string")
        if (lo is None) != (hi is None):
            raise ValueError(f"param {name!r}: declare both lo and hi, "
                             f"or neither")
        if lo is not None and lo > hi:
            raise ValueError(f"param {name!r}: empty domain [{lo}, {hi}]")
        self.name, self.lo, self.hi, self.default = name, lo, hi, default
        if dtype is None:
            probe = [v for v in (lo, hi, default) if v is not None]
            dtype = "float64" if any(isinstance(v, float) for v in probe) \
                else "int64"
        if dtype not in ("int64", "float64"):
            raise ValueError(f"param {name!r}: unsupported dtype {dtype!r}")
        self.dtype = dtype

    def spec(self) -> tuple:
        """Identity tuple: two placeholders with one name must agree on it."""
        return (self.name, self.lo, self.hi, self.default, self.dtype)


# ---------------------------------------------------------------------------
# plan nodes
# ---------------------------------------------------------------------------

_AGG_OPS = ("sum", "count", "min", "max", "avg")


def _check_aggs(aggs):
    aggs = tuple((n, op, (v if (v is None or isinstance(v, (str, Expr)))
                          else _wrap(v))) for n, op, v in aggs)
    for _, op, _v in aggs:
        if op not in _AGG_OPS:
            raise ValueError(f"unknown aggregate op {op!r}")
    return aggs


class Node:
    """Base plan node.  ``children`` lists input nodes (tables the operator
    consumes); expression-embedded scalar sub-queries are discovered by the
    planner's expression walk, not listed here."""
    children: tuple = ()
    __hash__ = object.__hash__


class LogicalTable(Node):
    """A node producing a (logical) table; carries the fluent builder API."""

    def filter(self, pred: Expr) -> "Filter":
        return Filter(self, pred)

    def select(self, *names: str) -> "Select":
        return Select(self, names)

    def with_col(self, **exprs: Expr) -> "WithCol":
        return WithCol(self, {k: _wrap(v) for k, v in exprs.items()})

    def rename(self, mapping: Mapping[str, str]) -> "Rename":
        return Rename(self, dict(mapping))

    def join(self, build: "LogicalTable", on, build_on,
             take: Sequence[str]) -> "Join":
        return Join(self, build, on, build_on, tuple(take))

    def semi(self, build: "LogicalTable", on, build_on) -> "Semi":
        return Semi(self, build, on, build_on)

    def anti(self, build: "LogicalTable", on, build_on) -> "Anti":
        return Anti(self, build, on, build_on)

    def left(self, build: "LogicalTable", on, build_on, take: Sequence[str],
             defaults: Mapping[str, Any]) -> "Left":
        return Left(self, build, on, build_on, tuple(take), dict(defaults))

    def group_by(self, keys: Sequence[str], aggs, exchange: str = "local",
                 final: bool = False, groups_hint: int | None = None,
                 ) -> "GroupBy":
        return GroupBy(self, tuple(keys), _check_aggs(aggs), exchange, final,
                       groups_hint)

    def agg_scalar(self, aggs) -> "AggScalar":
        return AggScalar(self, _check_aggs(aggs))

    def shuffle(self, key: str) -> "Shuffle":
        return Shuffle(self, key)

    def broadcast(self, p2p: bool = False) -> "Broadcast":
        return Broadcast(self, p2p)

    def shrink(self, cap: int) -> "Shrink":
        return Shrink(self, cap)

    def finalize(self, sort_keys=None, limit: int | None = None,
                 replicated: bool = False) -> "Finalize":
        return Finalize(self, tuple(sort_keys) if sort_keys else None, limit,
                        replicated)


class Scan(LogicalTable):
    def __init__(self, table: str):
        self.table = table


class Filter(LogicalTable):
    def __init__(self, child, pred: Expr):
        self.children = (child,)
        self.pred = pred


class Select(LogicalTable):
    def __init__(self, child, names: Sequence[str]):
        self.children = (child,)
        self.names = tuple(names)


class WithCol(LogicalTable):
    def __init__(self, child, exprs: dict):
        self.children = (child,)
        self.exprs = exprs


class Rename(LogicalTable):
    def __init__(self, child, mapping: dict):
        self.children = (child,)
        self.mapping = mapping


class _JoinBase(LogicalTable):
    def __init__(self, probe, build, on, build_on):
        self.children = (probe, build)
        self.on = on
        self.build_on = build_on

    @property
    def probe(self):
        return self.children[0]

    @property
    def build(self):
        return self.children[1]

    def on_pairs(self) -> list[tuple[str, str]]:
        """(probe_col, build_col) pairs when both sides name plain columns."""
        p = (self.on,) if isinstance(self.on, str) else tuple(self.on)
        b = (self.build_on,) if isinstance(self.build_on, str) \
            else tuple(self.build_on)
        return list(zip(p, b))


class Join(_JoinBase):
    def __init__(self, probe, build, on, build_on, take):
        super().__init__(probe, build, on, build_on)
        self.take = take


class Semi(_JoinBase):
    pass


class Anti(_JoinBase):
    pass


class Left(_JoinBase):
    def __init__(self, probe, build, on, build_on, take, defaults):
        super().__init__(probe, build, on, build_on)
        self.take = take
        self.defaults = defaults


class GroupBy(LogicalTable):
    def __init__(self, child, keys, aggs, exchange, final, groups_hint):
        if exchange not in ("local", "shuffle", "gather"):
            raise ValueError(f"unknown group_by exchange {exchange!r}")
        self.children = (child,)
        self.keys = keys
        self.aggs = aggs
        self.exchange = exchange
        self.final = final
        self.groups_hint = groups_hint   # plan-author claim; planner may tighten


class AggScalar(Node):
    """Scalar aggregation (allreduce).  Index with ``[name]`` to reference one
    result inside later expressions."""

    def __init__(self, child, aggs):
        self.children = (child,)
        self.aggs = aggs

    def __getitem__(self, name: str) -> ScalarRef:
        if name not in [n for n, _, _ in self.aggs]:
            raise KeyError(name)
        return ScalarRef(self, name)


class Shuffle(LogicalTable):
    def __init__(self, child, key: str):
        self.children = (child,)
        self.key = key


class Broadcast(LogicalTable):
    def __init__(self, child, p2p: bool):
        self.children = (child,)
        self.p2p = p2p


class Shrink(LogicalTable):
    def __init__(self, child, cap: int):
        self.children = (child,)
        self.cap = cap


class Finalize(Node):
    """Terminal result collection (gather + global ORDER BY / LIMIT)."""

    def __init__(self, child, sort_keys, limit, replicated):
        self.children = (child,)
        self.sort_keys = sort_keys
        self.limit = limit
        self.replicated = replicated


class ScalarResult(Node):
    """Terminal dict of named scalar expressions (Q6/Q14/Q17/Q19-style)."""

    def __init__(self, exprs: dict):
        self.exprs = {k: _wrap(v) for k, v in exprs.items()}


# ---------------------------------------------------------------------------
# builder helpers
# ---------------------------------------------------------------------------

def scan(table: str) -> Scan:
    return Scan(table)


def col(name: str) -> Col:
    return Col(name)


def lit(value) -> Lit:
    return Lit(value)


def scode(col: str, value: str) -> CodeLit:
    """Dictionary code literal: ``scode("n_name", "FRANCE")``."""
    return CodeLit(col, value)


def isin(a, values: Sequence) -> InSet:
    """Membership in a literal set of ints or ``scode`` values."""
    return InSet(_wrap(a), values)


def like(col: str, *subs: str) -> Like:
    return Like(col, subs)


def starts_with(col: str, prefix: str) -> StartsWith:
    return StartsWith(col, prefix)


def ends_with(col: str, suffix: str) -> EndsWith:
    return EndsWith(col, suffix)


def alpha_rank(col: str) -> AlphaRank:
    return AlphaRank(col)


def year(a) -> Year:
    return Year(_wrap(a))


def where(cond, a, b) -> Where:
    return Where(_wrap(cond), a, b)


def db_scale() -> DbScale:
    return DbScale()


def param(name: str, lo=None, hi=None, default=None,
          dtype: str | None = None) -> Param:
    """Template parameter placeholder with an optional provable domain:
    ``param("cutoff", lo=days("1998-08-03"), hi=days("1998-10-02"))``."""
    return Param(name, lo=lo, hi=hi, default=default, dtype=dtype)


def result(**exprs) -> ScalarResult:
    return ScalarResult(exprs)

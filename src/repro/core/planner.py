"""Compile logical plans (:mod:`repro.core.plan`) to physical ``Context`` calls.

The planner closes the gap ISSUE/ROADMAP call the *hint-threading convention*:
the physical engine's static hints — ``key_bits`` (provable per-column key
widths that unlock the sortless direct-addressing group-by) and
``groups_hint`` (distinct-group bound that shrinks partials before an
exchange) — used to be hand-carried by every query.  Here they are INFERRED
from the plan by bound propagation:

  * **Column statistics.** Host-side min/max per integer column of the
    database (computed once per ``Database`` and cached on it).  Dictionary
    columns are bounded by their dictionary domain (``ctx.dict_bits``'s fact);
    key columns by their generated ranges.  These are trace-time metadata,
    exactly like the string dictionaries.
  * **Refinement through filters.** ``col <cmp> literal`` conjuncts and
    literal-set membership tighten interval and cardinality bounds
    (``l_shipdate`` between two dates bounds ``year(l_shipdate)`` to 2 values).
  * **Interval arithmetic through expressions.** ``with_col`` bounds flow
    through ``+ - *``, ``year``, ``where``, casts; cardinalities multiply.
  * **Inference.** A group-by key column with a provable ``0 <= v <= hi``
    gets ``bits = bit_length(hi)``; when every key is provable and
    ``sum(bits) <= DIRECT_AGG_BITS_MAX`` the planner passes ``key_bits`` and
    the engine takes the sortless direct path (which re-checks each claimed
    width per column at runtime — a mismatch raises the overflow flag, never
    merges groups).  Wider provable widths are deliberately withheld: the
    sorted path's bits-packing carries no runtime check, so it keeps the
    legacy collision-safe packing instead.  The product of key cardinalities
    becomes ``groups_hint``.  A plan-author ``groups_hint=`` survives only
    where inference cannot prove a bound (or is tighter, matching the legacy
    overflow-retry semantics).
  * **Method selection.** When ``key_bits`` is UNPROVABLE but a
    ``groups_hint`` exists (Q13's data-dependent orders-per-customer bound is
    the canonical case), the planner selects the **hash-compaction** path:
    a trace-time on-device dictionary (``kernels/hash_group``) maps rows to
    dense group ids, keeping the group-by sortless with no width claim at
    all.  The dictionary re-checks the claim at runtime — an unplaceable row
    or an undercounting bound raises the overflow flag, and the fault
    runner's capacity escalation scales the dictionary (then drops hints
    entirely, falling back to the single-sort path, if escalation cannot
    help).

Everything inferred is *provable from the database that runs*, so a lying
bound is impossible on the data it was derived from.  A compile whose tables
are NOT the analyzed database (stand-in lowering like the SF=1000 dry-run)
must inject statistics matching the modeled scale or disable inference; as a
backstop, the engine's overflow flag still fires rather than corrupting
results, and the fault runner recompiles without hints after a failed
capacity escalation — inference never weakens the correctness story.

**Exchange placement stays authoritative in the plan** (the paper's §4.4
manual placement).  The planner derives a placement of its own from the §4.3
input partitioning and *validates*: redundant broadcasts/shuffles, group-bys
whose explicit ``local``/exchange disagrees with the derived device-
disjointness, and ``finalize(replicated=)`` flags that contradict the derived
distribution are reported via :func:`validate` / ``CompiledQuery.validate`` —
reported, never silently rewritten.  Paper Table-4 exchange counts are
likewise derived from the IR alone (:func:`static_plan_stats`, no execution).

``REPRO_PLANNER`` selects the default mode: unset/``1`` = inference on;
``0`` = conservative (no hints at all — the legacy unhinted path).  The two
modes are byte-identical per aggregation engine (pinned by
``tests/test_planner.py``; under ``REPRO_AGG_KERNEL=1`` the hinted direct
path sums on the one-hot kernel while the unhinted path uses segment_sum, so
that leg agrees at the same rtol=1e-9 the kernel-vs-oracle suite pins); CI
runs legs with each forced.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import os
from typing import Any, Callable

import numpy as np

from . import plan as P

__all__ = [
    "ColStats", "PlanInfo", "CompiledQuery",
    "analyze", "column_stats", "compile_query", "invalidate_stats",
    "params_of", "plan_signature", "planner_default",
    "register_invalidation", "static_plan_stats", "static_wire_stats",
    "stats_override", "subplan_signatures", "validate",
]

REPL = "replicated"          # partitioning lattice: REPL | tuple(cols) | None
_MAX_HINT = 1 << 31          # cardinality products beyond this are useless


def planner_default() -> bool:
    """Inference on unless REPRO_PLANNER=0 (the conservative CI leg)."""
    return os.environ.get("REPRO_PLANNER", "1").lower() not in \
        ("0", "false", "off")


# ---------------------------------------------------------------------------
# column statistics
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ColStats:
    """Provable bounds for an integer column: ``lo <= v <= hi`` with at most
    ``card`` distinct values.  ``None`` = unknown."""
    lo: int | None = None
    hi: int | None = None
    card: int | None = None

    def clamped(self) -> "ColStats":
        if self.lo is None or self.hi is None:
            return self
        width = max(0, self.hi - self.lo + 1)
        card = width if self.card is None else min(self.card, width)
        return ColStats(self.lo, self.hi, card)


_UNKNOWN = ColStats()


def column_stats(db) -> dict[str, ColStats]:
    """Host-side min/max/cardinality bounds per integer column (cached on db).

    Column names are globally unique in TPC-H (table-prefixed), so one flat
    namespace is enough.  Dictionary-encoded columns additionally clamp to
    their dictionary domain — ``ctx.dict_bits``'s fact, now a planner fact.
    """
    cached = db.__dict__.get("_plan_colstats")
    if cached is not None:
        return cached
    stats: dict[str, ColStats] = {}
    for _tname, cols in db.tables.items():
        for cname, v in cols.items():
            v = np.asarray(v)
            if not np.issubdtype(v.dtype, np.integer) or v.size == 0:
                continue
            lo, hi = int(v.min()), int(v.max())
            if cname in db.dicts:
                lo, hi = max(lo, 0), min(hi, len(db.dicts[cname]) - 1)
            stats[cname] = ColStats(lo, hi).clamped()
    db.__dict__["_plan_colstats"] = stats
    return stats


def _year_of_day(d: int) -> int:
    dt = np.datetime64("1970-01-01") + np.timedelta64(int(d), "D")
    return int(dt.astype("datetime64[Y]").astype(np.int64)) + 1970


def _is_int(v) -> bool:
    return isinstance(v, (int, np.integer)) and not isinstance(v, bool)


def _const(e: P.Expr, db):
    """Resolve a host-constant expression (literals, dictionary codes, scale,
    arithmetic over them); None when not a constant."""
    if isinstance(e, P.Lit):
        return e.value
    if isinstance(e, P.CodeLit):
        return db.code(e.col, e.value)
    if isinstance(e, P.DbScale):
        return db.scale
    if isinstance(e, P.Cast):
        return _const(e.a, db)
    if isinstance(e, P.BinOp) and e.op in ("+", "-", "*", "/"):
        a, b = _const(e.a, db), _const(e.b, db)
        if a is None or b is None:
            return None
        return {"+": a + b, "-": a - b, "*": a * b,
                "/": a / b if b != 0 else None}[e.op]
    return None


def _const_range(e: P.Expr, db):
    """Resolve an expression of host constants AND domained parameters to the
    closed interval ``(lo, hi)`` of values it can take over every admissible
    binding; ``None`` when unbounded.  A plain constant resolves to the
    degenerate interval ``(c, c)``, so template-free plans refine exactly as
    before — and a :class:`P.Param` contributes its declared domain, which is
    what makes one cached ``PlanInfo`` sound for every binding."""
    if isinstance(e, P.Param):
        return None if e.lo is None else (e.lo, e.hi)
    c = _const(e, db)
    if c is not None:
        return (c, c)
    if isinstance(e, P.Cast):
        return _const_range(e.a, db)
    if isinstance(e, P.BinOp) and e.op in ("+", "-", "*"):
        a, b = _const_range(e.a, db), _const_range(e.b, db)
        if a is None or b is None:
            return None
        if e.op == "+":
            return (a[0] + b[0], a[1] + b[1])
        if e.op == "-":
            return (a[0] - b[1], a[1] - b[0])
        prods = [a[0] * b[0], a[0] * b[1], a[1] * b[0], a[1] * b[1]]
        return (min(prods), max(prods))
    return None


def _mul_interval(a: ColStats, b: ColStats) -> tuple[int, int]:
    prods = [a.lo * b.lo, a.lo * b.hi, a.hi * b.lo, a.hi * b.hi]
    return min(prods), max(prods)


def _card_mul(a, b):
    if a is None or b is None:
        return None
    c = a * b
    return c if c <= _MAX_HINT else None


def _expr_stats(e: P.Expr, schema: dict[str, ColStats], db) -> ColStats:
    """Interval/cardinality bounds for an expression over ``schema``."""
    if isinstance(e, P.Col):
        return schema.get(e.name, _UNKNOWN)
    if isinstance(e, P.Lit):
        return ColStats(int(e.value), int(e.value), 1) if _is_int(e.value) \
            else _UNKNOWN
    if isinstance(e, P.CodeLit):
        c = db.code(e.col, e.value)
        return ColStats(c, c, 1)
    if isinstance(e, P.Param):
        # a template parameter is bounded by its declared DOMAIN (one value
        # per binding, any value across bindings) — never by any binding
        if e.dtype == "int64" and e.lo is not None:
            return ColStats(int(math.ceil(e.lo)), int(math.floor(e.hi)),
                            1).clamped()
        return _UNKNOWN
    if isinstance(e, P.Cast):
        return _expr_stats(e.a, schema, db)
    if isinstance(e, P.BinOp) and e.op in ("+", "-", "*"):
        a = _expr_stats(e.a, schema, db)
        b = _expr_stats(e.b, schema, db)
        if None in (a.lo, a.hi, b.lo, b.hi):
            return _UNKNOWN
        if e.op == "+":
            lo, hi = a.lo + b.lo, a.hi + b.hi
        elif e.op == "-":
            lo, hi = a.lo - b.hi, a.hi - b.lo
        else:
            lo, hi = _mul_interval(a, b)
        return ColStats(lo, hi, _card_mul(a.card, b.card)).clamped()
    if isinstance(e, P.Year):
        a = _expr_stats(e.a, schema, db)
        if a.lo is None or a.hi is None:
            return _UNKNOWN
        lo, hi = _year_of_day(a.lo), _year_of_day(a.hi)
        return ColStats(lo, hi, a.card).clamped()
    if isinstance(e, P.Where):
        a = _expr_stats(e.a, schema, db)
        b = _expr_stats(e.b, schema, db)
        if None in (a.lo, a.hi, b.lo, b.hi):
            return _UNKNOWN
        card = None if (a.card is None or b.card is None) else a.card + b.card
        return ColStats(min(a.lo, b.lo), max(a.hi, b.hi), card).clamped()
    if isinstance(e, P.AlphaRank):
        n = len(db.dicts[e.col])
        return ColStats(0, n - 1, n)
    return _UNKNOWN


_FLIP = {"<": ">", "<=": ">=", ">": "<", ">=": "<=", "==": "=="}


def _refine_filter(pred: P.Expr, schema: dict[str, ColStats], db
                   ) -> dict[str, ColStats]:
    """Tighten column bounds through the conjuncts of a filter predicate.

    Comparisons against host constants AND against domained template
    parameters refine — the latter by the WEAKEST bound over the parameter
    domain (``v <= p`` keeps rows up to the domain's hi, ``v >= p`` down to
    its lo), so the refinement is sound for every binding the template
    admits, not just one literal."""
    out = dict(schema)

    def _mn(a, b):
        return b if a is None else (a if b is None else min(a, b))

    def _mx(a, b):
        return b if a is None else (a if b is None else max(a, b))

    def _num(v) -> bool:
        return isinstance(v, (int, float, np.number)) and \
            not isinstance(v, bool)

    def apply(name: str, op: str, rng):
        s = out.get(name)
        if s is None or rng is None or not (_num(rng[0]) and _num(rng[1])):
            return
        clo, chi = rng
        lo, hi, card = s.lo, s.hi, s.card
        if op == "<=":                       # v <= c, c anywhere in [clo,chi]
            hi = _mn(hi, math.floor(chi))
        elif op == "<":
            hi = _mn(hi, math.ceil(chi) - 1)
        elif op == ">=":
            lo = _mx(lo, math.ceil(clo))
        elif op == ">":
            lo = _mx(lo, math.floor(clo) + 1)
        elif op == "==":
            # v equals SOME value in [clo, chi]: both ends clamp; the
            # surviving width bounds the distinct count (1 for a constant)
            lo = _mx(lo, math.ceil(clo))
            hi = _mn(hi, math.floor(chi))
            if lo is not None and hi is not None:
                card = _mn(card, max(1, hi - lo + 1))
        out[name] = ColStats(lo, hi, card).clamped()

    def visit(e):
        if isinstance(e, P.BinOp) and e.op == "&":
            visit(e.a)
            visit(e.b)
            return
        if isinstance(e, P.BinOp) and e.op in _FLIP:
            if isinstance(e.a, P.Col):
                apply(e.a.name, e.op, _const_range(e.b, db))
            elif isinstance(e.b, P.Col):
                apply(e.b.name, _FLIP[e.op], _const_range(e.a, db))
            return
        if isinstance(e, P.InSet) and isinstance(e.a, P.Col):
            vals = [_const(v, db) for v in e.values]
            if vals and all(_is_int(v) for v in vals):
                s = out.get(e.a.name)
                if s is not None:
                    lo = _mx(s.lo, min(vals))
                    hi = _mn(s.hi, max(vals))
                    card = len(set(vals)) if s.card is None \
                        else min(s.card, len(set(vals)))
                    out[e.a.name] = ColStats(lo, hi, card).clamped()

    visit(pred)
    return out


# ---------------------------------------------------------------------------
# plan walking
# ---------------------------------------------------------------------------

def _expr_children(e: P.Expr):
    if isinstance(e, P.BinOp):
        return (e.a, e.b)
    if isinstance(e, (P.NotE, P.Cast, P.Year)):
        return (e.a,)
    if isinstance(e, P.Where):
        return (e.cond, e.a, e.b)
    if isinstance(e, P.InSet):
        return (e.a,) + e.values
    return ()


def _expr_scalar_nodes(e: P.Expr) -> list:
    """AggScalar nodes referenced (via ScalarRef) inside an expression."""
    out, stack = [], [e]
    while stack:
        x = stack.pop()
        if isinstance(x, P.ScalarRef):
            out.append(x.node)
        stack.extend(_expr_children(x))
    return out


def _node_exprs(node: P.Node):
    if isinstance(node, P.Filter):
        return (node.pred,)
    if isinstance(node, P.WithCol):
        return tuple(node.exprs.values())
    if isinstance(node, (P.GroupBy, P.AggScalar)):
        return tuple(v for _, _, v in node.aggs if isinstance(v, P.Expr))
    if isinstance(node, P.ScalarResult):
        return tuple(node.exprs.values())
    return ()


def walk(root: P.Node) -> list[P.Node]:
    """Every node reachable from ``root`` — through child edges AND through
    scalar sub-queries embedded in expressions — each exactly once."""
    seen: dict[int, P.Node] = {}
    stack = [root]
    while stack:
        n = stack.pop()
        if id(n) in seen:
            continue
        seen[id(n)] = n
        stack.extend(n.children)
        for e in _node_exprs(n):
            stack.extend(_expr_scalar_nodes(e))
    return list(seen.values())


def static_plan_stats(root: P.Node) -> dict[str, int]:
    """Exchange counts derived from the IR alone — no database, no execution.

    Mirrors the backends' ``_count`` bookkeeping exactly (each DAG node
    executes once), so these equal runtime ``PlanStats.counts()`` on every
    backend and are asserted against paper Table 4 in
    ``tests/test_plan_stats.py``.
    """
    c = {"shuffles": 0, "broadcasts": 0, "final_gathers": 0, "allreduces": 0}
    for n in walk(root):
        if isinstance(n, P.Shuffle):
            c["shuffles"] += 1
        elif isinstance(n, P.Broadcast):
            c["broadcasts"] += 1
        elif isinstance(n, P.GroupBy):
            if n.exchange == "shuffle":
                c["shuffles"] += 1
            elif n.exchange == "gather":
                c["final_gathers" if n.final else "broadcasts"] += 1
        elif isinstance(n, P.AggScalar):
            c["allreduces"] += 1
        elif isinstance(n, P.Finalize) and not n.replicated:
            c["final_gathers"] += 1
    return c


# ---------------------------------------------------------------------------
# content plan signatures (compiled-plan cache keys + lineage fingerprints)
# ---------------------------------------------------------------------------

def _expr_sig(e: P.Expr, nsig) -> str:
    """Canonical serialization of an expression tree.  ``nsig(node)`` resolves
    an embedded scalar sub-query (:class:`P.ScalarRef`) to a stable string."""
    if isinstance(e, P.Col):
        return f"c:{e.name}"
    if isinstance(e, P.Lit):
        return f"l:{e.value!r}"
    if isinstance(e, P.CodeLit):
        return f"sc:{e.col}={e.value!r}"
    if isinstance(e, P.DbScale):
        return "dbscale"
    if isinstance(e, P.Param):
        return f"prm:{e.spec()!r}"
    if isinstance(e, P.ScalarRef):
        return f"sq:{nsig(e.node)}[{e.name}]"
    if isinstance(e, P.BinOp):
        return f"({_expr_sig(e.a, nsig)}{e.op}{_expr_sig(e.b, nsig)})"
    if isinstance(e, P.NotE):
        return f"~({_expr_sig(e.a, nsig)})"
    if isinstance(e, P.Cast):
        return f"cast[{e.dtype}]({_expr_sig(e.a, nsig)})"
    if isinstance(e, P.Where):
        return (f"where({_expr_sig(e.cond, nsig)},{_expr_sig(e.a, nsig)},"
                f"{_expr_sig(e.b, nsig)})")
    if isinstance(e, P.Year):
        return f"year({_expr_sig(e.a, nsig)})"
    if isinstance(e, P.AlphaRank):
        return f"rank:{e.col}"
    if isinstance(e, P.Like):
        return f"like:{e.col}~{e.subs!r}"
    if isinstance(e, P.StartsWith):
        return f"pre:{e.col}~{e.prefix!r}"
    if isinstance(e, P.EndsWith):
        return f"suf:{e.col}~{e.suffix!r}"
    if isinstance(e, P.InSet):
        vals = ",".join(_expr_sig(v, nsig) for v in e.values)
        return f"in({_expr_sig(e.a, nsig)};{vals})"
    raise TypeError(f"cannot serialize {type(e).__name__}")


def _aggs_sig(aggs, nsig) -> str:
    parts = []
    for name, op, v in aggs:
        if v is None:
            vs = "-"
        elif isinstance(v, str):
            vs = f"c:{v}"
        else:
            vs = _expr_sig(v, nsig)
        parts.append(f"{name}={op}({vs})")
    return ",".join(parts)


def _node_sig(n: P.Node, nsig) -> str:
    """One node's own content (type + every semantic attribute + expression
    trees); children/sub-queries are referenced through ``nsig``, never
    inlined, so the caller chooses identity- or content-addressing."""
    t = type(n).__name__
    if isinstance(n, P.Scan):
        return f"{t}:{n.table}"
    if isinstance(n, P.Filter):
        return f"{t}:{_expr_sig(n.pred, nsig)}"
    if isinstance(n, P.Select):
        return f"{t}:{','.join(n.names)}"
    if isinstance(n, P.WithCol):
        # insertion order kept: a later expr may read an earlier new column
        inner = ",".join(f"{k}={_expr_sig(e, nsig)}"
                         for k, e in n.exprs.items())
        return f"{t}:{inner}"
    if isinstance(n, P.Rename):
        return f"{t}:{sorted(n.mapping.items())!r}"
    if isinstance(n, P.Left):
        return (f"{t}:on={n.on!r}/{n.build_on!r}:take={n.take!r}"
                f":def={sorted(n.defaults.items())!r}")
    if isinstance(n, P.Join):
        return f"{t}:on={n.on!r}/{n.build_on!r}:take={n.take!r}"
    if isinstance(n, (P.Semi, P.Anti)):
        return f"{t}:on={n.on!r}/{n.build_on!r}"
    if isinstance(n, P.GroupBy):
        return (f"{t}:keys={list(n.keys)!r}:aggs={_aggs_sig(n.aggs, nsig)}"
                f":x={n.exchange}:final={n.final}:gh={n.groups_hint}")
    if isinstance(n, P.AggScalar):
        return f"{t}:aggs={_aggs_sig(n.aggs, nsig)}"
    if isinstance(n, P.Shuffle):
        return f"{t}:{n.key}"
    if isinstance(n, P.Broadcast):
        return f"{t}:p2p={n.p2p}"
    if isinstance(n, P.Shrink):
        return f"{t}:{n.cap}"
    if isinstance(n, P.Finalize):
        return (f"{t}:sort={n.sort_keys!r}:limit={n.limit}"
                f":repl={n.replicated}")
    if isinstance(n, P.ScalarResult):
        inner = ",".join(f"{k}={_expr_sig(e, nsig)}"
                         for k, e in n.exprs.items())
        return f"{t}:{inner}"
    raise TypeError(f"cannot serialize {t}")


def plan_signature(root: P.Node) -> str:
    """CONTENT signature of a plan: every node in deterministic ``walk``
    order — type, semantic attributes, expression trees (parameters by their
    full spec, never a binding) — plus the exact child/sub-query wiring by
    walk ordinal.  Two plans share a signature iff they are the same logical
    program, so it is the key material for the compiled-plan cache and (with
    the bindings appended) the lineage fingerprint; same-shaped plans with
    different columns, keys, literals or DAG sharing all diverge — the
    collision class of the old type-name-only fingerprint."""
    return _walk_signature(walk(root))


def _walk_signature(nodes) -> str:
    """:func:`plan_signature` body over an already-walked node list — shared
    with :func:`repro.distributed.lineage.plan_fingerprint`, which receives
    the executor's walk order rather than a root."""
    ordinal = {id(n): i for i, n in enumerate(nodes)}

    def nsig(m):
        return f"#{ordinal[id(m)]}"

    parts = []
    for i, n in enumerate(nodes):
        kids = ",".join(f"#{ordinal[id(c)]}" for c in n.children)
        parts.append(f"{i}={_node_sig(n, nsig)}<-[{kids}]")
    return ";".join(parts)


def subplan_signatures(root: P.Node) -> dict[int, tuple[str, frozenset]]:
    """Per-node ``id -> (subtree content hash, reachable parameter names)``.

    The hash content-addresses the whole SUBTREE (scalar sub-queries
    inlined), so two queries in a batch that share a logical subplan — same
    scan, same filtered fragment — hash alike even when built as distinct
    objects: the serving batch executor's cross-query memo keys on it.  The
    parameter set names which bindings the subtree's result can depend on, so
    the memo key only includes the bindings that matter."""
    memo: dict[int, tuple[str, frozenset]] = {}

    def expr_params(e: P.Expr, acc: set):
        if isinstance(e, P.Param):
            acc.add(e.name)
        elif isinstance(e, P.ScalarRef):
            acc.update(sub(e.node)[1])
        for ch in _expr_children(e):
            expr_params(ch, acc)

    def sub(n: P.Node) -> tuple[str, frozenset]:
        got = memo.get(id(n))
        if got is not None:
            return got
        local = _node_sig(n, lambda m: sub(m)[0])
        pnames: set = set()
        for e in _node_exprs(n):
            expr_params(e, pnames)
        kids = [sub(ch) for ch in n.children]
        text = local + "|" + ",".join(h for h, _ in kids)
        for _h, ps in kids:
            pnames.update(ps)
        out = (hashlib.blake2b(text.encode(), digest_size=16).hexdigest(),
               frozenset(pnames))
        memo[id(n)] = out
        return out

    sub(root)
    return memo


def params_of(root: P.Node) -> dict[str, P.Param]:
    """Every parameter placeholder reachable from ``root``, by name.  Two
    placeholders sharing a name must agree on the full spec (domain, default,
    dtype) — a conflict is an authoring error, raised here."""
    out: dict[str, P.Param] = {}

    def visit_expr(e: P.Expr):
        if isinstance(e, P.Param):
            prev = out.get(e.name)
            if prev is not None and prev.spec() != e.spec():
                raise ValueError(
                    f"param {e.name!r}: conflicting declarations "
                    f"{prev.spec()} vs {e.spec()}")
            out[e.name] = e
        for ch in _expr_children(e):
            visit_expr(ch)

    for n in walk(root):
        for e in _node_exprs(n):
            visit_expr(e)
    return out


# ---------------------------------------------------------------------------
# static wire-byte derivation (dtype propagation over the IR, no execution)
# ---------------------------------------------------------------------------

def _expr_scalar_nodes_ordered(e: P.Expr) -> list:
    """AggScalar nodes inside an expression, in EVALUATION order (the order
    ``_Executor._eval`` resolves ScalarRefs) — unlike the unordered
    :func:`_expr_scalar_nodes` walk used for reachability."""
    out: list = []
    if isinstance(e, P.ScalarRef):
        out.append(e.node)
    for ch in _expr_children(e):
        out.extend(_expr_scalar_nodes_ordered(ch))
    return out


def _agg_dtype(op: str, operand) -> np.dtype:
    """Aggregate output dtype, matching all three engines (count -> int64;
    integer sums -> int64; float sums / min / max preserve the operand)."""
    if op == "count":
        return np.dtype(np.int64)
    dt = np.result_type(operand)
    if op == "sum":
        return np.dtype(np.int64) if dt.kind in "biu" else dt
    if op == "avg":
        return np.dtype(np.float64)
    return dt                                   # min / max


def _expand_avg_static(aggs):
    """avg -> (__name_s sum, __name_c count): the PARTIAL column set an
    exchanged group-by actually moves (mirrors ``backend._expand_avg``)."""
    out = []
    for name, op, v in aggs:
        if op == "avg":
            out.append((f"__{name}_s", "sum", v))
            out.append((f"__{name}_c", "count", None))
        else:
            out.append((name, op, v))
    return out


class _DtypeWalker:
    """Column-dtype propagation over a plan DAG.

    Mirrors the executors' value semantics at the type level only (numpy and
    jnp promote identically for this engine's dtypes under x64), so the
    static wire layout of every exchange payload can be derived from the IR
    with no execution."""

    def __init__(self, db):
        self.db = db
        self.memo: dict[int, dict[str, np.dtype]] = {}

    # -- expressions: operand is an np.dtype or a host scalar (weak) --------
    def _operand(self, e: P.Expr, sdt: dict):
        if isinstance(e, P.Col):
            return sdt[e.name]
        if isinstance(e, P.Lit):
            return e.value
        if isinstance(e, P.CodeLit):
            return self.db.code(e.col, e.value)
        if isinstance(e, P.DbScale):
            return self.db.scale
        if isinstance(e, P.Param):
            return np.dtype(e.dtype)     # pinned: re-binding never re-types
        if isinstance(e, P.Cast):
            return np.dtype(e.dtype)
        if isinstance(e, P.ScalarRef):
            for name, op, v in e.node.aggs:
                if name == e.name:
                    child_dt = self.dtypes(e.node.children[0])
                    return _agg_dtype(op, self._operand_of_agg(v, child_dt))
            raise KeyError(e.name)
        if isinstance(e, P.BinOp):
            if e.op in ("<", "<=", ">", ">=", "==", "!="):
                return np.dtype(np.bool_)
            a = self._operand(e.a, sdt)
            b = self._operand(e.b, sdt)
            # & | promote like the executors' generic bitwise ops: bool for
            # bool operands (the filter-mask case), integer for integer ones
            r = np.result_type(a, b)
            if e.op == "/" and r.kind in "biu":
                return np.dtype(np.float64)     # true division
            return r
        if isinstance(e, P.NotE):
            return np.result_type(self._operand(e.a, sdt))
        if isinstance(e, P.Where):
            return np.result_type(self._operand(e.a, sdt),
                                  self._operand(e.b, sdt))
        if isinstance(e, (P.Year, P.AlphaRank)):
            return np.dtype(np.int64)
        if isinstance(e, (P.Like, P.StartsWith, P.EndsWith, P.InSet)):
            return np.dtype(np.bool_)
        raise TypeError(f"cannot type {type(e).__name__}")

    def _operand_of_agg(self, v, sdt):
        """Agg value spec: column name | expression | None (count)."""
        if v is None:
            return np.dtype(np.int64)
        if isinstance(v, str):
            return sdt[v]
        return self._operand(v, sdt)

    def expr_dtype(self, e: P.Expr, sdt: dict) -> np.dtype:
        return np.result_type(self._operand(e, sdt))

    # -- nodes --------------------------------------------------------------
    def dtypes(self, n: P.Node) -> dict[str, np.dtype]:
        got = self.memo.get(id(n))
        if got is not None:
            return got
        if isinstance(n, P.Scan):
            s = {c: np.dtype(v.dtype)
                 for c, v in self.db.tables[n.table].items()}
        elif isinstance(n, (P.Filter, P.Shuffle, P.Broadcast, P.Shrink)):
            s = dict(self.dtypes(n.children[0]))
        elif isinstance(n, P.Select):
            ch = self.dtypes(n.children[0])
            s = {c: ch[c] for c in n.names}
        elif isinstance(n, P.WithCol):
            s = dict(self.dtypes(n.children[0]))
            for name, e in n.exprs.items():
                s[name] = self.expr_dtype(e, s)
        elif isinstance(n, P.Rename):
            s = {n.mapping.get(c, c): v
                 for c, v in self.dtypes(n.children[0]).items()}
        elif isinstance(n, (P.Join, P.Left)):
            s = dict(self.dtypes(n.probe))
            bs = self.dtypes(n.build)
            for c in n.take:
                s[c] = bs[c]
            if isinstance(n, P.Left):
                s["__matched"] = np.dtype(np.bool_)
        elif isinstance(n, (P.Semi, P.Anti)):
            s = dict(self.dtypes(n.probe))
        elif isinstance(n, P.GroupBy):
            ch = self.dtypes(n.children[0])
            s = {k: ch[k] for k in n.keys}
            for name, op, v in n.aggs:
                s[name] = _agg_dtype(op, self._operand_of_agg(v, ch))
        else:           # Finalize / ScalarResult / AggScalar: not a table
            s = {}
        self.memo[id(n)] = s
        return s

    def payload(self, n: P.Node) -> dict[str, np.dtype]:
        """Column dtypes of the payload an exchange node moves."""
        if isinstance(n, P.GroupBy):
            ch = self.dtypes(n.children[0])
            s = {k: ch[k] for k in n.keys}
            for name, op, v in _expand_avg_static(n.aggs):
                s[name] = _agg_dtype(op, self._operand_of_agg(v, ch))
            return s
        return self.dtypes(n.children[0])


def static_wire_stats(root: P.Node, db, narrow: bool = True,
                      info: "PlanInfo | None" = None) -> list[dict]:
    """Per-exchange wire descriptors derived from the IR alone — no execution.

    Returns, in EXECUTION order (the order the backends log
    ``ExchangeStats``), one entry per exchange:
    ``{kind, row_wire_bytes, row_logical_bytes, wire}``.  These equal the
    runtime stats on every backend (asserted in ``tests/test_wire.py``), so
    wire-byte budgets are CI-gateable on CPU with no cluster
    (``benchmarks/bench_exchange_bytes.py``).  Pass a cached ``info``
    (``CompiledQuery.info``) to skip re-analysis; the wide leg needs no
    bounds and never analyzes.
    """
    from . import wire as wi      # deferred: wire pulls in jax
    if info is None and narrow:
        info = analyze(root, db)
    dtw = _DtypeWalker(db)
    entries: list[dict] = []
    seen: set[int] = set()

    def emit(kind: str, n: P.Node, force_wide: bool = False):
        dt = dtw.payload(n)
        use_narrow = narrow and not force_wide
        fmt = wi.plan_wire_format(
            sorted(dt), dt, bounds=info.wire_for(n) if use_narrow else None,
            narrow=use_narrow)
        # report the format's OWN verdict: plan_wire_format may demote a
        # latency-bound message to wide (wire.hockney_skip), and runtime
        # stats tag what actually shipped
        entries.append({"kind": kind, "row_wire_bytes": fmt.row_wire_bytes,
                        "row_logical_bytes": fmt.row_logical_bytes,
                        "wire": "narrow" if fmt.narrow else "wide"})

    def visit(n: P.Node):
        if id(n) in seen:
            return
        seen.add(id(n))
        for ch in n.children:
            visit(ch)
        for e in _node_exprs(n):
            for sub in _expr_scalar_nodes_ordered(e):
                visit(sub)
        if isinstance(n, P.Shuffle):
            emit("shuffle", n)
        elif isinstance(n, P.Broadcast):
            emit("broadcast_p2p" if n.p2p else "broadcast", n,
                 force_wide=n.p2p)          # §7.1 baseline stays wide
        elif isinstance(n, P.GroupBy) and n.exchange != "local":
            emit("shuffle" if n.exchange == "shuffle"
                 else ("gather" if n.final else "broadcast"), n)
        elif isinstance(n, P.Finalize) and not n.replicated:
            emit("gather", n)

    visit(root)
    return entries


# ---------------------------------------------------------------------------
# analysis: schemas, hints, derived placement
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PlanInfo:
    """Result of :func:`analyze`: per-group-by inferred hints, the derived
    partitioning per node, per-exchange wire bounds, validation notes, and
    static exchange counts."""
    group_hints: dict[int, tuple[tuple[int, ...] | None, int | None]]
    parts: dict[int, Any]
    notes: list[str]
    counts: dict[str, int]
    # per exchange-performing node: {column: (lo, hi)} provable value bounds
    # of the payload — the statistics the narrow wire format is derived from
    wire: dict[int, dict[str, tuple[int, int]]] = \
        dataclasses.field(default_factory=dict)
    # per group-by: explicit aggregation method, or None for the engine's
    # own direct/sort auto-dispatch.  The one rule today: "hash" when a
    # groups_hint exists (author-claimed or inferred) but key_bits is
    # unprovable — the data-dependent-domain shape (Q13) the direct path
    # cannot take, extended to zero sorts by the trace-time dictionary.
    methods: dict[int, str] = dataclasses.field(default_factory=dict)

    def hints_for(self, node: P.GroupBy):
        return self.group_hints.get(id(node), (None, None))

    def method_for(self, node: P.GroupBy) -> str | None:
        return self.methods.get(id(node))

    def wire_for(self, node: P.Node):
        return self.wire.get(id(node))


def _partition_keys() -> dict:
    from . import backend as B     # deferred: backend pulls in jax
    return B.PARTITION_KEYS


def analyze(root: P.Node, db) -> PlanInfo:
    base = column_stats(db)
    pkeys = _partition_keys()
    schemas: dict[int, dict[str, ColStats]] = {}
    parts: dict[int, Any] = {}
    notes: list[str] = []
    nodes = walk(root)
    consumers: dict[int, list[tuple[P.Node, int]]] = {}
    for n in nodes:
        for i, ch in enumerate(n.children):
            consumers.setdefault(id(ch), []).append((n, i))

    def label(n):
        return type(n).__name__

    # -- schema (column bounds) -------------------------------------------
    def schema(n: P.Node) -> dict[str, ColStats]:
        got = schemas.get(id(n))
        if got is not None:
            return got
        if isinstance(n, P.Scan):
            s = {c: base[c] for c in db.tables[n.table] if c in base}
        elif isinstance(n, P.Filter):
            s = _refine_filter(n.pred, schema(n.children[0]), db)
        elif isinstance(n, P.Select):
            ch = schema(n.children[0])
            s = {c: ch[c] for c in n.names if c in ch}
        elif isinstance(n, P.WithCol):
            s = dict(schema(n.children[0]))
            for name, e in n.exprs.items():
                s[name] = _expr_stats(e, s, db)
        elif isinstance(n, P.Rename):
            s = {n.mapping.get(c, c): v
                 for c, v in schema(n.children[0]).items()}
        elif isinstance(n, (P.Join, P.Left)):
            s = dict(schema(n.probe))
            bs = schema(n.build)
            for c in n.take:
                s[c] = bs.get(c, _UNKNOWN)
            if isinstance(n, P.Left):
                for c in n.take:
                    d = n.defaults.get(c)
                    t = s.get(c, _UNKNOWN)
                    if _is_int(d) and t.lo is not None and t.hi is not None:
                        s[c] = ColStats(min(t.lo, int(d)), max(t.hi, int(d)),
                                        None if t.card is None
                                        else t.card + 1).clamped()
                    else:
                        s[c] = _UNKNOWN
        elif isinstance(n, (P.Semi, P.Anti)):
            s = dict(schema(n.probe))
        elif isinstance(n, P.GroupBy):
            ch = schema(n.children[0])
            s = {k: ch.get(k, _UNKNOWN) for k in n.keys}
            for name, op, v in n.aggs:
                if op in ("min", "max"):
                    s[name] = ch.get(v, _UNKNOWN) if isinstance(v, str) else \
                        (_expr_stats(v, ch, db) if isinstance(v, P.Expr)
                         else _UNKNOWN)
                elif op == "count":
                    s[name] = ColStats(0, None, None)
                else:
                    s[name] = _UNKNOWN
        elif isinstance(n, (P.Shuffle, P.Broadcast, P.Shrink)):
            s = schema(n.children[0])
        else:           # Finalize / ScalarResult / AggScalar: not a table
            s = {}
        schemas[id(n)] = s
        return s

    # -- derived placement -------------------------------------------------
    def part(n: P.Node):
        got = parts.get(id(n), "__miss__")
        if got != "__miss__":
            return got
        p: Any
        if isinstance(n, P.Scan):
            k = pkeys.get(n.table)
            p = REPL if k is None else (k,)
        elif isinstance(n, (P.Filter, P.Select, P.Shrink)):
            p = part(n.children[0])
        elif isinstance(n, P.WithCol):
            p = part(n.children[0])
            if isinstance(p, tuple) and any(c in n.exprs for c in p):
                p = None            # partition column overwritten: unknown
        elif isinstance(n, P.Rename):
            p = part(n.children[0])
            if isinstance(p, tuple):
                p = tuple(n.mapping.get(c, c) for c in p)
        elif isinstance(n, P.Shuffle):
            p = (n.key,)
        elif isinstance(n, P.Broadcast):
            p = REPL
        elif isinstance(n, P._JoinBase):
            p = _join_part(n)
        elif isinstance(n, P.GroupBy):
            if n.exchange == "local":
                p = part(n.children[0])
            elif n.exchange == "shuffle":
                p = tuple(n.keys)
            else:
                p = REPL
        else:
            p = None
        parts[id(n)] = p
        return p

    def _translate(build_part, pairs):
        m = {b: pr for pr, b in pairs}
        if all(c in m for c in build_part):
            return tuple(m[c] for c in build_part)
        return None

    def _join_part(n: P._JoinBase):
        pp, bp = part(n.probe), part(n.build)
        pairs = n.on_pairs()
        if pp is None or bp is None:
            return pp
        if bp == REPL:
            if pp == REPL:
                return REPL
            return pp
        if pp == REPL:
            # replicated probe x partitioned build: every probe row matches on
            # exactly one device (unique build keys) -> output is partitioned
            # by the probe-side join column (the Q18 idiom); sound for inner
            # joins only — semi/anti would filter by a per-device subset.
            if isinstance(n, P.Join):
                return _translate(bp, pairs)
            notes.append(f"{label(n)}: replicated probe against partitioned "
                         f"build {bp} filters by a per-device subset")
            return None
        if _translate(bp, pairs) == pp:
            return pp               # co-partitioned
        notes.append(f"{label(n)} on {pairs}: build partitioned by {bp}, "
                     f"probe by {pp} — not co-partitioned and build not "
                     f"replicated (an exchange is missing)")
        return pp

    def _membership_only(n: P.Node) -> bool:
        """True if a table is consumed — possibly via select/rename/broadcast
        — only as the build side of semi/anti joins (key membership), where a
        per-device partial group-by is still globally correct."""
        for parent, role in consumers.get(id(n), []):
            if isinstance(parent, (P.Select, P.Rename, P.Broadcast)):
                if not _membership_only(parent):
                    return False
            elif isinstance(parent, (P.Semi, P.Anti)) and role == 1:
                continue
            else:
                return False
        return bool(consumers.get(id(n)))

    # -- validation of explicit placement against the derived one ----------
    for n in nodes:
        part(n)
        if isinstance(n, P.Broadcast) and part(n.children[0]) == REPL:
            notes.append("Broadcast of an already-replicated table "
                         "(removable)")
        elif isinstance(n, P.Shuffle) and part(n.children[0]) == (n.key,):
            notes.append(f"Shuffle to {n.key!r}: input already partitioned "
                         f"by it (removable)")
        elif isinstance(n, P.GroupBy):
            cp = part(n.children[0])
            if n.exchange == "local":
                disjoint = cp == REPL or (isinstance(cp, tuple) and
                                          set(cp) <= set(n.keys))
                if cp is not None and not disjoint and \
                        not _membership_only(n):
                    notes.append(
                        f"group_by(local) on {list(n.keys)} over input "
                        f"partitioned by {cp}: groups span devices and the "
                        f"result is consumed as a global aggregate")
            elif isinstance(cp, tuple) and set(cp) <= set(n.keys):
                notes.append(
                    f"group_by({n.exchange}) on {list(n.keys)}: input already "
                    f"partitioned by {cp} — exchange removable (paper-plan "
                    f"placement kept)")
        elif isinstance(n, P.Finalize):
            cp = part(n.children[0])
            if n.replicated and cp not in (REPL, None):
                notes.append(f"finalize(replicated=True) over input "
                             f"partitioned by {cp}")
            elif not n.replicated and cp == REPL:
                notes.append("finalize gathers an already-replicated table "
                             "(replicated=True would skip the exchange)")

    # -- hint inference ----------------------------------------------------
    # key_bits are only emitted when they unlock the DIRECT path: that path
    # re-checks every claimed width per column at runtime and raises the
    # overflow flag on a mismatch (stale stats, mutated tables).  The sorted
    # path's bits-packing has no such check, so wider provable widths are
    # withheld and multi-column sorted group-bys keep the legacy
    # collision-safe 32-bit-shift packing.
    direct_max = _direct_bits_max()
    hash_max = _hash_groups_max()
    hints: dict[int, tuple] = {}
    methods: dict[int, str] = {}
    for n in nodes:
        if not isinstance(n, P.GroupBy):
            continue
        ch = schema(n.children[0])
        bits: list[int] | None = []
        card: int | None = 1
        for k in n.keys:
            s = ch.get(k, _UNKNOWN)
            if bits is not None and s.lo is not None and s.lo >= 0 \
                    and s.hi is not None:
                bits.append(max(1, int(s.hi).bit_length()))
            else:
                bits = None
            card = _card_mul(card, s.card)
        key_bits = tuple(bits) if (n.keys and bits is not None and
                                   sum(bits) <= direct_max) else None
        gh = card if (n.keys and card is not None) else None
        if n.groups_hint is not None:
            gh = n.groups_hint if gh is None else min(gh, n.groups_hint)
        hints[id(n)] = (key_bits, gh)
        # the hash-compaction rule: a group bound exists (typically a plan-
        # author claim like Q13's orders-per-customer histogram) but the key
        # domain is unprovable — the direct path is out, yet a trace-time
        # dictionary of groups_hint keys keeps the group-by sortless.  The
        # engine re-checks at runtime: an unplaceable row or an undercounting
        # bound raises ctx.overflow, never a silent merge/drop.
        if key_bits is None and gh is not None and gh <= hash_max and \
                1 <= len(n.keys) <= 2:
            methods[id(n)] = "hash"

    # -- wire bounds per exchange payload ----------------------------------
    # The narrow wire format ships each exchanged column at the lane width
    # its provable (lo, hi) bounds allow — the SAME statistics key_bits came
    # from, now applied to every exchanged column instead of group keys only.
    # The engine range-checks every claim at pack time (ctx.overflow on a
    # lie), mirroring key_bits' runtime-check contract.
    def _payload_bounds(schema_map) -> dict[str, tuple[int, int]]:
        return {c: (s.lo, s.hi) for c, s in schema_map.items()
                if s.lo is not None and s.hi is not None}

    wire: dict[int, dict[str, tuple[int, int]]] = {}
    for n in nodes:
        if isinstance(n, (P.Shuffle, P.Broadcast)):
            wire[id(n)] = _payload_bounds(schema(n.children[0]))
        elif isinstance(n, P.Finalize) and not n.replicated:
            wire[id(n)] = _payload_bounds(schema(n.children[0]))
        elif isinstance(n, P.GroupBy) and n.exchange != "local":
            # the exchange moves the PARTIAL aggregate: keys + agg columns
            # (avg's sum/count temporaries are unbounded and ship full-width)
            wire[id(n)] = _payload_bounds(schema(n))

    return PlanInfo(hints, parts, notes, static_plan_stats(root), wire,
                    methods)


def validate(root: P.Node, db) -> list[str]:
    """Disagreements between the plan's explicit exchange placement and the
    placement derived from §4.3 partitioning.  Empty list = clean."""
    return analyze(root, db).notes


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------

def _is_exchange_node(node: P.Node) -> bool:
    """Nodes whose output is a post-exchange (replicated / reshuffled) state
    — the lineage-snapshot cut points."""
    return isinstance(node, (P.Shuffle, P.Broadcast)) or \
        (isinstance(node, P.GroupBy) and node.exchange != "local")


class _Executor:
    """Walk a plan DAG against a physical Context; each node runs once (the
    per-plan memo is also what makes the backend's build-side cache hit).

    When the context carries a ``lineage`` store
    (:class:`repro.distributed.lineage.LineageStore`, eager local runs
    only), every exchange-type node consults the store BEFORE recursing:
    a snapshot hit returns the durable post-exchange table and skips the
    entire subtree — depth-first from the root, so a query resumes from the
    topmost (= last computed, fewest-ops-remaining) durable exchange.  A
    miss executes the node and persists its output.  Tags are the node's
    ordinal in the deterministic ``walk()`` order."""

    def __init__(self, ctx, info: PlanInfo | None,
                 params: dict[str, Any] | None = None):
        self.ctx = ctx
        self.info = info
        self.params = params or {}
        self.memo: dict[int, Any] = {}
        self._tags: dict[int, int] = {}

    def run(self, node: P.Node):
        store = getattr(self.ctx, "lineage", None)
        if store is not None:
            nodes = walk(node)
            self._tags = {id(n): i for i, n in enumerate(nodes)}
            store.begin_executor(nodes, self.info is not None,
                                 getattr(self.ctx, "wire_format", None),
                                 bindings=self.params,
                                 n_devices=getattr(self.ctx,
                                                   "lineage_devices", 1))
        return self._exec(node)

    def _wire(self, node: P.Node):
        """Inferred payload bounds for an exchange node (None = no inference
        -> the engine ships full-width)."""
        return self.info.wire_for(node) if self.info is not None else None

    # -- expressions -------------------------------------------------------
    def _eval(self, e: P.Expr, t):
        ctx = self.ctx
        if isinstance(e, P.Col):
            if t is None:
                raise ValueError(f"column {e.name!r} referenced in a scalar "
                                 "context")
            return t[e.name]
        if isinstance(e, P.Lit):
            return e.value
        if isinstance(e, P.CodeLit):
            return ctx.db.code(e.col, e.value)
        if isinstance(e, P.DbScale):
            return ctx.db.scale
        if isinstance(e, P.Param):
            if e.name in self.params:
                return self.params[e.name]
            if e.default is not None:
                return e.default
            raise ValueError(f"unbound parameter {e.name!r} (no binding, "
                             "no default)")
        if isinstance(e, P.ScalarRef):
            return self._exec(e.node)[e.name]
        if isinstance(e, P.BinOp):
            a = self._eval(e.a, t)
            b = self._eval(e.b, t)
            return _BINOPS[e.op](a, b)
        if isinstance(e, P.NotE):
            return ~self._eval(e.a, t)
        if isinstance(e, P.Cast):
            return self._eval(e.a, t).astype(getattr(ctx.xp, e.dtype))
        if isinstance(e, P.Where):
            return ctx.xp.where(self._eval(e.cond, t), self._eval(e.a, t),
                                self._eval(e.b, t))
        if isinstance(e, P.Year):
            return ctx.year(self._eval(e.a, t))
        if isinstance(e, P.AlphaRank):
            return ctx.alpha_rank(t, e.col)
        if isinstance(e, P.Like):
            return ctx.like(t, e.col, *e.subs)
        if isinstance(e, P.StartsWith):
            return ctx.starts_with(t, e.col, e.prefix)
        if isinstance(e, P.EndsWith):
            return ctx.ends_with(t, e.col, e.suffix)
        if isinstance(e, P.InSet):
            x = self._eval(e.a, t)
            m = x == self._eval(e.values[0], t)
            for v in e.values[1:]:
                m = m | (x == self._eval(v, t))
            return m
        raise TypeError(f"cannot evaluate {type(e).__name__}")

    def _aggs(self, aggs):
        out = []
        for name, op, v in aggs:
            if isinstance(v, P.Expr):
                out.append((name, op,
                            lambda tt, e=v: self._eval(e, tt)))
            else:
                out.append((name, op, v))
        return out

    # -- nodes -------------------------------------------------------------
    def _exec(self, node: P.Node):
        if id(node) in self.memo:
            return self.memo[id(node)]
        store = getattr(self.ctx, "lineage", None)
        if store is not None and _is_exchange_node(node):
            tag = self._tags[id(node)]
            out = store.load(tag)      # checked BEFORE recursing: a hit
            if out is None:            # skips the whole subtree
                out = self._exec_inner(node)
                store.save(tag, out, self.ctx, node=node)
        else:
            out = self._exec_inner(node)
        self.memo[id(node)] = out
        return out

    def _exec_inner(self, node: P.Node):
        ctx = self.ctx
        if isinstance(node, P.Scan):
            return ctx.scan(node.table)
        if isinstance(node, P.Filter):
            t = self._exec(node.children[0])
            return ctx.filter(t, self._eval(node.pred, t))
        if isinstance(node, P.Select):
            return ctx.select(self._exec(node.children[0]), *node.names)
        if isinstance(node, P.WithCol):
            t = self._exec(node.children[0])
            return ctx.with_col(t, **{
                k: (lambda tt, e=e: self._eval(e, tt))
                for k, e in node.exprs.items()})
        if isinstance(node, P.Rename):
            return ctx.rename(self._exec(node.children[0]), node.mapping)
        if isinstance(node, P.Join):
            return ctx.join(self._exec(node.probe), self._exec(node.build),
                            node.on, node.build_on, list(node.take))
        if isinstance(node, P.Semi):
            return ctx.semi(self._exec(node.probe), self._exec(node.build),
                            node.on, node.build_on)
        if isinstance(node, P.Anti):
            return ctx.anti(self._exec(node.probe), self._exec(node.build),
                            node.on, node.build_on)
        if isinstance(node, P.Left):
            return ctx.left(self._exec(node.probe), self._exec(node.build),
                            node.on, node.build_on, list(node.take),
                            node.defaults)
        if isinstance(node, P.GroupBy):
            t = self._exec(node.children[0])
            if self.info is not None:
                key_bits, gh = self.info.hints_for(node)
                method = self.info.method_for(node) or "auto"
            else:
                # conservative: no hints at all (and hence the sort path)
                key_bits, gh, method = None, None, "auto"
            return ctx.group_by(t, list(node.keys), self._aggs(node.aggs),
                                exchange=node.exchange, final=node.final,
                                groups_hint=gh,
                                key_bits=list(key_bits) if key_bits else None,
                                wire=self._wire(node), method=method)
        if isinstance(node, P.AggScalar):
            t = self._exec(node.children[0])
            return ctx.agg_scalar(t, self._aggs(node.aggs))
        if isinstance(node, P.Shuffle):
            return ctx.shuffle(self._exec(node.children[0]), node.key,
                               wire=self._wire(node))
        if isinstance(node, P.Broadcast):
            return ctx.broadcast(self._exec(node.children[0]), p2p=node.p2p,
                                 wire=self._wire(node))
        if isinstance(node, P.Shrink):
            return ctx.shrink(self._exec(node.children[0]), node.cap)
        if isinstance(node, P.Finalize):
            return ctx.finalize(
                self._exec(node.children[0]),
                sort_keys=list(node.sort_keys) if node.sort_keys else None,
                limit=node.limit, replicated=node.replicated,
                wire=self._wire(node))
        if isinstance(node, P.ScalarResult):
            return {k: self._eval(e, None) for k, e in node.exprs.items()}
        raise TypeError(f"cannot execute {type(node).__name__}")


_BINOPS: dict[str, Callable] = {
    "+": lambda a, b: a + b, "-": lambda a, b: a - b,
    "*": lambda a, b: a * b, "/": lambda a, b: a / b,
    "<": lambda a, b: a < b, "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b, ">=": lambda a, b: a >= b,
    "==": lambda a, b: a == b, "!=": lambda a, b: a != b,
    "&": lambda a, b: a & b, "|": lambda a, b: a | b,
}


# ---------------------------------------------------------------------------
# compiled queries
# ---------------------------------------------------------------------------

class CompiledQuery:
    """A built-once logical plan, callable like the legacy ``query_fn(ctx)``.

    The plan is constructed lazily (first use) from ``build_fn`` and shared
    across calls; inference (:func:`analyze`) runs host-side once per
    database and is cached on the database object, so tracing a query twice
    never re-derives bounds.
    """

    def __init__(self, build_fn: Callable[[], P.Node], name: str | None = None):
        self._build_fn = build_fn
        self.name = name or getattr(build_fn, "__name__", "query")
        self._plan: P.Node | None = None

    @property
    def plan(self) -> P.Node:
        if self._plan is None:
            self._plan = self._build_fn()
        return self._plan

    # per-database PlanInfo cache bound: far above the 22 standing queries,
    # low enough that a process compiling throwaway queries per request
    # against one long-lived Database cannot grow without bound
    _INFO_CACHE_MAX = 256

    def info(self, db) -> PlanInfo:
        # keyed by id(self); the entry pins self so the id cannot be reused
        # by a later CompiledQuery while it is cached (FIFO-evicted at the
        # bound, which also unpins the evicted query)
        cache = db.__dict__.setdefault("_planinfo_cache", {})
        got = cache.get(id(self))
        if got is None or got[0] is not self:
            got = (self, analyze(self.plan, db))
            while len(cache) >= self._INFO_CACHE_MAX:
                cache.pop(next(iter(cache)))
            cache[id(self)] = got
        return got[1]

    def __call__(self, ctx):
        return self.run(ctx)

    def run(self, ctx, infer: bool | None = None,
            params: dict[str, Any] | None = None):
        if infer is None:
            infer = planner_default()
        info = self.info(ctx.db) if infer else None
        return _Executor(ctx, info, params=params).run(self.plan)

    def signature(self) -> str:
        """Content signature (:func:`plan_signature`) — cached: plans are
        immutable once built."""
        sig = self.__dict__.get("_signature")
        if sig is None:
            sig = self.__dict__["_signature"] = plan_signature(self.plan)
        return sig

    def params(self) -> dict[str, P.Param]:
        """Parameter placeholders of the plan (empty for literal queries)."""
        return params_of(self.plan)

    def with_inference(self, on: bool) -> "_PinnedQuery":
        """A ``query_fn(ctx)`` with the inference mode pinned (env-proof).

        Returns a wrapper that still exposes ``with_inference`` (and the
        plan/static introspection), so the fault runner's hint-drop recovery
        works on pinned queries too."""
        return _PinnedQuery(self, on)

    def approximate(self, db, den: int, seed: int | None = None,
                    min_rows: int | None = None, tables=None):
        """Sample-ladder rewrite of this plan onto rung ``1/den`` against
        ``db`` (``repro.approx.rewrite``): the aggregation's scan moves onto
        a stratified sample with scale-up + CLT moment columns injected.
        Returns an ``ApproxRewrite`` or None when the shape is non-estimable
        (min/max, semi/anti-dependent counts, tiny domains) and must run
        exact."""
        from repro.approx import rewrite as _ar   # deferred: approx imports us
        kwargs = {}
        if seed is not None:
            kwargs["seed"] = seed
        if min_rows is not None:
            kwargs["min_rows"] = min_rows
        return _ar.rewrite_for_rung(self, db, den, tables=tables, **kwargs)

    def static_counts(self) -> dict[str, int]:
        return static_plan_stats(self.plan)

    def static_wire(self, db, narrow: bool = True) -> list[dict]:
        """Per-exchange wire-byte descriptors from the IR (no execution);
        reuses the per-database PlanInfo cache."""
        return static_wire_stats(self.plan, db, narrow=narrow,
                                 info=self.info(db) if narrow else None)

    def validate(self, db) -> list[str]:
        return self.info(db).notes

    def explain(self, db) -> str:
        info = self.info(db)
        lines = [f"plan {self.name}: static exchanges {info.counts}"]
        for n in walk(self.plan):
            if isinstance(n, P.GroupBy):
                kb, gh = info.hints_for(n)
                if kb is not None:
                    path = "direct (sortless)"
                elif info.method_for(n) == "hash":
                    path = "hash (sortless dictionary)"
                else:
                    path = "single-sort"
                lines.append(
                    f"  group_by{list(n.keys)} exchange={n.exchange}: "
                    f"key_bits={list(kb) if kb else None} "
                    f"groups_hint={gh} -> {path}")
        for note in info.notes:
            lines.append(f"  NOTE: {note}")
        return "\n".join(lines)


def _direct_bits_max() -> int:
    from . import relational as rel     # deferred: relational pulls in jax
    return rel.DIRECT_AGG_BITS_MAX


def _hash_groups_max() -> int:
    from . import relational as rel     # deferred: relational pulls in jax
    return rel.HASH_AGG_GROUPS_MAX


class _PinnedQuery:
    """A CompiledQuery with the inference mode pinned; re-pinnable."""

    def __init__(self, query: CompiledQuery, infer: bool):
        self._query = query
        self._infer = infer

    def __call__(self, ctx):
        return self._query.run(ctx, infer=self._infer)

    def with_inference(self, on: bool) -> "_PinnedQuery":
        return _PinnedQuery(self._query, on)

    @property
    def plan(self) -> P.Node:
        return self._query.plan

    def static_counts(self) -> dict[str, int]:
        return self._query.static_counts()


def compile_query(build_fn: Callable[[], P.Node],
                  name: str | None = None) -> CompiledQuery:
    return CompiledQuery(build_fn, name)


# ---------------------------------------------------------------------------
# statistics-cache ownership (the only module that may touch these keys)
# ---------------------------------------------------------------------------

_INVALIDATION_HOOKS: list[Callable[[Any], None]] = []


def register_invalidation(hook: Callable[[Any], None]) -> None:
    """Register ``hook(db)`` to fire whenever :func:`invalidate_stats` drops
    a database's planner caches — the ONE doorway every stats-dependent cache
    above the planner (compiled-plan caches, serving templates) hangs off,
    so table mutation and ``stats_override`` entry/exit evict everywhere at
    once.  Idempotent per hook object; hooks must tolerate any ``db``."""
    if hook not in _INVALIDATION_HOOKS:
        _INVALIDATION_HOOKS.append(hook)


def invalidate_stats(db) -> None:
    """Drop the planner's caches on ``db`` (column stats + per-plan infos),
    then fire every registered invalidation hook.  For callers that mutate
    the database's tables, or benchmarks timing cold inference."""
    db.__dict__.pop("_plan_colstats", None)
    db.__dict__.pop("_planinfo_cache", None)
    for hook in list(_INVALIDATION_HOOKS):
        hook(db)


class stats_override:
    """Scoped replacement of ``db``'s column statistics (e.g. the SF=1000
    dry-run injecting modeled key domains).  Dependent PlanInfo caches are
    invalidated on entry AND exit, and the previous stats are restored, so
    executions after the scope re-infer at the database's actual scale."""

    def __init__(self, db, stats: dict[str, ColStats]):
        self.db = db
        self.stats = stats

    def __enter__(self):
        self._saved = self.db.__dict__.get("_plan_colstats")
        invalidate_stats(self.db)
        self.db.__dict__["_plan_colstats"] = self.stats
        return self.stats

    def __exit__(self, *exc):
        invalidate_stats(self.db)
        if self._saved is not None:
            self.db.__dict__["_plan_colstats"] = self._saved
        return False

"""Static-shape relational operators in pure JAX (the per-device TQP compute layer).

TPU adaptation (DESIGN.md §2): no atomics / no dynamic shapes, so
  * filter        = mask + stable-argsort compaction (sorting network)
  * hash join     = sort build side + ``searchsorted`` probe (unique build keys —
                    every TPC-H join is FK->PK once plans order probe/build sides)
  * group-by      = sort + segment reduction; small known domains use the
                    one-hot MXU kernel in ``repro.kernels.segsum``
  * order-by      = multi-pass stable argsort with validity sentinels

Every op preserves the Table invariant: valid rows compacted to the front,
``count`` = number of valid rows, capacity static.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .table import Table, KEY_SENTINEL

__all__ = [
    "compact",
    "filter_rows",
    "combine_keys",
    "join_unique",
    "semi_join",
    "anti_join",
    "left_join",
    "group_aggregate",
    "sort_by",
    "limit",
    "static_shrink",
    "hash_partition_ids",
]

_I64 = jnp.int64
_HASH_C1 = np.uint64(0xFF51AFD7ED558CCD)
_HASH_C2 = np.uint64(0xC4CEB9FE1A85EC53)


# ---------------------------------------------------------------------------
# compaction / filtering
# ---------------------------------------------------------------------------

def compact(t: Table, keep: jax.Array) -> Table:
    """Move rows where ``keep & valid`` to the front; count = how many."""
    keep = keep & t.valid_mask()
    order = jnp.argsort(~keep, stable=True)  # keep=True rows first, stable
    cols = {k: v[order] for k, v in t.columns.items()}
    return Table(cols, keep.sum().astype(jnp.int32))


def filter_rows(t: Table, mask: jax.Array) -> Table:
    return compact(t, mask)


def limit(t: Table, n: int) -> Table:
    """First n valid rows (callers sort first).  Statically shrinks capacity."""
    cols = {k: v[:n] for k, v in t.columns.items()}
    return Table(cols, jnp.minimum(t.count, n).astype(jnp.int32))


def static_shrink(t: Table, new_capacity: int) -> tuple[Table, jax.Array]:
    """Shrink capacity (planner's selectivity hint).  Returns (table, overflowed).

    Overflow (count > new_capacity) signals the fault-tolerant runner to retry
    with a larger capacity — the static-shape analogue of the paper's
    size-metadata exchange guarding receive-buffer allocation.
    """
    overflow = t.count > new_capacity
    cols = {k: v[:new_capacity] for k, v in t.columns.items()}
    return Table(cols, jnp.minimum(t.count, new_capacity).astype(jnp.int32)), overflow


# ---------------------------------------------------------------------------
# keys
# ---------------------------------------------------------------------------

def combine_keys(cols: Sequence[jax.Array]) -> jax.Array:
    """Pack two non-negative int key columns (< 2^31 each) into one int64.

    More than two keys must be packed explicitly by the plan (e.g.
    ``(brand*NTYPES + type)*NSIZES + size``) so collision-freedom is provable.
    """
    if len(cols) > 2:
        raise ValueError("pack >2 keys explicitly in the plan (collision safety)")
    k = cols[0].astype(_I64)
    for c in cols[1:]:
        k = (k << 32) | c.astype(_I64)
    return k


def _valid_key(t: Table, key: jax.Array) -> jax.Array:
    """Key column with padding rows forced to the +inf sentinel."""
    return jnp.where(t.valid_mask(), key.astype(_I64), KEY_SENTINEL)


def hash_partition_ids(key: jax.Array, num_partitions: int) -> jax.Array:
    """Fingerprint-based destination ids for shuffle (splitmix64 finalizer)."""
    k = key.astype(_I64).astype(jnp.uint64)
    k = (k ^ (k >> 33)) * _HASH_C1
    k = (k ^ (k >> 33)) * _HASH_C2
    k = k ^ (k >> 33)
    return (k % np.uint64(num_partitions)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# joins (unique build side)
# ---------------------------------------------------------------------------

def _probe(probe_key: jax.Array, probe_valid: jax.Array,
           build: Table, build_key: jax.Array):
    """Sorted-build searchsorted probe.  Returns (matched, build_row_idx)."""
    bkey = _valid_key(build, build_key)
    order = jnp.argsort(bkey)
    bkey_sorted = bkey[order]
    pk = probe_key.astype(_I64)
    pos = jnp.searchsorted(bkey_sorted, pk)
    pos = jnp.minimum(pos, build.capacity - 1)
    matched = (bkey_sorted[pos] == pk) & probe_valid & (pk != KEY_SENTINEL)
    return matched, order[pos]


def join_unique(probe: Table, build: Table, probe_on: jax.Array,
                build_on: jax.Array, take: Sequence[str]) -> Table:
    """Inner join; ``build`` keys must be unique among valid rows.

    Output = probe rows that matched, plus ``take`` columns gathered from build.
    Output capacity = probe capacity (FK->PK join never expands the probe side).
    """
    matched, bidx = _probe(probe_on, probe.valid_mask(), build, build_on)
    cols = dict(probe.columns)
    for name in take:
        if name in cols:
            raise ValueError(f"join output column collision: {name}")
        cols[name] = build[name][bidx]
    return compact(Table(cols, probe.count), matched)


def semi_join(probe: Table, build: Table, probe_on, build_on) -> Table:
    matched, _ = _probe(probe_on, probe.valid_mask(), build, build_on)
    return compact(probe, matched)


def anti_join(probe: Table, build: Table, probe_on, build_on) -> Table:
    matched, _ = _probe(probe_on, probe.valid_mask(), build, build_on)
    return compact(probe, ~matched & probe.valid_mask())


def left_join(probe: Table, build: Table, probe_on, build_on,
              take: Sequence[str], defaults: dict[str, float | int]) -> Table:
    """Left outer join; unmatched probe rows take ``defaults``; adds ``__matched``."""
    matched, bidx = _probe(probe_on, probe.valid_mask(), build, build_on)
    cols = dict(probe.columns)
    for name in take:
        gathered = build[name][bidx]
        cols[name] = jnp.where(matched, gathered,
                               jnp.asarray(defaults[name], dtype=gathered.dtype))
    cols["__matched"] = matched
    return Table(cols, probe.count)


# ---------------------------------------------------------------------------
# grouped aggregation
# ---------------------------------------------------------------------------

_MERGE_OP = {"sum": "sum", "count": "sum", "min": "min", "max": "max"}


def group_aggregate(t: Table, key_cols: Sequence[str],
                    aggs: Sequence[tuple[str, str, jax.Array | str | None]]) -> Table:
    """Sort-based grouped aggregation.

    aggs: (out_name, op, values) with op in {sum,count,min,max}; ``values`` is an
    array (an expression over t), a column name, or None for count.
    Output: key columns + agg columns; count = number of groups;
    capacity preserved (n_groups <= count <= capacity).
    """
    cap = t.capacity
    key = _valid_key(t, combine_keys([t[k] for k in key_cols])) if key_cols else \
        jnp.where(t.valid_mask(), jnp.int64(0), KEY_SENTINEL)
    order = jnp.argsort(key)
    sk = key[order]
    valid = sk != KEY_SENTINEL
    first = jnp.concatenate([valid[:1], (sk[1:] != sk[:-1]) & valid[1:]])
    gid = jnp.cumsum(first.astype(jnp.int32)) - 1           # 0-based group id
    ngroups = first.sum().astype(jnp.int32)
    # padding rows route to segment cap-1 which is provably not a valid group
    # whenever padding exists (ngroups <= count <= cap-1); see tests.
    seg = jnp.where(valid, gid, cap - 1)

    out: dict[str, jax.Array] = {}
    for k in key_cols:
        v = t[k][order]
        fill = jnp.zeros((), v.dtype)
        # scatter-set: all rows of a group share the key value, so duplicate
        # writes are benign; padding rows write the fill value into slot cap-1.
        out[k] = jnp.zeros((cap,), v.dtype).at[seg].set(jnp.where(valid, v, fill),
                                                        mode="drop")
    for out_name, op, values in aggs:
        if values is None:
            v = jnp.ones((cap,), dtype=jnp.int64)
        elif isinstance(values, str):
            v = t[values]
        else:
            v = values
        v = v[order]
        if op == "count":
            v = jnp.where(valid, 1, 0).astype(jnp.int64)
            out[out_name] = jax.ops.segment_sum(v, seg, num_segments=cap,
                                                indices_are_sorted=True)
        elif op == "sum":
            v = jnp.where(valid, v, jnp.zeros((), v.dtype))
            out[out_name] = jax.ops.segment_sum(v, seg, num_segments=cap,
                                                indices_are_sorted=True)
        elif op == "min":
            big = _dtype_max(v.dtype)
            v = jnp.where(valid, v, big)
            out[out_name] = jax.ops.segment_min(v, seg, num_segments=cap,
                                                indices_are_sorted=True)
        elif op == "max":
            small = _dtype_min(v.dtype)
            v = jnp.where(valid, v, small)
            out[out_name] = jax.ops.segment_max(v, seg, num_segments=cap,
                                                indices_are_sorted=True)
        else:
            raise ValueError(f"unknown agg op {op!r}")
    return Table(out, ngroups)


def _dtype_max(dt):
    return jnp.asarray(np.inf if jnp.issubdtype(dt, jnp.floating) else np.iinfo(dt).max, dt)


def _dtype_min(dt):
    return jnp.asarray(-np.inf if jnp.issubdtype(dt, jnp.floating) else np.iinfo(dt).min, dt)


# ---------------------------------------------------------------------------
# ordering
# ---------------------------------------------------------------------------

def sort_by(t: Table, keys: Sequence[tuple[str, bool]]) -> Table:
    """ORDER BY; keys = [(column, ascending)], first key most significant.

    Multi-pass stable argsort from least-significant key; padding rows always
    sink to the back via sentinels.
    """
    valid = t.valid_mask()
    order = jnp.arange(t.capacity)
    for col, asc in reversed(list(keys)):
        k = t[col][order]
        v = valid[order]
        if jnp.issubdtype(k.dtype, jnp.floating):
            k = jnp.where(v, k if asc else -k, np.inf)
        else:
            k = k.astype(_I64)
            k = jnp.where(v, k if asc else -k, KEY_SENTINEL)
        step = jnp.argsort(k, stable=True)
        order = order[step]
    return Table({k: v[order] for k, v in t.columns.items()}, t.count)

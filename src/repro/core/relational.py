"""Static-shape relational operators in pure JAX (the per-device TQP compute layer).

TPU adaptation (DESIGN.md §2): no atomics / no dynamic shapes, so
  * filter        = O(n) validity-mask merge (deferred compaction — no sort)
  * hash join     = sorted-build ``searchsorted`` probe, or the Pallas
                    bucket-table probe (``kernels/hash_probe``) behind a
                    dispatch flag; build sides index once per plan via
                    :class:`BuildIndex` (unique build keys — every TPC-H join
                    is FK->PK once plans order probe/build sides)
  * group-by      = sortless when the key domain is provably small (dense
                    group ids + the ``kernels/segsum`` one-hot MXU reduce —
                    aggregation-as-matmul); otherwise ONE stable argsort over
                    a packed int64 key + segment reductions reusing that
                    order for every aggregate
  * order-by      = ONE multi-operand stable ``lax.sort`` with validity
                    sentinels (single HLO sort regardless of key count)

Deferred-compaction invariant
-----------------------------
Operators accept both compact (``valid is None``) and masked tables and
preserve ``count == valid_mask().sum()``.  Mask-producing ops (``filter_rows``,
``join_unique``, ``semi_join``, ``anti_join``) are sort-free; the O(cap log cap)
front-compaction runs only where contiguity is genuinely required:
``sort_by`` (output is ordered hence compact), ``limit`` / ``static_shrink``
(slicing), and exchange payload packing (``exchange.broadcast_table``).

Sort-count budget per operator (HLO ``sort`` ops; enforced by
``benchmarks/bench_sort_tax.py`` and the CI regression gate):

  filter_rows / semi / anti      0
  join_unique / left_join        0 probe-side + 1 per *distinct* build index
  group_aggregate                0 with provable ``key_bits`` (packed domain
                                 <= 2^13: direct addressing via the segsum
                                 one-hot kernel), 0 with a claimed
                                 ``groups_hint`` (trace-time hash-compaction
                                 dictionary, ``kernels/hash_group``) or no
                                 key columns (scalar aggregation); 1 otherwise
  sort_by                        1 (any number of keys)
  shuffle (exchange)             0 (radix-hist counting rank), output masked
  compact / ensure_compact       1, boundaries only

``key_bits`` is no longer hand-threaded by query code: ``core/planner.py``
derives it (and ``groups_hint``) by bound propagation over the logical plan
(``core/plan.py``) and passes it here — the physical contract of this module
is unchanged, only the *source* of the widths moved from comments at call
sites into a compiler pass.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .table import Table, KEY_SENTINEL
# imported at module scope (not lazily inside traced code): the kernel modules
# materialize constants at import time, which must not happen under a trace
from repro.kernels.hash_group import ops as _hg_ops
from repro.kernels.hash_probe import ops as _hp_ops
from repro.kernels.segsum import ops as _ss_ops

# Largest packed-key domain (2^bits) the direct-addressing aggregation will
# take on: one-hot tiles are (blk, 2^bits) in VMEM, so 13 bits (8192 slots,
# 64 lane-tiles) is the practical MXU ceiling; larger domains fall back to
# the single-sort path.
DIRECT_AGG_BITS_MAX = 13
# Largest claimed group bound the hash-compaction path will take on: the
# dictionary is sized groups_hint * capacity_factor (<= 8192 slots at the
# default factor), keeping both the dictionary planes and the segsum one-hot
# tiles inside the same VMEM ceiling as the direct path.
HASH_AGG_GROUPS_MAX = 4096
# Which engine backs the sortless reductions (segsum / radix_hist):
#   REPRO_AGG_KERNEL=auto (default) — Pallas kernels on TPU, jnp
#     scatter-reduce everywhere else.  Interpret-mode Pallas is a correctness
#     vehicle, not a fast path: its grid loop re-slices full buffers per step,
#     a 20-90x wall-clock tax on CPU — while the jnp path lowers to the same
#     sort-free HLO, so the sort-tax win is identical.
#   REPRO_AGG_KERNEL=1 — force the kernels (the CI leg that exercises them
#     through all 22 query plans, in interpret mode off-TPU).
#   REPRO_AGG_KERNEL=0 — force the jnp oracle (the CI leg that pins the
#     kernels' reference semantics).
# Resolved lazily on first use: probing jax.default_backend() at import time
# would finalize the JAX backend as an import side effect, breaking drivers
# that call jax.distributed.initialize() after importing repro.
_AGG_KERNEL_CACHE: bool | None = None


def agg_kernel_default() -> bool:
    global _AGG_KERNEL_CACHE
    if _AGG_KERNEL_CACHE is None:
        env = os.environ.get("REPRO_AGG_KERNEL", "auto").lower()
        if env in ("1", "true", "kernel"):
            _AGG_KERNEL_CACHE = True
        elif env in ("0", "false", "oracle"):
            _AGG_KERNEL_CACHE = False
        else:
            _AGG_KERNEL_CACHE = jax.default_backend() == "tpu"
    return _AGG_KERNEL_CACHE

__all__ = [
    "compact",
    "ensure_compact",
    "filter_rows",
    "combine_keys",
    "BuildIndex",
    "build_index",
    "probe_index",
    "join_unique",
    "semi_join",
    "anti_join",
    "left_join",
    "group_aggregate",
    "sort_by",
    "limit",
    "static_shrink",
    "hash_partition_ids",
]

_I64 = jnp.int64
_HASH_C1 = np.uint64(0xFF51AFD7ED558CCD)
_HASH_C2 = np.uint64(0xC4CEB9FE1A85EC53)


# ---------------------------------------------------------------------------
# compaction / filtering
# ---------------------------------------------------------------------------

def compact(t: Table, keep: jax.Array) -> Table:
    """Move rows where ``keep & valid`` to the front; count = how many.

    This is the expensive boundary operator (one stable argsort over the full
    capacity) — hot paths defer it via masked tables (see module docstring).
    """
    keep = keep & t.valid_mask()
    order = jnp.argsort(~keep, stable=True)  # keep=True rows first, stable
    cols = {k: v[order] for k, v in t.columns.items()}
    return Table(cols, keep.sum().astype(jnp.int32))


def ensure_compact(t: Table) -> Table:
    """Materialize the front-compaction of a masked table (no-op if compact)."""
    if t.valid is None:
        return t
    return compact(t, t.valid)


def filter_rows(t: Table, mask: jax.Array) -> Table:
    """O(n) filter: merge ``mask`` into the validity mask — no sort."""
    keep = mask & t.valid_mask()
    return Table(dict(t.columns), keep.sum().astype(jnp.int32), keep)


def limit(t: Table, n: int) -> Table:
    """First n valid rows (callers sort first).  Statically shrinks capacity."""
    t = ensure_compact(t)
    cols = {k: v[:n] for k, v in t.columns.items()}
    return Table(cols, jnp.minimum(t.count, n).astype(jnp.int32))


def static_shrink(t: Table, new_capacity: int) -> tuple[Table, jax.Array]:
    """Shrink capacity (planner's selectivity hint).  Returns (table, overflowed).

    Overflow (count > new_capacity) signals the fault-tolerant runner to retry
    with a larger capacity — the static-shape analogue of the paper's
    size-metadata exchange guarding receive-buffer allocation.
    """
    t = ensure_compact(t)
    overflow = t.count > new_capacity
    cols = {k: v[:new_capacity] for k, v in t.columns.items()}
    return Table(cols, jnp.minimum(t.count, new_capacity).astype(jnp.int32)), overflow


# ---------------------------------------------------------------------------
# keys
# ---------------------------------------------------------------------------

def combine_keys(cols: Sequence[jax.Array], bits: Sequence[int] | None = None,
                 ) -> jax.Array:
    """Pack non-negative int key columns into one int64 sort/group/join key.

    Without ``bits``: exactly the seed behavior — at most two columns
    (< 2^31 each) packed with 32-bit shifts; more must be packed explicitly by
    the plan so collision-freedom is provable.

    With ``bits``: any number of columns, ``bits[i]`` the provable width of
    column i (``0 <= cols[i] < 2^bits[i]``), ``sum(bits) <= 63`` — the plan
    states its widths and gets a single collision-free key for one-sort
    multi-column ORDER BY / GROUP BY.
    """
    if bits is not None:
        if len(bits) != len(cols):
            raise ValueError("combine_keys: len(bits) != len(cols)")
        if sum(bits) > 63:
            raise ValueError(f"combine_keys: {sum(bits)} key bits > 63")
        k = jnp.zeros_like(cols[0], dtype=_I64)
        for c, b in zip(cols, bits):
            k = (k << b) | c.astype(_I64)
        return k
    if len(cols) > 2:
        raise ValueError("pack >2 keys explicitly in the plan (collision safety)")
    k = cols[0].astype(_I64)
    for c in cols[1:]:
        k = (k << 32) | c.astype(_I64)
    return k


def _valid_key(t: Table, key: jax.Array) -> jax.Array:
    """Key column with invalid rows forced to the +inf sentinel."""
    return jnp.where(t.valid_mask(), key.astype(_I64), KEY_SENTINEL)


def hash_partition_ids(key: jax.Array, num_partitions: int) -> jax.Array:
    """Fingerprint-based destination ids for shuffle (splitmix64 finalizer)."""
    k = key.astype(_I64).astype(jnp.uint64)
    k = (k ^ (k >> 33)) * _HASH_C1
    k = (k ^ (k >> 33)) * _HASH_C2
    k = k ^ (k >> 33)
    return (k % np.uint64(num_partitions)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# joins (unique build side)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BuildIndex:
    """Reusable probe structure over a unique-key build side.

    Built once per (build table, key) pair and cached per plan by the backend
    contexts, so a dimension table probed by several joins pays its build sort
    once.  Two methods:

      * ``sorted``: keys sorted once, probes are ``searchsorted`` (pure JAX —
        the always-available fallback).
      * ``hash``: (B, C) bucket table of 32-bit key planes probed by the
        Pallas kernel in ``repro.kernels.hash_probe`` — fixed probe length,
        no log-factor, bucket table VMEM-resident on TPU.
    """

    method: str
    capacity: int
    overflow: jax.Array
    # sorted
    sorted_keys: jax.Array | None = None
    sorted_rows: jax.Array | None = None
    # hash (two int32 planes hold the full 64-bit key)
    bk_lo: jax.Array | None = None
    bk_hi: jax.Array | None = None
    bvals: jax.Array | None = None


def build_index(build: Table, build_key: jax.Array, method: str = "sorted",
                bucket_cap: int = 16) -> BuildIndex:
    """Index the build side of a unique-key join (one argsort either way)."""
    bkey = _valid_key(build, build_key)
    if method == "sorted":
        order = jnp.argsort(bkey)
        return BuildIndex("sorted", build.capacity, jnp.asarray(False),
                          sorted_keys=bkey[order], sorted_rows=order)
    if method != "hash":
        raise ValueError(f"unknown join method {method!r}")
    rows = jnp.arange(build.capacity, dtype=jnp.int32)
    buckets = max(128, _hp_ops.next_pow2(2 * max(1, build.capacity)) // 4)
    bk_lo, bk_hi, bv, ov = _hp_ops.build_bucket_table64(
        bkey, rows, buckets, cap=bucket_cap, valid=bkey != KEY_SENTINEL)
    return BuildIndex("hash", build.capacity, ov,
                      bk_lo=bk_lo, bk_hi=bk_hi, bvals=bv)


def probe_index(index: BuildIndex, probe_key: jax.Array,
                probe_valid: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Probe an index.  Returns (matched, build_row_idx); idx arbitrary where
    unmatched (callers mask through ``matched``)."""
    pk = probe_key.astype(_I64)
    if index.method == "sorted":
        pos = jnp.searchsorted(index.sorted_keys, pk)
        pos = jnp.minimum(pos, index.capacity - 1)
        matched = (index.sorted_keys[pos] == pk) & probe_valid & \
            (pk != KEY_SENTINEL)
        return matched, index.sorted_rows[pos]
    row = _hp_ops.hash_probe64(pk, index.bk_lo, index.bk_hi, index.bvals)
    matched = (row >= 0) & probe_valid & (pk != KEY_SENTINEL)
    return matched, jnp.maximum(row, 0)


def _probe(probe_key: jax.Array, probe_valid: jax.Array,
           build: Table, build_key: jax.Array, index: BuildIndex | None,
           method: str):
    if index is None:
        index = build_index(build, build_key, method)
    return probe_index(index, probe_key, probe_valid)


def join_unique(probe: Table, build: Table, probe_on: jax.Array,
                build_on: jax.Array, take: Sequence[str],
                index: BuildIndex | None = None,
                method: str = "sorted") -> Table:
    """Inner join; ``build`` keys must be unique among valid rows.

    Output = probe rows that matched (as a masked table — no compaction),
    plus ``take`` columns gathered from build.  Output capacity = probe
    capacity (FK->PK join never expands the probe side).
    """
    matched, bidx = _probe(probe_on, probe.valid_mask(), build, build_on,
                           index, method)
    cols = dict(probe.columns)
    for name in take:
        if name in cols:
            raise ValueError(f"join output column collision: {name}")
        cols[name] = build[name][bidx]
    return Table(cols, matched.sum().astype(jnp.int32), matched)


def semi_join(probe: Table, build: Table, probe_on, build_on,
              index: BuildIndex | None = None, method: str = "sorted") -> Table:
    matched, _ = _probe(probe_on, probe.valid_mask(), build, build_on,
                        index, method)
    return Table(dict(probe.columns), matched.sum().astype(jnp.int32), matched)


def anti_join(probe: Table, build: Table, probe_on, build_on,
              index: BuildIndex | None = None, method: str = "sorted") -> Table:
    matched, _ = _probe(probe_on, probe.valid_mask(), build, build_on,
                        index, method)
    keep = ~matched & probe.valid_mask()
    return Table(dict(probe.columns), keep.sum().astype(jnp.int32), keep)


def left_join(probe: Table, build: Table, probe_on, build_on,
              take: Sequence[str], defaults: dict[str, float | int],
              index: BuildIndex | None = None, method: str = "sorted") -> Table:
    """Left outer join; unmatched probe rows take ``defaults``; adds ``__matched``."""
    matched, bidx = _probe(probe_on, probe.valid_mask(), build, build_on,
                           index, method)
    cols = dict(probe.columns)
    for name in take:
        gathered = build[name][bidx]
        cols[name] = jnp.where(matched, gathered,
                               jnp.asarray(defaults[name], dtype=gathered.dtype))
    cols["__matched"] = matched
    return Table(cols, probe.count, probe.valid)


# ---------------------------------------------------------------------------
# grouped aggregation
# ---------------------------------------------------------------------------

_MERGE_OP = {"sum": "sum", "count": "sum", "min": "min", "max": "max"}


def _agg_value(t: Table, values, cap: int) -> jax.Array:
    """Materialize an agg value spec (array | column name | None=ones)."""
    if values is None:
        return jnp.ones((cap,), dtype=jnp.int64)
    if isinstance(values, str):
        return t[values]
    return values


def group_aggregate(t: Table, key_cols: Sequence[str],
                    aggs: Sequence[tuple[str, str, jax.Array | str | None]],
                    key_bits: Sequence[int] | None = None,
                    method: str = "auto", use_kernel: bool | None = None,
                    return_overflow: bool = False,
                    groups_hint: int | None = None,
                    hash_factor: float = 2.0):
    """Grouped aggregation; sortless when the key domain is provably small
    OR a distinct-group bound is claimed.

    Three execution paths, selected by ``method``:

      * ``"direct"`` — direct addressing: the packed key IS the dense group
        id (domain ``2^sum(key_bits)``, which must be <= 2^13), aggregates
        run through the ``kernels/segsum`` one-hot MXU reduce, and the dense
        slots compact to the front via a cumsum rank — ZERO sorts.  Scalar
        aggregation (no key columns) is the trivial domain-1 case.
      * ``"hash"`` — hash compaction for *data-dependent* domains: a
        trace-time on-device dictionary (``kernels/hash_group``,
        insert-or-lookup over two-plane 64-bit keys) of
        ``groups_hint * hash_factor`` slots maps each row to its slot, slots
        rank to ascending-key dense group ids by a sort-free O(cap^2)
        compare over the SMALL dictionary, and aggregates ride the same
        segsum one-hot reduce — ZERO sorts with no ``key_bits`` at all.
        Needs 1-2 key columns (the legacy collision-safe packing) and
        ``groups_hint <= HASH_AGG_GROUPS_MAX``; any int64 key values work,
        negatives included.
      * ``"sort"`` — the phase-1 engine: exactly ONE stable argsort whose
        order is reused for every aggregate (segment reductions).
      * ``"auto"`` (default) — direct when eligible, else hash when eligible,
        sort otherwise.

    aggs: (out_name, op, values) with op in {sum,count,min,max}; ``values`` is an
    array (an expression over t), a column name, or None for count.
    ``key_bits`` gives provable per-column bit widths (``0 <= t[k] < 2^bits``)
    so >2 key columns pack into the single int64 key (see ``combine_keys``)
    AND so the direct path can trust the domain bound.  Neither claim ever
    silently drops groups: a lying ``key_bits`` routes out-of-domain valid
    rows to the dead slot and raises the overflow flag; a dictionary that
    cannot place a row (full, or ``groups_hint`` undercounted the distinct
    groups) raises the same flag (``return_overflow=True`` returns
    ``(table, overflow)``; the backends feed it to the re-execution runner,
    whose capacity-factor escalation scales ``hash_factor`` and hence the
    dictionary).
    Output: key columns + agg columns; count = number of groups; group order
    is ascending packed key on all paths; capacity preserved
    (n_groups <= count <= capacity); output is compact.

    Rows past ``count`` are unspecified and differ between paths: notably a
    scalar min/max over ZERO valid rows leaves 0 at slot 0 on the direct
    path (matching the NumPy oracle's empty convention) but the reduction
    identity on the sort path — consumers must respect ``count``.
    """
    if use_kernel is None:
        use_kernel = agg_kernel_default()
    direct_ok = (not key_cols) or (
        key_bits is not None and sum(key_bits) <= DIRECT_AGG_BITS_MAX)
    hash_ok = bool(key_cols) and len(key_cols) <= 2 and \
        groups_hint is not None and groups_hint <= HASH_AGG_GROUPS_MAX
    if method == "auto":
        method = "direct" if direct_ok else ("hash" if hash_ok else "sort")
    if method == "direct":
        if not direct_ok:
            raise ValueError("group_aggregate: direct path needs key_bits "
                             f"with sum <= {DIRECT_AGG_BITS_MAX}")
        out, overflow = _group_aggregate_direct(t, key_cols, aggs, key_bits,
                                                use_kernel)
    elif method == "hash":
        if not hash_ok:
            raise ValueError("group_aggregate: hash path needs 1-2 key "
                             "columns and groups_hint <= "
                             f"{HASH_AGG_GROUPS_MAX}")
        out, overflow = _group_aggregate_hash(t, key_cols, aggs, groups_hint,
                                              hash_factor, use_kernel)
    elif method == "sort":
        out = _group_aggregate_sorted(t, key_cols, aggs, key_bits)
        overflow = jnp.asarray(False)
    else:
        raise ValueError(f"unknown group_aggregate method {method!r}")
    return (out, overflow) if return_overflow else out


def _reduce_aggs(t: Table, aggs, gid: jax.Array, dom: int, in_dom: jax.Array,
                 cnt: jax.Array, use_kernel: bool, cap: int
                 ) -> dict[str, jax.Array]:
    """Shared sortless reduction core (direct + hash paths): per-agg (dom,)
    arrays via the segsum kernel, with same-dtype sums batched into one
    multi-column call.  ``in_dom`` masks rows excluded from every aggregate
    (invalid, out-of-claimed-domain, unresolved); ``cnt`` is the group
    occupancy, which doubles as every count aggregate."""
    reduced: dict[str, jax.Array] = {}
    sum_batches: dict = {}
    for out_name, op, values in aggs:
        if op == "count":
            reduced[out_name] = cnt
            continue
        v = _agg_value(t, values, cap)
        if op == "sum":
            v = jnp.where(in_dom, v, jnp.zeros((), v.dtype))
            sum_batches.setdefault(jnp.dtype(v.dtype), []).append((out_name, v))
        elif op == "min":
            v = jnp.where(in_dom, v, _dtype_max(v.dtype))
            reduced[out_name] = _ss_ops.segment_reduce(
                gid, v, dom, op="min", use_kernel=use_kernel)
        elif op == "max":
            v = jnp.where(in_dom, v, _dtype_min(v.dtype))
            reduced[out_name] = _ss_ops.segment_reduce(
                gid, v, dom, op="max", use_kernel=use_kernel)
        else:
            raise ValueError(f"unknown agg op {op!r}")
    for dt, items in sum_batches.items():
        stacked = jnp.stack([v for _, v in items], axis=1)
        sums = _ss_ops.segment_reduce(gid, stacked, dom, op="sum",
                                      use_kernel=use_kernel)
        for i, (name, _) in enumerate(items):
            reduced[name] = sums[:, i]
    return reduced


def _group_aggregate_direct(t: Table, key_cols: Sequence[str], aggs,
                            key_bits: Sequence[int] | None,
                            use_kernel: bool) -> tuple[Table, jax.Array]:
    """Sortless path: dense gid = packed key; segsum kernel; cumsum compact."""
    cap = t.capacity
    valid = t.valid_mask()
    if key_cols:
        bits = list(key_bits)
        dom = 1 << sum(bits)
        key = combine_keys([t[k] for k in key_cols], bits=bits)
        # the bits claim is checked PER COLUMN: an oversized value in a
        # non-leading column would OR into its neighbor's bits and alias an
        # in-range packed key, corrupting a group without tripping a range
        # check on the packed key alone
        in_dom = valid
        for k, b in zip(key_cols, bits):
            c = t[k]
            in_dom = in_dom & (c >= 0) & (c < (1 << b))
    else:
        bits, dom = [], 1
        key = jnp.zeros((cap,), _I64)
        in_dom = valid
    overflow = jnp.any(in_dom != valid)      # a valid row broke the bits claim
    gid = jnp.where(in_dom, key, dom).astype(jnp.int32)   # dead slot = dom

    # group occupancy doubles as every count aggregate
    cnt = _ss_ops.segment_reduce(gid, None, dom, op="count",
                                 use_kernel=use_kernel)               # (dom,)
    nonempty = cnt > 0
    ngroups = nonempty.sum().astype(jnp.int32)
    # compact dense slots to the front WITHOUT a sort: cumsum rank preserves
    # ascending-key order, so the output matches the sorted path row for row
    dst = jnp.where(nonempty, jnp.cumsum(nonempty.astype(jnp.int32)) - 1, cap)

    def _scatter(dom_vals: jax.Array) -> jax.Array:
        return jnp.zeros((cap,), dom_vals.dtype).at[dst].set(dom_vals,
                                                             mode="drop")

    out: dict[str, jax.Array] = {}
    # key columns decode from the slot index (packing is lossless in-domain)
    shift = sum(bits)
    for k, b in zip(key_cols, bits):
        shift -= b
        dom_keys = (jnp.arange(dom, dtype=_I64) >> shift) & ((1 << b) - 1)
        out[k] = _scatter(dom_keys.astype(t[k].dtype))

    reduced = _reduce_aggs(t, aggs, gid, dom, in_dom, cnt, use_kernel, cap)
    for out_name, _, _ in aggs:
        out[out_name] = _scatter(reduced[out_name])
    return Table(out, ngroups), overflow


def _group_aggregate_hash(t: Table, key_cols: Sequence[str], aggs,
                          groups_hint: int, hash_factor: float,
                          use_kernel: bool) -> tuple[Table, jax.Array]:
    """Hash-compaction path: trace-time dictionary -> ascending-key dense gid
    -> segsum kernel.  Zero sorts without provable key widths.

    The dictionary holds exact 64-bit keys (no domain claim to check), so the
    only failure modes are capacity-shaped: a row the dictionary cannot place
    (full, or an improbable probe-cluster) or more distinct groups than
    ``groups_hint`` claimed.  Both raise the overflow flag; the fault
    runner's escalation scales ``hash_factor`` (hence the dictionary), and
    an undercounting hint falls to its hint-drop recompilation — unplaced
    rows are EXCLUDED from every aggregate, never misassigned, so in-domain
    groups stay exact even on a flagged run (the lying-``key_bits``
    discipline, unchanged)."""
    cap = t.capacity
    valid = t.valid_mask()
    # legacy collision-safe packing (1-2 columns) — no width claims needed;
    # slots compare full 64-bit keys, so any int64 values group exactly
    key = combine_keys([t[k] for k in key_cols])
    dcap = _hg_ops.dict_capacity(groups_hint, hash_factor)
    slot, dkeys, occupied, unresolved = _hg_ops.build_group_dict(
        key, valid, dcap, use_kernel=use_kernel)
    rank = _hg_ops.dict_rank(dkeys, occupied)            # dcap for empty slots
    ngroups = occupied.sum().astype(jnp.int32)
    overflow = unresolved | (ngroups > groups_hint)
    resolved = valid & (slot >= 0)
    # gid IS the final output row (ascending packed key), so the reduced
    # arrays need no compaction scatter; dead slot = dcap (segsum convention)
    gid = jnp.where(resolved, rank[jnp.maximum(slot, 0)],
                    dcap).astype(jnp.int32)

    def _fit(dom_vals: jax.Array) -> jax.Array:
        if dcap >= cap:
            return dom_vals[:cap]
        return jnp.zeros((cap,), dom_vals.dtype).at[:dcap].set(dom_vals)

    out: dict[str, jax.Array] = {}
    # key columns scatter from the rows themselves (all rows of a group share
    # the value, duplicate writes are benign) — no packed-key decode, so the
    # path handles keys the bits-packing could not describe
    gid_drop = jnp.where(resolved, gid, cap)
    for k in key_cols:
        out[k] = jnp.zeros((cap,), t[k].dtype).at[gid_drop].set(
            t[k], mode="drop")
    cnt = _ss_ops.segment_reduce(gid, None, dcap, op="count",
                                 use_kernel=use_kernel)
    reduced = _reduce_aggs(t, aggs, gid, dcap, resolved, cnt, use_kernel, cap)
    for out_name, _, _ in aggs:
        out[out_name] = _fit(reduced[out_name])
    return Table(out, ngroups), overflow


def _group_aggregate_sorted(t: Table, key_cols: Sequence[str], aggs,
                            key_bits: Sequence[int] | None = None) -> Table:
    """Sort-based path: exactly ONE stable argsort, whose order is reused for
    every aggregate (segment reductions over the same segments)."""
    cap = t.capacity
    key = _valid_key(t, combine_keys([t[k] for k in key_cols], bits=key_bits)) \
        if key_cols else \
        jnp.where(t.valid_mask(), jnp.int64(0), KEY_SENTINEL)
    order = jnp.argsort(key)
    sk = key[order]
    valid = sk != KEY_SENTINEL
    first = jnp.concatenate([valid[:1], (sk[1:] != sk[:-1]) & valid[1:]])
    gid = jnp.cumsum(first.astype(jnp.int32)) - 1           # 0-based group id
    ngroups = first.sum().astype(jnp.int32)
    # invalid rows route to segment cap-1 which is provably not a valid group
    # whenever any invalid row exists (ngroups <= count <= cap-1); see tests.
    seg = jnp.where(valid, gid, cap - 1)

    out: dict[str, jax.Array] = {}
    for k in key_cols:
        v = t[k][order]
        fill = jnp.zeros((), v.dtype)
        # scatter-set: all rows of a group share the key value, so duplicate
        # writes are benign; invalid rows write the fill value into slot cap-1.
        out[k] = jnp.zeros((cap,), v.dtype).at[seg].set(jnp.where(valid, v, fill),
                                                        mode="drop")
    for out_name, op, values in aggs:
        v = _agg_value(t, values, cap)[order]
        if op == "count":
            v = jnp.where(valid, 1, 0).astype(jnp.int64)
            out[out_name] = jax.ops.segment_sum(v, seg, num_segments=cap,
                                                indices_are_sorted=True)
        elif op == "sum":
            v = jnp.where(valid, v, jnp.zeros((), v.dtype))
            out[out_name] = jax.ops.segment_sum(v, seg, num_segments=cap,
                                                indices_are_sorted=True)
        elif op == "min":
            big = _dtype_max(v.dtype)
            v = jnp.where(valid, v, big)
            out[out_name] = jax.ops.segment_min(v, seg, num_segments=cap,
                                                indices_are_sorted=True)
        elif op == "max":
            small = _dtype_min(v.dtype)
            v = jnp.where(valid, v, small)
            out[out_name] = jax.ops.segment_max(v, seg, num_segments=cap,
                                                indices_are_sorted=True)
        else:
            raise ValueError(f"unknown agg op {op!r}")
    return Table(out, ngroups)


def _dtype_max(dt):
    return jnp.asarray(np.inf if jnp.issubdtype(dt, jnp.floating) else np.iinfo(dt).max, dt)


def _dtype_min(dt):
    return jnp.asarray(-np.inf if jnp.issubdtype(dt, jnp.floating) else np.iinfo(dt).min, dt)


# ---------------------------------------------------------------------------
# ordering
# ---------------------------------------------------------------------------

def sort_by(t: Table, keys: Sequence[tuple[str, bool]]) -> Table:
    """ORDER BY; keys = [(column, ascending)], first key most significant.

    ONE stable multi-operand ``lax.sort`` (lexicographic over all key columns
    at once) instead of the seed's one argsort pass per key; invalid rows sink
    to the back via sentinels in every key operand, so the output is compact.
    """
    valid = t.valid_mask()
    operands = []
    for col, asc in keys:
        k = t[col]
        if jnp.issubdtype(k.dtype, jnp.floating):
            k = jnp.where(valid, k if asc else -k, np.inf)
        else:
            k = k.astype(_I64)
            k = jnp.where(valid, k if asc else -k, KEY_SENTINEL)
        operands.append(k)
    iota = jnp.arange(t.capacity, dtype=jnp.int32)
    res = jax.lax.sort(tuple(operands) + (iota,), num_keys=len(operands),
                       is_stable=True)
    order = res[-1]
    return Table({k: v[order] for k, v in t.columns.items()}, t.count)

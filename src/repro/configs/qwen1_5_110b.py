"""Qwen1.5-110B: 80L d8192 64H GQA(kv=8) ff49152 vocab 152064, QKV bias.
[hf:Qwen/Qwen1.5-110B family]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=49152, vocab=152064, act="swiglu", qkv_bias=True, rope_theta=1e6,
    param_count=111e9,
)

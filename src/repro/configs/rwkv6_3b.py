"""RWKV6 (Finch) 3B: 32L d2560 attention-free (data-dependent decay),
channel-mix ff8960, vocab 65536.  [arXiv:2404.05892]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab=65536, act="swiglu", rope_theta=1e4,
    sub_quadratic=True,
    param_count=3.1e9,
)

"""Gemma-7B: 28L d3072 16H (kv=16) head_dim=256 ff24576 vocab 256000,
GeGLU, tied embeddings, sqrt(d) embed scale.  [arXiv:2403.08295]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab=256000, act="geglu", rope_theta=1e4,
    tie_embeddings=True, embed_scale=True,
    param_count=8.5e9,
)

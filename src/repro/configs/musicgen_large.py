"""MusicGen-large backbone: 48L d2048 32H (kv=32) ff8192 over EnCodec token
vocab 2048.  The EnCodec frontend is a STUB: inputs are codec token ids
(the modality frontend would produce them offline).  [arXiv:2306.05284]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=2048, act="swiglu", rope_theta=1e4,
    frontend="audio_frames",
    param_count=3.3e9,
)

"""Phi-3-mini 3.8B: 32L d3072 32H (kv=32 -> MHA) ff8192 vocab 32064,
RoPE + SwiGLU.  [arXiv:2404.14219]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab=32064, act="swiglu", rope_theta=1e4,
    param_count=3.8e9,
)

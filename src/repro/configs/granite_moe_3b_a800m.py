"""IBM Granite-3.0 MoE 3B-A800M: 32L d1536 24H GQA(kv=8), MoE 40 experts
top-8, expert ff512, vocab 49155.  [hf:ibm-granite/granite-3.0-3b-a800m]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49155, act="swiglu", rope_theta=1e4,
    n_experts=40, top_k=8, d_ff_expert=512,
    param_count=3.3e9, active_param_count=0.8e9,
)

"""DeepSeek-V2 236B: 60L d5120 128H MLA(kv_lora=512, q_lora=1536,
qk_nope=128 qk_rope=64 v=128), MoE 160 routed top-6 + 2 shared,
expert ff1536, first layer dense, vocab 102400.  [arXiv:2405.04434]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288, vocab=102400, act="swiglu", rope_theta=1e4,
    n_experts=160, n_shared_experts=2, top_k=6, d_ff_expert=1536,
    first_dense_layers=1,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    param_count=236e9, active_param_count=21e9,
)

"""Zamba2-1.2B: 38 Mamba2 blocks (d2048, state 64, expand 2) with a shared
attention+MLP block (32H, ff8192) applied every 6 layers, vocab 32000.
[arXiv:2411.15242]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=32000, act="swiglu", rope_theta=1e4,
    ssm_state=64, ssm_expand=2, ssm_conv=4, shared_attn_every=6,
    sub_quadratic=True,
    param_count=1.2e9,
)

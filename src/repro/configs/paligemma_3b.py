"""PaliGemma-3B language backbone: 18L d2048 8H MQA(kv=1) ff16384
vocab 257216; SigLIP vision frontend is a STUB (input_specs supplies 256
precomputed patch embeddings), prefix-LM attention over the patch prefix.
[arXiv:2407.07726]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=257216, act="geglu", rope_theta=1e4,
    tie_embeddings=True, embed_scale=True,
    frontend="vision_patches", n_prefix=256, prefix_lm=True,
    param_count=2.9e9,
)

"""Assigned architectures (exact public configs) + input-shape sets.

Every (arch x shape) cell the dry-run must compile is enumerated by
``iter_cells()``; pure full-attention archs skip long_500k (DESIGN.md §5).
"""
from __future__ import annotations

import importlib

import jax
import numpy as np

from repro.models.common import ArchConfig

ARCH_IDS = [
    "mistral_nemo_12b", "phi3_mini_3_8b", "qwen1_5_110b", "gemma_7b",
    "deepseek_v2_236b", "granite_moe_3b_a800m", "zamba2_1_2b",
    "musicgen_large", "paligemma_3b", "rwkv6_3b",
]

# shape_id -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def get_config(arch_id: str) -> ArchConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def input_specs(cfg: ArchConfig, shape_id: str, reduced: bool = False):
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    No allocation: the dry-run lowers against these.  ``reduced`` scales the
    shapes down for smoke use."""
    seq, batch, kind = SHAPES[shape_id]
    if reduced:
        seq, batch = min(seq, 128), min(batch, 2)
    f = jax.ShapeDtypeStruct
    i32 = np.int32
    if kind == "train":
        spec = {"tokens": f((batch, seq), i32), "labels": f((batch, seq), i32)}
    elif kind == "prefill":
        spec = {"tokens": f((batch, seq), i32)}
    else:  # decode: one new token against a seq-long cache
        spec = {"token": f((batch, 1), i32)}
    if cfg.frontend == "vision_patches" and kind != "decode":
        spec["patches"] = f((batch, cfg.n_prefix, cfg.d_model), np.float32)
    return spec


def cell_enabled(cfg: ArchConfig, shape_id: str) -> tuple[bool, str]:
    if shape_id == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 524k decode skipped (DESIGN.md §5)"
    return True, ""


def iter_cells():
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        for shape_id in SHAPES:
            ok, why = cell_enabled(cfg, shape_id)
            yield arch_id, shape_id, ok, why

"""Mistral-Nemo-Base-2407: 40L d5120 32H GQA(kv=8) head_dim=128 ff14336
vocab 131072, 128k ctx.  [hf:mistralai/Mistral-Nemo-Base-2407]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=131072, act="swiglu", rope_theta=1e6,
    param_count=12.2e9,
)

"""Sharding rules: parameter PartitionSpecs by tree path + activation/cache specs.

Scheme (DESIGN.md §4): 2-D param sharding — FSDP over the data(+pod) axes on
one matrix dim, tensor parallelism over ``model`` on the other; experts shard
over ``model`` (EP); optimizer state mirrors param specs (ZeRO-3 via GSPMD).
KV caches shard batch over data — except batch-1 long-context decode, where the
*sequence* dim shards over data and GSPMD's partial-softmax all-reduce gives
flash-decode for free.
"""
from __future__ import annotations

from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ArchConfig

__all__ = ["param_specs", "batch_specs", "cache_specs", "make_constrain",
           "named", "MeshAxes"]


class MeshAxes:
    """fsdp = axes sharding the 'data' matrix dim; tp = tensor axis."""

    def __init__(self, fsdp: Sequence[str] = ("data",), tp: str = "model"):
        self.fsdp = tuple(fsdp)
        self.tp = tp

    def dp(self):
        return self.fsdp


# rule table: leaf name -> spec skeleton with 'F' (fsdp), 'T' (tp), None
_RULES_2D = {
    "embed": ("T", "F"), "lm_head": ("F", "T"),
    "wq": ("F", "T"), "wk": ("F", "T"), "wv": ("F", "T"), "wo": ("T", "F"),
    "wg": ("F", "T"),
    "w_gate": ("F", "T"), "w_up": ("F", "T"), "w_down": ("T", "F"),
    "shared_gate": ("F", "T"), "shared_up": ("F", "T"),
    "shared_down": ("T", "F"),
    "router": ("F", None),
    "wq_a": ("F", None), "wq_b": ("F", "T"),
    "wkv_a": ("F", None), "wkv_b": ("F", "T"),
    "w_in": ("F", "T"), "w_out": ("T", "F"),
    "conv_w": (None, "T"),
    "w_a": ("F", None), "w_b": (None, "F"),
    "fk": ("F", "T"), "fv": ("T", "F"), "fr": ("F", "T"),
    "u": (None, None),
}
_RULES_3D = {  # MoE expert stacks (E, D, F) / (E, F, D)
    "w_gate": ("T", "F", None), "w_up": ("T", "F", None),
    "w_down": ("T", None, "F"),
}
_RULES_1D = {
    "bq": ("T",), "bk": ("T",), "bv": ("T",), "conv_b": ("T",),
    "a_log": ("T",), "dt_bias": ("T",), "d_skip": ("T",),
}


def _resolve(skel, axes: MeshAxes):
    out = []
    for s in skel:
        if s == "F":
            if not axes.fsdp:                  # serving: TP-only params
                out.append(None)
            else:
                out.append(axes.fsdp if len(axes.fsdp) > 1 else axes.fsdp[0])
        elif s == "T":
            out.append(axes.tp)
        else:
            out.append(None)
    return P(*out)


def param_specs(params_like, axes: MeshAxes):
    """Spec tree matching the param tree (works on ShapeDtypeStructs too)."""

    def rule(path, leaf):
        names = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        name = names[-1]
        stacked = "segments" in names
        nd = leaf.ndim - (1 if stacked else 0)
        skel = None
        if nd == 3 and name in _RULES_3D:
            skel = _RULES_3D[name]
        elif nd == 2 and name in _RULES_2D:
            skel = _RULES_2D[name]
        elif nd == 1 and name in _RULES_1D:
            skel = _RULES_1D[name]
        if skel is None:
            spec = P(*([None] * nd))                    # replicate (norms etc.)
        else:
            spec = _resolve(skel, axes)
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(rule, params_like)


def batch_specs(axes: MeshAxes, spec_like):
    """tokens/labels (B, S) -> batch over dp; patches (B, P, D) likewise."""
    dp = axes.dp()
    dp = dp if len(dp) > 1 else dp[0]

    def rule(leaf):
        return P(dp, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(rule, spec_like)


def cache_specs(cfg: ArchConfig, cache_like, axes: MeshAxes, batch: int,
                mesh_shape: dict):
    """KV-cache/state specs; batch-1 long decode shards the sequence dim."""
    dp_axes = axes.dp()
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    dp_size = int(np.prod([mesh_shape[a] for a in dp_axes]))
    tp_size = mesh_shape[axes.tp]
    batch_sharded = batch % dp_size == 0 and batch >= dp_size

    def rule(path, leaf):
        names = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        name = names[-1] if names else None
        stacked = "segments" in names
        nd = leaf.ndim - (1 if stacked else 0)
        # KV caches: (B, S, H, hd) | MLA (B, S, r) | states (B, ...)
        spec: list = [None] * nd
        if nd >= 1:
            if batch_sharded:
                spec[0] = dp
            elif name in ("k", "v", "ckv", "krope") and nd >= 2:
                spec[1] = dp                      # seq-sharded flash-decode
        if name in ("k", "v") and nd == 4 and cfg.n_kv_heads % tp_size == 0:
            spec[2] = axes.tp
        out = P(*spec)
        if stacked:
            out = P(None, *out)
        return out

    return jax.tree_util.tree_map_with_path(rule, cache_like)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def make_constrain(mesh: Mesh, axes: MeshAxes, seq_parallel: bool = False):
    """Activation-sharding hook for Model.constrain.

    ``seq_parallel`` shards the residual stream's sequence dim over the tensor
    axis (Megatron-SP): the norm/elementwise chains between attention and MLP
    run on 1/TP of the tokens instead of being replicated TP times, and the
    output-projection all-reduce splits into reduce-scatter + all-gather."""
    dp = axes.dp()
    dp = dp if len(dp) > 1 else dp[0]

    def constrain(x, kind: str):
        if x.ndim < 2:
            return x
        if kind == "logits":
            spec = P(dp, *([None] * (x.ndim - 2)), axes.tp)
        elif kind == "residual" and seq_parallel and x.ndim == 3:
            spec = P(dp, axes.tp, None)
        else:
            spec = P(dp, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain

"""Seeded, deterministic fault injection — the chaos harness.

The paper's fault story (§2.4) is "re-execute the whole query"; proving that
story (and the finer-grained recovery this repo layers on top) requires
*injecting* every failure domain on demand, deterministically, so a CI leg
can replay the exact same fault schedule on every commit.

A :class:`FaultPlan` is a seeded list of :class:`FaultSpec` entries, each
naming WHERE (a cut point: ``scan`` / ``exchange`` / ``group_by`` /
``finalize``, or ``any`` for the first cut visited), WHEN (which visit of
that cut, on which run attempt) and WHAT (a fault kind) to inject.  The
:class:`ChaosInjector` holds the plan plus per-attempt visit counters; the
execution backends call :meth:`ChaosInjector.fire` from
``_BaseContext._chaos_point`` at every cut point.

Fault kinds and their mechanism:

  ``transient``      raises :class:`TransientFault` (simulated node loss /
                     flaky link) — aborts the attempt while tracing.
  ``deterministic``  raises ``ValueError`` (simulated plan-author bug) —
                     the fault runner must surface it on attempt 1, never
                     burn retries on it.
  ``straggler``      sleeps ``delay_s`` (simulated slow node) — the attempt
                     succeeds, late; visible in per-attempt wall time.
  ``overflow``       ORs the traced ``ctx.overflow`` flag (simulated lying
                     capacity bound) — exercises the escalation ladder.
  ``corrupt``        returns a payload-tamper callable that flips one
                     seed-chosen bit of the received exchange buffer inside
                     the compiled program — the wire checksum must catch it.
                     At cut points with no checksummed payload in flight the
                     detection is simulated by ORing ``ctx.corrupt``.

Enabled for any test or bench via the ``REPRO_CHAOS`` env leg: unset / ``0``
/ ``off`` disables; any other integer seeds :meth:`FaultPlan.default` (one
transient + one corrupt + one overflow across the first three attempts) and
arms the fault runner's default injector (``ChaosInjector.from_env``).

Everything here is deterministic in (seed, plan, query): the same schedule
fires at the same cut visits and flips the same bit on every run — chaos
you can bisect.
"""
from __future__ import annotations

import dataclasses
import enum
import os
import time
import zlib

import jax
import jax.numpy as jnp

__all__ = [
    "FailureKind", "TransientFault", "FaultSpec", "FaultPlan",
    "FiredFault", "ChaosInjector", "chaos_env_seed",
    "CUT_POINTS", "FAULT_KINDS",
]

CUT_POINTS = ("scan", "exchange", "group_by", "finalize")
FAULT_KINDS = ("transient", "deterministic", "straggler", "overflow",
               "corrupt")


class FailureKind(enum.Enum):
    """Failure taxonomy consumed by the retry policy (distributed/fault.py).

    TRANSIENT      environment fault (node loss, flaky link, timeout):
                   retry with exponential backoff.
    OVERFLOW       capacity/bound violation (the overflow-not-wrong flag):
                   escalate the capacity factor, then drop planner hints.
    CORRUPT        payload failed its wire integrity checksum: re-run on the
                   conservative wide format — never serve the bad buffer.
    DETERMINISTIC  a plan-author bug (TypeError, ValueError, assertion …):
                   raise immediately; retrying cannot help.
    """
    TRANSIENT = "transient"
    OVERFLOW = "overflow"
    CORRUPT = "corrupt"
    DETERMINISTIC = "deterministic"


class TransientFault(RuntimeError):
    """Simulated (or real) environment fault: node loss, dropped link.
    Classified TRANSIENT by the fault runner — retried with backoff."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: WHAT (``kind``), WHERE (``cut``, ``index``) and
    WHEN (``attempt``, 1-based)."""
    kind: str                 # one of FAULT_KINDS
    cut: str = "any"          # CUT_POINTS entry, or "any" = first cut visited
    index: int = 0            # which visit of that cut within the attempt
    attempt: int = 1          # fires on this run attempt only
    delay_s: float = 0.05     # straggler sleep

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.cut != "any" and self.cut not in CUT_POINTS:
            raise ValueError(f"unknown cut point {self.cut!r}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded schedule of faults.  The seed drives every data-dependent
    choice (which bit a corrupt fault flips), so a plan replays exactly."""
    seed: int
    faults: tuple[FaultSpec, ...]

    @classmethod
    def default(cls, seed: int) -> "FaultPlan":
        """The chaos-sweep schedule: one transient, one corrupt and one
        overflow fault across the first three attempts — a clean run needs
        attempt 4, exercising every recovery path of the retry policy.
        ``group_by`` covers scalar-only plans too (``agg_scalar`` fires it)."""
        return cls(seed, (
            FaultSpec("transient", cut="scan", index=0, attempt=1),
            FaultSpec("corrupt", cut="group_by", index=0, attempt=2),
            FaultSpec("overflow", cut="any", index=0, attempt=3),
        ))


@dataclasses.dataclass(frozen=True)
class FiredFault:
    """One injection that actually happened — surfaced in the RunReport."""
    attempt: int
    cut: str
    index: int
    kind: str
    simulated: bool = False   # corrupt w/o a checksummed payload in flight


def _mix(seed: int, *parts) -> int:
    """Deterministic (process-stable) integer from seed + context parts —
    NOT python ``hash()``, which is salted per process."""
    return zlib.crc32(repr((seed,) + parts).encode())


def chaos_env_seed() -> int | None:
    """``REPRO_CHAOS`` env leg: unset / ``0`` / ``off`` -> None (disabled);
    any other value is the integer seed of the default fault plan."""
    v = os.environ.get("REPRO_CHAOS", "").strip().lower()
    if v in ("", "0", "off", "false", "none"):
        return None
    return int(v)


class ChaosInjector:
    """Stateful driver of a :class:`FaultPlan` across run attempts.

    The fault runner calls :meth:`begin_attempt` before each (re-)execution;
    the backends call :meth:`fire` at every cut point.  Fired faults are
    recorded in :attr:`events` for the per-attempt RunReport.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.events: list[FiredFault] = []
        self.begin_attempt(1)

    @classmethod
    def from_env(cls) -> "ChaosInjector | None":
        seed = chaos_env_seed()
        return None if seed is None else cls(FaultPlan.default(seed))

    def begin_attempt(self, attempt: int) -> None:
        """Reset per-cut visit counters for a fresh (re-)execution."""
        self._attempt = attempt
        self._visits: dict[str, int] = {}
        self._total = 0

    # -- injection ----------------------------------------------------------
    def fire(self, cut: str, ctx, tamperable: bool = False):
        """Called by ``_BaseContext._chaos_point``.  Returns a tamper
        callable for a corrupt fault the call site can route into a
        checksummed exchange, else None.  May raise, sleep, or OR traced
        fault flags on ``ctx`` — see the module docstring."""
        i = self._visits.get(cut, 0)
        self._visits[cut] = i + 1
        total = self._total
        self._total += 1
        spec = self._due(cut, i, total)
        if spec is None:
            return None
        if spec.kind == "transient":
            self.events.append(FiredFault(self._attempt, cut, i, spec.kind))
            raise TransientFault(
                f"chaos: node lost at {cut}#{i} (attempt {self._attempt})")
        if spec.kind == "deterministic":
            self.events.append(FiredFault(self._attempt, cut, i, spec.kind))
            raise ValueError(
                f"chaos: plan bug at {cut}#{i} (attempt {self._attempt})")
        if spec.kind == "straggler":
            self.events.append(FiredFault(self._attempt, cut, i, spec.kind))
            time.sleep(spec.delay_s)
            return None
        if spec.kind == "overflow":
            self.events.append(FiredFault(self._attempt, cut, i, spec.kind))
            ctx.overflow = ctx.overflow | jnp.asarray(True)
            return None
        # corrupt: flip a seed-chosen payload bit where a checksummed buffer
        # is in flight; otherwise simulate the detection
        self.events.append(FiredFault(self._attempt, cut, i, spec.kind,
                                      simulated=not tamperable))
        if not tamperable:
            ctx.corrupt = ctx.corrupt | jnp.asarray(True)
            return None
        return self._tamper(cut, i)

    def _due(self, cut: str, index: int, total: int) -> FaultSpec | None:
        for spec in self.plan.faults:
            if spec.attempt != self._attempt:
                continue
            if spec.cut == "any":
                if total == spec.index:
                    return spec
            elif spec.cut == cut and spec.index == index:
                return spec
        return None

    def _tamper(self, cut: str, index: int):
        """Payload corrupter: flips ONE bit, chosen deterministically from
        (seed, cut, index, attempt) — embedded in the traced program."""
        r = _mix(self.plan.seed, cut, index, self._attempt)

        def tamper(payload: jax.Array) -> jax.Array:
            flat = payload.reshape(-1)
            u = jax.lax.bitcast_convert_type(flat, jnp.uint32)
            pos = r % max(1, u.shape[0])        # shapes are static at trace
            bit = jnp.uint32((r >> 16) & 31)
            u = u.at[pos].set(u[pos] ^ (jnp.uint32(1) << bit))
            return jax.lax.bitcast_convert_type(
                u, jnp.int32).reshape(payload.shape)

        return tamper

"""Seeded, deterministic fault injection — the chaos harness.

The paper's fault story (§2.4) is "re-execute the whole query"; proving that
story (and the finer-grained recovery this repo layers on top) requires
*injecting* every failure domain on demand, deterministically, so a CI leg
can replay the exact same fault schedule on every commit.

A :class:`FaultPlan` is a seeded list of :class:`FaultSpec` entries, each
naming WHERE (a cut point: ``scan`` / ``exchange`` / ``group_by`` /
``finalize``, or ``any`` for the first cut visited), WHEN (which visit of
that cut, on which run attempt) and WHAT (a fault kind) to inject.  The
:class:`ChaosInjector` holds the plan plus per-attempt visit counters; the
execution backends call :meth:`ChaosInjector.fire` from
``_BaseContext._chaos_point`` at every cut point.

Fault kinds and their mechanism:

  ``transient``      raises :class:`TransientFault` (simulated node loss /
                     flaky link) — aborts the attempt while tracing.
  ``deterministic``  raises ``ValueError`` (simulated plan-author bug) —
                     the fault runner must surface it on attempt 1, never
                     burn retries on it.
  ``straggler``      sleeps ``delay_s`` (simulated slow node) — the attempt
                     succeeds, late; visible in per-attempt wall time.
  ``overflow``       ORs the traced ``ctx.overflow`` flag (simulated lying
                     capacity bound) — exercises the escalation ladder.
  ``corrupt``        returns a payload-tamper callable that flips one
                     seed-chosen bit of the received exchange buffer inside
                     the compiled program — the wire checksum must catch it.
                     At cut points with no checksummed payload in flight the
                     detection is simulated by ORing ``ctx.corrupt``.
  ``device_lost``    raises :class:`DeviceLost` naming one or more mesh
                     participants dead — either an explicit ``devices`` set
                     or ``n_lost`` seeded-random ranks.  The fault runner
                     answers with a topology shrink: a new mesh over the
                     survivors, re-plan, re-execute.

Enabled for any test or bench via the ``REPRO_CHAOS`` env leg: unset / ``0``
/ ``off`` disables; any other integer seeds :meth:`FaultPlan.default` (one
transient + one corrupt + one overflow across the first three attempts) and
arms the fault runner's default injector (``ChaosInjector.from_env``).  A
``lose=`` suffix (``REPRO_CHAOS="<seed>,lose=<r0>[+<r1>...][@<cut>]"``)
arms :meth:`FaultPlan.device_loss` instead: the named ranks die at the
named cut (default ``exchange``) on attempt 1.

Everything here is deterministic in (seed, plan, query): the same schedule
fires at the same cut visits and flips the same bit on every run — chaos
you can bisect.
"""
from __future__ import annotations

import dataclasses
import enum
import os
import time
import zlib

import jax
import jax.numpy as jnp

__all__ = [
    "FailureKind", "TransientFault", "DeviceLost", "FaultSpec", "FaultPlan",
    "FiredFault", "ChaosInjector", "chaos_env_seed", "chaos_env_lost",
    "resolve_lost", "CUT_POINTS", "FAULT_KINDS",
]

CUT_POINTS = ("scan", "exchange", "group_by", "finalize")
FAULT_KINDS = ("transient", "deterministic", "straggler", "overflow",
               "corrupt", "device_lost")


class FailureKind(enum.Enum):
    """Failure taxonomy consumed by the retry policy (distributed/fault.py).

    TRANSIENT      environment fault (node loss, flaky link, timeout):
                   retry with exponential backoff.
    OVERFLOW       capacity/bound violation (the overflow-not-wrong flag):
                   escalate the capacity factor, then drop planner hints.
    CORRUPT        payload failed its wire integrity checksum: re-run on the
                   conservative wide format — never serve the bad buffer.
    DETERMINISTIC  a plan-author bug (TypeError, ValueError, assertion …):
                   raise immediately; retrying cannot help.
    DEVICE_LOST    one or more mesh participants are gone for good: retrying
                   on the same topology can only fail again — shrink the
                   mesh to the survivors, re-plan at the new width, and
                   re-execute (the topology-elastic rung).
    TOLERANCE_MISS an approximate answer's confidence interval exceeded the
                   caller's tolerance (repro.approx.progressive): not an
                   execution failure — the attempt ran clean — but the
                   outcome climbs the sample ladder to the next larger rung
                   the way OVERFLOW climbs the capacity factor.
    """
    TRANSIENT = "transient"
    OVERFLOW = "overflow"
    CORRUPT = "corrupt"
    DETERMINISTIC = "deterministic"
    DEVICE_LOST = "device_lost"
    TOLERANCE_MISS = "tolerance_miss"


class TransientFault(RuntimeError):
    """Simulated (or real) environment fault: node loss, dropped link.
    Classified TRANSIENT by the fault runner — retried with backoff."""


class DeviceLost(RuntimeError):
    """One or more mesh participants are permanently dead.

    ``lost`` is the tuple of dead device ranks when the injection site knew
    the live mesh width (``ctx.N`` on the distributed context, the logical
    ``lineage_devices`` width on resumable eager runs); otherwise it is
    empty and ``n_lost`` tells the fault runner how many seeded-random
    ranks to resolve against its own mesh (:func:`resolve_lost`).
    Classified DEVICE_LOST — recovered by topology shrink, never by
    same-topology retry."""

    def __init__(self, message: str, lost: tuple[int, ...] = (),
                 n_lost: int = 1, seed: int = 0):
        super().__init__(message)
        self.lost = tuple(lost)
        self.n_lost = int(n_lost)
        self.seed = int(seed)


def resolve_lost(exc: "DeviceLost", world: int) -> tuple[int, ...]:
    """Dead ranks of a :class:`DeviceLost` against a ``world``-wide mesh.

    Explicit ranks are clipped to the mesh; an unresolved fault picks
    ``n_lost`` distinct seeded-random ranks.  Never returns the whole mesh:
    at least one survivor remains (a query with zero devices is not a
    topology, it is an outage)."""
    if exc.lost:
        lost = tuple(sorted({d for d in exc.lost if 0 <= d < world}))
    else:
        ranks = list(range(world))
        lost_l: list[int] = []
        for i in range(min(exc.n_lost, world)):
            j = _mix(exc.seed, "device_lost", i) % len(ranks)
            lost_l.append(ranks.pop(j))
        lost = tuple(sorted(lost_l))
    if len(lost) >= world:
        lost = lost[: world - 1]
    return lost


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: WHAT (``kind``), WHERE (``cut``, ``index``) and
    WHEN (``attempt``, 1-based).  ``devices`` / ``n_lost`` parameterize a
    ``device_lost`` fault: an explicit dead-rank set, or how many
    seeded-random ranks to kill when the set is empty."""
    kind: str                 # one of FAULT_KINDS
    cut: str = "any"          # CUT_POINTS entry, or "any" = first cut visited
    index: int = 0            # which visit of that cut within the attempt
    attempt: int = 1          # fires on this run attempt only
    delay_s: float = 0.05     # straggler sleep
    devices: tuple[int, ...] = ()   # device_lost: explicit dead ranks
    n_lost: int = 1           # device_lost: seeded-random kill count

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.cut != "any" and self.cut not in CUT_POINTS:
            raise ValueError(f"unknown cut point {self.cut!r}")
        object.__setattr__(self, "devices", tuple(self.devices))
        if any(int(d) < 0 for d in self.devices):
            raise ValueError(f"negative device rank in {self.devices!r}")
        if self.kind == "device_lost" and not self.devices \
                and self.n_lost < 1:
            raise ValueError("device_lost needs devices or n_lost >= 1")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded schedule of faults.  The seed drives every data-dependent
    choice (which bit a corrupt fault flips), so a plan replays exactly."""
    seed: int
    faults: tuple[FaultSpec, ...]

    @classmethod
    def default(cls, seed: int) -> "FaultPlan":
        """The chaos-sweep schedule: one transient, one corrupt and one
        overflow fault across the first three attempts — a clean run needs
        attempt 4, exercising every recovery path of the retry policy.
        ``group_by`` covers scalar-only plans too (``agg_scalar`` fires it)."""
        return cls(seed, (
            FaultSpec("transient", cut="scan", index=0, attempt=1),
            FaultSpec("corrupt", cut="group_by", index=0, attempt=2),
            FaultSpec("overflow", cut="any", index=0, attempt=3),
        ))

    @classmethod
    def device_loss(cls, seed: int, devices: tuple[int, ...] = (),
                    n_lost: int = 1, cut: str = "exchange") -> "FaultPlan":
        """The topology-shrink schedule: the named ranks (or ``n_lost``
        seeded-random ones) die at the first visit of ``cut`` on attempt 1;
        the clean re-execution on the shrunken mesh is attempt 2."""
        return cls(seed, (
            FaultSpec("device_lost", cut=cut, index=0, attempt=1,
                      devices=tuple(devices), n_lost=n_lost),
        ))


@dataclasses.dataclass(frozen=True)
class FiredFault:
    """One injection that actually happened — surfaced in the RunReport."""
    attempt: int
    cut: str
    index: int
    kind: str
    simulated: bool = False   # corrupt w/o a checksummed payload in flight


def _mix(seed: int, *parts) -> int:
    """Deterministic (process-stable) integer from seed + context parts —
    NOT python ``hash()``, which is salted per process."""
    return zlib.crc32(repr((seed,) + parts).encode())


def chaos_env_seed() -> int | None:
    """``REPRO_CHAOS`` env leg: unset / ``0`` / ``off`` -> None (disabled);
    any other value is the integer seed of the armed fault plan.  A
    ``,lose=...`` suffix (see :func:`chaos_env_lost`) does not change the
    seed parse."""
    v = os.environ.get("REPRO_CHAOS", "").strip().lower()
    v = v.split(",", 1)[0].strip()
    if v in ("", "0", "off", "false", "none"):
        return None
    return int(v)


def chaos_env_lost() -> tuple[tuple[int, ...], str] | None:
    """Device-loss suffix of ``REPRO_CHAOS``: ``<seed>,lose=<r0>[+<r1>...]
    [@<cut>]`` -> (dead ranks, cut point); None when absent.

    ``REPRO_CHAOS="1,lose=3"`` kills rank 3 at the first exchange;
    ``REPRO_CHAOS="1,lose=1+4+6@scan"`` kills ranks 1, 4 and 6 at the first
    scan.  With the suffix present the armed plan is
    :meth:`FaultPlan.device_loss` instead of :meth:`FaultPlan.default`."""
    v = os.environ.get("REPRO_CHAOS", "").strip().lower()
    if "," not in v:
        return None
    suffix = v.split(",", 1)[1].strip()
    if not suffix.startswith("lose="):
        raise ValueError(f"REPRO_CHAOS suffix {suffix!r}: expected lose=...")
    spec = suffix[len("lose="):]
    cut = "exchange"
    if "@" in spec:
        spec, cut = spec.split("@", 1)
    ranks = tuple(int(r) for r in spec.split("+") if r)
    if not ranks:
        raise ValueError("REPRO_CHAOS lose= names no ranks")
    return ranks, cut


class ChaosInjector:
    """Stateful driver of a :class:`FaultPlan` across run attempts.

    The fault runner calls :meth:`begin_attempt` before each (re-)execution;
    the backends call :meth:`fire` at every cut point.  Fired faults are
    recorded in :attr:`events` for the per-attempt RunReport.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.events: list[FiredFault] = []
        self.begin_attempt(1)

    @classmethod
    def from_env(cls) -> "ChaosInjector | None":
        seed = chaos_env_seed()
        if seed is None:
            return None
        lost = chaos_env_lost()
        if lost is not None:
            ranks, cut = lost
            return cls(FaultPlan.device_loss(seed, devices=ranks, cut=cut))
        return cls(FaultPlan.default(seed))

    def begin_attempt(self, attempt: int) -> None:
        """Reset per-cut visit counters for a fresh (re-)execution."""
        self._attempt = attempt
        self._visits: dict[str, int] = {}
        self._total = 0

    # -- injection ----------------------------------------------------------
    def fire(self, cut: str, ctx, tamperable: bool = False):
        """Called by ``_BaseContext._chaos_point``.  Returns a tamper
        callable for a corrupt fault the call site can route into a
        checksummed exchange, else None.  May raise, sleep, or OR traced
        fault flags on ``ctx`` — see the module docstring."""
        i = self._visits.get(cut, 0)
        self._visits[cut] = i + 1
        total = self._total
        self._total += 1
        spec = self._due(cut, i, total)
        if spec is None:
            return None
        if spec.kind == "transient":
            self.events.append(FiredFault(self._attempt, cut, i, spec.kind))
            raise TransientFault(
                f"chaos: node lost at {cut}#{i} (attempt {self._attempt})")
        if spec.kind == "deterministic":
            self.events.append(FiredFault(self._attempt, cut, i, spec.kind))
            raise ValueError(
                f"chaos: plan bug at {cut}#{i} (attempt {self._attempt})")
        if spec.kind == "straggler":
            self.events.append(FiredFault(self._attempt, cut, i, spec.kind))
            time.sleep(spec.delay_s)
            return None
        if spec.kind == "device_lost":
            self.events.append(FiredFault(self._attempt, cut, i, spec.kind))
            world = getattr(ctx, "N", None) or \
                getattr(ctx, "lineage_devices", None)
            exc = DeviceLost(
                f"chaos: device(s) lost at {cut}#{i} "
                f"(attempt {self._attempt})", lost=spec.devices,
                n_lost=spec.n_lost, seed=self.plan.seed)
            if not exc.lost and world:
                exc.lost = resolve_lost(exc, int(world))
            raise exc
        if spec.kind == "overflow":
            self.events.append(FiredFault(self._attempt, cut, i, spec.kind))
            ctx.overflow = ctx.overflow | jnp.asarray(True)
            return None
        # corrupt: flip a seed-chosen payload bit where a checksummed buffer
        # is in flight; otherwise simulate the detection
        self.events.append(FiredFault(self._attempt, cut, i, spec.kind,
                                      simulated=not tamperable))
        if not tamperable:
            ctx.corrupt = ctx.corrupt | jnp.asarray(True)
            return None
        return self._tamper(cut, i)

    def _due(self, cut: str, index: int, total: int) -> FaultSpec | None:
        for spec in self.plan.faults:
            if spec.attempt != self._attempt:
                continue
            if spec.cut == "any":
                if total == spec.index:
                    return spec
            elif spec.cut == cut and spec.index == index:
                return spec
        return None

    def _tamper(self, cut: str, index: int):
        """Payload corrupter: flips ONE bit, chosen deterministically from
        (seed, cut, index, attempt) — embedded in the traced program."""
        r = _mix(self.plan.seed, cut, index, self._attempt)

        def tamper(payload: jax.Array) -> jax.Array:
            flat = payload.reshape(-1)
            u = jax.lax.bitcast_convert_type(flat, jnp.uint32)
            pos = r % max(1, u.shape[0])        # shapes are static at trace
            bit = jnp.uint32((r >> 16) & 31)
            u = u.at[pos].set(u[pos] ^ (jnp.uint32(1) << bit))
            return jax.lax.bitcast_convert_type(
                u, jnp.int32).reshape(payload.shape)

        return tamper

"""Sharded checkpointing: atomic save, checksummed restore, elastic resharding.

Layout:  <dir>/step_<N>/ manifest.json + <leaf-index>.npy
Save is atomic (tmp dir + rename) and optionally async (background thread);
restore re-shards onto any mesh via device_put with the target NamedShardings,
which is what elastic shrink/grow needs.  keep_last_k garbage-collects old
steps only after a newer step is durable — a crash mid-save never loses the
previous checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "restore_flat", "latest_step",
           "CheckpointManager"]


def _leaf_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree: Any, metadata: dict | None = None):
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _leaf_paths(tree)
    manifest = {"step": step, "n_leaves": len(leaves),
                "treedef": str(treedef), "metadata": metadata or {},
                "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        fn = f"{i:06d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        with open(os.path.join(tmp, fn), "rb") as f:
            crc = zlib.crc32(f.read())
        manifest["leaves"].append({"file": fn, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype), "crc32": crc})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(directory: str, step: int, tree_like: Any,
            shardings: Any = None, strict_checksum: bool = True):
    """Load into the structure of ``tree_like``; reshard if shardings given.

    ``shardings`` may target a different mesh than the one saved from —
    this is the elastic-scaling path."""
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _leaf_paths(tree_like)
    assert manifest["n_leaves"] == len(leaves), \
        f"checkpoint has {manifest['n_leaves']} leaves, target {len(leaves)}"
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    out = []
    for i, (like, shard) in enumerate(zip(leaves, shard_leaves)):
        meta = manifest["leaves"][i]
        fp = os.path.join(path, meta["file"])
        if strict_checksum:
            with open(fp, "rb") as f:
                crc = zlib.crc32(f.read())
            if crc != meta["crc32"]:
                raise IOError(f"checksum mismatch in {fp}")
        arr = np.load(fp)
        expect = tuple(like.shape)
        if tuple(arr.shape) != expect:
            raise ValueError(f"leaf {i}: shape {arr.shape} != {expect}")
        out.append(jax.device_put(arr, shard) if shard is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["metadata"]


def restore_flat(directory: str, step: int, strict_checksum: bool = True):
    """Load a checkpoint saved from a FLAT dict of arrays with no
    ``tree_like`` template — the reader may not know the shape of what was
    saved (the lineage-recovery path: a resuming query learns a snapshot's
    columns from the snapshot itself).

    Requires the writer to have recorded the key list as
    ``metadata["keys"]`` in save order (a flat dict flattens in sorted-key
    order).  Keeps the per-leaf CRC verification of :func:`restore`.
    """
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    keys = manifest["metadata"].get("keys")
    if keys is None:
        raise ValueError(
            f"{path}: not a flat-dict checkpoint (no metadata['keys'])")
    if len(keys) != manifest["n_leaves"]:
        raise ValueError(f"{path}: {len(keys)} keys vs "
                         f"{manifest['n_leaves']} leaves")
    out = {}
    for key, meta in zip(keys, manifest["leaves"]):
        fp = os.path.join(path, meta["file"])
        if strict_checksum:
            with open(fp, "rb") as f:
                crc = zlib.crc32(f.read())
            if crc != meta["crc32"]:
                raise IOError(f"checksum mismatch in {fp}")
        out[key] = jax.numpy.asarray(np.load(fp))
    return out, manifest["metadata"]


class CheckpointManager:
    """keep-last-k + async save."""

    def __init__(self, directory: str, keep_last: int = 3,
                 async_save: bool = False):
        self.dir = directory
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree: Any, metadata: dict | None = None):
        # snapshot to host synchronously (cheap), write in background
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            save(self.dir, step, host_tree, metadata)
            self._gc()

        if self.async_save:
            self.wait()
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    def restore_latest(self, tree_like, shardings=None):
        self.wait()
        step = latest_step(self.dir)
        if step is None:
            return None, None, None
        tree, meta = restore(self.dir, step, tree_like, shardings)
        return step, tree, meta

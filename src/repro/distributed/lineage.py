"""Exchange-boundary lineage snapshots — resume instead of re-execute.

The paper's recovery story is whole-query re-execution (§2.4).  The exchange
cut points are exactly the replicated / reshuffled states of a plan — the
same observation "Rethinking Analytical Processing in the GPU Era" uses for
out-of-core restartability — so a runner that persists each post-exchange
table can resume a failed query from the last durable exchange, re-executing
only the plan suffix.

Mechanics: the planner executor (:class:`repro.core.planner._Executor`)
consults an attached :class:`LineageStore` at every exchange-type node
(Shuffle, Broadcast, GroupBy with a non-local exchange) BEFORE recursing
into its children.  A hit returns the snapshot and skips the whole subtree
— the executor walks root-ward, so the topmost durable exchange wins.  A
miss executes the node and persists its output through
:mod:`repro.distributed.checkpoint`'s atomic, CRC-checksummed save.

Snapshots are only meaningful for EAGER single-device execution
(``run_local(jit=False)``): inside a jit trace the values are Tracers and
host I/O is impossible — the distributed engine keeps the paper's
whole-query re-execution.  Snapshot tags are the node's ordinal in the
deterministic ``walk()`` order; every snapshot records the (plan
fingerprint, inference leg, wire format) configuration and is ignored when
the resuming run's configuration differs — a hint-dropped or wide-format
re-run never resumes from a narrow-format snapshot.  Snapshots are never
written while ``ctx.overflow`` is set: an overflowed buffer is not durable
state.

``benchmarks/bench_recovery.py`` gates the payoff: resuming a query that
failed at ``finalize`` must cost < ``MAX_RECOVERY_RATIO`` x the full
re-execution.
"""
from __future__ import annotations

import hashlib
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as B
from repro.core import relational as rel
from repro.core.planner import _walk_signature
from repro.core.table import Table, to_numpy
from repro.core.wire import CorruptPayload
from . import checkpoint as ckpt

__all__ = ["LineageStore", "run_resumable", "plan_fingerprint"]


def _canon_binding(v):
    """Host-canonical form of one parameter binding for fingerprinting —
    numpy/jax scalars and python numbers of equal value must agree."""
    if isinstance(v, bool):
        return repr(v)
    if isinstance(v, (int, np.integer)):
        return repr(int(v))
    if isinstance(v, (float, np.floating)):
        return repr(float(v))
    try:                                     # 0-d jax/numpy array bindings
        return _canon_binding(v.item())
    except (AttributeError, ValueError):
        return repr(v)


def plan_fingerprint(nodes, bindings: dict | None = None) -> int:
    """Stable CONTENT fingerprint of a plan (walk order) plus its parameter
    bindings — keeps one store directory from serving another query's
    snapshots.

    Hashes the planner's canonical node serialization
    (:func:`repro.core.planner.plan_signature`): node types, column names,
    join/group keys, aggregate ops, literals and parameter specs, and the
    exact child wiring.  The predecessor hashed only the node-type-name
    sequence, so every same-shaped query — and every binding of one plan
    template — collided, letting a resume adopt a different query's
    snapshots: a silent wrong answer.  Distinct ``bindings`` of one template
    are distinct fingerprints for the same reason."""
    text = _walk_signature(nodes)
    if bindings:
        text += "||" + ";".join(f"{k}={_canon_binding(v)}"
                                for k, v in sorted(bindings.items()))
    return int.from_bytes(
        hashlib.blake2b(text.encode(), digest_size=8).digest(), "big")


def _is_traced(x) -> bool:
    return isinstance(x, jax.core.Tracer)


class LineageStore:
    """Durable post-exchange tables, keyed by plan-walk ordinal.

    One directory per query; each snapshot is a ``checkpoint`` step whose
    flat dict holds the table columns plus ``__count`` / ``__valid``.
    ``reused`` counts snapshot hits since the last :meth:`begin_plan` —
    surfaced as ``snapshots_reused`` in the fault runner's RunReport.
    """

    def __init__(self, directory: str):
        self.dir = directory
        self.config: dict = {}
        self.reused = 0
        self.saved = 0

    # -- lifecycle ----------------------------------------------------------
    def begin_plan(self, config: dict) -> None:
        """Pins the configuration that snapshots written/read during this
        run must carry — snapshots from another leg are ignored, not mixed."""
        self.config = dict(config)
        self.reused = 0
        self.saved = 0

    def begin_executor(self, nodes, inference: bool,
                       wire_format: str | None,
                       bindings: dict | None = None) -> None:
        """Called by ``planner._Executor.run`` (duck-typed: the core layer
        never imports this module) with the plan's walk order, the run's
        configuration legs, and the template parameter bindings (if any) —
        two bindings of one template must never exchange snapshots."""
        self.begin_plan({"plan": plan_fingerprint(nodes, bindings),
                         "inference": bool(inference),
                         "wire_format": wire_format})

    def clear(self) -> None:
        shutil.rmtree(self.dir, ignore_errors=True)

    # -- executor interface -------------------------------------------------
    def load(self, tag: int):
        """Snapshot for plan node ``tag`` under the pinned config, or None."""
        path = os.path.join(self.dir, f"step_{tag:010d}")
        if not os.path.isdir(path):
            return None
        try:
            flat, meta = ckpt.restore_flat(self.dir, tag)
        except (IOError, ValueError, OSError):
            return None          # torn/foreign snapshot: fall back to re-exec
        if meta.get("config") != self.config:
            return None          # other leg (inference/wire/plan): not ours
        count = flat.pop("__count").reshape(()).astype(jnp.int32)
        valid = flat.pop("__valid", None)
        self.reused += 1
        return Table(flat, count, valid)

    def save(self, tag: int, table, ctx) -> None:
        """Persist a post-exchange table — only when it is durable state:
        concrete (not a Tracer: eager execution only) and overflow-free."""
        if not isinstance(table, Table):
            return
        leaves = list(table.columns.values()) + [table.count]
        if any(_is_traced(v) for v in leaves) or _is_traced(table.valid):
            return               # under jit: snapshots are a no-op
        if bool(ctx.overflow):
            return               # overflowed state is not durable
        flat = {name: np.asarray(v) for name, v in table.columns.items()}
        flat["__count"] = np.asarray(table.count)
        if table.valid is not None:
            flat["__valid"] = np.asarray(table.valid)
        ckpt.save(self.dir, tag, flat,
                  metadata={"keys": sorted(flat), "config": self.config})
        self.saved += 1


def run_resumable(query_fn, db, store: LineageStore,
                  capacity_factor: float = 2.0, join_method: str = "sorted",
                  use_kernel: bool | None = None,
                  wire_format: str | None = None, chaos=None,
                  ) -> tuple[dict, B.PlanStats, bool, int]:
    """One eager single-device attempt with lineage snapshots armed.

    Returns ``(result, stats, overflow, snapshots_reused)`` — the fault
    runner's attempt signature.  A payload integrity failure raises
    :class:`CorruptPayload` exactly like the drivers in ``core.backend``.
    A resumed attempt's PlanStats cover only the re-executed suffix (skipped
    subtrees issue no exchanges).
    """
    tables = B._np_db_to_tables(db)
    ctx = B.LocalContext(db, tables, capacity_factor=capacity_factor,
                         join_method=join_method, use_kernel=use_kernel,
                         wire_format=wire_format)
    ctx.chaos = chaos
    ctx.lineage = store
    out = query_fn(ctx)
    if isinstance(out, dict):
        out = Table({k: jnp.asarray(v).reshape(1) for k, v in out.items()},
                    jnp.asarray(1, jnp.int32))
    out = rel.ensure_compact(out)
    if bool(ctx.corrupt):
        raise CorruptPayload("resumable run: payload integrity check failed")
    return (to_numpy(out), ctx.stats, bool(ctx.overflow), store.reused)

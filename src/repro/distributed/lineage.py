"""Exchange-boundary lineage snapshots — resume instead of re-execute.

The paper's recovery story is whole-query re-execution (§2.4).  The exchange
cut points are exactly the replicated / reshuffled states of a plan — the
same observation "Rethinking Analytical Processing in the GPU Era" uses for
out-of-core restartability — so a runner that persists each post-exchange
table can resume a failed query from the last durable exchange, re-executing
only the plan suffix.

Mechanics: the planner executor (:class:`repro.core.planner._Executor`)
consults an attached :class:`LineageStore` at every exchange-type node
(Shuffle, Broadcast, GroupBy with a non-local exchange) BEFORE recursing
into its children.  A hit returns the snapshot and skips the whole subtree
— the executor walks root-ward, so the topmost durable exchange wins.  A
miss executes the node and persists its output through
:mod:`repro.distributed.checkpoint`'s atomic, CRC-checksummed save.

Snapshots are only meaningful for EAGER single-device execution
(``run_local(jit=False)``): inside a jit trace the values are Tracers and
host I/O is impossible — the distributed engine keeps the paper's
whole-query re-execution.  Snapshot tags are the node's ordinal in the
deterministic ``walk()`` order; every snapshot records the (plan
fingerprint, inference leg, wire format) configuration and is ignored when
the resuming run's configuration differs — a hint-dropped or wide-format
re-run never resumes from a narrow-format snapshot.  Snapshots are never
written while ``ctx.overflow`` is set: an overflowed buffer is not durable
state.

``benchmarks/bench_recovery.py`` gates the payoff: resuming a query that
failed at ``finalize`` must cost < ``MAX_RECOVERY_RATIO`` x the full
re-execution.

Topology elasticity: every snapshot's pinned config carries the logical
device width (``n_devices``) the run was targeting, and eager snapshots are
stored in GLOBAL row order — width-independent by construction.  A resume
whose config differs ONLY in ``n_devices`` (the device-loss rung shrank the
mesh N -> N') therefore adopts the snapshot instead of discarding it; the
next exchange recomputes the partition assignment at N'.  Such adoptions are
counted in ``LineageStore.resharded`` and gated by
``bench_recovery.py --check``'s re-shard budget.  For the stacked
``partition_database`` layout (columns ``(n*cap,)``, counts ``(n,)``) the
module-level :func:`reshard` / :func:`unshard` pair re-partitions explicitly
and round-trips byte-identically via a carried ``__rowid`` anchor.
"""
from __future__ import annotations

import hashlib
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as B
from repro.core import plan as qp
from repro.core import relational as rel
from repro.core.planner import _walk_signature
from repro.core.table import Table, to_numpy
from repro.core.wire import CorruptPayload
from . import checkpoint as ckpt

__all__ = ["LineageStore", "run_resumable", "plan_fingerprint",
           "reshard", "unshard"]


def _canon_binding(v):
    """Host-canonical form of one parameter binding for fingerprinting —
    numpy/jax scalars and python numbers of equal value must agree."""
    if isinstance(v, bool):
        return repr(v)
    if isinstance(v, (int, np.integer)):
        return repr(int(v))
    if isinstance(v, (float, np.floating)):
        return repr(float(v))
    try:                                     # 0-d jax/numpy array bindings
        return _canon_binding(v.item())
    except (AttributeError, ValueError):
        return repr(v)


def plan_fingerprint(nodes, bindings: dict | None = None) -> int:
    """Stable CONTENT fingerprint of a plan (walk order) plus its parameter
    bindings — keeps one store directory from serving another query's
    snapshots.

    Hashes the planner's canonical node serialization
    (:func:`repro.core.planner.plan_signature`): node types, column names,
    join/group keys, aggregate ops, literals and parameter specs, and the
    exact child wiring.  The predecessor hashed only the node-type-name
    sequence, so every same-shaped query — and every binding of one plan
    template — collided, letting a resume adopt a different query's
    snapshots: a silent wrong answer.  Distinct ``bindings`` of one template
    are distinct fingerprints for the same reason."""
    text = _walk_signature(nodes)
    if bindings:
        text += "||" + ";".join(f"{k}={_canon_binding(v)}"
                                for k, v in sorted(bindings.items()))
    return int.from_bytes(
        hashlib.blake2b(text.encode(), digest_size=8).digest(), "big")


def _is_traced(x) -> bool:
    return isinstance(x, jax.core.Tracer)


def _partition_key_of(node) -> str | None:
    """Hash-partition key of an exchange node's output; None = replicated
    (Broadcast) or gathered-to-all (GroupBy via gather) state."""
    if isinstance(node, qp.Shuffle):
        return node.key
    if isinstance(node, qp.GroupBy) and node.exchange == "shuffle":
        return node.keys[0] if node.keys else None
    return None


# ---------------------------------------------------------------------------
# stacked-layout re-sharding (the partition_database wire format)
# ---------------------------------------------------------------------------

ROWID = "__rowid"


def unshard(cols: dict, n: int) -> dict:
    """Stacked shard layout -> one global dict of the valid rows.

    ``cols`` mirrors :func:`repro.core.backend.partition_database` output:
    data columns shaped ``(n*cap,)`` plus ``__count`` shaped ``(n,)``.
    Valid rows are concatenated in partition order; when a ``__rowid``
    anchor column is present the result is re-sorted (stably) to the
    original global order — that anchor is what makes :func:`reshard`
    round-trips byte-identical.  Replicated layouts (every shard holds the
    whole table) come back with ``n`` copies; callers that replicated with
    ``key=None`` should read shard 0 instead.
    """
    counts = np.asarray(cols["__count"]).astype(np.int64)
    if counts.shape != (n,):
        raise ValueError(f"__count shape {counts.shape} != ({n},)")
    data = {k: np.asarray(v) for k, v in cols.items() if k != "__count"}
    if not data:
        raise ValueError("no data columns to unshard")
    cap = next(iter(data.values())).shape[0] // n
    if np.any(counts > cap) or np.any(counts < 0):
        raise ValueError(f"counts {counts} exceed shard capacity {cap}")
    out = {name: np.concatenate([v[d * cap: d * cap + counts[d]]
                                 for d in range(n)])
           for name, v in data.items()}
    if ROWID in out:
        order = np.argsort(out[ROWID], kind="stable")
        out = {k: v[order] for k, v in out.items()}
    return out


def reshard(cols: dict, n_old: int, n_new: int, key: str | None,
            cap: int | None = None) -> dict:
    """Re-partition a stacked snapshot from ``n_old`` to ``n_new`` shards.

    The degraded-mesh primitive: rows are recovered in global order
    (see :func:`unshard`), re-assigned with the same splitmix64
    ``hash_partition_np`` the boot-time partitioner used, and re-stacked at
    the new width.  A ``__rowid`` anchor column is added on first contact
    and carried thereafter, so ``N -> N' -> N`` round-trips byte-identically
    — including masked/empty partitions, which zero-fill their padding just
    like :func:`repro.core.backend.partition_database`.  ``key=None``
    replicates the whole table into every shard (tiny dimension tables)."""
    if n_new < 1:
        raise ValueError(f"n_new must be >= 1, got {n_new}")
    glob = unshard(cols, n_old)
    nrows = len(next(iter(glob.values())))
    if ROWID not in glob:
        glob[ROWID] = np.arange(nrows, dtype=np.int64)
    if key is None:
        shards = [glob] * n_new
    else:
        dest = B.hash_partition_np(np.asarray(glob[key]), n_new)
        shards = [{k: v[dest == d] for k, v in glob.items()}
                  for d in range(n_new)]
    longest = max(len(next(iter(s.values()))) for s in shards)
    if cap is None:
        cap = max(8, -(-longest // 8) * 8)
    elif longest > cap:
        raise ValueError(f"shard of {longest} rows exceeds cap {cap}")
    out = {}
    for name in glob:
        stacked = np.zeros((n_new * cap,), dtype=glob[name].dtype)
        for d, s in enumerate(shards):
            stacked[d * cap: d * cap + len(s[name])] = s[name]
        out[name] = stacked
    out["__count"] = np.array([len(next(iter(s.values()))) for s in shards],
                              dtype=np.int32)
    return out


class LineageStore:
    """Durable post-exchange tables, keyed by plan-walk ordinal.

    One directory per query; each snapshot is a ``checkpoint`` step whose
    flat dict holds the table columns plus ``__count`` / ``__valid``.
    ``reused`` counts snapshot hits since the last :meth:`begin_plan` —
    surfaced as ``snapshots_reused`` in the fault runner's RunReport.
    """

    def __init__(self, directory: str):
        self.dir = directory
        self.config: dict = {}
        self.reused = 0
        self.saved = 0
        self.resharded = 0

    # -- lifecycle ----------------------------------------------------------
    def begin_plan(self, config: dict) -> None:
        """Pins the configuration that snapshots written/read during this
        run must carry — snapshots from another leg are ignored, not mixed."""
        self.config = dict(config)
        self.reused = 0
        self.saved = 0
        self.resharded = 0

    def begin_executor(self, nodes, inference: bool,
                       wire_format: str | None,
                       bindings: dict | None = None,
                       n_devices: int = 1) -> None:
        """Called by ``planner._Executor.run`` (duck-typed: the core layer
        never imports this module) with the plan's walk order, the run's
        configuration legs, and the template parameter bindings (if any) —
        two bindings of one template must never exchange snapshots.
        ``n_devices`` is the logical mesh width the run targets; it is the
        ONE config axis a resume may differ on (see :meth:`load`)."""
        self.begin_plan({"plan": plan_fingerprint(nodes, bindings),
                         "inference": bool(inference),
                         "wire_format": wire_format,
                         "n_devices": int(n_devices)})

    def _width_only_mismatch(self, cfg) -> bool:
        """True when ``cfg`` differs from the pinned config ONLY in the
        logical device width — the topology-shrink resume case."""
        if not isinstance(cfg, dict) or cfg == self.config:
            return False
        a = {k: v for k, v in cfg.items() if k != "n_devices"}
        b = {k: v for k, v in self.config.items() if k != "n_devices"}
        return a == b

    def clear(self) -> None:
        shutil.rmtree(self.dir, ignore_errors=True)

    # -- executor interface -------------------------------------------------
    def load(self, tag: int):
        """Snapshot for plan node ``tag`` under the pinned config, or None."""
        path = os.path.join(self.dir, f"step_{tag:010d}")
        if not os.path.isdir(path):
            return None
        try:
            flat, meta = ckpt.restore_flat(self.dir, tag)
        except (IOError, ValueError, OSError):
            return None          # torn/foreign snapshot: fall back to re-exec
        cfg = meta.get("config")
        if cfg != self.config:
            if not self._width_only_mismatch(cfg):
                return None      # other leg (inference/wire/plan): not ours
            # Topology shrink (N -> N'): eager snapshots are stored in
            # global row order, so the table itself is width-independent —
            # adopt it; downstream exchanges recompute the partition
            # assignment at N'.  This is the re-shard resume the recovery
            # benchmark gates against full re-execution.
            self.resharded += 1
        count = flat.pop("__count").reshape(()).astype(jnp.int32)
        valid = flat.pop("__valid", None)
        self.reused += 1
        return Table(flat, count, valid)

    def save(self, tag: int, table, ctx, node=None) -> None:
        """Persist a post-exchange table — only when it is durable state:
        concrete (not a Tracer: eager execution only) and overflow-free.
        ``node`` (the plan exchange node, when the executor passes it)
        contributes partition metadata — the shuffle key and targeted width
        — so out-of-band tooling can re-shard the snapshot explicitly."""
        if not isinstance(table, Table):
            return
        leaves = list(table.columns.values()) + [table.count]
        if any(_is_traced(v) for v in leaves) or _is_traced(table.valid):
            return               # under jit: snapshots are a no-op
        if bool(ctx.overflow):
            return               # overflowed state is not durable
        flat = {name: np.asarray(v) for name, v in table.columns.items()}
        flat["__count"] = np.asarray(table.count)
        if table.valid is not None:
            flat["__valid"] = np.asarray(table.valid)
        meta = {"keys": sorted(flat), "config": self.config}
        if node is not None:
            meta["partition"] = {
                "key": _partition_key_of(node),
                "n": int(self.config.get("n_devices", 1))}
        ckpt.save(self.dir, tag, flat, metadata=meta)
        self.saved += 1


def run_resumable(query_fn, db, store: LineageStore,
                  capacity_factor: float = 2.0, join_method: str = "sorted",
                  use_kernel: bool | None = None,
                  wire_format: str | None = None, chaos=None,
                  n_devices: int = 1,
                  ) -> tuple[dict, B.PlanStats, bool, int]:
    """One eager single-device attempt with lineage snapshots armed.

    Returns ``(result, stats, overflow, snapshots_reused)`` — the fault
    runner's attempt signature.  A payload integrity failure raises
    :class:`CorruptPayload` exactly like the drivers in ``core.backend``.
    A resumed attempt's PlanStats cover only the re-executed suffix (skipped
    subtrees issue no exchanges).  ``n_devices`` is the logical mesh width
    this attempt targets: it is pinned into the snapshot config
    (``ctx.lineage_devices``), so a post-shrink resume at N' re-adopts
    snapshots written at N through the store's re-shard path.
    """
    tables = B._np_db_to_tables(db)
    ctx = B.LocalContext(db, tables, capacity_factor=capacity_factor,
                         join_method=join_method, use_kernel=use_kernel,
                         wire_format=wire_format)
    ctx.chaos = chaos
    ctx.lineage = store
    ctx.lineage_devices = int(n_devices)
    out = query_fn(ctx)
    if isinstance(out, dict):
        out = Table({k: jnp.asarray(v).reshape(1) for k, v in out.items()},
                    jnp.asarray(1, jnp.int32))
    out = rel.ensure_compact(out)
    if bool(ctx.corrupt):
        raise CorruptPayload("resumable run: payload integrity check failed")
    return (to_numpy(out), ctx.stats, bool(ctx.overflow), store.reused)

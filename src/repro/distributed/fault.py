"""Fault tolerance + skew mitigation for distributed queries and training.

Queries: the paper's model (§2.4) — re-execution at interactive speed.  Our
static-shape adaptation adds one structured failure mode: capacity overflow
(a shuffle bucket, a shrink, a hash-join bucket table, a narrowed wire lane,
or the hash-aggregation group dictionary exceeded its planned size — all
raise ``ctx.overflow``, never assert locally).  The runner escalates the
capacity factor and re-executes; the factor also scales the hash-join
per-bucket capacity (``_BaseContext.bucket_cap``) AND the group-by hash
dictionary (``relational.group_aggregate(method="hash")`` sizes it
``groups_hint * factor``), so escalation genuinely enlarges both.
Unstructured failures (preempted node → surfaced as an exception in a real
deployment) get bounded retries.

Skew: the monitor computes the paper's §3.5 statistic (per-node send/recv max
over mean) from exchange recv-counts; the planner consults Eq. 3 to pick
broadcast vs shuffle given table sizes, and hot-key salting splits dominant
keys before a grouped shuffle (local pre-aggregation already bounds
per-key payload — salting bounds residual placement skew).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.core import backend as B
from repro.core import perfmodel as pm

__all__ = ["QueryRunner", "RunResult", "choose_exchange"]


@dataclasses.dataclass
class RunResult:
    result: dict
    stats: B.PlanStats
    attempts: int
    capacity_factor: float
    wall_s: float


class QueryRunner:
    """Re-execution with capacity escalation (paper §2.4 fault tolerance)."""

    def __init__(self, db, mesh, axis: str = "data",
                 capacity_factor: float = 2.0, max_attempts: int = 4,
                 escalation: float = 2.0, packed_exchange: bool = True,
                 join_method: str = "sorted", wire_format: str | None = None):
        self.db = db
        self.mesh = mesh
        self.axis = axis
        self.capacity_factor = capacity_factor
        self.max_attempts = max_attempts
        self.escalation = escalation
        self.packed = packed_exchange
        self.join_method = join_method
        self.wire_format = wire_format

    def run(self, query_fn) -> RunResult:
        factor = self.capacity_factor
        last_exc = None
        fn = query_fn
        for attempt in range(1, self.max_attempts + 1):
            t0 = time.perf_counter()
            try:
                result, stats, overflow = B.run_distributed(
                    fn, self.db, self.mesh, self.axis,
                    capacity_factor=factor, packed_exchange=self.packed,
                    join_method=self.join_method,
                    wire_format=self.wire_format)
            except Exception as exc:   # node failure -> re-execute
                last_exc = exc
                continue
            wall = time.perf_counter() - t0
            if not overflow:
                return RunResult(result, stats, attempt, factor, wall)
            factor *= self.escalation   # structured failure: bigger buffers
            if attempt >= 2 and hasattr(query_fn, "with_inference"):
                # capacity escalation cannot fix a groups_hint that undercounts
                # the true distinct groups (a plan-author claim like Q13's, or
                # hints analyzed against stand-in metadata) NOR a lying wire
                # bound tripping the narrow-lane range check: after one failed
                # escalation, recompile the plan with no hints at all — the
                # conservative program has no hint-induced overflow left
                # (hash-dictionary group-bys degrade to the single-sort path)
                # and, with no bounds, every exchange ships at full width
                fn = query_fn.with_inference(False)
        if last_exc is not None:
            raise last_exc
        raise RuntimeError(
            f"query overflowed at capacity_factor={factor:.1f} "
            f"after {self.max_attempts} attempts")


def choose_exchange(cluster: pm.ClusterSpec, v: int, small_bytes: float,
                    large_bytes: float) -> str:
    """Cost-based broadcast-vs-shuffle decision (paper Eq. 3)."""
    return "broadcast" if pm.broadcast_beats_shuffle(
        cluster, v, small_bytes, large_bytes) else "shuffle"


def skew_imbalance(recv_counts: np.ndarray, k: int = 1) -> float:
    """Paper §3.5: max over nodes / mean (k devices per node)."""
    v = len(recv_counts) // k
    per_node = recv_counts.reshape(v, k).sum(axis=1)
    return float(per_node.max() / max(per_node.mean(), 1e-9))


def salt_hot_keys(keys: np.ndarray, n_partitions: int,
                  hot_threshold: float = 4.0) -> np.ndarray:
    """Host-side salting: keys whose frequency exceeds ``hot_threshold`` x the
    mean get a per-row salt so their rows spread over all partitions.  Used
    before grouped shuffles (the merge aggregation is salt-agnostic since the
    final combine runs per full key)."""
    uniq, counts = np.unique(keys, return_counts=True)
    mean = counts.mean()
    hot = set(uniq[counts > hot_threshold * mean].tolist())
    if not hot:
        return keys
    salted = keys.astype(np.int64).copy()
    is_hot = np.isin(keys, list(hot))
    salt = np.arange(is_hot.sum(), dtype=np.int64) % n_partitions
    salted[is_hot] = salted[is_hot] * np.int64(n_partitions) + salt
    return salted

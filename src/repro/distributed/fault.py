"""Fault tolerance + skew mitigation for distributed queries and training.

Queries: the paper's model (§2.4) — re-execution at interactive speed —
extended with a failure TAXONOMY (:class:`repro.distributed.chaos.FailureKind`)
so the runner reacts to what actually went wrong instead of retrying blindly:

  TRANSIENT      environment fault (node loss, flaky link, timeout): retry
                 with bounded exponential backoff (:class:`RetryPolicy`).
  OVERFLOW       structured capacity failure (a shuffle bucket, a shrink, a
                 hash-join bucket table, a narrowed wire lane, or the hash-
                 aggregation dictionary exceeded its planned size — all raise
                 ``ctx.overflow``, never assert locally): escalate the
                 capacity factor; after a second overflow, recompile with
                 inference dropped (no hints -> no hint-induced overflow).
                 The factor also scales the hash-join per-bucket capacity
                 (``_BaseContext.bucket_cap``) AND the group-by dictionary
                 (``relational.group_aggregate(method="hash")`` sizes it
                 ``groups_hint * factor``), so escalation genuinely enlarges
                 both.
  CORRUPT        a packed payload failed its wire integrity checksum
                 (:class:`repro.core.wire.CorruptPayload`): re-run on the
                 conservative wide format — never serve the bad buffer.
  DETERMINISTIC  a plan-author bug (TypeError, ValueError, assertion …):
                 raised immediately on attempt 1 — re-execution cannot fix
                 code.
  DEVICE_LOST    one or more mesh participants are permanently dead
                 (:class:`repro.distributed.chaos.DeviceLost`): retrying on
                 the same topology can only fail again.  The runner shrinks
                 the mesh to the survivors (:func:`surviving_mesh`), bumps
                 its topology generation, re-derives the perf-model budgets
                 at the new width (``ClusterSpec.with_devices`` — Hockney /
                 Eq. 3 pricing uses N', not the boot-time N), re-plans and
                 re-executes.  ``run_distributed`` re-partitions the
                 database over the surviving N' devices, so per-device
                 capacity grows by N/N' automatically; with a lineage store
                 armed, snapshots written at width N are re-sharded onto N'
                 instead of discarded.

Each attempt is logged in a :class:`RunReport` (failure kind, chaos cut
point, backoff, snapshot reuse, live device count, topology generation)
surfaced through ``launch/report.py``; the seeded chaos harness
(:mod:`repro.distributed.chaos`, ``REPRO_CHAOS`` env) drives every branch
of this policy deterministically in CI.

Skew: the monitor computes the paper's §3.5 statistic (per-node send/recv max
over mean) from exchange recv-counts; the planner consults Eq. 3 to pick
broadcast vs shuffle given table sizes, and hot-key salting splits dominant
keys before a grouped shuffle (local pre-aggregation already bounds
per-key payload — salting bounds residual placement skew).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
from jax.sharding import Mesh

from repro.core import backend as B
from repro.core import perfmodel as pm
from repro.core.wire import CorruptPayload
from .chaos import (ChaosInjector, DeviceLost, FailureKind, FiredFault,
                    TransientFault, _mix, resolve_lost)

__all__ = [
    "QueryRunner", "RunResult", "RunReport", "AttemptReport", "RetryPolicy",
    "FailureKind", "QueryTimeout", "classify_failure", "surviving_mesh",
    "choose_exchange", "skew_imbalance", "salt_hot_keys",
]


# exception types that indicate a bug in plan/query code, not the
# environment: re-executing is useless and masks the error — raise on
# attempt 1 (the old catch-all burned max_attempts re-runs on these)
_DETERMINISTIC_EXC = (TypeError, ValueError, KeyError, IndexError,
                      AttributeError, AssertionError, NameError,
                      ZeroDivisionError)


def classify_failure(exc: BaseException) -> FailureKind:
    """Map a raised exception onto the failure taxonomy.

    ``CorruptPayload`` -> CORRUPT; plan-author bug types -> DETERMINISTIC;
    everything else (``TransientFault``, OSError, timeouts, the unknown) is
    treated as a TRANSIENT environment fault and retried — the conservative
    default, bounded by ``RetryPolicy.max_attempts``.
    """
    if isinstance(exc, DeviceLost):
        return FailureKind.DEVICE_LOST
    if isinstance(exc, CorruptPayload):
        return FailureKind.CORRUPT
    if isinstance(exc, _DETERMINISTIC_EXC):
        return FailureKind.DETERMINISTIC
    return FailureKind.TRANSIENT


class QueryTimeout(RuntimeError):
    """The runner's OVERALL wall-clock deadline (``QueryRunner.deadline_s``)
    expired with attempts still in the budget.  Distinct from the
    per-attempt straggler deadline (``RetryPolicy.deadline_s``), which
    discards one late attempt; this one ends the query.  Carries the
    partial :class:`RunReport` so the caller can audit what was tried."""

    def __init__(self, message: str, report: "RunReport"):
        super().__init__(message)
        self.report = report


def surviving_mesh(mesh: Mesh, lost: tuple[int, ...], axis: str) -> Mesh:
    """A fresh 1-D mesh over ``axis`` holding every device of ``mesh``
    except the ``lost`` ranks (ranks index the mesh's flat device order).
    The surviving devices are kept explicitly — never re-enumerated from
    the backend, which would resurrect the dead ones."""
    devices = [d for i, d in enumerate(np.asarray(mesh.devices).flat)
               if i not in set(lost)]
    if not devices:
        raise ValueError(f"no survivors: lost {lost!r} of mesh {mesh.shape}")
    return Mesh(np.asarray(devices), (axis,))


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retries with exponential backoff and an optional per-attempt
    deadline.

    ``deadline_s``: an attempt whose wall time exceeds it is treated as a
    straggler — its (correct) result is discarded and the query re-executes,
    the speculative-retry semantics of §2.4 (never applied to the final
    attempt: a late answer beats none).

    ``jitter``: with it on, :meth:`backoff` applies seeded decorrelated
    jitter — pure exponential backoff synchronizes the retry storms of
    concurrent runners that failed together.  The jitter is derived from a
    seed (the runner passes the chaos seed, or ``seed`` here), so chaos
    runs stay bit-deterministic; it is bounded to
    ``[backoff_s, max_backoff_s]``.
    """
    max_attempts: int = 4
    backoff_s: float = 0.05       # first TRANSIENT retry waits this long
    backoff_mult: float = 2.0     # then doubles ...
    max_backoff_s: float = 2.0    # ... up to this cap
    deadline_s: float | None = None
    jitter: bool = False          # seeded decorrelated jitter on backoff
    seed: int | None = None       # jitter seed override (else: chaos seed)

    def backoff(self, transient_failures: int,
                seed: int | None = None) -> float:
        """Sleep before the next attempt after the n-th transient failure.

        Without ``jitter`` (or with no seed available): bounded exponential,
        exactly ``backoff_s * mult^(n-1)`` capped at ``max_backoff_s``.
        With it: decorrelated jitter — uniform (seeded, deterministic) in
        ``[backoff_s, min(max_backoff_s, 3 * previous_sleep)]`` — each
        runner's sequence de-synchronizes from its neighbours' while keeping
        the same bounds."""
        exp = min(self.backoff_s * self.backoff_mult
                  ** (transient_failures - 1), self.max_backoff_s)
        seed = self.seed if self.seed is not None else seed
        if not self.jitter or seed is None:
            return exp
        prev = self.backoff(transient_failures - 1, seed) \
            if transient_failures > 1 else self.backoff_s
        hi = min(self.max_backoff_s, max(self.backoff_s, 3.0 * prev))
        u = (_mix(seed, "backoff", transient_failures) % 65536) / 65535.0
        return self.backoff_s + u * (hi - self.backoff_s)


@dataclasses.dataclass
class AttemptReport:
    """One row of the per-attempt audit trail."""
    attempt: int
    outcome: str                  # "ok" | FailureKind value
    wall_s: float
    capacity_factor: float
    wire_format: str | None
    inference: bool
    backoff_s: float = 0.0        # slept AFTER this attempt
    cut: str | None = None        # chaos cut point, when injected
    snapshots_reused: int = 0     # lineage: exchange snapshots resumed from
    error: str = ""
    devices: int = 0              # live mesh width this attempt ran on
    generation: int = 0           # topology generation (0 = boot mesh)
    rung: int = 0                 # approx ladder denominator (0 = exact plan)
    ci_width: float | None = None  # rel. CI half-width of an approx answer


@dataclasses.dataclass
class RunReport:
    """Full audit of one ``QueryRunner.run``: every attempt + every fault the
    chaos harness injected.  Rendered by ``launch/report.py --section runs``."""
    attempts: list[AttemptReport] = dataclasses.field(default_factory=list)
    injected: list[FiredFault] = dataclasses.field(default_factory=list)

    def outcomes(self) -> list[str]:
        return [a.outcome for a in self.attempts]

    def rows(self) -> list[dict]:
        return [dataclasses.asdict(a) for a in self.attempts]


@dataclasses.dataclass
class RunResult:
    result: dict
    stats: B.PlanStats
    attempts: int
    capacity_factor: float
    wall_s: float
    report: RunReport = dataclasses.field(default_factory=RunReport)


class QueryRunner:
    """Policy-driven re-execution (paper §2.4 fault tolerance + taxonomy).

    ``chaos``: a :class:`ChaosInjector` armed for every attempt (defaults to
    the ``REPRO_CHAOS`` env leg — unset means no injection).  ``lineage``: a
    :class:`repro.distributed.lineage.LineageStore`; when given, attempts
    execute eagerly on the single-device engine persisting every exchange
    boundary, so a mid-query failure resumes from the last durable exchange
    instead of re-executing the whole plan (the distributed engine keeps the
    paper's whole-query re-execution — snapshots cannot be written from
    inside a compiled SPMD program).
    """

    def __init__(self, db, mesh, axis: str = "data",
                 capacity_factor: float = 2.0, max_attempts: int = 4,
                 escalation: float = 2.0, packed_exchange: bool = True,
                 join_method: str = "sorted", wire_format: str | None = None,
                 policy: RetryPolicy | None = None,
                 chaos: ChaosInjector | None = None,
                 lineage=None, deadline_s: float | None = None,
                 cluster: pm.ClusterSpec | None = None,
                 local_jit: bool = True):
        self.db = db
        self.mesh = mesh
        self.axis = axis
        self.capacity_factor = capacity_factor
        self.escalation = escalation
        self.packed = packed_exchange
        self.join_method = join_method
        self.wire_format = wire_format
        self.policy = policy or RetryPolicy(max_attempts=max_attempts)
        self.chaos = chaos if chaos is not None else ChaosInjector.from_env()
        self.lineage = lineage
        self.deadline_s = deadline_s          # overall wall-clock budget
        self.cluster = cluster                # perf-model spec, kept at N'
        self.boot_devices = int(mesh.shape[axis]) if mesh is not None else 1
        self.topology_generation = 0
        self.lost_devices: tuple[int, ...] = ()
        self.local_jit = local_jit    # mesh-less single-device attempts

    # retained for callers that introspect the runner
    @property
    def max_attempts(self) -> int:
        return self.policy.max_attempts

    @property
    def devices(self) -> int:
        """Live mesh width (N' after topology shrinks, N at boot)."""
        if self.mesh is None:          # lineage-only eager path
            return 1
        return int(self.mesh.shape[self.axis])

    def _jitter_seed(self) -> int | None:
        if self.policy.seed is not None:
            return self.policy.seed
        return self.chaos.plan.seed if self.chaos is not None else None

    def _shrink_topology(self, exc: DeviceLost) -> tuple[int, ...]:
        """The topology-elastic rung: drop the dead ranks, re-derive the
        mesh over the survivors, bump the generation, and re-scale the
        perf-model budgets to the new width.  Returns the resolved dead
        ranks (empty when nothing can shrink — a 1-device mesh)."""
        world = self.devices
        lost = resolve_lost(exc, world)
        if not lost:
            return ()
        self.mesh = surviving_mesh(self.mesh, lost, self.axis)
        self.topology_generation += 1
        self.lost_devices = self.lost_devices + lost
        if self.cluster is not None:
            # Hockney / Eq. 3 pricing must see N', not the boot-time N
            self.cluster = self.cluster.with_devices(self.devices)
        return lost

    def _attempt(self, fn, factor: float, wire_format: str | None):
        """Execute one attempt; returns (result, stats, overflow, reused)."""
        if self.lineage is not None:
            from . import lineage as ln
            return ln.run_resumable(
                fn, self.db, self.lineage, capacity_factor=factor,
                join_method=self.join_method, wire_format=wire_format,
                chaos=self.chaos, n_devices=self.devices)
        if self.mesh is None:
            # mesh-less runner (the progressive approx ladder's default):
            # single-device execution under the SAME policy loop — overflow
            # is returned, not asserted, so capacity escalation still works
            result, stats, overflow = B.run_local(
                fn, self.db, jit=self.local_jit, capacity_factor=factor,
                join_method=self.join_method, wire_format=wire_format,
                chaos=self.chaos, return_overflow=True)
            return result, stats, overflow, 0
        result, stats, overflow = B.run_distributed(
            fn, self.db, self.mesh, self.axis, capacity_factor=factor,
            packed_exchange=self.packed, join_method=self.join_method,
            wire_format=wire_format, chaos=self.chaos)
        return result, stats, overflow, 0

    def run(self, query_fn, bindings: dict | None = None) -> RunResult:
        """Execute ``query_fn`` under the retry policy.

        ``query_fn`` may be a plain ``fn(ctx)``, a compiled query, or a
        parameterized plan template (``repro.serve.PlanTemplate``); in the
        template case pass the parameter values as ``bindings`` — they are
        bound ONCE here (domain-validated at bind time) and every retry,
        capacity escalation and hint-drop recompilation reuses the same
        bound query, so recovery can never silently change the answer the
        caller asked for."""
        if bindings is not None:
            if not hasattr(query_fn, "bind"):
                raise TypeError(
                    "bindings= requires a parameterized plan template "
                    "(repro.serve.PlanTemplate); got "
                    f"{type(query_fn).__name__}")
            query_fn = query_fn.bind(**bindings)
        policy = self.policy
        factor = self.capacity_factor
        wire_format = self.wire_format
        fn = query_fn
        report = RunReport()
        overflow_failures = transient_failures = 0
        t_start = time.perf_counter()
        for attempt in range(1, policy.max_attempts + 1):
            if self.deadline_s is not None and attempt > 1 and \
                    time.perf_counter() - t_start > self.deadline_s:
                raise QueryTimeout(
                    f"overall deadline {self.deadline_s:.3f}s exceeded "
                    f"after {attempt - 1} attempts "
                    f"({time.perf_counter() - t_start:.3f}s)", report)
            if self.chaos is not None:
                self.chaos.begin_attempt(attempt)
            inference = getattr(fn, "_infer", True) is not False
            rep = AttemptReport(attempt=attempt, outcome="ok", wall_s=0.0,
                                capacity_factor=factor,
                                wire_format=wire_format, inference=inference,
                                devices=self.devices,
                                generation=self.topology_generation)
            report.attempts.append(rep)
            t0 = time.perf_counter()
            try:
                result, stats, overflow, reused = self._attempt(
                    fn, factor, wire_format)
            except Exception as exc:
                rep.wall_s = time.perf_counter() - t0
                rep.error = f"{type(exc).__name__}: {exc}"
                kind = classify_failure(exc)
                rep.outcome = kind.value
                self._note_injected(report)
                if kind is FailureKind.DETERMINISTIC:
                    raise            # a bug: surface on attempt 1, no retries
                if attempt >= policy.max_attempts:
                    raise
                if kind is FailureKind.DEVICE_LOST:
                    # topology-elastic rung: shrink to the survivors and
                    # re-execute — the database re-partitions over N', and
                    # the planner re-derives its analysis for the re-run
                    # (statistics and key_bits are width-invariant; the
                    # per-device budgets re-price through the cluster spec)
                    lost = self._shrink_topology(exc)
                    if not lost:
                        raise    # 1-device mesh: no survivors to shrink onto
                    rep.error += (f" [lost {list(lost)} -> "
                                  f"{self.devices} devices]")
                    replan = getattr(fn, "info", None)
                    if callable(replan):
                        replan(self.db)
                elif kind is FailureKind.CORRUPT:
                    # never trust the failed buffer: conservative format
                    wire_format = "wide"
                else:                # TRANSIENT: bounded backoff
                    transient_failures += 1
                    rep.backoff_s = policy.backoff(
                        transient_failures, seed=self._jitter_seed())
                    time.sleep(rep.backoff_s)
                continue
            rep.wall_s = time.perf_counter() - t0
            rep.snapshots_reused = reused
            self._note_injected(report)
            if overflow:
                rep.outcome = FailureKind.OVERFLOW.value
                if attempt >= policy.max_attempts:
                    break
                factor *= self.escalation   # bigger buffers on re-execution
                overflow_failures += 1
                if overflow_failures >= 2 and \
                        hasattr(query_fn, "with_inference"):
                    # capacity escalation cannot fix a groups_hint that
                    # undercounts the true distinct groups (a plan-author
                    # claim like Q13's, or hints analyzed against stand-in
                    # metadata) NOR a lying wire bound tripping the narrow-
                    # lane range check: after one failed escalation,
                    # recompile with no hints at all — the conservative
                    # program has no hint-induced overflow left (hash-
                    # dictionary group-bys degrade to the single-sort path)
                    # and, with no bounds, every exchange ships at full width
                    fn = query_fn.with_inference(False)
                continue
            if policy.deadline_s is not None and \
                    rep.wall_s > policy.deadline_s and \
                    attempt < policy.max_attempts:
                # straggler: correct but late — speculative re-execution
                rep.outcome = FailureKind.TRANSIENT.value
                rep.error = (f"deadline {policy.deadline_s:.3f}s exceeded "
                             f"({rep.wall_s:.3f}s)")
                continue
            return RunResult(result, stats, attempt, factor,
                             time.perf_counter() - t_start, report)
        raise RuntimeError(
            f"query overflowed at capacity_factor={factor:.1f} "
            f"after {policy.max_attempts} attempts")

    def _note_injected(self, report: RunReport) -> None:
        if self.chaos is not None:
            new = self.chaos.events[len(report.injected):]
            report.injected.extend(new)
            # attribute the injection's cut point to the current attempt row
            if new and report.attempts:
                report.attempts[-1].cut = new[-1].cut


def choose_exchange(cluster: pm.ClusterSpec, v: int, small_bytes: float,
                    large_bytes: float) -> str:
    """Cost-based broadcast-vs-shuffle decision (paper Eq. 3)."""
    return "broadcast" if pm.broadcast_beats_shuffle(
        cluster, v, small_bytes, large_bytes) else "shuffle"


def skew_imbalance(recv_counts: np.ndarray, k: int = 1) -> float:
    """Paper §3.5: max over nodes / mean (k devices per node).

    Validates the shape up front (a ragged ``len(recv_counts) % k`` used to
    surface as an opaque numpy reshape error) and returns the neutral 1.0
    for the empty / single-node edge instead of dividing by a clamped mean.
    """
    recv_counts = np.asarray(recv_counts)
    if k < 1:
        raise ValueError(f"devices-per-node k must be >= 1, got {k}")
    if recv_counts.size % k != 0:
        raise ValueError(
            f"recv_counts has {recv_counts.size} entries, not divisible by "
            f"k={k} devices per node")
    v = recv_counts.size // k
    if v <= 1:
        return 1.0   # nothing to be imbalanced against
    per_node = recv_counts.reshape(v, k).sum(axis=1)
    mean = per_node.mean()
    if mean == 0:
        return 1.0   # no traffic at all
    return float(per_node.max() / mean)


def salt_hot_keys(keys: np.ndarray, n_partitions: int,
                  hot_threshold: float = 4.0) -> np.ndarray:
    """Host-side salting: keys whose frequency exceeds ``hot_threshold`` x the
    mean get a per-row salt so their rows spread over all partitions.  Used
    before grouped shuffles (the merge aggregation is salt-agnostic since the
    final combine runs per full key)."""
    uniq, counts = np.unique(keys, return_counts=True)
    mean = counts.mean()
    hot = set(uniq[counts > hot_threshold * mean].tolist())
    if not hot:
        return keys
    salted = keys.astype(np.int64).copy()
    is_hot = np.isin(keys, list(hot))
    salt = np.arange(is_hot.sum(), dtype=np.int64) % n_partitions
    salted[is_hot] = salted[is_hot] * np.int64(n_partitions) + salt
    return salted

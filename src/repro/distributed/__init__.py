"""distributed subpackage."""

"""Roofline terms from compiled artifacts (no hardware needed).

``cost_analysis()`` counts a while-loop body ONCE, so scanned-layer models
undercount by ~n_layers.  This module does loop-aware accounting directly on
the optimized HLO text:

  * computations are parsed into blocks with a name->shape symbol table;
  * ``while`` ops carry ``backend_config known_trip_count`` — bodies are
    weighted by their trip counts (nested loops compose multiplicatively);
  * dot FLOPs   = 2 * numel(result) * prod(lhs contracting dims)   (exact);
  * HBM traffic = sum of result+operand bytes over top-level ops (fusion
    internals excluded — they live in registers/VMEM);
  * collective bytes = result bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (async -start counted
    once, -done skipped).
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter

__all__ = ["CollectiveStats", "parse_collectives", "analyze_module",
           "roofline_terms", "op_histogram",
           "V5E_PEAK_FLOPS", "V5E_HBM_BW", "V5E_ICI_BW"]

V5E_PEAK_FLOPS = 197e12       # bf16, per chip
V5E_HBM_BW = 819e9            # bytes/s
V5E_ICI_BW = 50e9             # bytes/s per link; ~4 usable links per chip

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\]\S*))"
    r"\s+([\w\-]+)\(([^)]*)\)(.*)$")
# header: "%name (params...) -> result {"; param types may nest parens (tuples)
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "partition-id", "replica-id"}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class _Comp:
    name: str
    entry: bool
    flops: float = 0.0
    traffic: float = 0.0
    scores_traffic: float = 0.0   # ops whose result is seq x seq shaped
    coll_bytes: Counter = dataclasses.field(default_factory=Counter)
    coll_count: Counter = dataclasses.field(default_factory=Counter)
    children: list = dataclasses.field(default_factory=list)  # (name, mult, traffic?)


def _split_computations(text: str):
    comps, cur, name, entry = {}, None, None, False
    for line in text.splitlines():
        m = _COMP_RE.match(line)
        if m and not line.startswith(" "):
            name = m.group(2)
            entry = bool(m.group(1))
            cur = []
            comps[name] = (entry, cur)
        elif line.startswith("}"):
            name = None
        elif name is not None:
            cur.append(line)
    return comps


def _is_scores(shape_str: str, seq_dims) -> bool:
    """Result trailing two dims both sequence-length-like => attention scores
    / mask chain (what the flash kernel keeps in VMEM)."""
    if not seq_dims:
        return False
    dims = _shape_dims(shape_str)
    return bool(dims and len(dims) >= 2 and dims[-1] in seq_dims
                and dims[-2] in seq_dims)


def _parse_ops(lines):
    """Parse a computation body into op records + symbol table."""
    ops, shapes = [], {}
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        rn, rt, op, operands, rest = m.groups()
        shapes[rn] = rt
        ops.append((rn, rt, op, [o.strip().lstrip("%")
                                 for o in operands.split(",") if o.strip()],
                    rest))
    return ops, shapes


def _fusion_io(ops, shapes):
    """(write_bytes, read_bytes, io_shapes) of a fusion computation.

    Parameters consumed only through dynamic-slice count as the sliced bytes;
    a dynamic-update-slice root writes only its update region (and its
    operand-0 buffer is updated in place — zero read)."""
    params = {rn for rn, _, op, _, _ in ops if op == "parameter"}
    uses: dict[str, list] = {p: [] for p in params}
    root = ops[-1] if ops else None
    for rn, rt, op, opnds, rest in ops:
        for i, o in enumerate(opnds):
            if o in uses:
                uses[o].append((op, i, rt))
    read = 0.0
    io_shapes = []
    for p in params:
        pu = uses[p]
        if not pu:
            continue
        if all(op == "dynamic-slice" and i == 0 for op, i, _ in pu):
            read += sum(_shape_bytes(rt) for _, _, rt in pu)
            io_shapes.extend(rt for _, _, rt in pu)
        elif all(op == "dynamic-update-slice" and i == 0 for op, i, _ in pu):
            pass                                   # in-place buffer: no read
        else:
            read += _shape_bytes(shapes[p])
            io_shapes.append(shapes[p])
    if root is not None and root[2] == "dynamic-update-slice":
        upd = root[3][1] if len(root[3]) > 1 else None
        write = _shape_bytes(shapes.get(upd, root[1]))
        io_shapes.append(shapes.get(upd, root[1]))
    else:
        write = _shape_bytes(root[1]) if root else 0.0
        if root:
            io_shapes.append(root[1])
    return write, read, io_shapes


def _analyze_comp(name: str, entry: bool, parsed, all_parsed,
                  seq_dims=()) -> _Comp:
    comp = _Comp(name, entry)
    ops, shapes = parsed
    for rn, res_type, op, ops_list, rest in ops:
        base_op = op[:-6] if op.endswith("-start") else op
        if op.endswith("-done"):
            continue
        if base_op in _COLLECTIVES:
            comp.coll_bytes[base_op] += _shape_bytes(res_type)
            comp.coll_count[base_op] += 1
        if op == "dot":
            out_dims = _shape_dims(res_type) or []
            numel_out = 1
            for d in out_dims:
                numel_out *= d
            lhs_shape = _shape_dims(shapes.get(ops_list[0], "")) \
                if ops_list else []
            cdims = _DIMS_RE.search(rest)
            k = 1
            if cdims and lhs_shape:
                for i in cdims.group(1).split(","):
                    if i != "" and int(i) < len(lhs_shape):
                        k *= lhs_shape[int(i)]
            comp.flops += 2.0 * numel_out * k
        # traffic — op-specific models
        if op not in _NO_TRAFFIC:
            res_bytes = _shape_bytes(res_type)
            io_shapes = [res_type]
            if op == "fusion":
                called = _CALLS_RE.search(rest)
                sub = all_parsed.get(called.group(1)) if called else None
                if sub:
                    w, rd, io_shapes = _fusion_io(*sub)
                    t = w + rd
                else:
                    t = res_bytes
            elif op in ("dynamic-slice", "slice", "broadcast", "iota", "pad",
                        "reshape", "transpose", "reverse"):
                t = 2 * res_bytes
            elif op == "dynamic-update-slice":
                upd = shapes.get(ops_list[1], "") if len(ops_list) > 1 else ""
                t = 2 * (_shape_bytes(upd) or res_bytes)
                io_shapes = [upd or res_type]
            elif op in ("gather", "scatter"):
                t = 2 * res_bytes + sum(_shape_bytes(shapes.get(o, ""))
                                        for o in ops_list[1:])
            else:
                t = res_bytes
                for o in ops_list:
                    if o in shapes:
                        t += _shape_bytes(shapes[o])
                        io_shapes.append(shapes[o])
            comp.traffic += t
            if any(_is_scores(sh, seq_dims) for sh in io_shapes):
                comp.scores_traffic += t
        # sub-computation edges
        if op == "while":
            trip = 1
            tm = _TRIP_RE.search(rest)
            if tm:
                trip = int(tm.group(1))
            for cm in _CALLS_RE.finditer(rest):
                comp.children.append((cm.group(1), trip, True))
        elif op in ("call", "conditional"):
            for cm in _CALLS_RE.finditer(rest):
                comp.children.append((cm.group(1), 1, True))
        elif op in ("fusion", "reduce", "map", "sort", "scatter",
                    "reduce-window", "select-and-scatter", "all-reduce",
                    "reduce-scatter", "custom-call"):
            for cm in _CALLS_RE.finditer(rest):
                # internals: flops + collectives count; HBM traffic does not
                comp.children.append((cm.group(1), 1, False))
    return comp


def analyze_module(text: str, seq_dims=()) -> dict:
    """Loop-aware totals for the per-device module.

    ``seq_dims``: sequence lengths of the cell — ops whose result is
    seq x seq shaped are attributed to ``scores_traffic_bytes`` (the portion
    a fused flash-attention kernel never writes to HBM)."""
    raw = _split_computations(text)
    all_parsed = {n: _parse_ops(ls) for n, (e, ls) in raw.items()}
    comps = {n: _analyze_comp(n, e, all_parsed[n], all_parsed, seq_dims)
             for n, (e, ls) in raw.items()}
    entry = next((c for c in comps.values() if c.entry), None)
    if entry is None:
        return {"flops": 0.0, "traffic_bytes": 0.0,
                "scores_traffic_bytes": 0.0,
                "collective_bytes": {}, "collective_count": {}}

    memo: dict[tuple, tuple] = {}

    def total(name: str, with_traffic: bool, depth=0):
        key = (name, with_traffic)
        if key in memo:
            return memo[key]
        c = comps.get(name)
        if c is None or depth > 50:
            return (0.0, 0.0, 0.0, Counter(), Counter())
        fl = c.flops
        tr = c.traffic if with_traffic else 0.0
        sc = c.scores_traffic if with_traffic else 0.0
        cb, cc = Counter(c.coll_bytes), Counter(c.coll_count)
        for child, mult, traffic_ok in c.children:
            f2, t2, s2, b2, c2 = total(child, with_traffic and traffic_ok,
                                       depth + 1)
            fl += mult * f2
            tr += mult * t2
            sc += mult * s2
            for k, v in b2.items():
                cb[k] += mult * v
            for k, v in c2.items():
                cc[k] += mult * v
        memo[key] = (fl, tr, sc, cb, cc)
        return memo[key]

    fl, tr, sc, cb, cc = total(entry.name, True)
    return {"flops": fl, "traffic_bytes": tr, "scores_traffic_bytes": sc,
            "collective_bytes": dict(cb), "collective_count": dict(cc)}


# -- legacy flat interface (kept for quick greps / tests) --------------------

@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def parse_collectives(hlo_text: str, loop_aware: bool = True) -> CollectiveStats:
    if loop_aware:
        a = analyze_module(hlo_text)
        if a["collective_bytes"]:
            return CollectiveStats(a["collective_bytes"],
                                   a["collective_count"])
        # fall through to the flat regex (synthetic / headerless snippets)
    by_bytes: Counter = Counter()
    by_count: Counter = Counter()
    op_re = re.compile(
        r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\w+\[[\d,]*\][^\s]*)\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"(-start|-done)?\(", re.M)
    for m in op_re.finditer(hlo_text):
        shape_str, kind, startdone = m.group(1), m.group(2), m.group(3)
        if startdone == "-done":
            continue
        by_bytes[kind] += _shape_bytes(shape_str)
        by_count[kind] += 1
    return CollectiveStats(dict(by_bytes), dict(by_count))


def op_histogram(hlo_text: str, ops=("fusion", "dot", "convolution",
                                     "custom-call")) -> dict:
    hist = {}
    for op in ops:
        hist[op] = len(re.findall(rf"=\s*(?:\([^)]*\)|\S+)\s+{op}\(",
                                  hlo_text))
    return hist


def roofline_terms(hlo_flops: float, hlo_bytes: float,
                   collective_bytes: float, n_chips: int,
                   model_flops: float = 0.0,
                   peak_flops: float = V5E_PEAK_FLOPS,
                   hbm_bw: float = V5E_HBM_BW,
                   ici_bw: float = V5E_ICI_BW,
                   ici_links: float = 4.0) -> dict:
    """The three §Roofline terms, in seconds (per-device quantities in)."""
    t_compute = hlo_flops / peak_flops
    t_memory = hlo_bytes / hbm_bw
    t_coll = collective_bytes / (ici_bw * ici_links)
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(t_compute, t_memory, t_coll)
    out = {**terms, "bottleneck": dom.replace("_s", ""),
           "step_lower_bound_s": bound}
    if model_flops:
        out["model_flops"] = model_flops
        out["useful_flop_frac"] = model_flops / max(hlo_flops * n_chips, 1.0)
        out["roofline_frac"] = (model_flops / (n_chips * peak_flops)) / \
            max(bound, 1e-12)
    return out

#!/usr/bin/env python
"""Docs-consistency gate (CI): the README engine-flag matrix must cover every
``REPRO_*`` flag the code actually reads, and no tracked bytecode may sneak
back into the repository.

Checks, each fatal:
  1. every ``REPRO_[A-Z_]+`` token appearing in ``src/`` is documented in
     README.md (so a new flag cannot ship undocumented);
  2. every ``REPRO_*`` flag the README documents still exists in ``src/``
     (so the matrix cannot rot);
  3. every public serving entry point (``repro.serve.__all__``) is named in
     README.md (the serving table cannot drift from the module surface);
  4. every public SQL-frontend entry point (``repro.sql.__all__``) is named
     in README.md (same rule for the SQL quickstart section);
  5. ``git ls-files`` reports no ``*.pyc`` / ``__pycache__`` entries
     (commit ebdc242 shipped bytecode once; never again);
  6. every per-run switch in ``PER_RUN_SWITCHES`` (the ``join_method=`` /
     ``tolerance=`` keyword arguments that behave like flags but travel as
     arguments) is documented in README.md AND still accepted somewhere in
     ``src/`` as a keyword parameter.

    python tools/check_docs.py
"""
from __future__ import annotations

import ast
import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FLAG_RE = re.compile(r"\bREPRO_[A-Z_]+\b")

# keyword arguments that act as engine switches (README documents them in the
# same flag matrix as the env vars)
PER_RUN_SWITCHES = ("join_method", "tolerance")


def flags_in_src() -> set[str]:
    found = set()
    for dirpath, dirnames, filenames in os.walk(os.path.join(ROOT, "src")):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for f in filenames:
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f)) as fh:
                    found |= set(FLAG_RE.findall(fh.read()))
    return found


def flags_in_readme() -> set[str]:
    with open(os.path.join(ROOT, "README.md")) as fh:
        return set(FLAG_RE.findall(fh.read()))


def module_all(*rel: str) -> list[str]:
    """A module's literal ``__all__``, read without importing (no jax)."""
    path = os.path.join(ROOT, "src", *rel)
    with open(path) as fh:
        tree = ast.parse(fh.read())
    for node in tree.body:
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "__all__"
                for t in node.targets):
            return [ast.literal_eval(elt) for elt in node.value.elts]
    raise SystemExit(f"check_docs: {os.path.join(*rel)} has no literal "
                     "__all__")


def serve_all() -> list[str]:
    return module_all("repro", "serve", "__init__.py")


def sql_all() -> list[str]:
    return module_all("repro", "sql", "__init__.py")


def tracked_bytecode() -> list[str]:
    out = subprocess.run(["git", "ls-files", "*.pyc", "*__pycache__*"],
                         cwd=ROOT, capture_output=True, text=True, check=True)
    return [l for l in out.stdout.splitlines() if l]


def main() -> int:
    errors = []
    src, readme = flags_in_src(), flags_in_readme()
    undocumented = sorted(src - readme)
    stale = sorted(readme - src)
    if undocumented:
        errors.append(f"flags read in src/ but missing from the README "
                      f"matrix: {undocumented}")
    if stale:
        errors.append(f"flags documented in README but no longer read in "
                      f"src/: {stale}")
    with open(os.path.join(ROOT, "README.md")) as fh:
        readme_text = fh.read()
    missing = sorted(n for n in serve_all() if n not in readme_text)
    if missing:
        errors.append(f"serving entry points (repro.serve.__all__) missing "
                      f"from README: {missing}")
    missing_sql = sorted(n for n in sql_all() if n not in readme_text)
    if missing_sql:
        errors.append(f"SQL entry points (repro.sql.__all__) missing "
                      f"from README: {missing_sql}")
    src_text = []
    for dirpath, dirnames, filenames in os.walk(os.path.join(ROOT, "src")):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for f in filenames:
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f)) as fh:
                    src_text.append(fh.read())
    src_text = "\n".join(src_text)
    for switch in PER_RUN_SWITCHES:
        if f"`{switch}=`" not in readme_text:
            errors.append(f"per-run switch `{switch}=` missing from the "
                          f"README flag matrix")
        if not re.search(rf"\b{switch}\s*[:=]", src_text):
            errors.append(f"per-run switch `{switch}=` documented but no "
                          f"longer accepted anywhere in src/")
    pyc = tracked_bytecode()
    if pyc:
        errors.append(f"tracked bytecode files: {pyc[:5]}"
                      f"{' ...' if len(pyc) > 5 else ''}")
    for e in errors:
        print(f"check_docs: FAIL: {e}", file=sys.stderr)
    if not errors:
        print(f"check_docs: OK ({sorted(src)} documented, no tracked "
              f"bytecode)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())

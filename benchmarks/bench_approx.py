"""Sample-ladder benchmark: per-rung wall clock vs CI width for q1/q6/q18.

For each query the exact plan and every ladder rung (1/16..1/1) are compiled
once into a standing jitted executable (rung construction and compilation are
amortized, exactly as ``QueryServer`` amortizes them); the reported wall is
min-over-``--reps`` of the compiled call.  Each rung also reports the max
relative CI half-width ``repro.approx.estimators`` attaches to its answer —
the two axes of the accuracy/latency trade the progressive runner walks.

q18 is the deliberate odd one out: its grouped ``sum_qty`` feeds a
HAVING-style filter and two joins, so group membership would be decided by
un-barred estimates — the rewrite refuses every sampled rung (recorded as
``"refused": true``) and only the rename-only top rung runs.  The gate pins
that refusal: an estimability regression that starts sampling q18 again
fails the bench, because the last time that happened the scaled answer was
served with a fabricated zero CI.

    PYTHONPATH=src python benchmarks/bench_approx.py [--check] [--sf 0.05]

Writes ``BENCH_approx.json`` at the repo root.  ``--check`` exits non-zero
unless, for every query:

  * the top rung (den == 1) is byte-identical to the exact plan — the
    differential identity the rewrite guarantees by construction;
  * refusal is shape-based and therefore total: either every sampled rung
    refused (q18) or none did (q1/q6);
  * for measured ladders, CI width is non-increasing as the sample grows
    (inf sorts above everything; the top rung is exactly 0);
  * for measured ladders, wall clock is monotone across the sampled rungs
    (1/16..1/2) within a noise allowance, and the smallest rung is
    measurably below the exact wall — the whole point of answering from a
    sample.  The top rung is excluded from the wall gate: sampled rungs pay
    for the CLT moment aggregates the rename-only top rung drops, so a
    half-sample plan may legitimately cost as much as the exact one.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time

import jax
import jax.numpy as jnp

from repro.core import backend as B
from repro.core import relational as rel
from repro.core.table import Table, to_numpy
from repro.data import tpch
from repro.queries import QUERIES
from repro.approx.rewrite import rewrite_for_rung
from repro.approx.sampling import LADDER

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "BENCH_approx.json")

QIDS = (1, 6, 18)
# smallest rung must beat exact by at least this factor; adjacent rungs may
# regress by at most WALL_SLACK (timing noise on small inputs)
SPEEDUP_MIN = 1.25
WALL_SLACK = 1.15


def _executable(query_fn, db, capacity_factor: float = 3.0):
    """One standing jitted executable over the database's device tables."""
    tables = B._np_db_to_tables(db)

    def run(tables):
        ctx = B.LocalContext(db, tables, capacity_factor=capacity_factor)
        out = query_fn(ctx)
        if isinstance(out, dict):
            out = Table({k: jnp.asarray(v).reshape(1) for k, v in out.items()},
                        jnp.asarray(1, jnp.int32))
        return rel.ensure_compact(out), ctx.overflow
    return jax.jit(run), tables


def _time(fn, tables, reps: int):
    out, overflow = fn(tables)          # warm-up (compile) outside the clock
    assert not bool(overflow), "capacity overflow in bench run"
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out, _ = fn(tables)
        jax.block_until_ready(out.columns if hasattr(out, "columns") else out)
        best = min(best, time.perf_counter() - t0)
    return best, to_numpy(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless identity + monotonicity gates "
                         "hold for every query")
    args = ap.parse_args()

    db = tpch.generate(args.sf, seed=args.seed)
    queries, checks = {}, {}
    for qid in QIDS:
        q = QUERIES[qid]
        fn, tables = _executable(q, db)
        exact_wall, exact_cols = _time(fn, tables, args.reps)
        rungs = []
        identical = True
        for den in LADDER:
            rw = rewrite_for_rung(q, db, den)
            if rw is None:
                assert den != 1, f"q{qid}: the rename-only top rung refused"
                rungs.append({"den": den, "refused": True})
                continue
            rfn, rtables = _executable(rw.query, rw.db)
            wall, cols = _time(rfn, rtables, args.reps)
            est = rw.finalize(cols)
            ci = float(est.rel_width)
            rungs.append({"den": den, "wall_s": round(wall, 5),
                          "ci": None if math.isinf(ci) else round(ci, 5)})
            if den == 1:
                identical = set(cols) == set(exact_cols) and all(
                    (cols[k] == exact_cols[k]).all() for k in exact_cols)
        measured = [r for r in rungs if not r.get("refused")]
        refused = len(rungs) - len(measured)
        walls = [r["wall_s"] for r in measured]
        cis = [math.inf if r["ci"] is None else r["ci"] for r in measured]
        checks[f"q{qid}"] = {
            "rung1_byte_identical": bool(identical),
            "refusal_is_total": refused in (0, len(LADDER) - 1),
            "ci_monotone_nonincreasing": all(
                a >= b - 1e-12 for a, b in zip(cis, cis[1:])),
            "top_rung_ci_zero": cis[-1] == 0.0,
        }
        if refused == 0:
            checks[f"q{qid}"].update({
                "wall_monotone_with_slack": all(
                    a <= b * WALL_SLACK
                    for a, b in zip(walls[:-1], walls[1:-1])),
                "smallest_rung_beats_exact":
                    walls[0] * SPEEDUP_MIN <= exact_wall,
            })
        else:
            # the estimability gate, not the latency ladder, is under test:
            # this shape folds grouped estimates into downstream computation
            checks[f"q{qid}"]["sampled_rungs_refuse"] = \
                refused == len(LADDER) - 1
        queries[f"q{qid}"] = {"exact_wall_s": round(exact_wall, 5),
                              "rungs": rungs}
        parts = []
        for r in rungs:
            if r.get("refused"):
                parts.append(f"1/{r['den']} refused")
                continue
            ci_s = "inf" if r["ci"] is None else f"{100 * r['ci']:.2f}%"
            parts.append(f"1/{r['den']} {r['wall_s'] * 1e3:.2f}ms ci={ci_s}")
        print(f"q{qid}: exact {exact_wall * 1e3:.2f}ms | " + " ".join(parts))

    ok = all(all(c.values()) for c in checks.values())
    report = {"sf": args.sf, "seed": args.seed, "reps": args.reps,
              "ladder": list(LADDER), "queries": queries,
              "checks": checks, "pass": bool(ok)}
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=1)
    for qname, c in checks.items():
        for name, passed in c.items():
            if not passed:
                print(f"  FAIL {qname}.{name}")
    print(f"wrote {OUT_PATH}  pass={ok}")
    if args.check and not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

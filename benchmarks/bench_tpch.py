"""Paper Fig. 10 (workload performance) + Table 4 (exchange counts).

Single-device engine timings per query (this container's CPU stands in for
one device; the distributed variant runs in bench_exchange subprocesses) and
the per-plan exchange statistics that reproduce Table 4.
"""
from __future__ import annotations

from repro.core import backend as B
from repro.data import tpch
from repro.queries import PAPER_TABLE4, QUERIES

from .common import emit, time_fn

SF = 0.01


def main():
    db = tpch.generate(SF, seed=11)
    total = 0.0
    for qid in sorted(QUERIES):
        import jax

        fn = QUERIES[qid]
        holder = {}

        def run():
            out, stats = B.run_local(fn, db)
            holder["stats"] = stats
            return out

        t = time_fn(lambda: run(), warmup=1, iters=3)
        total += t
        s = holder["stats"]
        pc = PAPER_TABLE4.get(qid, (None, None))
        emit(f"tpch_q{qid}", t * 1e6,
             f"sf={SF};shuffles={s.shuffles};broadcasts={s.broadcasts};"
             f"paper_shuffles={pc[0]};paper_broadcasts={pc[1]}")
    emit("tpch_total_22q", total * 1e6, f"sf={SF};single_device")


if __name__ == "__main__":
    main()

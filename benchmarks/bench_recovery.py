"""Recovery benchmark: lineage resume vs whole-query re-execution.

The paper's fault story is re-execution from durable inputs (§2.4): a failed
query costs a full second pass.  The lineage subsystem
(``repro.distributed.lineage``) snapshots every post-exchange table through
the CRC-checksummed checkpoint writer, so a query that dies AFTER its
exchanges (the common case — finalize, result fetch, a straggler timeout on
the last collective) resumes from the topmost durable exchange and re-executes
only the plan suffix.

This benchmark measures that payoff end-to-end, per query:

  * ``full_s``    — warm eager re-execution of the whole query (the paper's
                    recovery cost; no lineage armed).
  * ``resume_s``  — warm resume from a populated lineage store: restore the
                    topmost snapshot (CRC-verified) + re-execute the suffix.
  * ``reshard_s`` — warm resume at a SHRUNKEN topology (snapshots written
                    for an 8-wide mesh, resumed at 5): the degraded-mesh
                    path, which adopts width-mismatched snapshots through
                    the store's re-shard rule instead of recomputing from
                    scan.  Gated against full re-execution by
                    ``MAX_RESHARD_RATIO``.

Timings are min-over-``--reps`` after a warm-up pass, so JIT/trace cost and
page-cache effects hit both legs equally.  The store is populated once by a
run that simulates the fault at ``finalize`` — the snapshots a real failed
attempt would have left behind.

    PYTHONPATH=src python benchmarks/bench_recovery.py [--check] [--sf 0.05]

Writes ``BENCH_recovery.json`` at the repo root.  ``--check`` exits non-zero
unless every gated query resumes in < ``MAX_RECOVERY_RATIO`` x its full
re-execution wall — bounded recovery, CI-gateable on CPU with no cluster.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

from repro.core import backend as B
from repro.data import tpch
from repro.distributed.chaos import ChaosInjector, FaultPlan, FaultSpec, \
    TransientFault
from repro.distributed.lineage import LineageStore, run_resumable
from repro.queries import QUERIES

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "BENCH_recovery.json")

# Resume must cost less than this fraction of a full re-execution.  The
# gated queries have deep exchange trees (joins feeding a group_by), so the
# suffix after the topmost exchange is a small tail of the plan; snapshot
# restore is CRC + npy I/O on a compacted table.
MAX_RECOVERY_RATIO = 0.6

# A degraded-mesh resume (snapshots written at width N, adopted at N') pays
# the same restore + suffix as a same-width resume — eager snapshots are
# stored in global row order, so no data movement is added — but gets its
# own, slightly looser budget so the gate localizes a regression in the
# re-shard rule itself.
MAX_RESHARD_RATIO = 0.7
RESHARD_FROM, RESHARD_TO = 8, 5

# Queries the ratio gate applies to at the default --sf.  Every query is
# still measured and reported.
RECOVERY_QUERIES = (5, 9, 18)


def _time(fn, reps: int) -> float:
    fn()                                  # warm-up: traces, page cache
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--queries", type=int, nargs="*", default=None,
                    help="query ids to measure (default: the gated set)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless every gated query resumes in"
                         " < MAX_RECOVERY_RATIO x full re-execution")
    args = ap.parse_args()
    qids = args.queries if args.queries else sorted(RECOVERY_QUERIES)

    db = tpch.generate(args.sf, seed=args.seed)
    report = {"sf": args.sf, "seed": args.seed, "reps": args.reps,
              "max_recovery_ratio": MAX_RECOVERY_RATIO,
              "max_reshard_ratio": MAX_RESHARD_RATIO,
              "reshard_widths": [RESHARD_FROM, RESHARD_TO],
              "gated_queries": sorted(RECOVERY_QUERIES), "queries": {}}
    ok = True
    work = tempfile.mkdtemp(prefix="bench_recovery_")
    try:
        for qid in qids:
            q = QUERIES[qid]
            store = LineageStore(os.path.join(work, f"q{qid}"))

            # populate: the snapshots a mid-query failure leaves behind
            # (fault fires at finalize -> every exchange already durable)
            inj = ChaosInjector(FaultPlan(qid, (
                FaultSpec("transient", cut="finalize", attempt=1),)))
            try:
                run_resumable(q, db, store, capacity_factor=3.0, chaos=inj)
            except TransientFault:
                pass
            snapshots = store.saved       # before resumes reset the counter
            assert snapshots >= 1, f"q{qid}: no exchange snapshots written"

            full_s = _time(
                lambda: B.run_local(q, db, jit=False, capacity_factor=3.0),
                args.reps)

            def resume():
                _, _, _, reused = run_resumable(q, db, store,
                                                capacity_factor=3.0)
                assert reused >= 1, f"q{qid}: resume did not hit a snapshot"
            resume_s = _time(resume, args.reps)

            # degraded-mesh leg: snapshots written for an 8-wide topology,
            # adopted by a 5-wide resume through the width-only-mismatch
            # re-shard rule (LineageStore.resharded counts the adoptions)
            store_w = LineageStore(os.path.join(work, f"q{qid}_w"))
            inj_w = ChaosInjector(FaultPlan(qid, (
                FaultSpec("transient", cut="finalize", attempt=1),)))
            try:
                run_resumable(q, db, store_w, capacity_factor=3.0,
                              chaos=inj_w, n_devices=RESHARD_FROM)
            except TransientFault:
                pass

            def reshard_resume():
                _, _, _, reused = run_resumable(q, db, store_w,
                                                capacity_factor=3.0,
                                                n_devices=RESHARD_TO)
                assert reused >= 1, f"q{qid}: re-shard resume missed"
                assert store_w.resharded >= 1, \
                    f"q{qid}: resume did not exercise the re-shard path"
            reshard_s = _time(reshard_resume, args.reps)

            ratio = resume_s / full_s
            reshard_ratio = reshard_s / full_s
            gated = qid in RECOVERY_QUERIES
            q_ok = (not gated) or (ratio < MAX_RECOVERY_RATIO
                                   and reshard_ratio < MAX_RESHARD_RATIO)
            ok &= q_ok
            report["queries"][f"q{qid}"] = {
                "full_s": round(full_s, 4), "resume_s": round(resume_s, 4),
                "ratio": round(ratio, 3),
                "reshard_s": round(reshard_s, 4),
                "reshard_ratio": round(reshard_ratio, 3),
                "snapshots": snapshots,
                "gated": gated,
            }
            flag = "" if q_ok else "  ** OVER RATIO **"
            print(f"q{qid:2d}: full {full_s * 1e3:7.1f}ms -> resume "
                  f"{resume_s * 1e3:7.1f}ms  (ratio {ratio:.2f}) -> reshard "
                  f"{RESHARD_FROM}->{RESHARD_TO} {reshard_s * 1e3:7.1f}ms "
                  f"(ratio {reshard_ratio:.2f}, "
                  f"{snapshots} snapshots){flag}", flush=True)
    finally:
        shutil.rmtree(work, ignore_errors=True)

    report["pass"] = bool(ok)
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {OUT_PATH}  pass={ok}")
    if args.check and not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""§Roofline source: per-(arch x shape x mesh) terms from the dry-run JSONs.

Run ``python -m repro.launch.dryrun --all`` first; this module reduces the
records into the roofline table (also embedded in EXPERIMENTS.md).
"""
from __future__ import annotations

import glob
import json
import os

from .common import ROOT, emit

RESULTS = os.path.join(ROOT, "results", "dryrun")


def main():
    files = sorted(glob.glob(os.path.join(RESULTS, "*.json")))
    if not files:
        emit("roofline_missing", 0, "run: python -m repro.launch.dryrun --all")
        return
    for f in files:
        r = json.load(open(f))
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        if r.get("skipped"):
            emit(name, 0, f"skipped:{r['skipped'][:40]}")
            continue
        if not r.get("ok"):
            emit(name, 0, f"FAILED:{r.get('error', '')[:60]}")
            continue
        rf = r["roofline"]
        emit(name, rf["step_lower_bound_s"] * 1e6,
             f"bottleneck={rf['bottleneck']};"
             f"compute_ms={rf['compute_s'] * 1e3:.2f};"
             f"memory_ms={rf['memory_s'] * 1e3:.2f};"
             f"collective_ms={rf['collective_s'] * 1e3:.2f};"
             f"roofline_frac={rf.get('roofline_frac', 0):.4f};"
             f"useful_flops={rf.get('useful_flop_frac', 0):.3f}")


if __name__ == "__main__":
    main()

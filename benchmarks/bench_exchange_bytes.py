"""Wire-byte benchmark: per-query exchange payload widths, derived from the
logical-plan IR with NO execution (``planner.static_wire_stats``).

The paper's Hockney model (§3.6) makes interconnect bytes-per-row the
dominant distributed term; the stats-driven narrow wire format
(``core/wire.py``) ships every exchanged column at its inferred lane width.
This benchmark derives, for each of the 22 query plans, the summed per-row
wire bytes of every exchange (shuffle / broadcast / final gather) in the
narrow format vs the legacy wide format — numbers that are asserted equal to
runtime ``ExchangeStats`` on all three backends (tests/test_wire.py), so the
win is CI-gateable on CPU with no cluster, exactly like the sort-tax gates.

    PYTHONPATH=src python benchmarks/bench_exchange_bytes.py [--check] [--sf 0.01]

Writes ``BENCH_exchange_bytes.json`` at the repo root.  ``--check`` exits
non-zero unless every query's narrow wire bytes are within its ABSOLUTE
budget (``MAX_WIRE_BYTES``) and the shuffle-heavy queries show at least a
40% reduction vs wide (``MIN_WIRE_DROP_QUERIES``).
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core import planner as PL
from repro.data import tpch
from repro.queries import QUERIES

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "BENCH_exchange_bytes.json")

# Absolute per-query budgets: summed narrow row-wire bytes across every
# exchange of the plan, measured at sf=0.01 seed=7 (bounds are column
# statistics of the generated database, stable per (sf, seed)).  Keep in
# sync with the narrow layout — a widened lane shows up here immediately.
MAX_WIRE_BYTES = {
    1: 92, 2: 28, 3: 16, 4: 12, 5: 20, 6: 0, 7: 20, 8: 32, 9: 44, 10: 32,
    11: 16, 12: 20, 13: 28, 14: 20, 15: 24, 16: 24, 17: 16, 18: 48, 19: 4,
    20: 16, 21: 16, 22: 32,
}

# Shuffle-heavy plans (ISSUE 4 acceptance): narrow must cut >= 40% of the
# wide format's wire bytes.  Integer arithmetic: (wide - narrow) / wide.
MIN_WIRE_DROP = 0.40
MIN_WIRE_DROP_QUERIES = (5, 7, 8, 9, 18)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=0.01)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless every query meets its absolute"
                         " wire-byte budget (and the shuffle-heavy set drops"
                         " >= 40%% vs the wide format)")
    args = ap.parse_args()

    db = tpch.generate(args.sf, seed=args.seed)
    report = {"sf": args.sf, "seed": args.seed, "queries": {},
              "max_wire_bytes": MAX_WIRE_BYTES,
              "min_wire_drop": MIN_WIRE_DROP,
              "min_wire_drop_queries": list(MIN_WIRE_DROP_QUERIES)}
    ok = True
    for qid in sorted(QUERIES):
        narrow = QUERIES[qid].static_wire(db, narrow=True)
        wide = QUERIES[qid].static_wire(db, narrow=False)
        nb = sum(e["row_wire_bytes"] for e in narrow)
        wb = sum(e["row_wire_bytes"] for e in wide)
        lb = sum(e["row_logical_bytes"] for e in narrow)
        drop = 0.0 if wb == 0 else 1.0 - nb / wb
        budget = MAX_WIRE_BYTES[qid]
        q_ok = nb <= budget
        # integer form of the >= 40% rule (no float edge at exactly 40%)
        if qid in MIN_WIRE_DROP_QUERIES:
            q_ok &= (wb - nb) * 100 >= int(MIN_WIRE_DROP * 100) * wb
        report["queries"][f"q{qid}"] = {
            "wire_bytes_narrow": nb,
            "wire_bytes_wide": wb,
            "logical_bytes": lb,
            "max_wire_bytes": budget,
            "reduction": round(drop, 3),
            "exchanges": [
                {"kind": n["kind"], "narrow": n["row_wire_bytes"],
                 "wide": w["row_wire_bytes"],
                 "logical": n["row_logical_bytes"]}
                for n, w in zip(narrow, wide)],
        }
        ok &= q_ok
        flag = "" if q_ok else "  ** OVER BUDGET **"
        print(f"q{qid:2d}: wire {wb:3d} -> {nb:3d} bytes/row "
              f"({drop:.0%} drop, budget {budget}){flag}", flush=True)

    report["pass"] = bool(ok)
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {OUT_PATH}  pass={ok}")
    if args.check and not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

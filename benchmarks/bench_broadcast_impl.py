"""Paper Fig. 19 / §7.1: collective broadcast vs p2p-emulated broadcast.

The p2p ring forwards the full shard N-1 times (duplicated inter-node
traffic); the collective all_gather pipelines it.  We report wall time AND
the structural byte counts the perf model uses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.exchange import broadcast_table, broadcast_table_p2p
from repro.core.table import Table
from repro.core.compat import make_mesh, shard_map

from .common import emit, time_fn

N = 8


def main():
    mesh = make_mesh((N,), ("data",))
    for lg in (12, 15, 18):
        rows = 1 << lg
        stats_holder = {}

        def make(p2p: bool):
            @jax.jit
            def run(x):
                def body(_):
                    t = Table({"k": jnp.arange(rows, dtype=jnp.int64),
                               "v": jnp.ones((rows,), jnp.float64)},
                              jnp.asarray(rows, jnp.int32))
                    if p2p:
                        out, st = broadcast_table_p2p(t, "data", N)
                    else:
                        out, _, st = broadcast_table(t, "data", N)
                    stats_holder[p2p] = st
                    return out.count.reshape(1)
                return shard_map(body, mesh=mesh, in_specs=P("data"),
                                 out_specs=P("data"))(x)
            return run

        x = jnp.zeros((N,), jnp.int32)
        t_coll = time_fn(make(False), x, iters=5)
        t_p2p = time_fn(make(True), x, iters=5)
        st_c, st_p = stats_holder[False], stats_holder[True]
        emit(f"broadcast_collective_{rows}rows", t_coll * 1e6,
             f"collectives={st_c.collectives};bytes={st_c.total_bytes}")
        emit(f"broadcast_p2p_{rows}rows", t_p2p * 1e6,
             f"collectives={st_p.collectives};bytes={st_p.total_bytes};"
             f"slowdown={t_p2p / t_coll:.2f}x")


if __name__ == "__main__":
    main()

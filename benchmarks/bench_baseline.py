"""Paper §6.7 analogue: tensor engine vs the single-node CPU baseline.

The paper compares against DuckDB; our NumPy reference executor is the
CPU baseline (independent implementation, exact-size arrays).  Both run the
same 22 logical plans.
"""
from __future__ import annotations

from repro.core import backend as B
from repro.data import tpch
from repro.queries import QUERIES

from .common import emit, time_fn

SF = 0.01


def main():
    db = tpch.generate(SF, seed=11)
    t_engine = 0.0
    t_base = 0.0
    for qid in sorted(QUERIES):
        fn = QUERIES[qid]
        te = time_fn(lambda: B.run_local(fn, db)[0], warmup=1, iters=3)
        tb = time_fn(lambda: B.run_reference(fn, db)[0], warmup=0, iters=3)
        t_engine += te
        t_base += tb
    emit("baseline_numpy_22q", t_base * 1e6, f"sf={SF}")
    emit("engine_jax_22q", t_engine * 1e6,
         f"sf={SF};note=both run on the same CPU here - the engine pays "
         f"static-shape padding+sorting for TPU-native execution; the "
         f"paper's GPU-vs-CPU-DB gap is projected in bench_projection")


if __name__ == "__main__":
    main()

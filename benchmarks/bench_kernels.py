"""Kernel microbenches: Pallas (interpret) vs jnp oracle per hot spot.

On CPU the interpreter is orders of magnitude slower than compiled jnp — the
derived column carries the structural facts that matter for the TPU target
(tile shapes, VMEM footprint), not the wall time ratio.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.kernels.flash_attention import ops as fa
from repro.kernels.hash_probe import ops as hp
from repro.kernels.radix_hist import ops as rh
from repro.kernels.segsum import ops as ss

from .common import emit, time_fn

rng = np.random.default_rng(0)


def main():
    n, g, c = 8192, 256, 8
    gids = jnp.asarray(rng.integers(0, g, n).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=(n, c)).astype(np.float32))
    t = time_fn(lambda: ss.segment_sum(gids, vals, g, use_kernel=False),
                iters=5)
    emit("segsum_ref_8k_256g", t * 1e6, "jnp oracle")
    t = time_fn(lambda: ss.segment_sum(gids, vals, g, blk=1024), iters=3)
    emit("segsum_pallas_8k_256g", t * 1e6,
         f"interpret;vmem_bytes={1024 * (384 + 128) * 4 + 384 * 128 * 4}")

    keys = jnp.asarray(rng.integers(0, 1 << 31, 8192).astype(np.int32))
    t = time_fn(lambda: rh.radix_hist(keys, 64, use_kernel=False), iters=5)
    emit("radix_hist_ref_8k_64p", t * 1e6, "jnp oracle")
    t = time_fn(lambda: rh.radix_hist(keys, 64, blk=2048), iters=3)
    emit("radix_hist_pallas_8k_64p", t * 1e6, "interpret")

    bkeys = jnp.asarray(rng.choice(1 << 30, 1024, replace=False)
                        .astype(np.int32))
    bvals = jnp.arange(1024, dtype=jnp.int32)
    pkeys = jnp.asarray(rng.integers(0, 1 << 30, 8192).astype(np.int32))
    t = time_fn(lambda: hp.hash_join_probe(pkeys, bkeys, bvals,
                                           use_kernel=False)[0], iters=5)
    emit("hash_probe_ref_8k", t * 1e6, "searchsorted oracle")
    t = time_fn(lambda: hp.hash_join_probe(pkeys, bkeys, bvals, cap=16)[0],
                iters=3)
    emit("hash_probe_pallas_8k", t * 1e6, "interpret;bucket_cap=16")

    q = jnp.asarray(rng.normal(size=(1, 4, 256, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)).astype(np.float32))
    t = time_fn(lambda: fa.flash_attention(q, k, k, use_kernel=False),
                iters=5)
    emit("flashattn_ref_256", t * 1e6, "jnp oracle")
    t = time_fn(lambda: fa.flash_attention(q, k, k, q_blk=128, kv_blk=128),
                iters=2)
    emit("flashattn_pallas_256", t * 1e6,
         "interpret;q_blk=128;kv_blk=128")


if __name__ == "__main__":
    main()

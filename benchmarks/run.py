"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Multi-device benches run in
subprocesses with virtual host devices so this process keeps the real single
device (see benchmarks.common.run_subprocess_bench).

  bench_tpch            Fig 10  workload performance + Table 4 counts
  bench_baseline        §6.7    engine vs CPU (NumPy) baseline
  bench_exchange        Figs 6/7 exchange microbench + Hockney fits   [8 dev]
  bench_skew            Figs 8/9/20/21 skewed exchange + JCC-H        [8 dev]
  bench_broadcast_impl  Fig 19  collective vs p2p broadcast           [8 dev]
  bench_q12_plans       Fig 22  partitioned vs non-partitioned plans  [8 dev]
  bench_projection      Figs 13/14/16 scale-out projection + QPS/$
  bench_kernels         DESIGN §6 Pallas kernels vs oracles
  bench_roofline        §Roofline table from the dry-run artifacts
"""
from __future__ import annotations

import sys

from . import (bench_baseline, bench_kernels, bench_projection,
               bench_roofline, bench_tpch)
from .common import run_subprocess_bench

SUBPROCESS = ["bench_exchange", "bench_skew", "bench_broadcast_impl",
              "bench_q12_plans"]
LOCAL = [("bench_tpch", bench_tpch), ("bench_baseline", bench_baseline),
         ("bench_projection", bench_projection),
         ("bench_kernels", bench_kernels),
         ("bench_roofline", bench_roofline)]


def main() -> None:
    print("name,us_per_call,derived")
    only = set(sys.argv[1:])
    for name, mod in LOCAL:
        if only and name not in only:
            continue
        print(f"# {name}", flush=True)
        mod.main()
    for name in SUBPROCESS:
        if only and name not in only:
            continue
        print(f"# {name} (8 virtual devices)", flush=True)
        out = run_subprocess_bench(name)
        sys.stdout.write(out)
        sys.stdout.flush()


if __name__ == "__main__":
    main()

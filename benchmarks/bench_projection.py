"""Paper Figs. 13/14 + Fig. 16: workload projection across cluster sizes and
price-performance, driven by the §3 analytical models (Projection I and the
'+Small Msg' Projection II with Hockney fits).
"""
from __future__ import annotations

from repro.core import perfmodel as pm

from .common import emit

# representative per-exchange workset bytes for the 22-query workload at
# SF=1000 (paper §6.5: 80th-pct messages imply worksets of O(1-10 GiB))
EXCHANGES = [("shuffle", 2e9)] * 10 + [("broadcast", 1e9)] * 14
COMPUTE_V1 = 1.06        # paper: 22 queries, 1 VM (8 GPUs), seconds


def main():
    fits = {
        # Hockney constants of IB-class networks (order-of-magnitude, §3.6)
        "bn": pm.Hockney(latency=20e-6, inv_bw=1 / 45e9),
        "bg": pm.Hockney(latency=5e-6, inv_bw=1 / 400e9),
    }
    for cname in ("h100_ib", "a100_eth", "tpu_v5e"):
        spec = pm.CLUSTERS[cname]
        # Projection I (peak-bandwidth)
        p1 = pm.project_workload(spec, range(1, 9), COMPUTE_V1, EXCHANGES)
        # Projection II (+ small messages) — NIC Hockney constants only make
        # sense for the paper's GPU clusters; the TPU pod row keeps proj I.
        p2 = None
        if cname != "tpu_v5e":
            p2 = pm.project_workload(spec, range(1, 9), COMPUTE_V1, EXCHANGES,
                                     hockney_n=fits["bn"],
                                     hockney_g=fits["bg"])
        for v in (1, 2, 4, 8):
            emit(f"project_{cname}_v{v}", p1[v]["total"] * 1e6,
                 f"projI;compute={p1[v]['compute']:.3f};"
                 f"shuffle={p1[v]['shuffle']:.4f};"
                 f"broadcast={p1[v]['broadcast']:.4f}")
            if p2:
                emit(f"project_smallmsg_{cname}_v{v}", p2[v]["total"] * 1e6,
                     f"projII;broadcast={p2[v]['broadcast']:.4f}")
        # paper's observation: adding machines stops helping at some V
        best_v = min(range(1, 9), key=lambda v: (p2 or p1)[v]["total"])
        emit(f"project_best_v_{cname}", best_v,
             "argmin total (paper: no gain beyond V~6)")
    # price-performance (Fig 16): QPS/$ for 22 queries
    for cname in ("a100_eth", "h100_ib", "mi300x_ib"):
        spec = pm.CLUSTERS[cname]
        if not spec.price_hr:
            continue
        p = pm.project_workload(spec, [1], COMPUTE_V1, EXCHANGES)
        qps = 22.0 / p[1]["total"]
        emit(f"qps_per_usd_{cname}_v1", qps / spec.price_hr * 3600 * 1e-3,
             f"qps={qps:.1f};price_hr={spec.price_hr}")


if __name__ == "__main__":
    main()

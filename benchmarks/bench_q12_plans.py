"""Paper Fig. 22 / §7.3: Q12 under different partitionings and plans.

  default — inputs co-partitioned on the join key: no exchange.
  Pa      — inputs partitioned off-key: shuffle BOTH tables to the join key.
  Pb      — inputs partitioned off-key: broadcast the filtered lineitem side.
"""
from __future__ import annotations

import numpy as np

import jax

from repro.core import backend as B
from repro.core.table import days
from repro.data import tpch
from repro.queries import QUERIES
from repro.core.compat import make_mesh

from .common import emit, time_fn

N = 8
OFFKEY = {"lineitem": "l_partkey", "orders": "o_custkey"}


def _filtered_lineitem(ctx):
    l = ctx.scan("lineitem")
    m = (ctx.isin(l, "l_shipmode", ["MAIL", "SHIP"]) &
         (l["l_commitdate"] < l["l_receiptdate"]) &
         (l["l_shipdate"] < l["l_commitdate"]) &
         (l["l_receiptdate"] >= days("1994-01-01")) &
         (l["l_receiptdate"] < days("1995-01-01")))
    return ctx.select(ctx.filter(l, m), "l_orderkey", "l_shipmode")


def _finish(ctx, j):
    hi = ["1-URGENT", "2-HIGH"]
    g = ctx.group_by(j, ["l_shipmode"], [
        ("high_line_count", "sum",
         lambda t: ctx.xp.where(ctx.isin(t, "o_orderpriority", hi), 1, 0)),
        ("low_line_count", "sum",
         lambda t: ctx.xp.where(ctx.isin(t, "o_orderpriority", hi), 0, 1)),
    ], exchange="gather", final=True)
    g = ctx.with_col(g, m_rank=lambda t: ctx.alpha_rank(t, "l_shipmode"))
    return ctx.finalize(g, sort_keys=[("m_rank", True)], replicated=True)


def q12_pa(ctx):
    """Shuffle both sides to the join key (plan Pa)."""
    ls = ctx.shuffle(_filtered_lineitem(ctx), "l_orderkey")
    o = ctx.scan("orders")
    os_ = ctx.shuffle(ctx.select(o, "o_orderkey", "o_orderpriority"),
                      "o_orderkey")
    j = ctx.join(ls, os_, "l_orderkey", "o_orderkey", ["o_orderpriority"])
    return _finish(ctx, j)


def q12_pb(ctx):
    """Broadcast the (small) filtered lineitem side (plan Pb)."""
    lb = ctx.broadcast(_filtered_lineitem(ctx))
    o = ctx.scan("orders")
    j = ctx.join(lb, o, "l_orderkey", "o_orderkey", ["o_orderpriority"])
    return _finish(ctx, j)


def main():
    mesh = make_mesh((N,), ("data",))
    db = tpch.generate(0.01, seed=11)
    ref, _ = B.run_reference(QUERIES[12], db)

    plans = [("default_copart", QUERIES[12], None),
             ("pa_shuffle_both", q12_pa, OFFKEY),
             ("pb_broadcast", q12_pb, OFFKEY)]
    for name, fn, pk in plans:
        def run():
            out, stats, ov = B.run_distributed(fn, db, mesh,
                                               capacity_factor=4.0,
                                               partition_keys=pk)
            assert not ov, name
            return out, stats
        out, stats = run()
        for k in set(ref) & set(out):
            np.testing.assert_allclose(np.asarray(out[k], np.float64),
                                       np.asarray(ref[k], np.float64),
                                       rtol=1e-7, err_msg=f"{name} {k}")
        t = time_fn(lambda: run()[0], warmup=1, iters=3)
        xbytes = sum(e.total_bytes for e in stats.log)
        emit(f"q12_{name}", t * 1e6,
             f"shuffles={stats.shuffles};broadcasts={stats.broadcasts};"
             f"exchange_bytes={xbytes}")


if __name__ == "__main__":
    main()

"""Paper Figs. 6/7 (exchange microbenchmarks + model validation) and the
Hockney fits used by the projections.  Runs under 8 virtual host devices
(spawned by run.py); wall times are CPU-host times, so the *trend* (latency
floor, bandwidth saturation, model fit quality) is the deliverable, and the
fitted constants parameterize B_n(m)/B_g(m) exactly as the paper's §3.6.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import perfmodel as pm
from repro.core.exchange import broadcast_table, shuffle
from repro.core.table import Table
from repro.core.compat import make_mesh, shard_map

from .common import emit, time_fn

N = 8
SIZES_LOG2 = range(10, 19)   # rows per device: 1k .. 256k (x8 bytes/row)


def _mktable(rows: int) -> Table:
    cols = {"k": jnp.arange(rows, dtype=jnp.int64),
            "v": jnp.ones((rows,), jnp.float64)}
    return Table(cols, jnp.asarray(rows, jnp.int32))


def main():
    mesh = make_mesh((N,), ("data",))
    meas = {"shuffle": [], "broadcast": []}
    for lg in SIZES_LOG2:
        rows = 1 << lg
        bytes_per_dev = rows * 16          # two 8-byte columns

        @jax.jit
        def do_shuffle(key0):
            def body(_):
                t = _mktable(rows)
                out, ov, _, _ = shuffle(t, t["k"] + key0, "data", N,
                                        cap_per_dest=rows // N * 4)
                return out.count.reshape(1)
            return shard_map(body, mesh=mesh, in_specs=P("data"),
                             out_specs=P("data"))(
                jnp.zeros((N,), jnp.int64))

        @jax.jit
        def do_broadcast(key0):
            def body(_):
                t = _mktable(rows)
                out, _, _ = broadcast_table(t, "data", N)
                return out.count.reshape(1)
            return shard_map(body, mesh=mesh, in_specs=P("data"),
                             out_specs=P("data"))(
                jnp.zeros((N,), jnp.int64))

        t_sh = time_fn(do_shuffle, jnp.asarray(0, jnp.int64), iters=5)
        t_bc = time_fn(do_broadcast, jnp.asarray(0, jnp.int64), iters=5)
        total = bytes_per_dev * N
        meas["shuffle"].append((total / (N * N), t_sh))   # p2p msg size
        meas["broadcast"].append((bytes_per_dev, t_bc))   # ring payload
        emit(f"shuffle_{1 << lg}rows", t_sh * 1e6,
             f"thpt_GBps={total / t_sh / 1e9:.3f};msg_bytes={total // (N * N)}")
        emit(f"broadcast_{1 << lg}rows", t_bc * 1e6,
             f"thpt_GBps={total / t_bc / 1e9:.3f};msg_bytes={bytes_per_dev}")

    # Hockney fits (paper fits V=2 microbenchmarks; we fit the sweep)
    for kind in ("shuffle", "broadcast"):
        ms = np.array([m for m, _ in meas[kind]], dtype=np.float64)
        ts = np.array([t for _, t in meas[kind]], dtype=np.float64)
        fit = pm.fit_hockney(ms, ts)
        emit(f"hockney_{kind}", fit.latency * 1e6,
             f"inv_bw_s_per_byte={fit.inv_bw:.3e};"
             f"bw_at_1MB_GBps={fit.bandwidth(1e6) / 1e9:.3f}")
        # model validation: predicted vs measured at the largest size
        m_big, t_big = meas[kind][-1]
        pred = fit.time(m_big)
        emit(f"model_check_{kind}", pred * 1e6,
             f"measured_us={t_big * 1e6:.1f};"
             f"rel_err={abs(pred - t_big) / t_big:.3f}")


if __name__ == "__main__":
    main()

"""Shared benchmark utilities: timing, CSV emission, subprocess launcher."""
from __future__ import annotations

import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call (jit-warmed)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def run_subprocess_bench(module: str, n_devices: int = 8, timeout: int = 1800,
                         extra_env: dict | None = None) -> str:
    """Run ``python -m benchmarks.<module>`` with N virtual host devices.

    Benchmarks needing multiple devices run in a subprocess so the main bench
    process (and its CSV) keeps seeing the real single device."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.update(extra_env or {})
    r = subprocess.run([sys.executable, "-m", f"benchmarks.{module}"],
                       env=env, capture_output=True, text=True,
                       timeout=timeout, cwd=ROOT)
    if r.returncode != 0:
        print(f"# {module} FAILED:\n{r.stderr[-2000:]}", file=sys.stderr)
        return ""
    return r.stdout

"""Serving benchmark: compiled-template throughput vs per-request jit.

Multi-tenant serving (``repro.serve``) amortizes planning and compilation
across requests: each of the 22 TPC-H templates is analyzed once against the
parameter DOMAINS and jit-traced once; a request binds parameter VALUES into
the standing executable as traced scalars.  This benchmark drives a mixed,
interleaved parameterized request stream (every template, every sample
binding) through three execution modes:

  * ``server``   — :class:`repro.serve.QueryServer`: bind + cached
                   executable + device call per request.
  * ``batch``    — :class:`repro.serve.BatchExecutor`: the whole stream as
                   one eager batch with the cross-query subplan memo.
  * ``per_jit``  — the no-serving baseline: ``run_local(jit=True)`` per
                   request, i.e. every request pays trace + compile.

Timings are min-over-``--reps`` of a full stream pass after a warm-up pass
(the server's warm-up pass is also where all compiles happen — reported as
``cold_s``).

    PYTHONPATH=src python benchmarks/bench_serve.py [--check] [--sf 0.05]

Writes ``BENCH_serve.json`` at the repo root.  ``--check`` exits non-zero
unless the recompile count equals the number of DISTINCT TEMPLATES in the
stream — re-binding a parameter must never re-trace; an accidental retrace
(dtype drift, pytree-structure drift, a binding leaking into a cache key)
breaks exactly this invariant, and the counter increments inside the traced
body so no retrace can hide.  The gate also requires cross-query sharing in
batch mode and at least two exercised bindings per parameterized template,
so the stream genuinely covers the serving surface.
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro import serve
from repro.core import backend as B
from repro.data import tpch

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "BENCH_serve.json")


def _stream():
    """Mixed interleaved parameterized traffic: every sample of all 22
    templates, round-robin so consecutive requests come from different
    templates (the serving-unfriendly order)."""
    per = [[(t, s) for s in t.samples]
           for _, t in sorted(serve.TEMPLATES.items())]
    out, i = [], 0
    while any(per):
        if per[i % len(per)]:
            out.append(per[i % len(per)].pop(0))
        i += 1
    return out


def _time(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--baseline", action="store_true",
                    help="also time the per-request-jit baseline (slow: "
                         "every request re-traces)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless recompiles == distinct "
                         "templates (and batch sharing happened)")
    args = ap.parse_args()

    db = tpch.generate(args.sf, seed=args.seed)
    reqs = _stream()
    n_templates = len({id(t) for t, _ in reqs})
    n_param = sum(1 for t in serve.TEMPLATES.values() if t.params)

    srv = serve.QueryServer(db)
    t0 = time.perf_counter()
    srv.serve(reqs, infer=True)          # cold pass: every template compiles
    cold_s = time.perf_counter() - t0
    serve_s = _time(lambda: srv.serve(reqs, infer=True), args.reps)

    bx = serve.BatchExecutor(db)
    batch_s = _time(lambda: bx.run_batch(reqs, infer=True), args.reps)

    per_jit_s = None
    if args.baseline:
        def per_jit():
            for t, s in reqs:
                B.run_local(t.bind(**s), db, jit=True, capacity_factor=3.0)
        per_jit_s = _time(per_jit, 1)

    bindings_per_template = {
        t.name: len(t.samples) for t, _ in reqs if t.params}
    checks = {
        # THE gate: one trace per template, no matter how many bindings or
        # how many warm passes the stream replayed
        "one_trace_per_template": srv.recompiles == n_templates,
        "cross_query_sharing": bx.shared_hits > 0,
        "no_overflow_reruns": srv.overflow_reruns == 0,
        "multi_binding_coverage": all(
            n >= 2 for n in bindings_per_template.values()),
    }
    ok = all(checks.values())

    report = {
        "sf": args.sf, "seed": args.seed, "reps": args.reps,
        "requests": len(reqs), "templates": n_templates,
        "parameterized_templates": n_param,
        "recompiles": srv.recompiles, "cache_hits": srv.cache_hits,
        "shared_hits": bx.shared_hits,
        "cold_s": round(cold_s, 4),
        "serve_s": round(serve_s, 4),
        "serve_qps": round(len(reqs) / serve_s, 2),
        "batch_s": round(batch_s, 4),
        "batch_qps": round(len(reqs) / batch_s, 2),
        "per_jit_s": None if per_jit_s is None else round(per_jit_s, 4),
        "checks": checks, "pass": bool(ok),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=1)

    print(f"{len(reqs)} requests over {n_templates} templates "
          f"({n_param} parameterized): cold {cold_s:.2f}s, "
          f"warm {serve_s:.2f}s ({report['serve_qps']} q/s), "
          f"batch {batch_s:.2f}s ({report['batch_qps']} q/s)")
    print(f"recompiles={srv.recompiles} cache_hits={srv.cache_hits} "
          f"shared_hits={bx.shared_hits}")
    if per_jit_s is not None:
        print(f"per-request-jit baseline {per_jit_s:.2f}s "
              f"({len(reqs) / per_jit_s:.2f} q/s)")
    for name, passed in checks.items():
        print(f"  {'ok ' if passed else 'FAIL'} {name}")
    print(f"wrote {OUT_PATH}  pass={ok}")
    if args.check and not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Paper Figs. 8/9 (exchange under skew), 20/21 (JCC-H memory + per-query).

Shuffle with a skew gradient f (the paper's synthetic placement: device i
holds x + i*f*x rows) — broadcast unaffected, shuffle degraded; plus JCC-H
partition imbalance and the per-query comparison of §7.2.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import backend as B
from repro.core.exchange import broadcast_table, shuffle
from repro.core.table import Table
from repro.data import jcch, tpch
from repro.queries import QUERIES
from repro.core.compat import make_mesh, shard_map

from .common import emit, time_fn

N = 8
BASE_ROWS = 1 << 14


def _skewed_counts(f: float) -> np.ndarray:
    """Device i holds x*(1+i*f) rows, total fixed at N*BASE_ROWS."""
    w = 1 + np.arange(N) * f
    return np.maximum(8, (BASE_ROWS * N * w / w.sum()).astype(np.int64))


def main():
    mesh = make_mesh((N,), ("data",))
    cap = BASE_ROWS * 4
    for f in (0.0, 0.5, 1.0, 2.0):
        counts = _skewed_counts(f)

        @jax.jit
        def do_shuffle(cnts):
            def body(c):
                rows = cap
                t = Table({"k": jnp.arange(rows, dtype=jnp.int64),
                           "v": jnp.ones((rows,), jnp.float64)},
                          c[0].astype(jnp.int32))
                out, ov, _, _ = shuffle(t, t["k"], "data", N,
                                        cap_per_dest=cap)
                return out.count.reshape(1)
            return shard_map(body, mesh=mesh, in_specs=P("data"),
                             out_specs=P("data"))(cnts)

        @jax.jit
        def do_broadcast(cnts):
            def body(c):
                t = Table({"k": jnp.arange(cap, dtype=jnp.int64),
                           "v": jnp.ones((cap,), jnp.float64)},
                          c[0].astype(jnp.int32))
                out, _, _ = broadcast_table(t, "data", N)
                return out.count.reshape(1)
            return shard_map(body, mesh=mesh, in_specs=P("data"),
                             out_specs=P("data"))(cnts)

        arg = jnp.asarray(counts)
        t_sh = time_fn(do_shuffle, arg, iters=3)
        t_bc = time_fn(do_broadcast, arg, iters=3)
        emit(f"skew_shuffle_f{f}", t_sh * 1e6,
             f"imbalance={counts.max() / counts.mean():.2f}")
        emit(f"skew_broadcast_f{f}", t_bc * 1e6,
             f"imbalance={counts.max() / counts.mean():.2f}")

    # JCC-H vs TPC-H: partition imbalance (the paper's Fig 20 proxy: peak
    # memory tracks partition size under our static-capacity tables)
    sf = 0.005
    uni = tpch.generate(sf, seed=11)
    skw = jcch.generate(sf, seed=11, skew=0.3)
    for name, db in (("tpch", uni), ("jcch", skw)):
        # partition by the skewed FK (the paper's Fig 20 memory imbalance)
        parts, caps = B.partition_database(
            db, N, partition_keys={"lineitem": "l_partkey"})
        c = parts["lineitem"]["__count"]
        emit(f"{name}_lineitem_imbalance", float(c.max()) / float(c.mean()) * 100,
             f"max={int(c.max())};mean={c.mean():.0f};cap={caps['lineitem']}")
    # per-query (Fig 21): Q4 / Q13 under uniform vs skewed data
    for qid in (4, 13):
        for name, db in (("tpch", uni), ("jcch", skw)):
            def run():
                out, _, ov = B.run_distributed(QUERIES[qid], db, mesh,
                                               capacity_factor=4.0)
                assert not ov
                return out
            t = time_fn(lambda: run(), warmup=1, iters=2)
            emit(f"q{qid}_{name}_dist8", t * 1e6, "")


if __name__ == "__main__":
    main()

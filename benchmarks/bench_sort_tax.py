"""Sort-tax benchmark: HLO ``sort`` op counts + wall clock for representative
TPC-H local plans (Q1 scan-heavy, Q3 join+topk, Q6 pure scan, Q9 multi-join,
Q12 join+small-domain group), vs the seed engine's numbers.

The seed engine paid an O(cap log cap) argsort in every filter (compaction),
every join (build re-sort) and one argsort per ORDER BY key; phase 1 removed
most of it (deferred compaction / single-sort operators / build cache) and
phase 2 removed the rest of the hot-path sorts (direct-addressing group-bys
via ``key_bits``, counting-rank shuffle dispatch).  This benchmark guards
both phases against regression.  Run:

    PYTHONPATH=src python benchmarks/bench_sort_tax.py [--check] [--sf 0.01]

Writes ``BENCH_sort_tax.json`` at the repo root.  ``--check`` exits non-zero
unless every query's HLO sort count is within its ABSOLUTE budget
(``MAX_SORT_OPS`` — the phase-2 gate) and, where a true seed measurement
exists, down >= 40% vs the seed (the phase-1 gate).  Phase 3 (the logical
planner): queries compile through the builder+planner path with inference
pinned on, and the report additionally records the planner's own cost per
query (``plan_build_ms`` / ``plan_infer_ms`` — DAG construction and bound
propagation, both host-side and cached per database in production use).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import backend as B
from repro.core import planner as PL
from repro.core import relational as rel
from repro.core.table import Table
from repro.data import tpch
from repro.distributed.hlo_analysis import op_histogram
from repro.queries import PLANS, QUERIES

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "BENCH_sort_tax.json")

BENCH_QUERIES = (1, 3, 6, 9, 12, 13)

# Seed-engine numbers, measured at sf=0.01 seed=7 on the pre-optimization
# commit (eager compaction, per-key sort passes, per-join build sorts) with
# the same best-of-9 protocol used below.  q6/q12 were added for phase 2 and
# have no true seed measurement; their baseline is the phase-1 engine
# (PR 1: deferred compaction + single-sort operators + build cache).  q13 was
# added for the hash-compaction path: its baseline is the phase-3 engine,
# where the data-dependent c_count group-by still paid the single-sort path.
SEED_BASELINE = {
    "q1": {"sort_ops": 4, "wall_ms": 81.3},
    "q3": {"sort_ops": 10, "wall_ms": 140.0},
    "q9": {"sort_ops": 12, "wall_ms": 142.0},
    "q6": {"sort_ops": 1, "wall_ms": 19.5, "phase1": True},
    "q12": {"sort_ops": 3, "wall_ms": 35.1, "phase1": True},
    "q13": {"sort_ops": 3, "wall_ms": 8.4, "phase1": True},
}

MIN_SORT_DROP = 0.40

# Phase-2 absolute budgets (hinted group-bys sortless, dispatch sortless;
# q13's group-by stage sortless via the hash-compaction dictionary);
# keep in sync with tests/test_sort_tax.py::_MAX_SORTS.
MAX_SORT_OPS = {"q1": 1, "q3": 4, "q6": 0, "q9": 5, "q12": 2, "q13": 2}


def _plan_times(db, qid: int, iters: int = 9) -> tuple[float, float]:
    """(plan build ms, planner inference ms): the cost of the logical layer.

    Build = constructing the plan DAG from the builder; inference = bound
    propagation + hint derivation + placement validation (host-side, cached
    per database in production use — measured uncached here).
    """
    build_ts, infer_ts = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        root = PLANS[qid]()
        build_ts.append(time.perf_counter() - t0)
        PL.invalidate_stats(db)                       # measure cold inference
        t0 = time.perf_counter()
        PL.analyze(root, db)
        infer_ts.append(time.perf_counter() - t0)
    return min(build_ts) * 1e3, min(infer_ts) * 1e3


def _compile_and_time(db, tables, qid: int, join_method: str,
                      iters: int = 9):
    def run(tables):
        ctx = B.LocalContext(db, tables, join_method=join_method)
        # inference pinned ON: the gate measures the compiled planner path
        # regardless of the REPRO_PLANNER leg running the bench
        out = QUERIES[qid].run(ctx, infer=True)
        if isinstance(out, dict):
            out = Table({k: jnp.asarray(v).reshape(1) for k, v in out.items()},
                        jnp.asarray(1, jnp.int32))
        return rel.ensure_compact(out), ctx.overflow

    fn = jax.jit(run)
    compiled = fn.lower(tables).compile()
    nsort = op_histogram(compiled.as_text(), ops=("sort",))["sort"]
    jax.block_until_ready(fn(tables))          # warm
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(tables))
        ts.append(time.perf_counter() - t0)
    # best-of-N: the engines are deterministic, so min suppresses scheduler
    # noise that medians on a shared host do not
    return nsort, min(ts) * 1e3


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=0.01)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless every query meets its absolute"
                         " sort budget (and >= 40%% drop vs a true seed)")
    args = ap.parse_args()

    db = tpch.generate(args.sf, seed=args.seed)
    tables = B._np_db_to_tables(db)

    report = {"sf": args.sf, "seed_baseline": SEED_BASELINE, "queries": {}}
    ok = True
    for qid in BENCH_QUERIES:
        nsort, wall_ms = _compile_and_time(db, tables, qid, "sorted")
        _, wall_hash = _compile_and_time(db, tables, qid, "hash")
        build_ms, infer_ms = _plan_times(db, qid)
        seed = SEED_BASELINE[f"q{qid}"]
        budget = MAX_SORT_OPS[f"q{qid}"]
        drop = 1.0 - nsort / seed["sort_ops"]
        speedup = seed["wall_ms"] / wall_ms
        report["queries"][f"q{qid}"] = {
            "sort_ops": nsort,
            "max_sort_ops": budget,
            "seed_sort_ops": seed["sort_ops"],
            "sort_drop": round(drop, 3),
            "wall_ms": round(wall_ms, 2),
            "wall_ms_hash_join": round(wall_hash, 2),
            "seed_wall_ms": seed["wall_ms"],
            "speedup_vs_seed": round(speedup, 2),
            "plan_build_ms": round(build_ms, 3),
            "plan_infer_ms": round(infer_ms, 3),
        }
        ok &= nsort <= budget
        if not seed.get("phase1"):      # the 40% rule needs a true seed
            ok &= drop >= MIN_SORT_DROP
        print(f"q{qid}: sorts {seed['sort_ops']} -> {nsort} "
              f"({drop:.0%} drop, budget {budget}), wall {seed['wall_ms']:.1f}"
              f" -> {wall_ms:.1f} ms ({speedup:.2f}x)"
              f"  [hash-join {wall_hash:.1f} ms,"
              f" plan build {build_ms:.2f} ms + infer {infer_ms:.2f} ms]",
              flush=True)

    report["min_sort_drop"] = MIN_SORT_DROP
    report["max_sort_ops"] = MAX_SORT_OPS
    report["pass"] = bool(ok)
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {OUT_PATH}  pass={ok}")
    if args.check and not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

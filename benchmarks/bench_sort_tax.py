"""Sort-tax benchmark: HLO ``sort`` op counts + wall clock for representative
TPC-H local plans (Q1 scan-heavy, Q3 join+topk, Q9 multi-join), vs the seed
engine's numbers.

The seed engine paid an O(cap log cap) argsort in every filter (compaction),
every join (build re-sort) and one argsort per ORDER BY key; this benchmark
guards the deferred-compaction / single-sort / build-cache rework against
regression.  Run:

    PYTHONPATH=src python benchmarks/bench_sort_tax.py [--check] [--sf 0.01]

Writes ``BENCH_sort_tax.json`` at the repo root.  ``--check`` exits non-zero
unless every query's HLO sort count is down >= 40% vs the seed (the CI gate).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import backend as B
from repro.core import relational as rel
from repro.core.table import Table
from repro.data import tpch
from repro.distributed.hlo_analysis import op_histogram
from repro.queries import QUERIES

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "BENCH_sort_tax.json")

BENCH_QUERIES = (1, 3, 9)

# Seed-engine numbers, measured at sf=0.01 seed=7 on the pre-optimization
# commit (eager compaction, per-key sort passes, per-join build sorts) with
# the same best-of-9 protocol used below.
SEED_BASELINE = {
    "q1": {"sort_ops": 4, "wall_ms": 81.3},
    "q3": {"sort_ops": 10, "wall_ms": 140.0},
    "q9": {"sort_ops": 12, "wall_ms": 142.0},
}

MIN_SORT_DROP = 0.40


def _compile_and_time(db, tables, qid: int, join_method: str,
                      iters: int = 9):
    def run(tables):
        ctx = B.LocalContext(db, tables, join_method=join_method)
        out = QUERIES[qid](ctx)
        if isinstance(out, dict):
            out = Table({k: jnp.asarray(v).reshape(1) for k, v in out.items()},
                        jnp.asarray(1, jnp.int32))
        return rel.ensure_compact(out), ctx.overflow

    fn = jax.jit(run)
    compiled = fn.lower(tables).compile()
    nsort = op_histogram(compiled.as_text(), ops=("sort",))["sort"]
    jax.block_until_ready(fn(tables))          # warm
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(tables))
        ts.append(time.perf_counter() - t0)
    # best-of-N: the engines are deterministic, so min suppresses scheduler
    # noise that medians on a shared host do not
    return nsort, min(ts) * 1e3


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=0.01)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless sort drop >= 40%% per query")
    args = ap.parse_args()

    db = tpch.generate(args.sf, seed=args.seed)
    tables = B._np_db_to_tables(db)

    report = {"sf": args.sf, "seed_baseline": SEED_BASELINE, "queries": {}}
    ok = True
    for qid in BENCH_QUERIES:
        nsort, wall_ms = _compile_and_time(db, tables, qid, "sorted")
        _, wall_hash = _compile_and_time(db, tables, qid, "hash")
        seed = SEED_BASELINE[f"q{qid}"]
        drop = 1.0 - nsort / seed["sort_ops"]
        speedup = seed["wall_ms"] / wall_ms
        report["queries"][f"q{qid}"] = {
            "sort_ops": nsort,
            "seed_sort_ops": seed["sort_ops"],
            "sort_drop": round(drop, 3),
            "wall_ms": round(wall_ms, 2),
            "wall_ms_hash_join": round(wall_hash, 2),
            "seed_wall_ms": seed["wall_ms"],
            "speedup_vs_seed": round(speedup, 2),
        }
        ok &= drop >= MIN_SORT_DROP
        print(f"q{qid}: sorts {seed['sort_ops']} -> {nsort} "
              f"({drop:.0%} drop), wall {seed['wall_ms']:.1f} -> "
              f"{wall_ms:.1f} ms ({speedup:.2f}x)  [hash-join {wall_hash:.1f} ms]",
              flush=True)

    report["min_sort_drop"] = MIN_SORT_DROP
    report["pass"] = bool(ok)
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {OUT_PATH}  pass={ok}")
    if args.check and not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Distributed (8 virtual devices) tests — run in a subprocess so the
device-count XLA flag never leaks into the main test process."""
import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


_PRELUDE = """
import numpy as np, jax
from repro.core import backend as B
from repro.core.compat import make_mesh
from repro.data import tpch
from repro.queries import QUERIES
mesh = make_mesh((8,), ("data",))
db = tpch.generate(0.005, seed=11)
def check(qid, **kw):
    r_ref, _ = B.run_reference(QUERIES[qid], db)
    r_dist, stats, ov = B.run_distributed(QUERIES[qid], db, mesh,
                                          capacity_factor=3.0, **kw)
    assert not ov, f"q{qid} overflow"
    n = len(next(iter(r_ref.values())))
    for k in set(r_ref) & set(r_dist):
        assert len(r_dist[k]) == n, (qid, k, len(r_dist[k]), n)
        np.testing.assert_allclose(np.asarray(r_dist[k], np.float64),
                                   np.asarray(r_ref[k], np.float64),
                                   rtol=1e-7, err_msg=f"q{qid} {k}")
    return stats
"""


@pytest.mark.slow
def test_distributed_queries_exchange_heavy():
    """The exchange-heavy plans: shuffles, broadcasts, left join, allreduce."""
    out = _run(_PRELUDE + """
for qid in (1, 3, 9, 10, 13, 16, 18, 22):
    check(qid)
    print("q%d ok" % qid)
""")
    assert out.count("ok") == 8


@pytest.mark.slow
def test_distributed_all_22_planner_path():
    """Every builder plan runs SPMD and matches the NumPy reference, with
    runtime exchange counts equal to the IR-derived static counts — the full
    three-backend acceptance sweep for the planner path."""
    out = _run(_PRELUDE + """
for qid in sorted(QUERIES):
    stats = check(qid)
    assert stats.counts() == QUERIES[qid].static_counts(), (
        qid, stats.counts(), QUERIES[qid].static_counts())
    print("q%d ok" % qid)
""", timeout=2400)
    assert out.count("ok") == 22


@pytest.mark.slow
def test_distributed_wire_narrow_equals_wide_all22():
    """ISSUE 4 acceptance: the stats-narrowed wire format is byte-identical
    to the wide format on every query, on real 8-device exchanges, with no
    overflow, on BOTH planner legs (inference off -> no bounds -> narrow
    degenerates to wide by construction, asserted equal all the same)."""
    out = _run(_PRELUDE + """
# inference ON: all 22 plans; inference OFF: a sample — with no bounds the
# narrow format degenerates to wide by construction, so the interesting
# surface is the hinted leg
for infer, qids in ((True, sorted(QUERIES)), (False, [1, 5, 9, 13, 18])):
    for qid in qids:
        q = QUERIES[qid].with_inference(infer)
        r_n, s_n, ov_n = B.run_distributed(q, db, mesh, capacity_factor=3.0,
                                           wire_format="narrow")
        r_w, s_w, ov_w = B.run_distributed(q, db, mesh, capacity_factor=3.0,
                                           wire_format="wide")
        assert not ov_n and not ov_w, (qid, infer)
        assert set(r_n) == set(r_w), (qid, infer)
        for k in r_n:
            np.testing.assert_array_equal(r_n[k], r_w[k],
                                          err_msg="q%d %s" % (qid, k))
        if infer:
            assert sum(e.message_bytes for e in s_n.log) <= \
                sum(e.message_bytes for e in s_w.log), qid
        print("q%d infer=%s ok" % (qid, infer))
""", timeout=4800)
    assert out.count("ok") == 27


@pytest.mark.slow
def test_distributed_wire_stats_match_static_all22():
    """Runtime ExchangeStats wire descriptors == the IR derivation on the
    distributed backend, all 22 queries (Ref/Local legs are fast tests)."""
    out = _run(_PRELUDE + """
from repro.core import planner as PL
for qid in sorted(QUERIES):
    _, stats, ov = B.run_distributed(QUERIES[qid], db, mesh,
                                     capacity_factor=3.0,
                                     wire_format="narrow")
    assert not ov, qid
    got = [(e.kind, e.wire, e.row_wire_bytes, e.row_logical_bytes)
           for e in stats.log]
    want = [(d["kind"], d["wire"], d["row_wire_bytes"],
             d["row_logical_bytes"])
            for d in QUERIES[qid].static_wire(db, narrow=True)]
    assert got == want, (qid, got, want)
    print("q%d ok" % qid)
""", timeout=2400)
    assert out.count("ok") == 22


@pytest.mark.slow
def test_distributed_per_column_exchange_matches_packed():
    """Paper-faithful per-column exchange == packed fused exchange."""
    _run(_PRELUDE + """
s_packed = check(9, packed_exchange=True)
s_col = check(9, packed_exchange=False)
# same logical plan, more collectives in per-column mode
packed_ops = sum(e.collectives for e in s_packed.log)
col_ops = sum(e.collectives for e in s_col.log)
assert col_ops > packed_ops, (col_ops, packed_ops)
print("collectives packed=%d per-column=%d" % (packed_ops, col_ops))
""")


@pytest.mark.slow
def test_distributed_broadcast_p2p_variant():
    """§7.1: p2p-emulated broadcast gives identical results (and more traffic)."""
    _run(_PRELUDE + """
import jax.numpy as jnp
from repro.core.table import Database
def q(ctx):
    c = ctx.scan("customer")
    cb = ctx.broadcast(ctx.select(c, "c_custkey", "c_acctbal"), p2p=True)
    g = ctx.group_by(cb, ["c_custkey"], [("n", "count", None)],
                     exchange="local")
    s = ctx.agg_scalar(g, [("total", "sum", "n")])
    return {"total": s["total"]}
r_ref, _ = B.run_reference(q, db)
r_dist, stats, ov = B.run_distributed(q, db, mesh)
# broadcast replicates: every device sees all customers exactly once
assert int(r_dist["total"][0]) == 8 * int(r_ref["total"][0]), (r_dist, r_ref)
kinds = [e.kind for e in stats.log]
assert "broadcast_p2p" in kinds
print("p2p broadcast ok", kinds)
""")


@pytest.mark.slow
def test_skewed_jcch_runs_and_matches():
    """JCC-H skew: correctness preserved, skew visible in partition sizes."""
    _run("""
import numpy as np, jax
from repro.core import backend as B
from repro.core.compat import make_mesh
from repro.data import jcch
from repro.queries import QUERIES
mesh = make_mesh((8,), ("data",))
db = jcch.generate(0.005, seed=11, skew=0.3)
# partitioning by the SKEWED foreign key exposes the imbalance the paper's
# Fig 20 reports (unique-PK partitioning stays balanced by construction)
parts, _ = B.partition_database(db, 8,
                                partition_keys={"lineitem": "l_partkey"})
counts = parts["lineitem"]["__count"]
imb = counts.max() / counts.mean()
uni = jcch.generate(0.005, seed=11, skew=0.0)
parts_u, _ = B.partition_database(uni, 8,
                                  partition_keys={"lineitem": "l_partkey"})
cu = parts_u["lineitem"]["__count"]
imb_u = cu.max() / cu.mean()
assert imb > imb_u + 0.05, (imb, imb_u)
for qid in (4, 13):
    r_ref, _ = B.run_reference(QUERIES[qid], db)
    r_dist, _, ov = B.run_distributed(QUERIES[qid], db, mesh,
                                      capacity_factor=4.0)
    assert not ov
    for k in set(r_ref) & set(r_dist):
        np.testing.assert_allclose(np.asarray(r_dist[k], np.float64),
                                   np.asarray(r_ref[k], np.float64), rtol=1e-7)
print("jcch ok, lineitem imbalance=%.2f" % imb)
""")


@pytest.mark.slow
def test_fault_runner_escalates_capacity():
    _run("""
import numpy as np, jax
from repro.core import backend as B
from repro.core.compat import make_mesh
from repro.data import tpch
from repro.distributed.fault import QueryRunner
from repro.queries import QUERIES
mesh = make_mesh((8,), ("data",))
db = tpch.generate(0.005, seed=11)
# absurdly small starting factor forces overflow -> escalation
runner = QueryRunner(db, mesh, capacity_factor=0.05, max_attempts=8)
res = runner.run(QUERIES[13])
assert res.attempts > 1, "expected at least one overflow retry"
r_ref, _ = B.run_reference(QUERIES[13], db)
np.testing.assert_allclose(np.asarray(res.result["custdist"], np.float64),
                           np.asarray(r_ref["custdist"], np.float64))
print("fault runner ok: attempts=%d factor=%.2f" % (res.attempts,
                                                    res.capacity_factor))
""")


@pytest.mark.slow
def test_sf1000_plan_compiles():
    """The paper's workload at SF=1000 lowers+compiles (shape-only)."""
    _run("""
import jax, numpy as np
from repro.core.compat import make_mesh
from repro.data import tpch
from repro.launch import dryrun_analytics as da
db = tpch.generate(0.001, seed=7)
db.scale = 1000.0
mesh = make_mesh((8,), ("data",))
rec = da.dryrun_query(6, db, mesh)
assert rec["plan"]["allreduces"] == 1
assert rec["hlo_bytes"] > 0
rec9 = da.dryrun_query(9, db, mesh)
assert rec9["plan"]["shuffles"] == 1 and rec9["plan"]["broadcasts"] == 2
print("sf1000 compile ok: q6 m=%.1fms q9 m=%.1fms" % (
    rec["roofline"]["memory_s"]*1e3, rec9["roofline"]["memory_s"]*1e3))
""", timeout=1200)

"""Checkpointing (atomic, checksummed, elastic) + fault/skew utilities."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.distributed import checkpoint as ckpt
from repro.distributed.fault import salt_hot_keys, skew_imbalance
from repro.core.perfmodel import CLUSTERS
from repro.distributed.fault import choose_exchange


@pytest.fixture
def tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path, tree):
    d = str(tmp_path / "ck")
    ckpt.save(d, 10, tree, {"note": "x"})
    assert ckpt.latest_step(d) == 10
    out, meta = ckpt.restore(d, 10, tree)
    assert meta == {"note": "x"}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checksum_detects_corruption(tmp_path, tree):
    d = str(tmp_path / "ck")
    path = ckpt.save(d, 1, tree)
    victim = os.path.join(path, "000000.npy")
    with open(victim, "r+b") as f:
        f.seek(-1, 2)
        f.write(b"\x7f")
    with pytest.raises(IOError):
        ckpt.restore(d, 1, tree)


def test_manager_keeps_last_k(tmp_path, tree):
    mgr = ckpt.CheckpointManager(str(tmp_path / "ck"), keep_last=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    steps = sorted(os.listdir(str(tmp_path / "ck")))
    assert steps == ["step_0000000003", "step_0000000004"]
    step, out, _ = mgr.restore_latest(tree)
    assert step == 4 and out is not None


def test_async_save(tmp_path, tree):
    mgr = ckpt.CheckpointManager(str(tmp_path / "ck"), async_save=True)
    mgr.save(5, tree)
    mgr.wait()
    assert ckpt.latest_step(str(tmp_path / "ck")) == 5


def test_elastic_reshard_restore(tmp_path, tree):
    """Restore onto explicit shardings (the elastic shrink/grow path).

    Single-device here, but exercises the device_put-with-sharding branch."""
    d = str(tmp_path / "ck")
    ckpt.save(d, 2, tree)
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("data",))
    sh = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec()), tree)
    out, _ = ckpt.restore(d, 2, tree, shardings=sh)
    for leaf in jax.tree.leaves(out):
        assert leaf.sharding.mesh.shape["data"] == 1


def test_salt_hot_keys_spreads_hot_population():
    keys = np.concatenate([np.full(1000, 7, dtype=np.int64),
                           np.arange(100, dtype=np.int64) + 100])
    salted = salt_hot_keys(keys, 8)
    hot = salted[keys == 7]
    assert len(np.unique(hot % 8)) == 8       # hot key spread over all salts
    cold = salted[keys != 7]
    np.testing.assert_array_equal(np.unique(cold), np.unique(keys[keys != 7]))


def test_skew_imbalance_per_node():
    counts = np.array([10, 10, 10, 10, 40, 10, 10, 10])
    assert skew_imbalance(counts, k=1) == pytest.approx(40 / 13.75)
    # grouping into nodes of 4 hides intra-node skew
    assert skew_imbalance(counts, k=4) == pytest.approx(70 / 55)


def test_choose_exchange_uses_eq3():
    h100 = CLUSTERS["h100_ib"]
    assert choose_exchange(h100, 1, 1e9, 10e9) == "broadcast"
    assert choose_exchange(h100, 16, 1e9, 10e9) == "shuffle"

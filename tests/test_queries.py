"""All 22 TPC-H queries: JAX engine vs NumPy reference.

Plan-statistics assertions (paper Table 4, static + runtime) live in
tests/test_plan_stats.py; planner differentials in tests/test_planner.py.
"""
import numpy as np
import pytest

from repro.core import backend as B
from repro.data import tpch
from repro.queries import QUERIES


@pytest.fixture(scope="module")
def db():
    return tpch.generate(0.005, seed=11)


def _compare(r_got, r_want, qid, label):
    keys = set(r_got) & set(r_want)
    assert keys, f"q{qid}: no common output columns"
    n = len(next(iter(r_want.values())))
    for k in sorted(keys):
        assert len(r_got[k]) == n, f"q{qid} {label} {k}: row count"
        np.testing.assert_allclose(
            np.asarray(r_got[k], dtype=np.float64),
            np.asarray(r_want[k], dtype=np.float64),
            rtol=1e-7, err_msg=f"q{qid} {label} {k}")


@pytest.mark.parametrize("qid", sorted(QUERIES))
def test_query_local_vs_reference(db, qid):
    r_ref, _ = B.run_reference(QUERIES[qid], db)
    r_loc, _ = B.run_local(QUERIES[qid], db)
    _compare(r_loc, r_ref, qid, "local")

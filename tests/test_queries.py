"""All 22 TPC-H queries: JAX engine vs NumPy reference + plan statistics."""
import numpy as np
import pytest

from repro.core import backend as B
from repro.data import tpch
from repro.queries import PAPER_TABLE4, QUERIES


@pytest.fixture(scope="module")
def db():
    return tpch.generate(0.005, seed=11)


def _compare(r_got, r_want, qid, label):
    keys = set(r_got) & set(r_want)
    assert keys, f"q{qid}: no common output columns"
    n = len(next(iter(r_want.values())))
    for k in sorted(keys):
        assert len(r_got[k]) == n, f"q{qid} {label} {k}: row count"
        np.testing.assert_allclose(
            np.asarray(r_got[k], dtype=np.float64),
            np.asarray(r_want[k], dtype=np.float64),
            rtol=1e-7, err_msg=f"q{qid} {label} {k}")


@pytest.mark.parametrize("qid", sorted(QUERIES))
def test_query_local_vs_reference(db, qid):
    r_ref, _ = B.run_reference(QUERIES[qid], db)
    r_loc, _ = B.run_local(QUERIES[qid], db)
    _compare(r_loc, r_ref, qid, "local")


@pytest.mark.parametrize("qid", sorted(QUERIES))
def test_plan_exchange_counts_match_paper(db, qid):
    """Our plans reproduce paper Table 4 (Q11 deviates; see DESIGN.md)."""
    _, stats = B.run_reference(QUERIES[qid], db)
    shuffles, broadcasts = PAPER_TABLE4[qid]
    if qid == 11:
        assert (stats.shuffles, stats.broadcasts) == (0, 1)
        return
    assert stats.shuffles == shuffles, \
        f"q{qid}: {stats.shuffles} shuffles != paper {shuffles}"
    if broadcasts is not None:
        assert stats.broadcasts == broadcasts, \
            f"q{qid}: {stats.broadcasts} broadcasts != paper {broadcasts}"


def test_exchange_counts_identical_across_backends(db):
    for qid in (1, 9, 13, 18):
        _, s_ref = B.run_reference(QUERIES[qid], db)
        _, s_loc = B.run_local(QUERIES[qid], db)
        assert s_ref.counts() == s_loc.counts(), qid

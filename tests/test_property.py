"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import reference as REF
from repro.core import relational as R
from repro.core.backend import hash_partition_np
from repro.core.exchange import pack_columns, unpack_columns
from repro.core.table import Table, from_numpy, to_numpy

_small = st.integers(min_value=1, max_value=60)


@st.composite
def tables(draw):
    n = draw(_small)
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    return {
        "k": rng.integers(0, draw(st.integers(1, 20)), n).astype(np.int64),
        "v": rng.normal(size=n),
        "b": rng.integers(0, 2, n).astype(bool),
        "i": rng.integers(-1000, 1000, n).astype(np.int32),
    }


@settings(max_examples=25, deadline=None)
@given(tables())
def test_group_sum_preserved_under_grouping(cols):
    """sum over groups == total sum (conservation)."""
    t = from_numpy(cols, capacity=max(8, len(cols["k"]) + 5))
    g = R.group_aggregate(t, ["k"], [("s", "sum", "v")])
    got = to_numpy(g)
    np.testing.assert_allclose(got["s"].sum(), cols["v"].sum(), rtol=1e-9)
    # group count == distinct keys
    assert len(got["s"]) == len(np.unique(cols["k"]))


@settings(max_examples=25, deadline=None)
@given(tables(), st.integers(0, 19))
def test_filter_compact_invariant(cols, thresh):
    """After filter: count == mask sum, and all valid rows satisfy the mask."""
    t = from_numpy(cols, capacity=max(8, len(cols["k"]) + 3))
    f = R.filter_rows(t, t["k"] < thresh)
    got = to_numpy(f)
    assert (got["k"] < thresh).all()
    assert len(got["k"]) == int((cols["k"] < thresh).sum())


@settings(max_examples=25, deadline=None)
@given(tables())
def test_pack_unpack_roundtrip(cols):
    """Column packing for the fused exchange is lossless for every dtype.

    Wide format: every row round-trips verbatim with a statically-False
    overflow flag (narrow-format properties live in tests/test_wire.py)."""
    t = from_numpy(cols, capacity=max(8, len(cols["k"])))
    buf, fmt, overflow = pack_columns(t, narrow=False)
    assert buf.dtype == jnp.int32
    assert not bool(overflow)
    back = unpack_columns(buf, fmt)
    for name in t.names:
        np.testing.assert_array_equal(np.asarray(back[name]),
                                      np.asarray(t[name]))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 2**40), min_size=1, max_size=50),
       st.integers(1, 16))
def test_hash_partition_host_device_agree(keys, n):
    """Host partitioner (data loading) must agree with the in-jit hash
    (shuffle destinations) or co-partitioned joins would silently break."""
    k = np.asarray(keys, dtype=np.int64)
    host = hash_partition_np(k, n)
    dev = np.asarray(R.hash_partition_ids(jnp.asarray(k), n))
    np.testing.assert_array_equal(host, dev)


@settings(max_examples=20, deadline=None)
@given(tables())
def test_sort_matches_reference(cols):
    t = from_numpy(cols, capacity=max(8, len(cols["k"]) + 2))
    got = to_numpy(R.sort_by(t, [("k", True), ("i", False)]))
    want = REF.sort_by(cols, [("k", True), ("i", False)])
    np.testing.assert_array_equal(got["k"], want["k"])
    np.testing.assert_array_equal(got["i"], want["i"])


# ---------------------------------------------------------------------------
# sortless (direct-addressing) aggregation vs the NumPy oracle
# ---------------------------------------------------------------------------

_AGGS = [("s", "sum", "v"), ("c", "count", None),
         ("mn", "min", "i"), ("mx", "max", "i")]


def _check_direct_vs_oracle(cols, bits, use_kernel):
    """Direct path over a padded/masked table == np.unique-based oracle."""
    n = len(cols["k"])
    t = from_numpy(cols, capacity=max(8, n + 7))
    got = to_numpy(R.group_aggregate(t, ["k"], _AGGS, key_bits=[bits],
                                     method="direct", use_kernel=use_kernel))
    want = REF.group_aggregate(cols, ["k"], _AGGS)
    assert len(got["k"]) == len(want["k"])
    np.testing.assert_array_equal(got["k"], want["k"])
    np.testing.assert_allclose(got["s"], want["s"], rtol=1e-9, atol=1e-12)
    np.testing.assert_array_equal(got["c"], want["c"])
    np.testing.assert_array_equal(got["mn"], want["mn"])
    np.testing.assert_array_equal(got["mx"], want["mx"])


@settings(max_examples=25, deadline=None)
@given(tables(), st.integers(5, 10), st.booleans())
def test_direct_aggregate_matches_reference(cols, bits, use_kernel):
    """Random tables + random (honest) domain hints: the sortless path must
    agree with the NumPy oracle for all four ops — k < 20 <= 2^5 always fits,
    wider random hints exercise empty-slot compaction."""
    _check_direct_vs_oracle(cols, bits, use_kernel)


# ---------------------------------------------------------------------------
# hash-compaction dictionary insert vs the NumPy oracle (capacity boundary)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(1, 120), st.integers(1, 24), st.integers(0, 2**31),
       st.booleans(), st.booleans())
def test_dict_insert_matches_oracle_at_capacity_boundary(n, distinct, seed,
                                                         use_kernel, exact):
    """The insert-or-lookup dictionary must agree with np.unique for any key
    set that fits: same distinct keys, a consistent slot per key, and rank
    ids identical to the oracle's ascending order.  ``exact`` pins the
    dictionary to EXACTLY the distinct-key count (tiny caps scan every slot,
    so a 100% load factor must still resolve); otherwise the default 2x
    headroom applies.  Negative and 40-bit keys exercise both planes."""
    from repro.kernels.hash_group import ops as HG
    from repro.kernels.hash_group.ref import group_ids_np
    rng = np.random.default_rng(seed)
    domain = rng.integers(-(1 << 40), 1 << 40, distinct).astype(np.int64)
    keys = domain[rng.integers(0, distinct, n)]
    valid = rng.random(n) > 0.25
    uniq = np.unique(keys[valid])
    cap = max(1, len(uniq)) if exact else HG.dict_capacity(len(uniq))
    slot, dkeys, occ, unres = HG.build_group_dict(
        jnp.asarray(keys), jnp.asarray(valid), cap, use_kernel=use_kernel)
    slot, dkeys, occ = map(np.asarray, (slot, dkeys, occ))
    assert not bool(unres)
    assert sorted(dkeys[occ].tolist()) == uniq.tolist()
    assert (slot[valid] >= 0).all()
    np.testing.assert_array_equal(dkeys[slot[valid]], keys[valid])
    rank = np.asarray(HG.dict_rank(jnp.asarray(dkeys), jnp.asarray(occ)))
    gid_oracle, _ = group_ids_np(keys, valid)
    np.testing.assert_array_equal(rank[slot[valid]], gid_oracle[valid])


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 40), st.integers(0, 2**31), st.booleans())
def test_dict_insert_overflow_is_flagged_not_silent(extra, seed, use_kernel):
    """More distinct keys than slots: the flag MUST fire, and every resolved
    row must still point at its own key (unplaced rows are -1, never
    misassigned)."""
    from repro.kernels.hash_group import ops as HG
    cap = 16
    rng = np.random.default_rng(seed)
    keys = rng.permutation((np.arange(cap + extra) * 7919).astype(np.int64))
    slot, dkeys, occ, unres = HG.build_group_dict(
        jnp.asarray(keys), jnp.ones(len(keys), bool), cap,
        use_kernel=use_kernel)
    slot, dkeys, occ = map(np.asarray, (slot, dkeys, occ))
    assert bool(unres)
    placed = slot >= 0
    np.testing.assert_array_equal(dkeys[slot[placed]], keys[placed])
    assert occ.sum() <= cap


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 200), st.integers(0, 2**31), st.booleans())
def test_direct_aggregate_jcch_skewed_keys(n, seed, use_kernel):
    """JCC-H-style heavy hitters: one hot key owns ~half the rows and sits at
    the TOP of the claimed domain (2^bits - 1), so the hot group is adjacent
    to the kernel's padding/dead-group slot — any off-by-one in dead-slot
    routing leaks the hot group's mass."""
    bits = 7
    hot = (1 << bits) - 1
    rng = np.random.default_rng(seed)
    k = rng.integers(0, 1 << bits, n).astype(np.int64)
    k[rng.random(n) < 0.5] = hot                     # redirect to the hot key
    cols = {"k": k, "v": rng.normal(size=n),
            "i": rng.integers(-1000, 1000, n).astype(np.int64)}
    _check_direct_vs_oracle(cols, bits, use_kernel)

"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import reference as REF
from repro.core import relational as R
from repro.core.backend import hash_partition_np
from repro.core.exchange import pack_columns, unpack_columns
from repro.core.table import Table, from_numpy, to_numpy

_small = st.integers(min_value=1, max_value=60)


@st.composite
def tables(draw):
    n = draw(_small)
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    return {
        "k": rng.integers(0, draw(st.integers(1, 20)), n).astype(np.int64),
        "v": rng.normal(size=n),
        "b": rng.integers(0, 2, n).astype(bool),
        "i": rng.integers(-1000, 1000, n).astype(np.int32),
    }


@settings(max_examples=25, deadline=None)
@given(tables())
def test_group_sum_preserved_under_grouping(cols):
    """sum over groups == total sum (conservation)."""
    t = from_numpy(cols, capacity=max(8, len(cols["k"]) + 5))
    g = R.group_aggregate(t, ["k"], [("s", "sum", "v")])
    got = to_numpy(g)
    np.testing.assert_allclose(got["s"].sum(), cols["v"].sum(), rtol=1e-9)
    # group count == distinct keys
    assert len(got["s"]) == len(np.unique(cols["k"]))


@settings(max_examples=25, deadline=None)
@given(tables(), st.integers(0, 19))
def test_filter_compact_invariant(cols, thresh):
    """After filter: count == mask sum, and all valid rows satisfy the mask."""
    t = from_numpy(cols, capacity=max(8, len(cols["k"]) + 3))
    f = R.filter_rows(t, t["k"] < thresh)
    got = to_numpy(f)
    assert (got["k"] < thresh).all()
    assert len(got["k"]) == int((cols["k"] < thresh).sum())


@settings(max_examples=25, deadline=None)
@given(tables())
def test_pack_unpack_roundtrip(cols):
    """Column packing for the fused exchange is lossless for every dtype."""
    t = from_numpy(cols, capacity=max(8, len(cols["k"])))
    buf, spec = pack_columns(t)
    assert buf.dtype == jnp.int32
    back = unpack_columns(buf, spec)
    for name in t.names:
        np.testing.assert_array_equal(np.asarray(back[name]),
                                      np.asarray(t[name]))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 2**40), min_size=1, max_size=50),
       st.integers(1, 16))
def test_hash_partition_host_device_agree(keys, n):
    """Host partitioner (data loading) must agree with the in-jit hash
    (shuffle destinations) or co-partitioned joins would silently break."""
    k = np.asarray(keys, dtype=np.int64)
    host = hash_partition_np(k, n)
    dev = np.asarray(R.hash_partition_ids(jnp.asarray(k), n))
    np.testing.assert_array_equal(host, dev)


@settings(max_examples=20, deadline=None)
@given(tables())
def test_sort_matches_reference(cols):
    t = from_numpy(cols, capacity=max(8, len(cols["k"]) + 2))
    got = to_numpy(R.sort_by(t, [("k", True), ("i", False)]))
    want = REF.sort_by(cols, [("k", True), ("i", False)])
    np.testing.assert_array_equal(got["k"], want["k"])
    np.testing.assert_array_equal(got["i"], want["i"])

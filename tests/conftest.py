import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# NOTE: XLA_FLAGS / device-count overrides are NOT set here — smoke tests and
# benches must see the real single device.  Multi-device tests spawn
# subprocesses that set XLA_FLAGS before importing jax.

# The statistical approx suite (tests/test_approx.py) is pinned to one seed:
# the empirical coverage rates it asserts are exact deterministic numbers at
# this seed, not flaky draws.  Change the seed only together with the
# documented binomial-slack analysis in that file.
APPROX_SEED = 20260807


@pytest.fixture(scope="session")
def approx_seed():
    return APPROX_SEED

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# NOTE: XLA_FLAGS / device-count overrides are NOT set here — smoke tests and
# benches must see the real single device.  Multi-device tests spawn
# subprocesses that set XLA_FLAGS before importing jax.

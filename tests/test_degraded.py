"""Elastic degraded-mesh execution (ISSUE 9): device-loss taxonomy,
topology-shrink recovery, snapshot re-sharding, jittered backoff, overall
deadlines, and capacity-aware serving.

Fast lane: everything here runs on the default single-device CPU backend —
the real 8-device shrink sweeps live in ``tests/test_device_loss_sweep.py``
(slow, subprocess)."""
import os

import numpy as np
import pytest

from repro.core import backend as B
from repro.core import perfmodel as pm
from repro.core.compat import make_mesh
from repro.data import tpch
from repro.distributed import lineage as ln
from repro.distributed.chaos import (ChaosInjector, DeviceLost, FailureKind,
                                     FaultPlan, FaultSpec, chaos_env_lost,
                                     resolve_lost)
from repro.distributed.fault import (QueryRunner, QueryTimeout, RetryPolicy,
                                     classify_failure, surviving_mesh)
from repro.distributed.lineage import LineageStore, run_resumable
from repro.queries import QUERIES
from repro.serve import AdmissionGate, Degraded, QueryServer, Served, Shed


@pytest.fixture(scope="module")
def db():
    return tpch.generate(0.002, seed=11)


# ---------------------------------------------------------------------------
# taxonomy + fault plumbing
# ---------------------------------------------------------------------------

def test_device_lost_classification():
    assert classify_failure(DeviceLost("gone")) is FailureKind.DEVICE_LOST
    assert FailureKind.DEVICE_LOST.value == "device_lost"


def test_fault_spec_device_lost_validation():
    FaultSpec("device_lost", devices=(0, 3))          # fine
    FaultSpec("device_lost", n_lost=2)                # fine
    with pytest.raises(ValueError):
        FaultSpec("device_lost", devices=(-1,))
    with pytest.raises(ValueError):
        FaultSpec("device_lost", n_lost=0)


def test_resolve_lost_deterministic_and_survivor_preserving():
    e = DeviceLost("x", n_lost=3, seed=42)
    a = resolve_lost(e, 8)
    assert a == resolve_lost(e, 8)                    # seeded: reproducible
    assert len(a) == 3 and len(set(a)) == 3
    assert all(0 <= d < 8 for d in a)
    # different seed, (almost surely) different ranks
    assert a != resolve_lost(DeviceLost("x", n_lost=3, seed=43), 8) or True
    # explicit ranks clip to the mesh
    assert resolve_lost(DeviceLost("x", lost=(2, 11)), 8) == (2,)
    # never the whole mesh: at least one survivor
    assert len(resolve_lost(DeviceLost("x", n_lost=64, seed=1), 8)) == 7
    assert resolve_lost(DeviceLost("x", n_lost=5, seed=1), 1) == ()


def test_chaos_env_lost_grammar(monkeypatch):
    monkeypatch.setenv("REPRO_CHAOS", "9,lose=3")
    assert chaos_env_lost() == ((3,), "exchange")
    monkeypatch.setenv("REPRO_CHAOS", "9,lose=1+4+6@scan")
    assert chaos_env_lost() == ((1, 4, 6), "scan")
    monkeypatch.setenv("REPRO_CHAOS", "9")
    assert chaos_env_lost() is None
    monkeypatch.setenv("REPRO_CHAOS", "9,drop=3")
    with pytest.raises(ValueError):
        chaos_env_lost()
    # lose= arms a device-loss plan end to end
    monkeypatch.setenv("REPRO_CHAOS", "9,lose=3@scan")
    inj = ChaosInjector.from_env()
    assert inj is not None
    assert inj.plan.faults[0].kind == "device_lost"
    assert inj.plan.faults[0].devices == (3,)
    assert inj.plan.faults[0].cut == "scan"


def test_surviving_mesh_single_device_has_no_survivors():
    mesh = make_mesh((1,), ("data",))
    with pytest.raises(ValueError):
        surviving_mesh(mesh, (0,), "data")


# ---------------------------------------------------------------------------
# satellite: seeded decorrelated jitter
# ---------------------------------------------------------------------------

def test_backoff_without_jitter_is_exact_exponential():
    p = RetryPolicy(backoff_s=0.1, backoff_mult=2.0, max_backoff_s=0.5)
    assert [p.backoff(i) for i in (1, 2, 3, 4)] == [0.1, 0.2, 0.4, 0.5]


def test_backoff_jitter_deterministic_bounded_decorrelated():
    p = RetryPolicy(backoff_s=0.05, max_backoff_s=2.0, jitter=True, seed=7)
    seq = [p.backoff(i) for i in (1, 2, 3, 4, 5)]
    assert seq == [p.backoff(i) for i in (1, 2, 3, 4, 5)]   # deterministic
    assert all(p.backoff_s <= s <= p.max_backoff_s for s in seq)
    # decorrelated-jitter bound: each sleep <= 3x the previous one
    prev = p.backoff_s
    for s in seq:
        assert s <= min(p.max_backoff_s, max(p.backoff_s, 3.0 * prev)) + 1e-12
        prev = s
    # two seeds de-synchronize (the whole point: no retry storms)
    q = RetryPolicy(backoff_s=0.05, max_backoff_s=2.0, jitter=True, seed=8)
    assert seq != [q.backoff(i) for i in (1, 2, 3, 4, 5)]
    # jitter armed but no seed anywhere: falls back to exact exponential
    r = RetryPolicy(backoff_s=0.05, jitter=True)
    assert r.backoff(2) == 0.1


# ---------------------------------------------------------------------------
# satellite: overall wall-clock deadline
# ---------------------------------------------------------------------------

def test_query_timeout_carries_partial_report(db):
    mesh = make_mesh((1,), ("data",))
    # transient faults on every attempt; the overall deadline expires after
    # the first failure, long before the 4-attempt budget
    inj = ChaosInjector(FaultPlan(3, tuple(
        FaultSpec("transient", cut="scan", attempt=a) for a in (1, 2, 3))))
    runner = QueryRunner(db, mesh, chaos=inj, deadline_s=0.0,
                         policy=RetryPolicy(max_attempts=4, backoff_s=0.0))
    with pytest.raises(QueryTimeout) as ei:
        runner.run(QUERIES[1])
    rep = ei.value.report
    assert rep.outcomes() == ["transient"]            # partial audit trail
    assert "deadline" in str(ei.value)


def test_no_deadline_keeps_full_attempt_budget(db):
    mesh = make_mesh((1,), ("data",))
    inj = ChaosInjector(FaultPlan(3, (
        FaultSpec("transient", cut="scan", attempt=1),)))
    runner = QueryRunner(db, mesh, chaos=inj,
                         policy=RetryPolicy(max_attempts=3, backoff_s=0.0))
    res = runner.run(QUERIES[1])
    assert res.report.outcomes() == ["transient", "ok"]


# ---------------------------------------------------------------------------
# re-shard: stacked-layout round trips (all width pairs, plus hypothesis)
# ---------------------------------------------------------------------------

def _stacked(rng, nrows, n, key_range=1000):
    one = {"k": rng.integers(0, key_range, nrows).astype(np.int64),
           "v": rng.standard_normal(nrows),
           "f": rng.integers(0, 2, nrows).astype(bool),
           "__count": np.array([nrows], np.int32)}
    return ln.reshard(one, 1, n, "k")


@pytest.mark.parametrize("n_from,n_to", [(n, m) for n in range(1, 9)
                                         for m in range(1, 9) if n != m])
def test_reshard_round_trips_all_width_pairs(n_from, n_to):
    """N -> N' -> N is byte-identical for every divisor AND non-divisor pair
    up to 8 — including masked/empty partitions (tiny row counts leave some
    shards empty)."""
    rng = np.random.default_rng(n_from * 10 + n_to)
    for nrows in (0, 3, 57):          # 0 and 3 rows: empty partitions
        a = _stacked(rng, nrows, n_from)
        b = ln.reshard(a, n_from, n_to, "k")
        c = ln.reshard(b, n_to, n_from, "k")
        assert set(a) == set(c)
        for k in a:
            assert a[k].dtype == c[k].dtype, k
            assert np.array_equal(a[k], c[k]), (k, nrows)
        # conservation: no rows appear or vanish
        assert b["__count"].sum() == a["__count"].sum() == (nrows or 0)


def test_reshard_rowid_restores_global_order():
    rng = np.random.default_rng(0)
    nrows = 41
    one = {"k": rng.integers(0, 100, nrows).astype(np.int64),
           "v": rng.standard_normal(nrows),
           "__count": np.array([nrows], np.int32)}
    a = ln.reshard(one, 1, 7, "k")
    g = ln.unshard(a, 7)
    assert np.array_equal(g["__rowid"], np.arange(nrows))
    assert np.array_equal(g["k"], one["k"])
    assert np.array_equal(g["v"], one["v"])


def test_reshard_replicated_and_errors():
    rng = np.random.default_rng(1)
    one = {"k": rng.integers(0, 9, 10).astype(np.int64),
           "__count": np.array([10], np.int32)}
    rep = ln.reshard(one, 1, 4, None)          # replicated: whole table x4
    assert np.array_equal(rep["__count"], np.full(4, 10, np.int32))
    with pytest.raises(ValueError):
        ln.reshard(one, 1, 0, "k")
    with pytest.raises(ValueError):
        ln.unshard({"k": np.zeros(8, np.int64),
                    "__count": np.array([9], np.int32)}, 1)  # count > cap


def test_reshard_property_hypothesis():
    """Hypothesis leg of the satellite: random tables, random width pairs,
    byte-identical round trips."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 80), st.integers(1, 8), st.integers(1, 8),
           st.integers(0, 2**31 - 1))
    def prop(nrows, n_from, n_to, seed):
        rng = np.random.default_rng(seed)
        a = _stacked(rng, nrows, n_from)
        c = ln.reshard(ln.reshard(a, n_from, n_to, "k"), n_to, n_from, "k")
        for k in a:
            assert np.array_equal(a[k], c[k])

    prop()


# ---------------------------------------------------------------------------
# lineage: width-elastic snapshot adoption
# ---------------------------------------------------------------------------

def _populate(db, store, qid, n_devices):
    inj = ChaosInjector(FaultPlan(qid, (
        FaultSpec("transient", cut="finalize", attempt=1),)))
    with pytest.raises(Exception):
        run_resumable(QUERIES[qid], db, store, chaos=inj,
                      n_devices=n_devices)
    assert store.saved >= 1


def test_lineage_resume_across_widths_byte_identical(db, tmp_path):
    """Snapshots written at width 8 are adopted by a width-5 resume (the
    re-shard rule) and the answer is byte-identical to a clean eager run."""
    qid = 5
    store = LineageStore(str(tmp_path / "lin"))
    _populate(db, store, qid, n_devices=8)
    res, _, _, reused = run_resumable(QUERIES[qid], db, store, n_devices=5)
    assert reused >= 1
    assert store.resharded >= 1        # exercised the width-mismatch path
    clean = B.run_local(QUERIES[qid], db, jit=False)[0]
    assert set(res) == set(clean)
    for k in res:
        assert np.asarray(res[k]).dtype == np.asarray(clean[k]).dtype
        assert np.array_equal(np.asarray(res[k]), np.asarray(clean[k])), k


def test_lineage_same_width_resume_does_not_count_reshard(db, tmp_path):
    store = LineageStore(str(tmp_path / "lin"))
    _populate(db, store, 5, n_devices=8)
    _, _, _, reused = run_resumable(QUERIES[5], db, store, n_devices=8)
    assert reused >= 1 and store.resharded == 0


def test_lineage_rejects_non_width_mismatch(db, tmp_path):
    """A wire-format change is NOT a topology shrink: those snapshots stay
    rejected even when the width also differs."""
    store = LineageStore(str(tmp_path / "lin"))
    _populate(db, store, 5, n_devices=8)
    _, _, _, reused = run_resumable(QUERIES[5], db, store, n_devices=5,
                                    wire_format="wide")
    assert reused == 0 and store.resharded == 0


def test_lineage_torn_snapshot_falls_back_to_reexecution(db, tmp_path):
    """A corrupted snapshot fails its CRC and the resume silently
    re-executes that subtree — wrong data is never adopted, at any width."""
    store = LineageStore(str(tmp_path / "lin"))
    _populate(db, store, 5, n_devices=8)
    # tear every snapshot payload
    for step in os.listdir(store.dir):
        d = os.path.join(store.dir, step)
        for f in os.listdir(d):
            if f.endswith(".npy"):
                with open(os.path.join(d, f), "r+b") as fh:
                    fh.seek(-1, os.SEEK_END)
                    last = fh.read(1)
                    fh.seek(-1, os.SEEK_END)
                    fh.write(bytes([last[0] ^ 0xFF]))
    res, _, _, reused = run_resumable(QUERIES[5], db, store, n_devices=5)
    assert reused == 0                 # every snapshot refused
    clean = B.run_local(QUERIES[5], db, jit=False)[0]
    for k in res:
        assert np.array_equal(np.asarray(res[k]), np.asarray(clean[k])), k


# ---------------------------------------------------------------------------
# runner: topology shrink rung (logical, single-device mesh semantics)
# ---------------------------------------------------------------------------

def test_runner_device_lost_on_1_mesh_raises(db):
    """No survivors to shrink onto: the fault surfaces instead of looping."""
    mesh = make_mesh((1,), ("data",))
    inj = ChaosInjector(FaultPlan.device_loss(3, n_lost=1, cut="scan"))
    runner = QueryRunner(db, mesh, chaos=inj)
    with pytest.raises(DeviceLost):
        runner.run(QUERIES[1])
    assert runner.topology_generation == 0


def test_runner_attempt_reports_carry_width_and_generation(db):
    mesh = make_mesh((1,), ("data",))
    runner = QueryRunner(db, mesh)
    res = runner.run(QUERIES[1])
    (a,) = res.report.attempts
    assert a.devices == 1 and a.generation == 0


# ---------------------------------------------------------------------------
# perfmodel: live width (satellite bugfix)
# ---------------------------------------------------------------------------

def test_cluster_spec_live_width_changes_pricing():
    spec = pm.CLUSTERS["h100_eth"]
    assert spec.live_n(2) == 16
    s7 = spec.with_devices(7)
    assert s7.live_n(2) == 7 and s7.name == spec.name
    assert (pm.broadcast_throughput(s7, 2)
            != pm.broadcast_throughput(spec, 2))
    assert (pm.shuffle_throughput(s7, 2) == pm.shuffle_throughput(spec, 2))
    # Eq. 3 crossover moves with N
    r, s = 1e6, 8e6
    assert (pm.broadcast_beats_shuffle(spec, 2, r, s)
            or not pm.broadcast_beats_shuffle(s7, 2, r, s)) is not None
    with pytest.raises(ValueError):
        spec.with_devices(0)


def test_exchange_time_from_stats_prefers_pinned_width():
    class FakeStats:
        kind = "shuffle"
        message_bytes = 1 << 20
        participants = 8
    spec = pm.CLUSTERS["h100_eth"]
    t8 = pm.exchange_time_from_stats(FakeStats(), spec, v=2)
    t4 = pm.exchange_time_from_stats(FakeStats(), spec.with_devices(4), v=2)
    assert t8 != t4
    # explicit n_devices wins over the pin
    t8b = pm.exchange_time_from_stats(FakeStats(), spec.with_devices(4),
                                      v=2, n_devices=8)
    assert t8b == t8


# ---------------------------------------------------------------------------
# serving: one re-trace per topology generation + structured shed outcomes
# ---------------------------------------------------------------------------

def test_server_retraces_once_per_topology_generation(db):
    srv = QueryServer(db, devices=8)
    srv.submit(1, {})
    srv.submit(1, {})
    base = srv.recompiles
    assert base == 1                   # jit once per template
    gen = srv.degrade(6)
    assert gen == 1 and srv.devices == 6
    srv.submit(1, {})
    srv.submit(1, {})                  # same generation: cache hit
    assert srv.recompiles == base + 1  # exactly one re-trace for gen 1
    srv.degrade(6)                     # no-op: width unchanged
    assert srv.topology_generation == 1
    srv.restore()
    assert srv.devices == 8 and srv.topology_generation == 2
    with pytest.raises(ValueError):
        srv.degrade(9)                 # cannot degrade upward


def test_server_sheds_and_drains_structured_outcomes(db):
    # budget sized so the request fits at 8 devices but not at 2
    fits_at_8 = QueryServer(db, devices=8).footprint_bytes()
    gate = AdmissionGate(hbm_bytes=fits_at_8 * 2.5, headroom=1.0)
    srv = QueryServer(db, devices=8, gate=gate)
    out = srv.submit_guarded(1, {})
    assert isinstance(out, Served) and out.devices == 8
    srv.degrade(2)
    out = srv.submit_guarded(1, {})
    assert isinstance(out, Shed) and out.queued
    assert out.estimated_bytes > out.budget_bytes
    assert "footprint" in out.reason and len(srv.backlog) == 1
    assert srv.shed_count == 1
    # declined, not queued
    out2 = srv.submit_guarded(1, {}, queue=False)
    assert isinstance(out2, Shed) and not out2.queued
    assert len(srv.backlog) == 1
    # capacity returns: the backlog drains to real answers
    srv.restore()
    drained = srv.drain_backlog()
    assert len(drained) == 1 and isinstance(drained[0], Served)
    assert srv.backlog == []
    np.testing.assert_allclose(
        np.asarray(drained[0].result[next(iter(drained[0].result))]),
        np.asarray(srv.submit(1, {})[next(iter(drained[0].result))]))


def test_server_degraded_outcome_same_answer(db):
    srv = QueryServer(db, devices=8)
    full = srv.submit_guarded(5, {})
    assert isinstance(full, Served)
    srv.degrade(5)
    deg = srv.submit_guarded(5, {})
    assert isinstance(deg, Degraded)
    assert deg.devices == 5 and deg.lost == 3 and deg.generation == 1
    for k in full.result:
        assert np.array_equal(np.asarray(full.result[k]),
                              np.asarray(deg.result[k])), k

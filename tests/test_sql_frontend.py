"""SQL frontend tests: parser contract, printer round trip, differential.

Three layers:

  * **Negative paths** — malformed or unsupported SQL raises ``SqlError``
    with a clear message and (wherever a token is at fault) a 1-based
    line/column, never a bare ``KeyError``/``AttributeError``.
  * **Round trip** — ``parse_expr(format_expr(e)) == e`` on generated
    expression trees (hypothesis) and ``parse(format_query(parse(text)))``
    is a fixpoint on all 22 committed TPC-H texts: the canonical printer
    emits exactly the SQL the parser accepts.
  * **Differential** — every committed SQL query compiles through the
    optimizer to a plan that validates clean, matches paper Table 4
    exchange counts EXACTLY, stays within the hand-built plans' wire-byte
    budgets, keeps static exchange counts equal to runtime, and returns
    byte-identical results to the hand-built DAG on the reference backend
    (the ``REPRO_FRONTEND=sql`` CI leg re-runs the whole tier on these
    plans; the local-backend leg here is the slow marker).
"""
import numpy as np
import pytest

from repro.core import backend as B
from repro.core import planner as PL
from repro.data import tpch
from repro.queries import PAPER_TABLE4, QUERIES
from repro.sql import SqlError, sql_queries
from repro.sql import ast as A
from repro.sql.ast import format_expr, format_query
from repro.sql.frontend import plan_sql, sql_text
from repro.sql.parser import parse, parse_expr

# hand-built plans' CI wire budgets (benchmarks/bench_exchange_bytes.py):
# the SQL-compiled plans must not exceed them
MAX_WIRE_BYTES = {1: 92, 2: 28, 3: 16, 4: 12, 5: 20, 6: 0, 7: 20, 8: 32,
                  9: 44, 10: 32, 11: 16, 12: 20, 13: 28, 14: 20, 15: 24,
                  16: 24, 17: 16, 18: 48, 19: 4, 20: 16, 21: 16, 22: 32}


@pytest.fixture(scope="module")
def db():
    return tpch.generate(0.005, seed=11)


@pytest.fixture(scope="module")
def sqlq():
    return sql_queries()


# ---------------------------------------------------------------------------
# negative paths
# ---------------------------------------------------------------------------

_BAD = [
    ("select x from nosuchtable", "unknown table", True),
    ("select nosuch from lineitem", "unknown column", True),
    ("select l_orderkey from lineitem, orders", "comma joins", False),
    ("select l_orderkey from lineitem where l_quantity = 'FOO'",
     "non-dictionary", True),
    ("select l_orderkey from lineitem where l_comment is null",
     "IS [NOT] NULL", True),
    ("select cast(l_quantity as int) from lineitem", "CAST", True),
    ("select /*+ bogus(3) */ l_orderkey from lineitem", "unknown hint", True),
    ("select l_orderkey from lineitem where l_quantity < :p",
     "undeclared parameter", False),
    ("select case when l_quantity > 1 then 1.0 end as x from lineitem",
     "ELSE", False),
    ("select case when l_quantity > 1 then 1.0 else 0.0 end from lineitem",
     "needs AS", False),
    ("with a as (select l_orderkey as k, l_tax from lineitem) "
     "select l_tax from lineitem join a on l_orderkey = k",
     "ambiguous column", True),
    ("select l_orderkey from lineitem order by nosuch",
     "not in the select list", True),
    ("select l_orderkey from lineitem where", "unexpected", True),
    ("select sum(l_quantity) from lineitem group by", "unexpected", True),
]


@pytest.mark.parametrize("text,needle,has_pos", _BAD,
                         ids=[n for _, n, _ in _BAD])
def test_negative_paths_raise_sql_error(text, needle, has_pos):
    with pytest.raises(SqlError) as exc:
        plan_sql(text)
    assert needle in str(exc.value), str(exc.value)
    if has_pos:
        assert exc.value.line is not None and exc.value.col is not None
        assert exc.value.line >= 1 and exc.value.col >= 1
        assert f"line {exc.value.line}" in str(exc.value)


def test_error_position_points_at_offender():
    with pytest.raises(SqlError) as exc:
        plan_sql("select l_orderkey,\n       oops\nfrom lineitem")
    assert (exc.value.line, exc.value.col) == (2, 8)


# ---------------------------------------------------------------------------
# printer round trip
# ---------------------------------------------------------------------------

def _roundtrip(e: A.Expr):
    text = format_expr(e)
    back = parse_expr(text)
    assert back == e, f"{e!r} -> {text!r} -> {back!r}"


def test_roundtrip_fixed_shapes():
    sub = A.Select(items=(A.SelectItem(A.Ident("k")),),
                   frm=(A.FromItem(A.Table("t")),))
    for e in [
        A.Binary("-", A.Number(1), A.Binary("-", A.Number(2), A.Number(3))),
        A.Binary("/", A.Binary("/", A.Ident("a"), A.Ident("b")),
                 A.Ident("c")),
        A.Unary("not", A.Binary("and", A.LikeE(A.Ident("s"), "%x%"),
                                A.Between(A.Ident("a"), A.Number(1),
                                          A.Number(2)))),
        A.Func("count", (A.Star(),)),
        A.Func("count", (A.Ident("a"),), distinct=True),
        A.InQuery(A.Ident("a"), sub),
        A.ExistsE(sub, negated=True),
        A.Binary("+", A.Scalar(sub), A.Number(1)),
        A.CaseE(((A.Binary(">", A.Ident("a"), A.Number(0)),
                  A.Number(1)),), A.Number(0)),
    ]:
        _roundtrip(e)


def test_roundtrip_interval_and_date_arith():
    _roundtrip(A.Binary("+", A.DateL("1994-01-01"), A.IntervalL(90, "day")))
    _roundtrip(A.Binary("<", A.Func("year", (A.Ident("d"),)),
                        A.Number(1997)))


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:                                     # pragma: no cover
    HAVE_HYP = False


if HAVE_HYP:
    _names = st.sampled_from(["a", "b", "c_name", "l_qty", "x1"])
    _idents = st.builds(A.Ident, _names,
                        st.one_of(st.none(), st.sampled_from(["t", "u"])))
    _numbers = st.one_of(
        st.integers(0, 10**6).map(A.Number),
        st.sampled_from([0.5, 0.05, 2.25, 100.75]).map(A.Number))
    _strings = st.text(alphabet="abcXYZ 09#%-", min_size=0,
                       max_size=8).map(A.String)
    _dates = st.sampled_from(["1994-01-01", "1998-12-01"]).map(A.DateL)
    _atoms = st.one_of(_idents, _numbers, _strings, _dates,
                       st.builds(A.ParamE, st.sampled_from(["p", "q2"])))

    def _compound(children):
        arith = st.sampled_from(["+", "-", "*", "/"])
        cmp_ops = st.sampled_from(["=", "<>", "<", "<=", ">", ">="])
        logic = st.sampled_from(["and", "or"])
        return st.one_of(
            st.builds(A.Binary, arith, children, children),
            st.builds(A.Binary, cmp_ops, children, children),
            st.builds(A.Binary, logic, children, children),
            st.builds(A.Unary, st.just("-"), _idents),
            st.builds(A.Unary, st.just("not"),
                      st.builds(A.Binary, cmp_ops, children, children)),
            st.builds(A.Between, children, _atoms, _atoms, st.booleans()),
            st.builds(A.InList, children,
                      st.lists(_atoms, min_size=1, max_size=3)
                      .map(tuple), st.booleans()),
            st.builds(A.LikeE, _idents,
                      st.text(alphabet="abc%", min_size=1, max_size=6),
                      st.booleans()),
            st.builds(A.CaseE,
                      st.lists(st.tuples(
                          st.builds(A.Binary, cmp_ops, _atoms, _atoms),
                          _atoms), min_size=1, max_size=2).map(tuple),
                      st.one_of(st.none(), _atoms)),
            st.builds(A.Func,
                      st.sampled_from(["sum", "min", "max", "avg", "year"]),
                      st.tuples(children)),
        )

    _exprs = st.recursive(_atoms, _compound, max_leaves=25)

    @settings(max_examples=150, deadline=None)
    @given(_exprs)
    def test_roundtrip_property(e):
        """parse(print(ast)) == ast on generated expression trees."""
        _roundtrip(e)


@pytest.mark.parametrize("qid", sorted(MAX_WIRE_BYTES))
def test_query_print_parse_fixpoint(qid):
    """format_query emits SQL the parser maps back to the same AST —
    declares, CTEs, hints and all — for every committed TPC-H text."""
    ast1 = parse(sql_text(qid))
    ast2 = parse(format_query(ast1))
    assert ast2 == ast1, qid


# ---------------------------------------------------------------------------
# all-22 differential vs the hand-built plans
# ---------------------------------------------------------------------------

def _check_budgets(qid, q, db):
    notes = PL.validate(q.plan, db)
    assert not notes, notes
    counts = q.static_counts()
    want_s, want_b = PAPER_TABLE4[qid]
    if qid == 11:          # documented deviation: local group-by under our
        want_s, want_b = 0, 1   # partitioning (see queries/__init__.py)
    assert counts["shuffles"] == want_s, counts
    if want_b is not None:
        assert counts["broadcasts"] == want_b, counts
    per_row = sum(e["row_wire_bytes"] for e in q.static_wire(db))
    assert per_row <= MAX_WIRE_BYTES[qid], (per_row, MAX_WIRE_BYTES[qid])


def _compare(r_sql, r_hand, qid):
    keys = set(r_sql) & set(r_hand)
    assert keys, "no common output columns"
    for k in sorted(keys):
        a, b = np.asarray(r_sql[k]), np.asarray(r_hand[k])
        assert a.shape == b.shape, (qid, k, a.shape, b.shape)
        np.testing.assert_array_equal(a, b, err_msg=f"q{qid} {k}")


@pytest.mark.parametrize("qid", sorted(MAX_WIRE_BYTES))
def test_sql_plan_matches_hand_reference(db, sqlq, qid):
    q = sqlq[qid]
    _check_budgets(qid, q, db)
    r_sql, stats = B.run_reference(q, db)
    assert q.static_counts() == stats.counts(), qid
    r_hand, _ = B.run_reference(QUERIES[qid], db)
    _compare(r_sql, r_hand, qid)


@pytest.mark.slow
@pytest.mark.parametrize("qid", [1, 6, 9, 13, 16, 18, 20, 22])
def test_sql_plan_matches_hand_local(db, sqlq, qid):
    r_sql, stats = B.run_local(sqlq[qid], db)
    assert sqlq[qid].static_counts() == stats.counts(), qid
    r_hand, _ = B.run_local(QUERIES[qid], db)
    _compare(r_sql, r_hand, qid)


def test_ad_hoc_sql_compiles_and_runs(db):
    """A non-TPC-H query (the examples/sql_quickstart.py shape) end to end."""
    from repro.sql import compile_sql
    q = compile_sql("""
        select n_name, count(*) as suppliers, sum(s_acctbal) as total_bal
        from supplier
        join nation on s_nationkey = n_nationkey
        group by n_name
        order by total_bal desc
        limit 5
    """, name="adhoc")
    assert PL.validate(q.plan, db) == []
    r, _ = B.run_reference(q, db)
    assert set(r) == {"n_name", "suppliers", "total_bal"}
    assert len(r["n_name"]) == 5
    bal = np.asarray(r["total_bal"], np.float64)
    assert np.all(bal[:-1] >= bal[1:])

"""Plan statistics: paper Table 4 exchange counts, static and at runtime.

Two layers of assertion:

  * **Static** — counts derived from the logical-plan IR alone
    (``planner.static_plan_stats``, no database, no execution) must match
    paper Table 4 (Q11 deviates; see queries/__init__.py).
  * **Runtime** — the counts the backends actually record while executing
    must equal the static derivation on every backend (the logical plan and
    the physical execution cannot drift apart silently).
"""
import numpy as np
import pytest

from repro.core import backend as B
from repro.data import tpch
from repro.queries import PAPER_TABLE4, QUERIES


@pytest.fixture(scope="module")
def db():
    return tpch.generate(0.005, seed=11)


def _assert_table4(qid, shuffles, broadcasts, label):
    """Compare measured (shuffles, broadcasts) against paper Table 4; Q11's
    documented deviation (our partitioning removes the paper's shuffle) is
    asserted exactly."""
    want_s, want_b = PAPER_TABLE4[qid]
    if qid == 11:
        assert (shuffles, broadcasts) == (0, 1), label
        return
    assert shuffles == want_s, \
        f"q{qid} {label}: {shuffles} shuffles != paper {want_s}"
    if want_b is not None:
        assert broadcasts == want_b, \
            f"q{qid} {label}: {broadcasts} broadcasts != paper {want_b}"


@pytest.mark.parametrize("qid", sorted(QUERIES))
def test_static_counts_match_paper_table4(qid):
    """Table 4 is derivable from the IR with no execution at all."""
    counts = QUERIES[qid].static_counts()
    _assert_table4(qid, counts["shuffles"], counts["broadcasts"], "static")


@pytest.mark.parametrize("qid", sorted(QUERIES))
def test_plan_exchange_counts_match_paper(db, qid):
    """Runtime counts reproduce paper Table 4 (Q11 deviates; see DESIGN.md)."""
    _, stats = B.run_reference(QUERIES[qid], db)
    _assert_table4(qid, stats.shuffles, stats.broadcasts, "runtime")


@pytest.mark.parametrize("qid", sorted(QUERIES))
def test_static_counts_equal_runtime_counts(db, qid):
    """The IR derivation equals what execution records, count for count."""
    _, stats = B.run_reference(QUERIES[qid], db)
    assert QUERIES[qid].static_counts() == stats.counts(), qid


def test_exchange_counts_identical_across_backends(db):
    for qid in (1, 9, 13, 18):
        _, s_ref = B.run_reference(QUERIES[qid], db)
        _, s_loc = B.run_local(QUERIES[qid], db)
        assert s_ref.counts() == s_loc.counts() == \
            QUERIES[qid].static_counts(), qid

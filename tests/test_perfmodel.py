"""Analytical model tests: the paper's §3 claims hold in our implementation."""
import numpy as np
import pytest

from repro.core import perfmodel as pm


@pytest.fixture
def h100():
    return pm.CLUSTERS["h100_ib"]


def test_broadcast_throughput_decreases_with_v(h100):
    ths = [pm.broadcast_throughput(h100, v) for v in range(1, 9)]
    assert all(a >= b - 1e-6 for a, b in zip(ths, ths[1:]))
    # converges towards min(Bn/k, Bg) (paper Fig 5a); V=8 -> N=64
    assert ths[-1] == pytest.approx(
        64 / 63 * min(h100.bn / h100.k, h100.bg), rel=1e-6)


def test_shuffle_throughput_increases_with_v(h100):
    ss = [pm.shuffle_throughput(h100, v) for v in range(2, 9)]
    assert all(a <= b for a, b in zip(ss, ss[1:]))


def test_shuffle_vs_broadcast_v_times(h100):
    """§3.3: shuffle ~V times more efficient than broadcast for IB-class Bn."""
    for v in (2, 4, 8):
        ratio = pm.shuffle_throughput(h100, v) / \
            pm.broadcast_throughput(h100, v)
        assert ratio > v / 2


def test_eq3_broadcast_beats_shuffle(h100):
    # V=1: |S|/|R| > N-1
    assert pm.broadcast_beats_shuffle(h100, 1, 1.0, 8.0)
    assert not pm.broadcast_beats_shuffle(h100, 1, 1.0, 6.9)
    # more machines make shuffle favourable (fixed size ratio): the
    # threshold grows ~V (paper: "more GPUs make shuffle more favorable")
    wins = [pm.broadcast_beats_shuffle(h100, v, 1.0, 30.0)
            for v in (1, 8, 64)]
    assert wins[0] and wins[1] and not wins[2]


def test_skew_model_per_node_not_per_gpu(h100):
    """§3.5: intra-node skew does NOT slow the shuffle; inter-node does."""
    n, k = 16, 8
    base = np.full((n, n), 1.0)
    t0 = pm.shuffle_time_skewed(*pm.node_send_recv(base, k), h100.bn)
    # skew WITHIN node 0 only: devices of node 0 unbalanced, node totals equal
    intra = base.copy()
    intra[0, :] += 0.5
    intra[7, :] -= 0.5
    t1 = pm.shuffle_time_skewed(*pm.node_send_recv(intra, k), h100.bn)
    assert t1 == pytest.approx(t0, rel=1e-9)
    # inter-node skew: node 0 sends 2x
    inter = base.copy()
    inter[:8, :] *= 2
    t2 = pm.shuffle_time_skewed(*pm.node_send_recv(inter, k), h100.bn)
    assert t2 > t0 * 1.5


def test_hockney_fit_recovers_parameters():
    L, c = 12e-6, 1 / (25e9)
    ms = np.logspace(2, 9, 25)
    fit = pm.fit_hockney(ms, L + c * ms)
    assert fit.latency == pytest.approx(L, rel=1e-6)
    assert fit.inv_bw == pytest.approx(c, rel=1e-9)
    assert fit.bandwidth(1e9) < 25e9  # latency always costs something


def test_projection_shapes_match_paper(h100):
    """§6.3: compute drops with V; broadcast term grows (Fig 13b)."""
    proj = pm.project_workload(h100, range(1, 9), 1.0,
                               [("broadcast", 5e9), ("shuffle", 5e9)])
    assert proj[8]["compute"] < proj[1]["compute"]
    assert proj[8]["broadcast"] > proj[2]["broadcast"]


def test_small_messages_hurt(h100):
    fit = pm.Hockney(latency=20e-6, inv_bw=1 / h100.bn)
    t_small = pm.exchange_time("shuffle", h100, 4, 1e6, fit, fit)
    t_large = pm.exchange_time("shuffle", h100, 4, 1e10, fit, fit)
    # per-byte cost much worse for the small exchange
    assert (t_small / 1e6) > 5 * (t_large / 1e10)
